"""Train a ~100M-parameter llama-family model for a few hundred steps with the
integrative controller managing the data plane (straggler mitigation via the
MILP), checkpointing every 50 steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Equivalent to:
    python -m repro.launch.train --arch llama3_2_3b --d-model 640 --layers 10 \
        --vocab 32768 --steps 300 --batch 16 --seq-len 256 --hetero 0.6
"""

import sys

from repro.launch.train import main as train_main


def main() -> None:
    extra = sys.argv[1:]
    sys.argv = [
        "train",
        "--arch", "llama3_2_3b",
        "--d-model", "640",
        "--layers", "10",
        "--vocab", "32768",
        "--steps", "300",
        "--batch", "16",
        "--seq-len", "256",
        "--num-shards", "16",
        "--num-workers", "4",
        "--hetero", "0.6",
        "--ckpt-dir", "checkpoints/train_100m",
        *extra,
    ]
    train_main()


if __name__ == "__main__":
    main()

"""Quickstart: integrative reconfiguration on a toy streaming job.

Builds a 3-operator word-count-style topology, runs it on 4 logical nodes
with a deliberately bad allocation, and lets the paper's controller (MILP +
ALBIC, Algorithm 1) rebalance and collocate it live.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import AdaptationFramework, AlbicParams
from repro.engine import Controller, ControllerConfig, Engine, ExecutionConfig
from repro.engine.topology import OperatorSpec, Topology


def tokenize(state, keys, values, ts):
    out = []
    for k, v, t in zip(keys, values, ts):
        for word in v["text"].split():
            out.append((word, {"word": word}, float(t)))
    return state, out


def count(state, keys, values, ts):
    counts = state.setdefault("counts", {})
    out = []
    for k, v, t in zip(keys, values, ts):
        counts[v["word"]] = counts.get(v["word"], 0) + 1
        out.append((v["word"], {"word": v["word"], "n": counts[v["word"]]}, float(t)))
    return state, out


def main() -> None:
    topo = Topology()
    topo.add_operator(OperatorSpec("lines", None, num_keygroups=16, is_source=True))
    topo.add_operator(OperatorSpec("tokenize", tokenize, num_keygroups=16))
    topo.add_operator(
        OperatorSpec(
            "count",
            count,
            num_keygroups=16,
            key_by_value=lambda v: v["word"],
            is_sink=True,
        )
    )
    topo.connect("lines", "tokenize")
    topo.connect("tokenize", "count")

    engine = Engine(
        topo,
        num_nodes=4,
        config=ExecutionConfig.typed(),  # the default execution tier, spelled out
        ser_cost=0.5,
        service_rate=1500.0,
        seed=0,
    )

    rng = np.random.default_rng(0)
    vocab = ["stream", "engine", "balance", "migrate", "collocate", "scale"]

    def feeder(eng, tick):
        n = rng.poisson(120)
        keys = rng.integers(0, 1000, n)
        values = [
            {"text": " ".join(rng.choice(vocab, size=rng.integers(2, 6)))}
            for _ in range(n)
        ]
        eng.push_source("lines", keys, values, np.full(n, float(tick)))

    controller = Controller(
        engine,
        AdaptationFramework(
            mode="albic",
            max_migrations=8,
            albic_params=AlbicParams(max_ld=15.0, time_limit=1.0),
        ),
        ControllerConfig(ticks_per_period=10),
        feeder=feeder,
    )

    print("period | load_dist | colloc% | load_idx | migrations | p99 latency")
    for p in range(8):
        m = controller.period()
        print(
            f"{p:6d} | {m.load_distance:9.2f} | {m.collocation_factor:7.1f} |"
            f" {m.load_index:8.1f} | {m.num_migrations:10d} | {m.latency['p99']:.3f}"
        )
    top = sorted(
        (
            (w, c)
            for _, s in engine.store.items()
            for w, c in s.get("counts", {}).items()
        ),
        key=lambda x: -x[1],
    )[:3]
    print("top words:", top)


if __name__ == "__main__":
    main()

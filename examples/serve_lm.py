"""Serve a small GLM4-family model with continuous batching: the controller
rebalances sequences (KV caches migrate between decode workers) and scales
the worker pool elastically.

    PYTHONPATH=src python examples/serve_lm.py [--ticks 120]
"""

import sys

from repro.launch.serve import main as serve_main


def main() -> None:
    extra = sys.argv[1:]
    sys.argv = [
        "serve",
        "--arch", "glm4_9b",
        "--ticks", "90",
        "--workers", "3",
        "--slots", "8",
        "--arrival-rate", "1.5",
        "--hetero", "0.5",
        *extra,
    ]
    serve_main()


if __name__ == "__main__":
    main()

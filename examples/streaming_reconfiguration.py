"""Reproduce the paper's Fig. 12 scenario end to end (Real Job 2).

Airline-delay pipeline where both operators partition on the same attribute:
starting from the worst allocation, ALBIC gradually collocates communicating
key groups, halving the system load (load index), while the MILP holds the
load distance low with ≤10 migrations per period.

    PYTHONPATH=src python examples/streaming_reconfiguration.py
"""

import numpy as np

from repro.core import AdaptationFramework, AlbicParams
from repro.data import airline_stream, real_job_2
from repro.data.synthetic import StreamSpec
from repro.engine import Controller, ControllerConfig, Engine, ExecutionConfig


def main() -> None:
    nodes, kgs = 6, 30
    topo = real_job_2(keygroups_per_op=kgs)
    g = topo.num_keygroups

    # Anti-collocated initial allocation (the paper's starting point).
    alloc = np.zeros(g, dtype=np.int64)
    alloc[:kgs] = np.arange(kgs) % nodes
    alloc[kgs : 2 * kgs] = np.arange(kgs) % nodes
    alloc[2 * kgs :] = (np.arange(kgs) + nodes // 2) % nodes

    engine = Engine(
        topo,
        nodes,
        config=ExecutionConfig.typed(),
        initial_alloc=alloc,
        ser_cost=0.75,
        service_rate=2500.0,
    )
    stream = airline_stream(StreamSpec(rate=260.0, seed=1))

    def feeder(eng, tick):
        keys, values, ts = next(stream)
        eng.push_source("airline", keys, values, ts)

    controller = Controller(
        engine,
        AdaptationFramework(
            mode="albic",
            max_migrations=10,
            albic_params=AlbicParams(max_ld=10.0, time_limit=2.0),
        ),
        ControllerConfig(ticks_per_period=12),
        feeder=feeder,
    )

    print("Fig.12 reproduction — collocation ↑, load index ↓, ≤10 migrations/SPL")
    print("period | colloc% | load_idx | load_dist | migrations")
    for p in range(12):
        m = controller.period()
        bar = "#" * int(m.collocation_factor // 4)
        print(
            f"{p:6d} | {m.collocation_factor:7.1f} | {m.load_index:8.1f} |"
            f" {m.load_distance:9.2f} | {m.num_migrations:10d}  {bar}"
        )


if __name__ == "__main__":
    main()

from repro.kernels.moe_gemm.ops import moe_gemm

__all__ = ["moe_gemm"]

"""Dispatching wrapper for the grouped expert matmul."""

from __future__ import annotations

import jax

from repro.kernels.moe_gemm.moe_gemm import moe_gemm_pallas
from repro.kernels.moe_gemm.ref import moe_gemm_ref


def moe_gemm(x: jax.Array, w: jax.Array, *, force_pallas: bool = False) -> jax.Array:
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_pallas:
        return moe_gemm_pallas(x, w, interpret=not on_tpu)
    return moe_gemm_ref(x, w)

"""Grouped expert matmul: (E, C, d) × (E, d, f) → (E, C, f)  (Pallas TPU).

The MoE hot loop after sort-based dispatch.  Grid (E, C/bc, f/bf, d/bd) with
the contraction dim innermost, accumulating partial products in an f32 VMEM
scratch tile and casting once on the last step — the standard MXU matmul
pattern, batched over experts via the leading grid dim.

Block shapes default to (bc, bd, bf) = (256, 512, 256):
    x tile (256×512) bf16 = 256 KB, w tile (512×256) bf16 = 256 KB,
    acc   (256×256) f32  = 256 KB  → well under VMEM, MXU-aligned.

Skipping empty capacity tail-blocks (experts rarely fill C) is the kernel-
level analogue of the paper's load balancing: the dispatcher's
tokens-per-expert statistics feed repro.core's gLoad_k, and a balanced
expert placement keeps these tiles dense.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_scr, *, nd: int):
    jd = pl.program_id(3)

    @pl.when(jd == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]  # (bc, bd)
    w = w_ref[0]  # (bd, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(jd == nd - 1)
    def _finalize():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_d", "block_f", "interpret")
)
def moe_gemm_pallas(
    x: jax.Array,  # (E, C, d)
    w: jax.Array,  # (E, d, f)
    *,
    block_c: int = 256,
    block_d: int = 512,
    block_f: int = 256,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = x.shape
    _, _, f = w.shape
    bc, bd, bf = min(block_c, c), min(block_d, d), min(block_f, f)
    assert c % bc == 0 and d % bd == 0 and f % bf == 0, (c, d, f, bc, bd, bf)
    nc, nd, nf = c // bc, d // bd, f // bf

    kernel = functools.partial(_kernel, nd=nd)
    return pl.pallas_call(
        kernel,
        grid=(e, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e_, ic, jf, jd: (e_, ic, jd)),
            pl.BlockSpec((1, bd, bf), lambda e_, ic, jf, jd: (e_, jd, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e_, ic, jf, jd: (e_, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)

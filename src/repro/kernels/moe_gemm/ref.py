"""Pure-jnp oracle for the grouped expert matmul."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gemm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """(E,C,d) × (E,d,f) → (E,C,f) in f32 accumulation."""
    out = jnp.einsum(
        "ecd,edf->ecf",
        x.astype(jnp.float32),
        w.astype(jnp.float32),
    )
    return out.astype(x.dtype)

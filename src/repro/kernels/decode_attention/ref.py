"""Pure-jnp oracle for flash-decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def decode_attention_ref(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, T, KV, hd)
    v_cache: jax.Array,
    kv_len: jax.Array,  # (B,)
) -> jax.Array:
    b, _, h, hd = q.shape
    t, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k_cache.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd)
    valid = jnp.arange(t)[None, :] < kv_len[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)

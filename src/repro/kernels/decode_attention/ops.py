"""Dispatching wrapper for decode attention."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention.decode_attention import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    kv_len: jax.Array,
    *,
    block_kv: int = 1024,
    force_pallas: bool = False,
) -> jax.Array:
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_pallas:
        return decode_attention_pallas(
            q, k_cache, v_cache, kv_len, block_kv=block_kv, interpret=not on_tpu
        )
    return decode_attention_ref(q, k_cache, v_cache, kv_len)

"""Single-token flash-decode attention (Pallas TPU).

Decode is memory-bound: the whole KV cache streams HBM→VMEM once while the
query stays resident.  Grid: (batch, kv_heads, num_kv_blocks) with the KV
block dim innermost; the online-softmax state for *all* q heads in the group
is carried in VMEM scratch.  Per-batch ``kv_len`` masks unwritten cache slots.

VMEM per cell: k/v block (BK, hd) ×2 + q (G, hd) + scores (G, BK) + state —
with BK = 1024, hd = 128, G = 16: ~1.3 MB.  The q@k matmul is (G×hd)·(hd×BK),
MXU-aligned for hd, BK multiples of 128 (G is padded to the 8-sublane tile by
Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30
LANES = 128


def _kernel(
    kvlen_ref,  # SMEM (1,)   int32 — this batch row's cache length
    q_ref,  # (1, 1, G*?, hd) block: all heads of this kv group
    k_ref,  # (1, bk, 1, hd)
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    bk: int,
    nkv: int,
    scale: float,
):
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = kvlen_ref[0]
    k_start = jk * bk

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0, 0, :, :].astype(jnp.float32)  # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = (
            jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
            * scale
        )  # (G, bk)
        tpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tpos < kv_len, s, NEG_INF)
        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_scr[:, 0] * alpha + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(jk == nkv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-37)
        o_ref[0, 0, 0, :, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention_pallas(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, T, KV, hd)
    v_cache: jax.Array,
    kv_len: jax.Array,  # (B,) int32
    *,
    block_kv: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    b, one, h, hd = q.shape
    assert one == 1
    t, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    bk = min(block_kv, t)
    assert t % bk == 0
    nkv = t // bk
    scale = 1.0 / (hd ** 0.5)

    # Regroup q so one grid cell sees all heads of one kv group.
    qg = q.reshape(b, 1, kvh, g, hd)

    kernel = functools.partial(_kernel, bk=bk, nkv=nkv, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, nkv),
        in_specs=[
            pl.BlockSpec(
                memory_space=pltpu.SMEM,
                block_shape=(1,),
                index_map=lambda b_, h_, j: (b_,),
            ),
            pl.BlockSpec((1, 1, 1, g, hd), lambda b_, h_, j: (b_, 0, h_, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b_, h_, j: (b_, j, h_, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b_, h_, j: (b_, j, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, g, hd), lambda b_, h_, j: (b_, 0, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1, kvh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, 1, h, hd)

"""Blocked linear recurrence h_t = a_t·h_{t−1} + b_t (Pallas TPU).

The RG-LRU recurrence is elementwise over the width dimension, so it tiles
perfectly: grid (batch, width_blocks, seq_blocks) with the *sequence* dim
innermost/sequential, carrying h across sequence blocks in VMEM scratch.
Within a block the recurrence runs as a ``fori_loop`` over rows — a VPU
(8×128 vector) workload, not MXU.  This is the TPU-native replacement for
the paper's (GPU) fused linear-scan kernel: HBM traffic is exactly one read
of (a, b) and one write of h per element, the roofline floor for a scan.

VMEM per cell: 3 blocks of (BS, BW) f32 + (1, BW) carry ≈ 3·(256×512)·4 B
≈ 1.6 MB at the default tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, o_ref, carry_scr, *, bs: int):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        carry_scr[...] = h0_ref[0, :].astype(jnp.float32)[None, :]

    a = a_ref[0].astype(jnp.float32)  # (bs, bw)
    b = b_ref[0].astype(jnp.float32)

    def row(i, h):
        h_new = a[i] * h + b[i]
        o_ref[0, i, :] = h_new.astype(o_ref.dtype)
        return h_new

    h_final = jax.lax.fori_loop(0, bs, row, carry_scr[0, :])
    carry_scr[...] = h_final[None, :]


@functools.partial(jax.jit, static_argnames=("block_seq", "block_width", "interpret"))
def rglru_scan_pallas(
    a: jax.Array,  # (B, S, W)
    b: jax.Array,  # (B, S, W)
    h0: jax.Array,  # (B, W)
    *,
    block_seq: int = 256,
    block_width: int = 512,
    interpret: bool = False,
) -> jax.Array:
    bsz, s, w = a.shape
    bs = min(block_seq, s)
    bw = min(block_width, w)
    assert s % bs == 0 and w % bw == 0, (s, w, bs, bw)
    ns, nw = s // bs, w // bw

    kernel = functools.partial(_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=(bsz, nw, ns),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda b_, iw, js: (b_, js, iw)),
            pl.BlockSpec((1, bs, bw), lambda b_, iw, js: (b_, js, iw)),
            pl.BlockSpec((1, bw), lambda b_, iw, js: (b_, iw)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda b_, iw, js: (b_, js, iw)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)

"""Pure-jnp oracle: sequential linear recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t·h_{t−1} + b_t.  a, b (B,S,W); h0 (B,W) → (B,S,W)."""

    def step(h, ab):
        a_t, b_t = ab
        h_new = a_t * h + b_t
        return h_new, h_new

    af = a.astype(jnp.float32).swapaxes(0, 1)  # (S,B,W)
    bf = b.astype(jnp.float32).swapaxes(0, 1)
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), (af, bf))
    return hs.swapaxes(0, 1).astype(a.dtype)

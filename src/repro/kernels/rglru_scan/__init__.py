from repro.kernels.rglru_scan.ops import rglru_scan_op

__all__ = ["rglru_scan_op"]

"""Dispatching wrapper for the RG-LRU blocked scan."""

from __future__ import annotations

import jax

from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rglru_scan.rglru_scan import rglru_scan_pallas


def rglru_scan_op(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array,
    *,
    force_pallas: bool = False,
) -> jax.Array:
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_pallas:
        return rglru_scan_pallas(a, b, h0, interpret=not on_tpu)
    return rglru_scan_ref(a, b, h0)

"""Pallas TPU kernels for the compute hot spots.

Each kernel package ships three modules:

* ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec VMEM
  tiling (TPU is the target; ``interpret=True`` validates on CPU),
* ``ops.py``   — the jit-ready wrapper that picks Pallas on TPU and the pure
  XLA reference elsewhere,
* ``ref.py``   — the pure-jnp oracle the tests assert against.

The paper itself contributes scheduling, not kernels; these cover the LM
workloads' hot spots (DESIGN.md §2): flash_attention (causal/windowed GQA),
decode_attention (single-token flash-decode), rglru_scan (blocked linear
recurrence), moe_gemm (grouped expert matmul).

keygroup_partition is the one kernel the paper's own hot path contributes:
the engine's hash-partition/histogram routing step (key → key group, plus
the per-group tuple counts the SPL statistics consume), running the same
32-bit mix as `repro.engine.topology.mix32` so CPU and TPU routing agree
bit-for-bit.
"""

"""Pallas TPU kernels for the compute hot spots.

Each kernel package ships three modules:

* ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec VMEM
  tiling (TPU is the target; ``interpret=True`` validates on CPU),
* ``ops.py``   — the jit-ready wrapper that picks Pallas on TPU and the pure
  XLA reference elsewhere,
* ``ref.py``   — the pure-jnp oracle the tests assert against.

The paper itself contributes scheduling, not kernels; these cover the LM
workloads' hot spots (DESIGN.md §2): flash_attention (causal/windowed GQA),
decode_attention (single-token flash-decode), rglru_scan (blocked linear
recurrence), moe_gemm (grouped expert matmul).
"""

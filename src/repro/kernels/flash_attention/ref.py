"""Pure-jnp oracle for flash attention (the test ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Naive softmax attention with GQA, fp32 math.  q (B,S,H,hd)."""
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd)
    spos = jnp.arange(s)[:, None]
    tpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= tpos <= spos
    if window is not None:
        mask &= tpos > spos - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)

"""Dispatching wrapper: Pallas kernel on TPU, interpret/XLA path elsewhere."""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    force_pallas: bool = False,
) -> jax.Array:
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_pallas:
        return flash_attention_pallas(
            q,
            k,
            v,
            causal=causal,
            window=window,
            block_q=block_q,
            block_kv=block_kv,
            interpret=not on_tpu,
        )
    return attention_ref(q, k, v, causal=causal, window=window)

"""Blockwise causal/windowed GQA flash attention (Pallas TPU).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the KV dimension is the
innermost (sequential on TPU), carrying the online-softmax state (m, l, acc)
in VMEM scratch across KV steps for one (b, h, iq) cell.

VMEM working set per grid cell:
    q block   (BQ, hd)    bf16
    k/v block (BK, hd)    bf16 ×2
    scores    (BQ, BK)    f32
    m, l      (BQ, 128)   f32 ×2        (lane-padded)
    acc       (BQ, hd)    f32
With BQ = BK = 512 and hd = 128 this is ~1.9 MB — comfortably inside the
16 MB/core v5e VMEM, and all matmul dims are multiples of the 128×128 MXU
tile.  Fully-masked blocks (kv block entirely above the causal diagonal, or
entirely outside the local window) are *skipped* via ``pl.when`` — this is
exactly the FLOP waste the XLA chunked path cannot avoid (see §Perf log).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30
LANES = 128


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    bq: int,
    bk: int,
    nkv: int,
    causal: bool,
    window: int | None,
    scale: float,
):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = jk * bk

    # Static-shape positions for this block pair.
    spos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    tpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Block-level relevance: skip fully-masked blocks entirely.
    below_diag = (not causal) or (k_start <= q_start + bq - 1)
    if window is not None:
        in_window = k_start + bk - 1 > q_start - window
    else:
        in_window = True

    def compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= tpos <= spos
        if window is not None:
            mask &= tpos > spos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_scr[:, 0] * alpha + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    if isinstance(below_diag, bool) and isinstance(in_window, bool):
        if below_diag and in_window:
            compute()
    else:
        pl.when(jnp.logical_and(below_diag, in_window))(compute)

    @pl.when(jk == nkv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-37)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q (B,S,H,hd); k,v (B,T,KV,hd) with H % KV == 0.  Returns (B,S,H,hd)."""
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    bq = min(block_q, s)
    bk = min(block_kv, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    nq, nkv = s // bq, t // bk
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, nkv=nkv, causal=causal, window=window, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b_, h_, i, j: (b_, i, h_, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b_, h_, i, j: (b_, j, h_ // group, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b_, h_, i, j: (b_, j, h_ // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b_, h_, i, j: (b_, i, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

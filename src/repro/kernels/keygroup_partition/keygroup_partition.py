"""Hash-partition + histogram: folded int32 keys → key-group ids (Pallas TPU).

The engine's routing step (paper §3: hash-partitioning input keys into key
groups) as a kernel: for each key compute the 32-bit mix the CPU data plane
uses (``repro.engine.topology.mix32``) and its key-group id
``(mix & 0x7FFFFFFF) % num_keygroups``, and accumulate the per-key-group
tuple histogram the SPL statistics feed on (gLoad counting).

Layout: keys are reshaped to (rows, block) int32; grid is (rows,).  Each step
mixes one block on the VPU (uint32 multiply/xor/shift lanes) and scatters its
one-hot histogram contribution into an f32-free int32 VMEM scratch
accumulator, written out on the last step — the same accumulate-then-finalize
pattern as moe_gemm's MXU tiles.  The histogram one-hot compare costs
``block × num_keygroups`` int lanes, so ``block`` defaults small enough to
keep the tile well under VMEM at the paper's key-group counts (≤ a few
thousand).

The 64→32 fold of raw keys happens in the wrapper (ops.py): TPU lanes are
32-bit, and a 32-bit mix keeps the CPU and TPU paths bit-identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_MIX_C1 = 0x85EBCA6B
_MIX_C2 = 0xC2B2AE35
_MASK31 = 0x7FFFFFFF


def _mix32_u32(h: jax.Array) -> jax.Array:
    """murmur3-style finisher on uint32 lanes (== topology.mix32)."""
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_MIX_C1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_MIX_C2)
    h = h ^ (h >> 16)
    return h


def _kernel(keys_ref, valid_ref, kg_ref, hist_ref, hist_scr, *, nkg: int, nblocks: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_scr[...] = jnp.zeros_like(hist_scr)

    k = keys_ref[...]  # (1, block) int32
    h = _mix32_u32(jax.lax.bitcast_convert_type(k, jnp.uint32))
    kg = (h & jnp.uint32(_MASK31)).astype(jnp.int32) % nkg
    kg_ref[...] = kg

    block = kg.shape[-1]
    onehot = kg.reshape(block, 1) == jax.lax.broadcasted_iota(
        jnp.int32, (block, nkg), 1
    )
    contrib = onehot.astype(jnp.int32) * valid_ref[...].reshape(block, 1)
    # dtype pinned: with jax x64 enabled (the jit tier flips it process-wide)
    # an int32 sum would promote its accumulator to int64 and fail the swap
    # into the int32 VMEM scratch.
    hist_scr[...] += contrib.sum(axis=0, keepdims=True, dtype=jnp.int32)

    @pl.when(i == nblocks - 1)
    def _finalize():
        hist_ref[...] = hist_scr[...]


@functools.partial(
    jax.jit, static_argnames=("num_keygroups", "block", "interpret")
)
def keygroup_partition_pallas(
    keys32: jax.Array,  # (n,) int32 — already 64→32 folded
    valid: jax.Array,  # (n,) int32 — 1 for real keys, 0 for padding
    *,
    num_keygroups: int,
    block: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Return (key-group id per key (n,), histogram (num_keygroups,))."""
    n = keys32.shape[0]
    pad = (-n) % block
    if pad:
        keys32 = jnp.concatenate([keys32, jnp.zeros(pad, jnp.int32)])
        valid = jnp.concatenate([valid, jnp.zeros(pad, jnp.int32)])
    rows = (n + pad) // block
    kernel = functools.partial(_kernel, nkg=num_keygroups, nblocks=rows)
    kg, hist = pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, num_keygroups), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, block), jnp.int32),
            jax.ShapeDtypeStruct((1, num_keygroups), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, num_keygroups), jnp.int32)],
        interpret=interpret,
    )(keys32.reshape(rows, block), valid.reshape(rows, block))
    return kg.reshape(-1)[:n], hist[0]

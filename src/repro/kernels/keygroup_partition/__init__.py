from repro.kernels.keygroup_partition.ops import fold_keys64, keygroup_partition

__all__ = ["fold_keys64", "keygroup_partition"]

"""Dispatching wrapper for the hash-partition/histogram kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.keygroup_partition.keygroup_partition import (
    keygroup_partition_pallas,
)
from repro.kernels.keygroup_partition.ref import keygroup_partition_ref


def fold_keys64(keys: np.ndarray) -> np.ndarray:
    """Fold raw 64-bit integer keys to the int32 lanes the TPU mix runs on.

    Identical to the first step of `repro.engine.topology.mix32`, so
    kernel(fold(keys)) == the engine's numpy key-group assignment.
    """
    u = np.asarray(keys).astype(np.uint64)
    folded = ((u ^ (u >> np.uint64(32))) & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return folded.view(np.int32)


def keygroup_partition(
    keys: np.ndarray,
    num_keygroups: int,
    *,
    base: int = 0,
    force_pallas: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Key-group id per key plus the per-key-group tuple histogram.

    ``keys`` are raw integer keys (any 64-bit range); ``base`` offsets the
    returned ids into the job's global key-group space, matching
    ``Topology.keygroups_of``.
    """
    if len(np.asarray(keys)) == 0:
        return np.empty(0, dtype=np.int64), np.zeros(num_keygroups, dtype=np.int64)
    folded = jnp.asarray(fold_keys64(keys))
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_pallas:
        valid = jnp.ones(folded.shape[0], jnp.int32)
        kg, hist = keygroup_partition_pallas(
            folded, valid, num_keygroups=num_keygroups, interpret=not on_tpu
        )
    else:
        kg, hist = keygroup_partition_ref(folded, num_keygroups)
    return np.asarray(kg, dtype=np.int64) + base, np.asarray(hist, dtype=np.int64)

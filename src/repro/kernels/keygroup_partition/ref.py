"""Pure-jnp oracle for the hash-partition/histogram kernel.

The ultimate reference is the engine's numpy group-by
(`repro.engine.topology.Topology.keygroups_of`); this oracle restates it in
jnp so the Pallas kernel can be asserted against it in tests at any shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_MIX_C1 = 0x85EBCA6B
_MIX_C2 = 0xC2B2AE35
_MASK31 = 0x7FFFFFFF


def keygroup_partition_ref(
    keys32: jax.Array, num_keygroups: int
) -> tuple[jax.Array, jax.Array]:
    """(n,) folded int32 keys → (key-group ids (n,), histogram (nkg,))."""
    h = jax.lax.bitcast_convert_type(keys32, jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_MIX_C1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_MIX_C2)
    h = h ^ (h >> 16)
    kg = (h & jnp.uint32(_MASK31)).astype(jnp.int32) % num_keygroups
    hist = jnp.zeros(num_keygroups, jnp.int32).at[kg].add(1)
    return kg, hist

from repro.kernels.radix_sort.ops import bucket_argsort, bucket_argsort_jax

__all__ = ["bucket_argsort", "bucket_argsort_jax"]

"""Dispatching wrappers for the bucketed radix argsort.

Two entry points, matching the two places the engine sorts routing codes:

* :func:`bucket_argsort` — host-side (numpy in, numpy out).  On CPU this is
  the *pre-sorted order handoff*: numpy's radix argsort is the fastest
  stable sort at these ranges, so the host computes the permutation and
  hands it to the device (``keyed_running_sum(order=...)``).  On TPU the
  Pallas kernel runs instead.

* :func:`bucket_argsort_jax` — traceable, for use **inside** a jit region
  (the fused superstep's routing step, where no host is reachable).  TPU →
  Pallas counting sort; other backends → XLA's stable argsort.

Both produce the permutation ``np.argsort(codes, kind="stable")`` would.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.radix_sort.radix_sort import bucket_argsort_pallas
from repro.kernels.radix_sort.ref import bucket_argsort_ref


def bucket_argsort(
    codes: np.ndarray,
    num_buckets: int,
    *,
    force_pallas: bool = False,
) -> np.ndarray:
    """Stable argsort of host codes in ``[0, num_buckets)`` → int64 order."""
    codes = np.asarray(codes)
    if codes.size == 0:
        return np.empty(0, dtype=np.int64)
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_pallas:
        order = bucket_argsort_pallas(
            jnp.asarray(codes, jnp.int32),
            num_buckets=num_buckets,
            interpret=not on_tpu,
        )
        return np.asarray(order, dtype=np.int64)
    return bucket_argsort_ref(codes).astype(np.int64)


def bucket_argsort_jax(codes: jax.Array, num_buckets: int) -> jax.Array:
    """Traceable stable argsort for codes in ``[0, num_buckets)``."""
    if jax.default_backend() == "tpu":
        return bucket_argsort_pallas(
            codes.astype(jnp.int32), num_buckets=num_buckets
        )
    return jnp.argsort(codes, stable=True)

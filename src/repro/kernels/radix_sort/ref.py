"""Oracles for the bucketed radix argsort.

The ultimate reference is numpy's stable (radix) argsort — the exact
permutation the CPU data plane's routing step produces — so the Pallas
kernel is pinned **bit-identical** against it, not merely allclose.  A jnp
restatement is provided for asserting inside traced code at any shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def bucket_argsort_ref(codes: np.ndarray) -> np.ndarray:
    """numpy stable argsort — the engine's host routing permutation."""
    return np.argsort(np.asarray(codes), kind="stable")


def bucket_argsort_jnp(codes: jax.Array) -> jax.Array:
    """jnp stable argsort (XLA comparison sort) — traceable oracle."""
    return jnp.argsort(codes, stable=True)

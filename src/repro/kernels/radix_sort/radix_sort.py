"""Stable bucketed counting argsort for small-range int keys (Pallas TPU).

The engine's routing hot path sorts composite codes ``node*nkg + local_kg``
(bounded by ``num_nodes × num_keygroups``, a few thousand at paper scale) to
group a routed batch into contiguous (node, key-group) runs.  XLA's generic
comparison sort is ~20× slower than a counting sort at these ranges, so this
kernel restates numpy's stable radix argsort as two Pallas passes:

1. **histogram** — grid over row blocks; each step writes its block's
   per-bucket tuple counts (one row of a ``(rows, nbuckets)`` table).
2. **rank** — after a cheap jnp prefix-sum turns the histogram table into
   per-block bucket base offsets, a second grid pass computes each element's
   destination rank: ``base[block, bucket] + exclusive-cumsum`` of the
   block-local one-hot, i.e. elements of equal code keep their input order.

Stability is structural: bases are accumulated in block order and the
within-block cumsum runs in element order, so the produced permutation is
bit-identical to ``np.argsort(codes, kind="stable")`` — the CPU data plane's
radix argsort — at every shape.  Padding rides a dedicated overflow bucket
(``nbuckets``) appended by the wrapper so it sinks to the tail of the
permutation without disturbing valid ranks.

The one-hot compare costs ``block × nbuckets`` int32 lanes per step, the
same VMEM budget shape as keygroup_partition's histogram tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(codes_ref, hist_ref, *, nbk: int):
    c = codes_ref[...]  # (1, block) int32, padding pre-mapped to nbk-1
    block = c.shape[-1]
    onehot = c.reshape(block, 1) == jax.lax.broadcasted_iota(
        jnp.int32, (block, nbk), 1
    )
    # dtype pinned: the jit tier flips x64 process-wide; an un-pinned sum
    # would promote to int64 and fail the swap into the int32 output tile.
    hist_ref[...] = onehot.astype(jnp.int32).sum(
        axis=0, keepdims=True, dtype=jnp.int32
    )


def _rank_kernel(codes_ref, base_ref, ranks_ref, *, nbk: int):
    c = codes_ref[...]  # (1, block) int32
    block = c.shape[-1]
    onehot = (
        c.reshape(block, 1)
        == jax.lax.broadcasted_iota(jnp.int32, (block, nbk), 1)
    ).astype(jnp.int32)
    # Exclusive cumsum in element order == "how many equal codes before me
    # in this block" — the stability guarantee.
    within = jnp.cumsum(onehot, axis=0, dtype=jnp.int32) - onehot
    own_off = (within * onehot).sum(axis=1, dtype=jnp.int32)
    own_base = (base_ref[...].reshape(1, nbk) * onehot).sum(
        axis=1, dtype=jnp.int32
    )
    ranks_ref[...] = (own_base + own_off).reshape(1, block)


@functools.partial(
    jax.jit, static_argnames=("num_buckets", "block", "interpret")
)
def bucket_argsort_pallas(
    codes: jax.Array,  # (n,) int32 in [0, num_buckets)
    *,
    num_buckets: int,
    block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Stable argsort of small-range codes; returns the (n,) int32 order.

    ``codes[order]`` is sorted ascending and equal codes keep input order —
    bit-identical to ``np.argsort(codes, kind="stable")``.
    """
    n = codes.shape[0]
    nbk = num_buckets + 1  # +1 overflow bucket for padding
    pad = (-n) % block
    if pad:
        codes = jnp.concatenate(
            [codes, jnp.full(pad, num_buckets, jnp.int32)]
        )
    npad = n + pad
    rows = npad // block

    hist = pl.pallas_call(
        functools.partial(_hist_kernel, nbk=nbk),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, nbk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, nbk), jnp.int32),
        interpret=interpret,
    )(codes.reshape(rows, block))

    # Per-block bucket bases: global bucket start (exclusive cumsum over
    # buckets of the totals) + count of this bucket in earlier blocks
    # (exclusive cumsum over blocks).  (rows, nbk) ints — cheap on-device.
    totals = hist.sum(axis=0, dtype=jnp.int32)
    global_start = jnp.cumsum(totals, dtype=jnp.int32) - totals
    block_excl = jnp.cumsum(hist, axis=0, dtype=jnp.int32) - hist
    base = global_start[None, :] + block_excl

    ranks = pl.pallas_call(
        functools.partial(_rank_kernel, nbk=nbk),
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, nbk), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block), jnp.int32),
        interpret=interpret,
    )(codes.reshape(rows, block), base)

    ranks = ranks.reshape(-1)
    # Invert ranks → order.  Valid elements occupy ranks [0, n) (padding
    # sank into the overflow bucket), so the first n entries are the
    # stable argsort of the unpadded input.
    order = jnp.zeros(npad, jnp.int32).at[ranks].set(
        jnp.arange(npad, dtype=jnp.int32)
    )
    return order[:n]

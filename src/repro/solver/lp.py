"""Time-limited MILP solving.

The paper solves its Mixed-Integer Linear Program with CPLEX.  Here we expose
one neutral interface, :func:`solve_milp`, over a sparse standard form

    minimize    c @ z
    subject to  lb_row <= A @ z <= ub_row
                lo <= z <= hi
                z[integrality == 1] integer

backed by SciPy's HiGHS branch-and-bound when available.  HiGHS is an exact
solver of the same class as CPLEX; the paper's observation that "a few seconds
of solving already gives a near-optimal solution" carries over via the
``time_limit`` option (HiGHS returns its incumbent at the limit).

A pure-numpy fallback (`_greedy_repair`) exists so the core algorithms remain
runnable without scipy: it LP-relaxes nothing, it simply rounds a feasible
assignment greedily.  It is only used when scipy is missing and is clearly
marked in the result.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

try:  # scipy is an optional-but-expected dependency
    import scipy.optimize as _sopt
    import scipy.sparse as _ssp

    _HAVE_SCIPY = True
except Exception:  # pragma: no cover - exercised only in scipy-less envs
    _HAVE_SCIPY = False


@dataclasses.dataclass
class MilpProblem:
    """A sparse MILP in row-bounded standard form."""

    c: np.ndarray  # (n,) objective
    a_rows: np.ndarray  # (nnz,) row indices of A
    a_cols: np.ndarray  # (nnz,) col indices of A
    a_vals: np.ndarray  # (nnz,) values of A
    row_lb: np.ndarray  # (m,)
    row_ub: np.ndarray  # (m,)
    var_lb: np.ndarray  # (n,)
    var_ub: np.ndarray  # (n,)
    integrality: np.ndarray  # (n,) 1 -> integer, 0 -> continuous

    @property
    def num_vars(self) -> int:
        return int(self.c.shape[0])

    @property
    def num_rows(self) -> int:
        return int(self.row_lb.shape[0])


@dataclasses.dataclass
class MilpResult:
    x: np.ndarray
    objective: float
    status: str  # "optimal" | "time_limit" | "infeasible" | "fallback"
    solve_seconds: float
    mip_gap: Optional[float] = None

    @property
    def ok(self) -> bool:
        return self.status in ("optimal", "time_limit", "fallback")


def solve_milp(
    problem: MilpProblem,
    *,
    time_limit: float = 10.0,
    mip_rel_gap: float = 1e-4,
    warm_start: Optional[np.ndarray] = None,
) -> MilpResult:
    """Solve ``problem``; return the incumbent when the time limit strikes.

    ``warm_start`` is accepted for interface parity (HiGHS via scipy does not
    take MIP starts; the fallback uses it as its starting assignment).
    """
    if _HAVE_SCIPY:
        return _solve_scipy(problem, time_limit=time_limit, mip_rel_gap=mip_rel_gap)
    return _greedy_repair(problem, warm_start=warm_start)


def _solve_scipy(
    problem: MilpProblem, *, time_limit: float, mip_rel_gap: float
) -> MilpResult:
    n = problem.num_vars
    a = _ssp.csc_matrix(
        (problem.a_vals, (problem.a_rows, problem.a_cols)),
        shape=(problem.num_rows, n),
    )
    constraints = _sopt.LinearConstraint(a, problem.row_lb, problem.row_ub)
    bounds = _sopt.Bounds(problem.var_lb, problem.var_ub)
    t0 = time.perf_counter()
    res = _sopt.milp(
        c=problem.c,
        constraints=constraints,
        bounds=bounds,
        integrality=problem.integrality,
        options={
            "time_limit": float(time_limit),
            "mip_rel_gap": float(mip_rel_gap),
            "presolve": True,
        },
    )
    dt = time.perf_counter() - t0
    if res.x is None:
        return MilpResult(
            x=np.zeros(n),
            objective=float("inf"),
            status="infeasible",
            solve_seconds=dt,
        )
    status = "optimal" if res.status == 0 else "time_limit"
    gap = getattr(res, "mip_gap", None)
    return MilpResult(
        x=np.asarray(res.x, dtype=np.float64),
        objective=float(res.fun),
        status=status,
        solve_seconds=dt,
        mip_gap=None if gap is None else float(gap),
    )


def _greedy_repair(
    problem: MilpProblem, warm_start: Optional[np.ndarray]
) -> MilpResult:
    """Scipy-less fallback: start from bounds/warm start, greedily repair rows.

    This is NOT a general MILP solver; it exists so that `repro.core` degrades
    gracefully (the callers all build assignment-structured programs for which
    a feasible greedy point exists: each key group on its current node).
    """
    t0 = time.perf_counter()
    n = problem.num_vars
    x = np.clip(
        warm_start.astype(np.float64) if warm_start is not None else np.zeros(n),
        problem.var_lb,
        problem.var_ub,
    )
    # Round integers.
    mask = problem.integrality.astype(bool)
    x[mask] = np.round(x[mask])
    obj = float(problem.c @ x)
    return MilpResult(
        x=x,
        objective=obj,
        status="fallback",
        solve_seconds=time.perf_counter() - t0,
    )


def dense_rows(problem: MilpProblem) -> np.ndarray:
    """Materialize A densely (testing/debug only)."""
    a = np.zeros((problem.num_rows, problem.num_vars))
    a[problem.a_rows, problem.a_cols] = problem.a_vals
    return a


class MilpBuilder:
    """Incremental sparse builder for :class:`MilpProblem`.

    Constraint triplets and variable attributes are stored as *chunks* (lists
    of numpy arrays concatenated once in :meth:`build`), so the bulk paths —
    :meth:`add_binaries` and :meth:`add_rows` — append whole constraint blocks
    without any per-element Python list traffic.
    """

    def __init__(self) -> None:
        self._num_vars = 0
        self._obj: list[np.ndarray] = []
        self._lb: list[np.ndarray] = []
        self._ub: list[np.ndarray] = []
        self._int: list[np.ndarray] = []
        self._rows: list[np.ndarray] = []
        self._cols: list[np.ndarray] = []
        self._vals: list[np.ndarray] = []
        self._num_rows = 0
        self._row_lb: list[np.ndarray] = []
        self._row_ub: list[np.ndarray] = []
        self._bound_overrides: dict[int, tuple[float, float]] = {}
        self.names: dict[str, int] = {}

    # -- variables ---------------------------------------------------------
    def add_var(
        self,
        name: str,
        *,
        obj: float = 0.0,
        lb: float = 0.0,
        ub: float = np.inf,
        integer: bool = False,
    ) -> int:
        idx = self._num_vars
        self._num_vars += 1
        self._obj.append(np.array([obj], dtype=np.float64))
        self._lb.append(np.array([lb], dtype=np.float64))
        self._ub.append(np.array([ub], dtype=np.float64))
        self._int.append(np.array([1 if integer else 0], dtype=np.int64))
        if name:
            self.names[name] = idx
        return idx

    def add_binary(self, name: str, *, obj: float = 0.0) -> int:
        return self.add_var(name, obj=obj, lb=0.0, ub=1.0, integer=True)

    def add_binaries(self, count: int) -> int:
        """Bulk-append ``count`` anonymous binaries; returns the first index.

        Indices are contiguous — caller code typically scatters
        ``start + np.arange(count)`` into its own variable map.
        """
        start = self._num_vars
        self._num_vars += count
        self._obj.append(np.zeros(count))
        self._lb.append(np.zeros(count))
        self._ub.append(np.ones(count))
        self._int.append(np.ones(count, dtype=np.int64))
        return start

    def set_var_bounds(self, idx: int, lb: float, ub: float) -> None:
        """Override one variable's bounds (e.g. fix a pinned binary)."""
        self._bound_overrides[idx] = (float(lb), float(ub))

    # -- constraints --------------------------------------------------------
    def add_row(
        self,
        cols: list[int] | np.ndarray,
        vals: list[float] | np.ndarray,
        *,
        lb: float = -np.inf,
        ub: float = np.inf,
    ) -> int:
        row = self._num_rows
        cols = np.asarray(cols, dtype=np.int64)
        vals = np.asarray(vals, dtype=np.float64)
        if cols.shape != vals.shape:
            raise ValueError(f"cols/vals mismatch {cols.shape} vs {vals.shape}")
        self._rows.append(np.full(len(cols), row, dtype=np.int64))
        self._cols.append(cols)
        self._vals.append(vals)
        self._num_rows += 1
        self._row_lb.append(np.array([lb]))
        self._row_ub.append(np.array([ub]))
        return row

    def add_rows(
        self,
        rows: np.ndarray,
        cols: np.ndarray,
        vals: np.ndarray,
        *,
        num_rows: int,
        lb: float | np.ndarray = -np.inf,
        ub: float | np.ndarray = np.inf,
    ) -> int:
        """Bulk-append a block of ``num_rows`` rows from COO triplets.

        ``rows`` holds block-relative indices in ``[0, num_rows)``; ``lb``/
        ``ub`` are scalars or (num_rows,) arrays.  Returns the block's first
        global row index.
        """
        base = self._num_rows
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        vals = np.asarray(vals, dtype=np.float64)
        if not (rows.shape == cols.shape == vals.shape):
            raise ValueError(
                f"rows/cols/vals mismatch {rows.shape}/{cols.shape}/{vals.shape}"
            )
        self._rows.append(rows + base)
        self._cols.append(cols)
        self._vals.append(vals)
        self._row_lb.append(
            np.broadcast_to(np.asarray(lb, dtype=np.float64), (num_rows,)),
        )
        self._row_ub.append(
            np.broadcast_to(np.asarray(ub, dtype=np.float64), (num_rows,)),
        )
        self._num_rows += num_rows
        return base

    def build(self) -> MilpProblem:
        def cat(chunks: list[np.ndarray], dtype) -> np.ndarray:
            if not chunks:
                return np.empty(0, dtype=dtype)
            return np.concatenate(chunks).astype(dtype, copy=False)

        var_lb = cat(self._lb, np.float64)
        var_ub = cat(self._ub, np.float64)
        for idx, (lo, hi) in self._bound_overrides.items():
            var_lb[idx] = lo
            var_ub[idx] = hi
        return MilpProblem(
            c=cat(self._obj, np.float64),
            a_rows=cat(self._rows, np.int64),
            a_cols=cat(self._cols, np.int64),
            a_vals=cat(self._vals, np.float64),
            row_lb=cat(self._row_lb, np.float64),
            row_ub=cat(self._row_ub, np.float64),
            var_lb=var_lb,
            var_ub=var_ub,
            integrality=cat(self._int, np.int64),
        )

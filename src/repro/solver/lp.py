"""Time-limited MILP solving.

The paper solves its Mixed-Integer Linear Program with CPLEX.  Here we expose
one neutral interface, :func:`solve_milp`, over a sparse standard form

    minimize    c @ z
    subject to  lb_row <= A @ z <= ub_row
                lo <= z <= hi
                z[integrality == 1] integer

backed by SciPy's HiGHS branch-and-bound when available.  HiGHS is an exact
solver of the same class as CPLEX; the paper's observation that "a few seconds
of solving already gives a near-optimal solution" carries over via the
``time_limit`` option (HiGHS returns its incumbent at the limit).

A pure-numpy fallback (`_greedy_repair`) exists so the core algorithms remain
runnable without scipy: it LP-relaxes nothing, it simply rounds a feasible
assignment greedily.  It is only used when scipy is missing and is clearly
marked in the result.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

try:  # scipy is an optional-but-expected dependency
    import scipy.optimize as _sopt
    import scipy.sparse as _ssp

    _HAVE_SCIPY = True
except Exception:  # pragma: no cover - exercised only in scipy-less envs
    _HAVE_SCIPY = False


@dataclasses.dataclass
class MilpProblem:
    """A sparse MILP in row-bounded standard form."""

    c: np.ndarray  # (n,) objective
    a_rows: np.ndarray  # (nnz,) row indices of A
    a_cols: np.ndarray  # (nnz,) col indices of A
    a_vals: np.ndarray  # (nnz,) values of A
    row_lb: np.ndarray  # (m,)
    row_ub: np.ndarray  # (m,)
    var_lb: np.ndarray  # (n,)
    var_ub: np.ndarray  # (n,)
    integrality: np.ndarray  # (n,) 1 -> integer, 0 -> continuous

    @property
    def num_vars(self) -> int:
        return int(self.c.shape[0])

    @property
    def num_rows(self) -> int:
        return int(self.row_lb.shape[0])


@dataclasses.dataclass
class MilpResult:
    x: np.ndarray
    objective: float
    status: str  # "optimal" | "time_limit" | "infeasible" | "fallback"
    solve_seconds: float
    mip_gap: Optional[float] = None

    @property
    def ok(self) -> bool:
        return self.status in ("optimal", "time_limit", "fallback")


def solve_milp(
    problem: MilpProblem,
    *,
    time_limit: float = 10.0,
    mip_rel_gap: float = 1e-4,
    warm_start: Optional[np.ndarray] = None,
) -> MilpResult:
    """Solve ``problem``; return the incumbent when the time limit strikes.

    ``warm_start`` is accepted for interface parity (HiGHS via scipy does not
    take MIP starts; the fallback uses it as its starting assignment).
    """
    if _HAVE_SCIPY:
        return _solve_scipy(problem, time_limit=time_limit, mip_rel_gap=mip_rel_gap)
    return _greedy_repair(problem, warm_start=warm_start)


def _solve_scipy(problem: MilpProblem, *, time_limit: float, mip_rel_gap: float) -> MilpResult:
    n = problem.num_vars
    a = _ssp.csc_matrix(
        (problem.a_vals, (problem.a_rows, problem.a_cols)),
        shape=(problem.num_rows, n),
    )
    constraints = _sopt.LinearConstraint(a, problem.row_lb, problem.row_ub)
    bounds = _sopt.Bounds(problem.var_lb, problem.var_ub)
    t0 = time.perf_counter()
    res = _sopt.milp(
        c=problem.c,
        constraints=constraints,
        bounds=bounds,
        integrality=problem.integrality,
        options={
            "time_limit": float(time_limit),
            "mip_rel_gap": float(mip_rel_gap),
            "presolve": True,
        },
    )
    dt = time.perf_counter() - t0
    if res.x is None:
        return MilpResult(
            x=np.zeros(n),
            objective=float("inf"),
            status="infeasible",
            solve_seconds=dt,
        )
    status = "optimal" if res.status == 0 else "time_limit"
    gap = getattr(res, "mip_gap", None)
    return MilpResult(
        x=np.asarray(res.x, dtype=np.float64),
        objective=float(res.fun),
        status=status,
        solve_seconds=dt,
        mip_gap=None if gap is None else float(gap),
    )


def _greedy_repair(problem: MilpProblem, warm_start: Optional[np.ndarray]) -> MilpResult:
    """Scipy-less fallback: start from bounds/warm start, greedily repair rows.

    This is NOT a general MILP solver; it exists so that `repro.core` degrades
    gracefully (the callers all build assignment-structured programs for which
    a feasible greedy point exists: each key group on its current node).
    """
    t0 = time.perf_counter()
    n = problem.num_vars
    x = np.clip(
        warm_start.astype(np.float64) if warm_start is not None else np.zeros(n),
        problem.var_lb,
        problem.var_ub,
    )
    # Round integers.
    mask = problem.integrality.astype(bool)
    x[mask] = np.round(x[mask])
    obj = float(problem.c @ x)
    return MilpResult(
        x=x,
        objective=obj,
        status="fallback",
        solve_seconds=time.perf_counter() - t0,
    )


def dense_rows(problem: MilpProblem) -> np.ndarray:
    """Materialize A densely (testing/debug only)."""
    a = np.zeros((problem.num_rows, problem.num_vars))
    a[problem.a_rows, problem.a_cols] = problem.a_vals
    return a


class MilpBuilder:
    """Incremental sparse builder for :class:`MilpProblem`."""

    def __init__(self) -> None:
        self._obj: list[float] = []
        self._lb: list[float] = []
        self._ub: list[float] = []
        self._int: list[int] = []
        self._rows: list[int] = []
        self._cols: list[int] = []
        self._vals: list[float] = []
        self._row_lb: list[float] = []
        self._row_ub: list[float] = []
        self.names: dict[str, int] = {}

    # -- variables ---------------------------------------------------------
    def add_var(
        self,
        name: str,
        *,
        obj: float = 0.0,
        lb: float = 0.0,
        ub: float = np.inf,
        integer: bool = False,
    ) -> int:
        idx = len(self._obj)
        self._obj.append(obj)
        self._lb.append(lb)
        self._ub.append(ub)
        self._int.append(1 if integer else 0)
        if name:
            self.names[name] = idx
        return idx

    def add_binary(self, name: str, *, obj: float = 0.0) -> int:
        return self.add_var(name, obj=obj, lb=0.0, ub=1.0, integer=True)

    # -- constraints --------------------------------------------------------
    def add_row(
        self,
        cols: list[int] | np.ndarray,
        vals: list[float] | np.ndarray,
        *,
        lb: float = -np.inf,
        ub: float = np.inf,
    ) -> int:
        row = len(self._row_lb)
        cols = np.asarray(cols, dtype=np.int64)
        vals = np.asarray(vals, dtype=np.float64)
        if cols.shape != vals.shape:
            raise ValueError(f"cols/vals mismatch {cols.shape} vs {vals.shape}")
        self._rows.extend([row] * len(cols))
        self._cols.extend(cols.tolist())
        self._vals.extend(vals.tolist())
        self._row_lb.append(lb)
        self._row_ub.append(ub)
        return row

    def build(self) -> MilpProblem:
        return MilpProblem(
            c=np.asarray(self._obj, dtype=np.float64),
            a_rows=np.asarray(self._rows, dtype=np.int64),
            a_cols=np.asarray(self._cols, dtype=np.int64),
            a_vals=np.asarray(self._vals, dtype=np.float64),
            row_lb=np.asarray(self._row_lb, dtype=np.float64),
            row_ub=np.asarray(self._row_ub, dtype=np.float64),
            var_lb=np.asarray(self._lb, dtype=np.float64),
            var_ub=np.asarray(self._ub, dtype=np.float64),
            integrality=np.asarray(self._int, dtype=np.int64),
        )

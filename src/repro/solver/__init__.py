"""Optimization substrate: LP/MILP solving and balanced graph partitioning.

The paper uses IBM CPLEX v12.6.1 and METIS v5.1.  This package provides the
equivalents used by :mod:`repro.core`:

* :mod:`repro.solver.lp` — a time-limited MILP interface backed by SciPy's
  HiGHS (``scipy.optimize.milp``) with a pure-numpy greedy-repair fallback.
* :mod:`repro.solver.graphpart` — multilevel balanced graph partitioning
  (heavy-edge-matching coarsening + greedy growth + FM boundary refinement),
  standing in for METIS.
"""

from repro.solver.lp import MilpProblem, MilpResult, solve_milp
from repro.solver.graphpart import partition_graph

__all__ = ["MilpProblem", "MilpResult", "solve_milp", "partition_graph"]

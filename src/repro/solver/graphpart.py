"""Balanced graph partitioning (METIS stand-in).

ALBIC (Algorithm 2, step 2) and the COLA baseline both need *balanced graph
partitioning*: split a weighted graph into ``nparts`` parts with (approximately)
equal total vertex weight while minimizing the weight of cut edges.  The paper
uses METIS v5.1 [20]; this module implements the same multilevel scheme in
numpy:

1. **Coarsening** — heavy-edge matching collapses matched vertex pairs until
   the graph is small (or matching stalls).
2. **Initial partitioning** — greedy region growing over the coarsest graph,
   seeded by heaviest vertices, targeting equal part weights.
3. **Uncoarsening + refinement** — project labels back up and run
   Fiduccia–Mattheyses-style boundary refinement: move border vertices to the
   neighbouring part with maximal cut gain subject to the balance constraint.

The implementation favours clarity and determinism (seeded RNG) over raw
speed; the graphs ALBIC feeds it are collocation sets (tens to a few hundred
key groups), and COLA's largest benchmark graph is 1,200 vertices.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Graph:
    """Undirected weighted graph in COO triplet form (each edge stored once)."""

    num_vertices: int
    edge_u: np.ndarray  # (e,) int
    edge_v: np.ndarray  # (e,) int
    edge_w: np.ndarray  # (e,) float
    vertex_w: np.ndarray  # (n,) float

    def __post_init__(self) -> None:
        self.edge_u = np.asarray(self.edge_u, dtype=np.int64)
        self.edge_v = np.asarray(self.edge_v, dtype=np.int64)
        self.edge_w = np.asarray(self.edge_w, dtype=np.float64)
        self.vertex_w = np.asarray(self.vertex_w, dtype=np.float64)
        if (
            self.edge_u.shape != self.edge_v.shape
            or self.edge_u.shape != self.edge_w.shape
        ):
            raise ValueError("edge arrays must share a shape")
        if self.vertex_w.shape != (self.num_vertices,):
            raise ValueError("vertex_w must have shape (num_vertices,)")

    @property
    def num_edges(self) -> int:
        return int(self.edge_u.shape[0])

    def adjacency(self) -> list[dict[int, float]]:
        adj: list[dict[int, float]] = [dict() for _ in range(self.num_vertices)]
        for u, v, w in zip(self.edge_u, self.edge_v, self.edge_w):
            if u == v:
                continue
            u, v = int(u), int(v)
            adj[u][v] = adj[u].get(v, 0.0) + float(w)
            adj[v][u] = adj[v].get(u, 0.0) + float(w)
        return adj


def cut_weight(graph: Graph, labels: np.ndarray) -> float:
    """Total weight of edges whose endpoints live in different parts."""
    mask = labels[graph.edge_u] != labels[graph.edge_v]
    return float(graph.edge_w[mask].sum())


def part_weights(graph: Graph, labels: np.ndarray, nparts: int) -> np.ndarray:
    return np.bincount(labels, weights=graph.vertex_w, minlength=nparts)


# ---------------------------------------------------------------------------
# Coarsening
# ---------------------------------------------------------------------------


def _heavy_edge_matching(graph: Graph, rng: np.random.Generator) -> np.ndarray:
    """Return match[i] = partner vertex (or i itself when unmatched)."""
    adj = graph.adjacency()
    match = np.arange(graph.num_vertices)
    visited = np.zeros(graph.num_vertices, dtype=bool)
    order = rng.permutation(graph.num_vertices)
    for u in order:
        if visited[u]:
            continue
        best_v, best_w = -1, -1.0
        for v, w in adj[u].items():
            if not visited[v] and v != u and w > best_w:
                best_v, best_w = v, w
        if best_v >= 0:
            match[u], match[best_v] = best_v, u
            visited[best_v] = True
        visited[u] = True
    return match


def _coarsen(graph: Graph, match: np.ndarray) -> tuple[Graph, np.ndarray]:
    """Collapse matched pairs; return (coarse graph, fine->coarse map)."""
    n = graph.num_vertices
    cmap = -np.ones(n, dtype=np.int64)
    nxt = 0
    for u in range(n):
        if cmap[u] >= 0:
            continue
        v = int(match[u])
        cmap[u] = nxt
        if v != u and cmap[v] < 0:
            cmap[v] = nxt
        nxt += 1
    cvw = np.zeros(nxt)
    np.add.at(cvw, cmap, graph.vertex_w)
    cu, cv = cmap[graph.edge_u], cmap[graph.edge_v]
    keep = cu != cv
    cu, cv, cw = cu[keep], cv[keep], graph.edge_w[keep]
    # Merge parallel edges.
    lo, hi = np.minimum(cu, cv), np.maximum(cu, cv)
    key = lo * nxt + hi
    order = np.argsort(key, kind="stable")
    key, lo, hi, cw = key[order], lo[order], hi[order], cw[order]
    if key.size:
        uniq, start = np.unique(key, return_index=True)
        sums = np.add.reduceat(cw, start)
        eu, ev, ew = lo[start], hi[start], sums
    else:
        eu = ev = np.zeros(0, dtype=np.int64)
        ew = np.zeros(0)
    return Graph(nxt, eu, ev, ew, cvw), cmap


# ---------------------------------------------------------------------------
# Initial partitioning
# ---------------------------------------------------------------------------


def _greedy_grow(graph: Graph, nparts: int, rng: np.random.Generator) -> np.ndarray:
    """Region-growing initial partition targeting equal part weights."""
    n = graph.num_vertices
    target = graph.vertex_w.sum() / nparts
    adj = graph.adjacency()
    labels = -np.ones(n, dtype=np.int64)
    weights = np.zeros(nparts)
    # Seed parts spread apart: first the heaviest vertex, then repeatedly the
    # vertex least connected to any existing seed (uniform weights would
    # otherwise place every seed inside one dense cluster).
    conn = np.zeros(n)
    seeds = [int(np.argmax(graph.vertex_w + rng.uniform(0, 1e-6, n)))]
    for _ in range(nparts - 1):
        for v, w in adj[seeds[-1]].items():
            conn[v] += w
        conn[seeds[-1]] = np.inf
        cand = np.where(np.isfinite(conn))[0]
        seeds.append(int(cand[np.argmin(conn[cand])]))
    frontier: list[list[int]] = [[] for _ in range(nparts)]
    for p, s in enumerate(seeds):
        if labels[s] < 0:
            labels[s] = p
            weights[p] += graph.vertex_w[s]
            frontier[p] = [int(s)]
    # Grow the lightest part by its best-connected frontier vertex.
    unassigned = set(int(i) for i in range(n) if labels[i] < 0)
    while unassigned:
        p = int(np.argmin(weights))
        # Candidate = unassigned neighbour of part p with max connectivity.
        best_u, best_gain = -1, -1.0
        for f in frontier[p]:
            for v, w in adj[f].items():
                if labels[v] < 0 and w > best_gain:
                    best_u, best_gain = v, w
        if best_u < 0:  # disconnected: pull an arbitrary unassigned vertex
            best_u = next(iter(unassigned))
        labels[best_u] = p
        weights[p] += graph.vertex_w[best_u]
        frontier[p].append(best_u)
        unassigned.discard(best_u)
        if weights[p] > target * 1.5:
            frontier[p] = []  # stop growing an overweight part actively
    return labels


# ---------------------------------------------------------------------------
# Refinement
# ---------------------------------------------------------------------------


def _fm_refine(
    graph: Graph,
    labels: np.ndarray,
    nparts: int,
    *,
    balance_tol: float,
    max_passes: int = 8,
) -> np.ndarray:
    """FM-style boundary refinement under a balance constraint."""
    labels = labels.copy()
    adj = graph.adjacency()
    total = graph.vertex_w.sum()
    max_part = (total / nparts) * (1.0 + balance_tol)
    weights = part_weights(graph, labels, nparts)
    for _ in range(max_passes):
        moved = 0
        for u in range(graph.num_vertices):
            lu = labels[u]
            # Connectivity of u to each part.
            conn = np.zeros(nparts)
            for v, w in adj[u].items():
                conn[labels[v]] += w
            internal = conn[lu]
            # Best external part by gain, respecting balance.
            best_p, best_gain = -1, 0.0
            for p in range(nparts):
                if p == lu:
                    continue
                if weights[p] + graph.vertex_w[u] > max_part:
                    continue
                gain = conn[p] - internal
                # Also allow zero-gain moves that improve balance.
                improves_balance = (
                    gain == 0.0
                    and weights[lu] - graph.vertex_w[u] > weights[p]
                    and weights[lu] > total / nparts
                )
                if gain > best_gain or (improves_balance and best_p < 0):
                    best_p, best_gain = p, gain
            if best_p >= 0:
                weights[lu] -= graph.vertex_w[u]
                weights[best_p] += graph.vertex_w[u]
                labels[u] = best_p
                moved += 1
        if moved == 0:
            break
    return labels


def _rebalance(
    graph: Graph, labels: np.ndarray, nparts: int, balance_tol: float
) -> np.ndarray:
    """Force part weights under the cap by evicting smallest-loss vertices."""
    labels = labels.copy()
    total = graph.vertex_w.sum()
    max_part = (total / nparts) * (1.0 + balance_tol)
    weights = part_weights(graph, labels, nparts)
    adj = graph.adjacency()
    for p in range(nparts):
        guard = 0
        while weights[p] > max_part and guard < graph.num_vertices:
            guard += 1
            members = np.where(labels == p)[0]
            if len(members) <= 1:
                break
            # Evict the member with least internal connectivity.
            best_u, best_cost = -1, np.inf
            for u in members:
                cost = sum(w for v, w in adj[u].items() if labels[v] == p)
                if cost < best_cost:
                    best_u, best_cost = int(u), cost
            q = int(np.argmin(weights))
            if q == p:
                break
            weights[p] -= graph.vertex_w[best_u]
            weights[q] += graph.vertex_w[best_u]
            labels[best_u] = q
    return labels


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def partition_graph(
    graph: Graph,
    nparts: int,
    *,
    balance_tol: float = 0.10,
    seed: int = 0,
    coarsen_to: int = 64,
) -> np.ndarray:
    """Partition ``graph`` into ``nparts`` balanced parts minimizing edge cut.

    Returns an int label array of shape (num_vertices,).
    """
    if nparts <= 0:
        raise ValueError("nparts must be positive")
    if nparts == 1:
        return np.zeros(graph.num_vertices, dtype=np.int64)
    if nparts >= graph.num_vertices:
        return np.arange(graph.num_vertices, dtype=np.int64) % nparts

    rng = np.random.default_rng(seed)

    # Multilevel V-cycle.
    levels: list[tuple[Graph, np.ndarray]] = []  # (finer graph, fine->coarse)
    g = graph
    while g.num_vertices > max(coarsen_to, 2 * nparts):
        match = _heavy_edge_matching(g, rng)
        coarse, cmap = _coarsen(g, match)
        if coarse.num_vertices >= g.num_vertices:  # matching stalled
            break
        levels.append((g, cmap))
        g = coarse

    labels = _greedy_grow(g, nparts, rng)
    labels = _fm_refine(g, labels, nparts, balance_tol=balance_tol)

    for finer, cmap in reversed(levels):
        labels = labels[cmap]
        labels = _fm_refine(finer, labels, nparts, balance_tol=balance_tol)

    labels = _rebalance(graph, labels, nparts, balance_tol)
    return labels


def graph_from_dense(weights: np.ndarray, vertex_w: np.ndarray) -> Graph:
    """Build a Graph from a dense symmetric (or to-be-symmetrized) matrix."""
    w = np.asarray(weights, dtype=np.float64)
    w = w + w.T  # symmetrize; diagonal ignored below
    iu, iv = np.triu_indices(w.shape[0], k=1)
    mask = w[iu, iv] > 0
    return Graph(
        num_vertices=w.shape[0],
        edge_u=iu[mask],
        edge_v=iv[mask],
        edge_w=w[iu, iv][mask],
        vertex_w=np.asarray(vertex_w, dtype=np.float64),
    )

"""Fault-tolerance substrate: atomic, manifest-versioned, async checkpoints."""

from repro.checkpoint.checkpoint import (
    CheckpointManager,
    load_pytree,
    save_pytree,
)

__all__ = ["CheckpointManager", "load_pytree", "save_pytree"]

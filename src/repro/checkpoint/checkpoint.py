"""Checkpoint/restart for training and the streaming engine.

Design (scaled-down from what a 1000-node deployment needs, same invariants):

* **Atomicity** — a checkpoint directory is staged under ``.tmp-<step>`` and
  ``os.rename``d into place; the ``MANIFEST.json`` is written last inside the
  stage, so a directory with a manifest is complete by construction.
* **Versioned retention** — ``keep`` newest checkpoints are retained; garbage
  is pruned after a successful commit, never before.
* **Async** — ``save_async`` snapshots the (host) arrays synchronously
  (cheap: device→host copy) and writes in a background thread, keeping the
  training loop off the disk path.
* **Self-describing** — arrays go into an ``.npz``; the pytree structure and
  non-array leaves are pickled alongside; the manifest records step, wall
  time and user metadata (data-pipeline cursor, engine routing table, RNG).

On a real multi-host deployment each host writes its own shard of the
jax.Array pieces (`addressable_shards`) under the same manifest — the layout
here is the single-host specialization of that.
"""

from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

MANIFEST = "MANIFEST.json"


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """npz-safe encoding: custom dtypes (bfloat16 etc.) stored as raw views."""
    dt = str(arr.dtype)
    if arr.dtype.kind == "V" or dt in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
        width = {"bfloat16": np.uint16}.get(dt, np.uint8)
        return arr.view(width), dt
    return arr, dt


def _decode(arr: np.ndarray, dt: str) -> np.ndarray:
    if str(arr.dtype) == dt:
        return arr
    import ml_dtypes

    return arr.view(np.dtype(getattr(ml_dtypes, dt)))


def _flatten(tree: Any) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


def save_pytree(path: str, tree: Any, *, metadata: Optional[dict] = None) -> None:
    """Synchronous atomic save of one pytree to a checkpoint directory."""
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    stage = path + ".tmp"
    if os.path.exists(stage):
        shutil.rmtree(stage)
    os.makedirs(stage)
    leaves, treedef = _flatten(tree)
    encoded = [_encode(x) for x in leaves]
    np.savez(os.path.join(stage, "arrays.npz"), *[e[0] for e in encoded])
    with open(os.path.join(stage, "treedef.pkl"), "wb") as f:
        pickle.dump((treedef, [e[1] for e in encoded]), f)
    with open(os.path.join(stage, MANIFEST), "w") as f:
        json.dump(
            {
                "num_leaves": len(leaves),
                "written_at": time.time(),
                "metadata": metadata or {},
            },
            f,
        )
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(stage, path)


def load_pytree(path: str) -> tuple[Any, dict]:
    """Load (tree, metadata) from a checkpoint directory."""
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    with open(os.path.join(path, "treedef.pkl"), "rb") as f:
        treedef, dtypes = pickle.load(f)
    with np.load(os.path.join(path, "arrays.npz"), allow_pickle=True) as z:
        leaves = [_decode(z[k], dt) for k, dt in zip(z.files, dtypes)]
    return jax.tree.unflatten(treedef, leaves), manifest.get("metadata", {})


class CheckpointManager:
    """Step-indexed checkpoints with retention and async writing."""

    def __init__(self, directory: str, *, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        self._prune_stages()

    def _prune_stages(self) -> None:
        """Remove stage directories a killed writer left behind.

        A crash between staging and the ``os.rename`` commit leaves a
        ``step_*.tmp`` directory — possibly with a complete manifest inside.
        It was never committed, so it is garbage: prune it on construction
        (create the manager before starting new saves).
        """
        for name in os.listdir(self.directory):
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            # Committed checkpoints only: a stage dir ("step_*.tmp") can hold
            # a manifest too (it is written last *inside* the stage), but an
            # unrenamed stage was never committed — skip non-numeric suffixes.
            tail = name[len("step_") :]
            if (
                name.startswith("step_")
                and tail.isdigit()
                and os.path.exists(os.path.join(self.directory, name, MANIFEST))
            ):
                out.append(int(tail))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    # -- writing --------------------------------------------------------------
    def save(self, step: int, tree: Any, *, metadata: Optional[dict] = None) -> None:
        self.wait()
        save_pytree(
            self._step_dir(step),
            tree,
            metadata={"step": step, **(metadata or {})},
        )
        self._prune()

    def save_async(
        self, step: int, tree: Any, *, metadata: Optional[dict] = None
    ) -> None:
        """Snapshot now (host copy), write in the background."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work() -> None:
            save_pytree(
                self._step_dir(step),
                host_tree,
                metadata={"step": step, **(metadata or {})},
            )
            self._prune()

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _prune(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- reading --------------------------------------------------------------
    def restore(self, step: Optional[int] = None) -> tuple[Any, dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        return load_pytree(self._step_dir(step))

"""Attention (GQA full / chunked-flash / local-window / decode / cross) and
MLP (SwiGLU / GeGLU / GELU) layers, functional style.

GQA is computed with an explicit group dimension so repeated KV heads are
never materialized:  q (B,S,KV,G,hd) × k (B,T,KV,hd) → scores (B,KV,G,S,T).

Long sequences use a chunked, online-softmax ("flash-style") path built from
``jax.lax.scan`` so activation memory is O(S·chunk) rather than O(S²) — the
XLA fallback for the Pallas kernel in :mod:`repro.kernels.flash_attention`
(selected on TPU).  The causal chunked path skips fully-masked KV chunks'
*memory*, not their FLOPs; the §Perf log tracks that overhead explicitly.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    ParamSpec,
    apply_mrope,
    apply_rope,
    norm_specs,
    text_mrope_positions,
)

NEG_INF = -2.0e38
CHUNK_Q = 1024
CHUNK_KV = 1024
FULL_ATTN_MAX_SEQ = 8192  # above this, use the chunked path


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig, *, cross: bool = False) -> dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
        **{f"norm_{k}": v for k, v in norm_specs(cfg.norm_kind, d).items()},
    }


def mlp_specs(cfg: ModelConfig) -> dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "w_gate": ParamSpec((d, f), ("embed", "ff")),
            "w_up": ParamSpec((d, f), ("embed", "ff")),
            "w_down": ParamSpec((f, d), ("ff", "embed")),
            **{f"norm_{k}": v for k, v in norm_specs(cfg.norm_kind, d).items()},
        }
    return {
        "w_up": ParamSpec((d, f), ("embed", "ff")),
        "w_down": ParamSpec((f, d), ("ff", "embed")),
        **{f"norm_{k}": v for k, v in norm_specs(cfg.norm_kind, d).items()},
    }


# ---------------------------------------------------------------------------
# Projections + positional encoding
# ---------------------------------------------------------------------------


def qkv_project(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[
    jax.Array,
    jax.Array,
    jax.Array,
]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    return q, k, v


def position_encode(
    cfg: ModelConfig, q: jax.Array, k: jax.Array, positions: jax.Array
) -> tuple[jax.Array, jax.Array]:
    if cfg.rope_kind == "rope":
        return (
            apply_rope(q, positions, cfg.rope_theta),
            apply_rope(k, positions, cfg.rope_theta),
        )
    if cfg.rope_kind == "mrope":
        thw = text_mrope_positions(positions)
        return (
            apply_mrope(q, thw, cfg.rope_theta),
            apply_mrope(k, thw, cfg.rope_theta),
        )
    return q, k  # "none" | "learned" (handled at the embedding)


# ---------------------------------------------------------------------------
# Core attention math (GQA, grouped)
# ---------------------------------------------------------------------------


def _grouped(q: jax.Array, num_kv: int) -> jax.Array:
    b, s, h, hd = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, hd)


def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Unchunked GQA attention.  q (B,S,H,hd); k,v (B,T,KV,hd)."""
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    qg = _grouped(q, kvh)  # (B,S,KV,G,hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    # Positions: q_offset may be scalar or per-batch (B,) (windowed decode).
    offset = jnp.asarray(q_offset)
    spos = jnp.arange(s)[None, :, None] + offset.reshape(-1, 1, 1)  # (B?|1, S, 1)
    tpos = jnp.arange(t)[None, None, :]  # (1, 1, T)
    mask = jnp.ones(jnp.broadcast_shapes(spos.shape, tpos.shape), dtype=bool)
    if causal:
        mask &= tpos <= spos
    if window is not None:
        mask &= tpos > spos - window
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    if kv_len is not None:  # decode: only the first kv_len cache slots exist
        valid = jnp.arange(t)[None, :] < kv_len[:, None]  # (B,T)
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, s, h, hd)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk_q: int = CHUNK_Q,
    chunk_kv: int = CHUNK_KV,
) -> jax.Array:
    """Flash-style online-softmax attention with O(S·chunk) memory.

    Outer scan over query chunks; inner scan over KV chunks with an
    (m, l, acc) carry.  Masked-out chunks contribute nothing numerically;
    fully-masked chunks are still *computed* on the XLA path (see module
    docstring) — the Pallas kernel version skips them.
    """
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    nq, nkv = s // chunk_q, t // chunk_kv
    assert s % chunk_q == 0 and t % chunk_kv == 0, (s, t, chunk_q, chunk_kv)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qg = _grouped(q, kvh).reshape(b, nq, chunk_q, kvh, g, hd)
    kc = k.reshape(b, nkv, chunk_kv, kvh, hd)
    vc = v.reshape(b, nkv, chunk_kv, kvh, hd)

    def q_block(qi: jax.Array, q_chunk: jax.Array) -> jax.Array:
        # q_chunk: (B, Cq, KV, G, hd)
        m0 = jnp.full((b, kvh, g, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, chunk_q, hd), jnp.float32)

        def kv_block(carry, inputs):
            m, l, acc = carry
            kj, k_chunk, v_chunk = inputs
            sc = (
                jnp.einsum("bskgh,btkh->bkgst", q_chunk, k_chunk).astype(jnp.float32)
                * scale
            )
            spos = qi * chunk_q + jnp.arange(chunk_q)[:, None]
            tpos = kj * chunk_kv + jnp.arange(chunk_kv)[None, :]
            mask = jnp.ones((chunk_q, chunk_kv), dtype=bool)
            if causal:
                mask &= tpos <= spos
            if window is not None:
                mask &= tpos > spos - window
            sc = jnp.where(mask, sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgst,btkh->bkgsh", p.astype(v_chunk.dtype), v_chunk)
            acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        ks = jnp.arange(nkv)
        (m, l, acc), _ = jax.lax.scan(
            kv_block,
            (m0, l0, a0),
            (ks, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
        )
        out = acc / jnp.maximum(l, 1e-37)[..., None]  # (B,KV,G,Cq,hd)
        return jnp.moveaxis(out, 3, 1).astype(q.dtype)  # (B,Cq,KV,G,hd)

    qs = jnp.arange(nq)
    outs = jax.lax.map(
        lambda args: q_block(args[0], args[1]), (qs, jnp.moveaxis(qg, 1, 0))
    )  # (nq, B, Cq, KV, G, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)
    return out


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    max_full_seq: int = FULL_ATTN_MAX_SEQ,
) -> jax.Array:
    s = q.shape[1]
    if s <= max_full_seq or s % CHUNK_Q != 0 or k.shape[1] % CHUNK_KV != 0:
        return full_attention(q, k, v, causal=causal, window=window)
    return chunked_attention(q, k, v, causal=causal, window=window)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    kv_len: jax.Array,
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """One-token decode against a (B,T,KV,hd) cache with per-batch lengths."""
    return full_attention(
        q,
        k_cache,
        v_cache,
        causal=False,
        window=window,
        q_offset=jnp.maximum(kv_len - 1, 0) if window is not None else 0,
        kv_len=kv_len,
    )


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_forward(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp_kind == "swiglu":
        gate = jax.nn.silu(x @ p["w_gate"])
        return (gate * (x @ p["w_up"])) @ p["w_down"]
    if cfg.mlp_kind == "geglu":
        gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
        return (gate * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"], approximate=True) @ p["w_down"]

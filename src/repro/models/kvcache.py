"""Decode caches for every block kind, as pytrees with a stacked scan dim.

Cache layout mirrors the parameter layout: one stacked entry per pattern
position (leading dim = cycles), plus unstacked entries for remainder blocks
and, for enc-dec models, a per-decoder-layer cross-attention cache.

``cache_specs`` builds the ShapeDtypeStruct version for the dry-run (no
allocation); ``init_cache`` materializes zeros for real serving.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct

from repro.configs.base import (
    ATTN,
    ATTN_MOE,
    LOCAL_ATTN,
    MLSTM,
    RGLRU,
    SLSTM,
    ModelConfig,
)


def _block_cache_shapes(
    cfg: ModelConfig, kind: str, batch: int, capacity: int
) -> dict[str, tuple[tuple[int, ...], Any]]:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    h = cfg.num_heads
    d = cfg.d_model
    if kind in (ATTN, ATTN_MOE):
        cap = min(capacity, cfg.max_seq_len)
        return {
            "k": ((batch, cap, kv, hd), jnp.bfloat16),
            "v": ((batch, cap, kv, hd), jnp.bfloat16),
        }
    if kind == LOCAL_ATTN:
        w = min(cfg.local_window, capacity)
        return {
            "k": ((batch, w, kv, hd), jnp.bfloat16),
            "v": ((batch, w, kv, hd), jnp.bfloat16),
        }
    if kind == RGLRU:
        w = cfg.lru_width or d
        return {
            "h": ((batch, w), jnp.float32),
            "conv": ((batch, 3, w), jnp.bfloat16),
        }
    if kind == MLSTM:
        mhd = d // h
        return {
            "C": ((batch, h, mhd, mhd), jnp.float32),
            "n": ((batch, h, mhd), jnp.float32),
            "m": ((batch, h), jnp.float32),
        }
    if kind == SLSTM:
        return {
            "c": ((batch, d), jnp.float32),
            "n": ((batch, d), jnp.float32),
            "h": ((batch, d), jnp.bfloat16),
            "m": ((batch, d), jnp.float32),
        }
    raise ValueError(kind)


_LOGICAL_BY_KIND: dict[str, dict[str, tuple]] = {
    ATTN: {
        "k": ("cache_batch", "cache_seq", "cache_heads", None),
        "v": ("cache_batch", "cache_seq", "cache_heads", None),
    },
    LOCAL_ATTN: {
        "k": ("cache_batch", "cache_seq", "cache_heads", None),
        "v": ("cache_batch", "cache_seq", "cache_heads", None),
    },
    RGLRU: {"h": ("cache_batch", "lru"), "conv": ("cache_batch", None, "lru")},
    MLSTM: {
        "C": ("cache_batch", "heads", None, None),
        "n": ("cache_batch", "heads", None),
        "m": ("cache_batch", "heads"),
    },
    SLSTM: {
        "c": ("cache_batch", None),
        "n": ("cache_batch", None),
        "h": ("cache_batch", None),
        "m": ("cache_batch", None),
    },
}
_LOGICAL_BY_KIND[ATTN_MOE] = _LOGICAL_BY_KIND[ATTN]


def cache_logical(cfg: ModelConfig) -> dict:
    """Logical-axis tree mirroring cache_specs/init_cache structure."""
    out: dict[str, Any] = {"scan": [], "rem": []}
    for kind in cfg.pattern:
        out["scan"].append(
            {n: ("layers", *ax) for n, ax in _LOGICAL_BY_KIND[kind].items()}
        )
    for kind in cfg.remainder:
        out["rem"].append(dict(_LOGICAL_BY_KIND[kind]))
    if cfg.is_encdec:
        out["cross"] = {
            "k": ("layers", "cache_batch", "cache_seq", "cache_heads", None),
            "v": ("layers", "cache_batch", "cache_seq", "cache_heads", None),
        }
    return out


def _build(
    cfg: ModelConfig,
    batch: int,
    capacity: int,
    make: Callable[[tuple[int, ...], Any], Any],
    enc_len: int = 0,
) -> dict:
    cache: dict[str, Any] = {"scan": [], "rem": []}
    for kind in cfg.pattern:
        shapes = _block_cache_shapes(cfg, kind, batch, capacity)
        cache["scan"].append(
            {n: make((cfg.cycles, *shp), dt) for n, (shp, dt) in shapes.items()}
        )
    for kind in cfg.remainder:
        shapes = _block_cache_shapes(cfg, kind, batch, capacity)
        cache["rem"].append({n: make(shp, dt) for n, (shp, dt) in shapes.items()})
    if cfg.is_encdec:
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        # Cross-attention k/v over the encoder sequence, one per decoder layer.
        cache["cross"] = {
            "k": make((cfg.cycles, batch, enc_len, kv, hd), jnp.bfloat16),
            "v": make((cfg.cycles, batch, enc_len, kv, hd), jnp.bfloat16),
        }
    return cache


def cache_specs(cfg: ModelConfig, batch: int, capacity: int, enc_len: int = 0) -> dict:
    return _build(
        cfg, batch, capacity, lambda s, d: ShapeDtypeStruct(s, d), enc_len=enc_len
    )


def init_cache(cfg: ModelConfig, batch: int, capacity: int, enc_len: int = 0) -> dict:
    return _build(cfg, batch, capacity, lambda s, d: jnp.zeros(s, d), enc_len=enc_len)


def cache_capacity(cfg: ModelConfig, kind: str, capacity: int) -> int:
    if kind == LOCAL_ATTN:
        return min(cfg.local_window, capacity)
    return min(capacity, cfg.max_seq_len)


def update_kv(
    cache_k: jax.Array,
    cache_v: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    positions: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Write one token's k/v at per-batch positions (mod capacity)."""
    cap = cache_k.shape[1]
    idx = positions % cap

    def write(c, n, i):
        return jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0)

    ck = jax.vmap(write)(cache_k, k_new, idx)
    cv = jax.vmap(write)(cache_v, v_new, idx)
    return ck, cv

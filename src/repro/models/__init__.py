"""LM workloads hosted by the framework: the 10 assigned architectures.

A single :class:`repro.models.transformer.Model` assembles any of the
families (dense GQA, MoE, RG-LRU hybrid, enc-dec, VLM backbone, xLSTM) from a
:class:`repro.configs.base.ModelConfig` block pattern; layers are stacked with
``jax.lax.scan`` so HLO size and compile time stay flat in depth.
"""

from repro.models.transformer import (
    Model,
    init_params,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

__all__ = [
    "Model",
    "init_params",
    "make_prefill_step",
    "make_serve_step",
    "make_train_step",
]

"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory, recurrent) — the xlstm-1.3b architecture at ratio 7:1.

mLSTM state per head: C (hd×hd) matrix memory, n (hd) normalizer, m scalar
stabilizer.

    i_t = exp(ĩ_t),  f_t = σ(f̃_t)  (stabilized: m_t = max(log f + m⁻, log i))
    C_t = f C_{t−1} + i (v_t k_tᵀ)
    n_t = f n_{t−1} + i k_t
    h_t = (C_t q_t) / max(|n_tᵀ q_t|, 1)

TPU adaptation: training/prefill uses the *chunkwise-parallel* form — a
``lax.scan`` over sequence chunks carrying (C, n, m), with the intra-chunk
part computed attention-like on the MXU.  That keeps the compute O(S·chunk)
(sub-quadratic for long context) and maps the heavy lifting onto matmuls,
instead of porting the paper's CUDA fused recurrent kernel.  Decode is the
O(hd²) recurrent step — constant in sequence length, which is what makes
``long_500k`` runnable for this arch.

sLSTM keeps the true recurrence (h_{t−1} feeds the gates) and is scanned
sequentially over time; with 6 sLSTM layers of 48 total the scan cost is
bounded.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, norm_specs

MLSTM_CHUNK = 256


def mlstm_specs(cfg: ModelConfig) -> dict[str, ParamSpec]:
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", None)),
        "wk": ParamSpec((d, h, hd), ("embed", "heads", None)),
        "wv": ParamSpec((d, h, hd), ("embed", "heads", None)),
        "w_i": ParamSpec((d, h), ("embed", "heads")),
        "w_f": ParamSpec((d, h), ("embed", "heads")),
        "b_i": ParamSpec((h,), (None,), init="zeros"),
        "b_f": ParamSpec((h,), (None,), init="ones"),
        "w_o": ParamSpec((d, d), ("embed", None)),  # output gate
        "w_up": ParamSpec((d, 2 * d), ("embed", "ff")),
        "w_down": ParamSpec((2 * d, d), ("ff", "embed")),
        "wo": ParamSpec((h, hd, d), ("heads", None, "embed")),
        **{f"norm_{k}": v for k, v in norm_specs(cfg.norm_kind, d).items()},
    }


def slstm_specs(cfg: ModelConfig) -> dict[str, ParamSpec]:
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    return {
        # Input projections for z, i, f, o.
        "w_z": ParamSpec((d, d), ("embed", None)),
        "w_i": ParamSpec((d, d), ("embed", None)),
        "w_f": ParamSpec((d, d), ("embed", None)),
        "w_o": ParamSpec((d, d), ("embed", None)),
        # Block-diagonal recurrent matrices (per head hd×hd).
        "r_z": ParamSpec((h, hd, hd), ("heads", None, None)),
        "r_i": ParamSpec((h, hd, hd), ("heads", None, None)),
        "r_f": ParamSpec((h, hd, hd), ("heads", None, None)),
        "r_o": ParamSpec((h, hd, hd), ("heads", None, None)),
        "b_z": ParamSpec((d,), (None,), init="zeros"),
        "b_i": ParamSpec((d,), (None,), init="zeros"),
        "b_f": ParamSpec((d,), (None,), init="ones"),
        "b_o": ParamSpec((d,), (None,), init="zeros"),
        "w_proj": ParamSpec((d, d), ("embed", None)),
        **{f"norm_{k}": v for k, v in norm_specs(cfg.norm_kind, d).items()},
    }


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mlstm_gates(p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """log-input-gate ĩ and log-forget-gate log σ(f̃), shapes (B,S,H)."""
    i_pre = jnp.einsum("bsd,dh->bsh", x, p["w_i"]) + p["b_i"]
    f_pre = jnp.einsum("bsd,dh->bsh", x, p["w_f"]) + p["b_f"]
    return i_pre.astype(jnp.float32), jax.nn.log_sigmoid(f_pre.astype(jnp.float32))


def mlstm_chunk_parallel(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    state: tuple | None = None,
) -> tuple[jax.Array, tuple]:
    """Chunkwise-parallel mLSTM.  x (B,S,d) with S % chunk == 0."""
    b, s, d = x.shape
    h = cfg.num_heads
    hd = d // h
    chunk = min(MLSTM_CHUNK, s)
    assert s % chunk == 0
    nc = s // chunk

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]) / jnp.sqrt(hd)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"]) / jnp.sqrt(hd)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    i_pre, log_f = _mlstm_gates(p, x)

    def reshape_c(a, extra=()):
        return a.reshape(b, nc, chunk, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = reshape_c(q), reshape_c(k), reshape_c(v)
    ic, fc = reshape_c(i_pre), reshape_c(log_f)

    if state is None:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.zeros((b, h), jnp.float32)
    else:
        c0, n0, m0 = state

    def chunk_step(carry, inp):
        c_prev, n_prev, m_prev = carry
        qj, kj, vj, ij, fj = inp  # (B,chunk,H,*) ; gates (B,chunk,H)
        csum_f = jnp.cumsum(fj, axis=1)  # (B,chunk,H): Σ log f within chunk
        total_f = csum_f[:, -1, :]
        log_w_inter = csum_f + m_prev[:, None, :]  # weight of carry-in at t
        # a_ut = i_u + csum_f_t − csum_f_u  for u ≤ t.
        a = (
            csum_f[:, :, None, :]  # target t
            - csum_f[:, None, :, :]  # source u
            + ij[:, None, :, :]
        )  # (B, t, u, H)
        tri = jnp.tril(jnp.ones((qj.shape[1], qj.shape[1]), bool))
        a = jnp.where(tri[None, :, :, None], a, -jnp.inf)
        m_t = jnp.maximum(jnp.max(a, axis=2), log_w_inter)  # (B,chunk,H)
        w_intra = jnp.exp(a - m_t[:, :, None, :])  # (B,t,u,H)
        w_inter = jnp.exp(log_w_inter - m_t)  # (B,t,H)
        # Intra-chunk attention-like term.
        scores = jnp.einsum(
            "bthk,buhk->btuh", qj.astype(jnp.float32), kj.astype(jnp.float32)
        )
        scores = scores * w_intra
        num_intra = jnp.einsum("btuh,buhk->bthk", scores, vj.astype(jnp.float32))
        # Normalizer n_t·q_t = Σ_u w_ut (k_u·q_t) — sum the weighted scores.
        den_intra = jnp.einsum(
            "btuh,buh->bth", scores, jnp.ones(kj.shape[:3], jnp.float32)
        )
        # Inter-chunk carry term.
        num_inter = jnp.einsum(
            "bthk,bhkl->bthl", qj.astype(jnp.float32), c_prev
        ) * w_inter[..., None]
        den_inter = (
            jnp.einsum("bthk,bhk->bth", qj.astype(jnp.float32), n_prev) * w_inter
        )
        num = num_intra + num_inter
        den = jnp.abs(den_intra + den_inter)
        h_chunk = num / jnp.maximum(den, jnp.exp(-m_t))[..., None]
        # Update carry to end of chunk.
        m_new = jnp.maximum(
            m_prev + total_f, jnp.max(ij + (total_f[:, None, :] - csum_f), axis=1)
        )
        w_c = jnp.exp(m_prev + total_f - m_new)  # carry decay
        w_u = jnp.exp(
            ij + (total_f[:, None, :] - csum_f) - m_new[:, None, :]
        )  # (B,chunk,H) per-source weight at chunk end
        c_new = c_prev * w_c[..., None, None] + jnp.einsum(
            "buh,buhk,buhl->bhkl", w_u, vj.astype(jnp.float32), kj.astype(jnp.float32)
        )
        n_new = n_prev * w_c[..., None] + jnp.einsum(
            "buh,buhk->bhk", w_u, kj.astype(jnp.float32)
        )
        return (c_new, n_new, m_new), h_chunk

    (c_f, n_f, m_f), hs = jax.lax.scan(chunk_step, (c0, n0, m0), (qc, kc, vc, ic, fc))
    hs = hs.swapaxes(0, 1).reshape(b, s, h, hd).astype(x.dtype)
    return hs, (c_f, n_f, m_f)


def mlstm_block(
    cfg: ModelConfig, p: dict, x: jax.Array, *, cache: dict | None = None
) -> tuple[jax.Array, dict | None]:
    from repro.models.common import apply_norm

    b, s, d = x.shape
    h = cfg.num_heads
    hd = d // h
    normed = apply_norm(
        cfg.norm_kind, {k[5:]: v for k, v in p.items() if k.startswith("norm_")}, x
    )
    if cache is None:
        hs, (c_f, n_f, m_f) = mlstm_chunk_parallel(cfg, p, normed)
        new_cache = {"C": c_f, "n": n_f, "m": m_f}  # built prefill→decode cache
    else:
        # Recurrent decode step (B,1,d).
        c_prev, n_prev, m_prev = cache["C"], cache["n"], cache["m"]
        q = jnp.einsum("bsd,dhk->bshk", normed, p["wq"])[:, 0] / jnp.sqrt(hd)
        k = jnp.einsum("bsd,dhk->bshk", normed, p["wk"])[:, 0] / jnp.sqrt(hd)
        v = jnp.einsum("bsd,dhk->bshk", normed, p["wv"])[:, 0]
        i_pre, log_f = _mlstm_gates(p, normed)
        i_pre, log_f = i_pre[:, 0], log_f[:, 0]  # (B,H)
        m_t = jnp.maximum(log_f + m_prev, i_pre)
        w_f = jnp.exp(log_f + m_prev - m_t)
        w_i = jnp.exp(i_pre - m_t)
        c_t = c_prev * w_f[..., None, None] + w_i[..., None, None] * jnp.einsum(
            "bhk,bhl->bhkl", v.astype(jnp.float32), k.astype(jnp.float32)
        )
        n_t = n_prev * w_f[..., None] + w_i[..., None] * k.astype(jnp.float32)
        num = jnp.einsum("bhkl,bhl->bhk", c_t, q.astype(jnp.float32))
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n_t, q.astype(jnp.float32)))
        h_t = num / jnp.maximum(den, jnp.exp(-m_t))[..., None]
        hs = h_t[:, None].astype(x.dtype)
        new_cache = {"C": c_t, "n": n_t, "m": m_t}

    o_gate = jax.nn.sigmoid(normed @ p["w_o"])
    attn_out = jnp.einsum("bshk,hkd->bsd", hs, p["wo"]) * o_gate
    y = x + attn_out
    # Position-wise up/down projection (the block's internal 2× FFN).
    y = y + jax.nn.gelu(y @ p["w_up"], approximate=True) @ p["w_down"]
    return y, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_block(
    cfg: ModelConfig, p: dict, x: jax.Array, *, cache: dict | None = None
) -> tuple[jax.Array, dict | None]:
    from repro.models.common import apply_norm

    b, s, d = x.shape
    h = cfg.num_heads
    hd = d // h
    normed = apply_norm(
        cfg.norm_kind, {k[5:]: v for k, v in p.items() if k.startswith("norm_")}, x
    )
    zx = normed @ p["w_z"] + p["b_z"]
    ix = normed @ p["w_i"] + p["b_i"]
    fx = normed @ p["w_f"] + p["b_f"]
    ox = normed @ p["w_o"] + p["b_o"]

    def blockdiag(hvec: jax.Array, r: jax.Array) -> jax.Array:
        return jnp.einsum("bhk,hkl->bhl", hvec.reshape(b, h, hd), r).reshape(b, d)

    def step(carry, inp):
        c_prev, n_prev, h_prev, m_prev = carry
        zx_t, ix_t, fx_t, ox_t = inp  # (B,d)
        z = jnp.tanh(zx_t + blockdiag(h_prev, p["r_z"]))
        i_pre = ix_t + blockdiag(h_prev, p["r_i"])
        f_pre = fx_t + blockdiag(h_prev, p["r_f"])
        o = jax.nn.sigmoid(ox_t + blockdiag(h_prev, p["r_o"]))
        log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
        m_t = jnp.maximum(log_f + m_prev, i_pre.astype(jnp.float32))
        i_g = jnp.exp(i_pre.astype(jnp.float32) - m_t)
        f_g = jnp.exp(log_f + m_prev - m_t)
        c_t = f_g * c_prev + i_g * z.astype(jnp.float32)
        n_t = f_g * n_prev + i_g
        h_t = (o.astype(jnp.float32) * c_t / jnp.maximum(n_t, 1e-6)).astype(x.dtype)
        return (c_t, n_t, h_t, m_t), h_t

    if cache is None:
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.zeros((b, d), jnp.float32)
        h0 = jnp.zeros((b, d), x.dtype)
        m0 = jnp.zeros((b, d), jnp.float32)
    else:
        c0, n0, h0, m0 = cache["c"], cache["n"], cache["h"], cache["m"]

    xs = (
        zx.swapaxes(0, 1),
        ix.swapaxes(0, 1),
        fx.swapaxes(0, 1),
        ox.swapaxes(0, 1),
    )
    (c_f, n_f, h_f, m_f), hs = jax.lax.scan(step, (c0, n0, h0, m0), xs)
    hs = hs.swapaxes(0, 1)  # (B,S,d)
    out = x + hs @ p["w_proj"]
    new_cache = {"c": c_f, "n": n_f, "h": h_f, "m": m_f}
    return out, new_cache

"""RecurrentGemma RG-LRU block (arXiv:2402.19427).

Block structure (Griffin recurrent block):

    x ─ norm ─┬─ linear → GeLU ───────────────────┐
              └─ linear → conv1d(4) → RG-LRU ──────┤⊙ → linear → + residual

RG-LRU recurrence (per channel):

    r_t = σ(W_a x_t + b_a)                    recurrence gate
    i_t = σ(W_x x_t + b_x)                    input gate
    a_t = exp(−c · softplus(Λ) · r_t)         gated decay, a ∈ (0,1)
    h_t = a_t · h_{t−1} + √(1 − a_t²) · (i_t ⊙ x_t)

TPU adaptation: the GPU reference uses a fused linear-scan CUDA kernel; here
the training/prefill path is a ``jax.lax.associative_scan`` over (a, b) pairs
(log-depth on the VPU) with a Pallas blocked-scan kernel as the TPU hot-spot
implementation (repro.kernels.rglru_scan); decode is the O(1) step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, norm_specs

RGLRU_C = 8.0  # the paper's fixed decay temperature
CONV_WIDTH = 4


def rglru_specs(cfg: ModelConfig) -> dict[str, ParamSpec]:
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "w_gate_branch": ParamSpec((d, w), ("embed", "lru")),
        "w_x_branch": ParamSpec((d, w), ("embed", "lru")),
        "conv_w": ParamSpec((CONV_WIDTH, w), (None, "lru")),
        "conv_b": ParamSpec((w,), ("lru",), init="zeros"),
        "w_a": ParamSpec((w, w), ("lru", None)),
        "b_a": ParamSpec((w,), (None,), init="zeros"),
        "w_i": ParamSpec((w, w), ("lru", None)),
        "b_i": ParamSpec((w,), (None,), init="zeros"),
        "lam": ParamSpec((w,), (None,), init="ones"),  # Λ (softplus → decay)
        "w_out": ParamSpec((w, d), ("lru", "embed")),
        **{f"norm_{k}": v for k, v in norm_specs(cfg.norm_kind, d).items()},
    }


def _decay(p: dict, gated_x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Return (a_t, gated input b_t) for the recurrence h = a·h⁻ + b."""
    r = jax.nn.sigmoid(gated_x @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(gated_x @ p["w_i"] + p["b_i"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r  # (…, w)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i * gated_x)
    return a, b


def conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width 4.  x (B,S,W); w (4,W)."""
    pads = jnp.pad(x, ((0, 0), (CONV_WIDTH - 1, 0), (0, 0)))
    out = sum(
        pads[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(CONV_WIDTH)
    )
    return out + b


def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array | None = None) -> jax.Array:
    """h_t = a_t h_{t−1} + b_t over axis 1, via associative scan."""
    if h0 is not None:
        # Fold h0 into the first step: b_0 += a_0 · h0
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_forward(
    cfg: ModelConfig, p: dict, x_branch: jax.Array, h0: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Sequence form.  x_branch (B,S,W) post-conv; returns (h_seq, h_last)."""
    a, b = _decay(p, x_branch.astype(jnp.float32))
    h = rglru_scan(a, b, h0)
    return h.astype(x_branch.dtype), h[:, -1, :]


def rglru_step(
    cfg: ModelConfig, p: dict, x_t: jax.Array, h_prev: jax.Array
) -> jax.Array:
    """Decode step.  x_t (B,W); h_prev (B,W) → h_t."""
    a, bb = _decay(p, x_t.astype(jnp.float32))
    return (a * h_prev + bb).astype(x_t.dtype)


def rglru_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    """Full Griffin recurrent block.  x (B,S,d).

    With ``cache`` (decode): uses/updates {"h": (B,W), "conv": (B,3,W)}.
    """
    from repro.models.common import apply_norm

    normed = apply_norm(
        cfg.norm_kind,
        {k[5:]: v for k, v in p.items() if k.startswith("norm_")},
        x,
    )
    gate = jax.nn.gelu(normed @ p["w_gate_branch"], approximate=True)
    xb = normed @ p["w_x_branch"]

    if cache is None:
        xb_conv = conv1d_causal(xb, p["conv_w"], p["conv_b"])
        h, h_last = rglru_forward(cfg, p, xb_conv)
        out = (gate * h) @ p["w_out"]
        # Built decode cache: final recurrent state + last 3 raw conv inputs.
        s = xb.shape[1]
        if s >= 3:
            conv_buf = xb[:, -3:, :]
        else:
            conv_buf = jnp.pad(xb, ((0, 0), (3 - s, 0), (0, 0)))
        built = {"h": h_last.astype(jnp.float32), "conv": conv_buf}
        return x + out, built

    # Decode: xb (B,1,W). Conv over the rolling buffer of the last 3 inputs.
    xb_t = xb[:, 0, :]
    conv_buf = cache["conv"]  # (B, 3, W) — previous inputs, oldest first
    window = jnp.concatenate([conv_buf, xb_t[:, None, :]], axis=1)  # (B,4,W)
    conv_out = jnp.einsum("bcw,cw->bw", window, p["conv_w"]) + p["conv_b"]
    h_t = rglru_step(cfg, p, conv_out, cache["h"])
    out = (gate[:, 0, :] * h_t) @ p["w_out"]
    new_cache = {"h": h_t, "conv": window[:, 1:, :]}
    return x + out[:, None, :], new_cache

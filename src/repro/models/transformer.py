"""Model assembly: any assigned architecture from its block pattern.

Layers are stacked with ``jax.lax.scan`` over *pattern cycles* (params carry a
leading ``cycles`` dim), so HLO size and compile time are flat in depth —
essential for dry-running 40-layer models on 512 virtual devices.  Remainder
blocks (e.g. recurrentgemma's trailing two RG-LRU layers) run outside the
scan.  Remat (``jax.checkpoint``) wraps the cycle body per config policy.

Three public step builders:

* ``make_train_step``  — loss + grads + optimizer update (training shapes)
* ``make_prefill_step``— forward + cache construction (prefill shapes)
* ``make_serve_step``  — one-token decode against a cache (decode shapes)
"""

from __future__ import annotations

import dataclasses

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ATTN,
    ATTN_MOE,
    LOCAL_ATTN,
    MLSTM,
    RGLRU,
    SLSTM,
    ModelConfig,
)
from repro.models import kvcache as kv
from repro.models.common import (
    ParamSpec,
    apply_norm,
    constrain,
    init_from_specs,
    norm_specs,
    softcap,
)
from repro.models.layers import (
    attention,
    attn_specs,
    decode_attention,
    full_attention,
    mlp_forward,
    mlp_specs,
    position_encode,
    qkv_project,
)
from repro.models.moe import moe_forward, moe_specs
from repro.models.rglru import rglru_block, rglru_specs
from repro.models.xlstm import mlstm_block, mlstm_specs, slstm_block, slstm_specs


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _block_specs(cfg: ModelConfig, kind: str, *, with_cross: bool = False) -> dict:
    if kind == ATTN:
        s = {"attn": attn_specs(cfg), "mlp": mlp_specs(cfg)}
    elif kind == ATTN_MOE:
        s = {"attn": attn_specs(cfg), "moe": moe_specs(cfg)}
    elif kind == LOCAL_ATTN:
        s = {"attn": attn_specs(cfg), "mlp": mlp_specs(cfg)}
    elif kind == RGLRU:
        s = {"rglru": rglru_specs(cfg), "mlp": mlp_specs(cfg)}
    elif kind == MLSTM:
        s = {"mlstm": mlstm_specs(cfg)}
    elif kind == SLSTM:
        s = {"slstm": slstm_specs(cfg)}
    else:
        raise ValueError(kind)
    if with_cross:
        s["cross"] = attn_specs(cfg, cross=True)
    return s


def _stack_spec(spec: ParamSpec, n: int) -> ParamSpec:
    return ParamSpec(
        shape=(n, *spec.shape),
        logical=("layers", *spec.logical),
        init=spec.init,
        scale=spec.scale,
    )


def param_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    # NB: the embedding's d_model dim is deliberately NOT FSDP-sharded —
    # sharding it over "data" makes the (un)embedding contraction conflict
    # with batch-over-data activations and GSPMD de-shards the batch
    # (full-batch f32 logits all-gathers; §Perf iteration 1).
    specs: dict[str, Any] = {
        "embed": ParamSpec((v, d), ("vocab", "embed_nofsdp")),
        "final_norm": norm_specs(cfg.norm_kind, d),
        "blocks": [],
        "rem_blocks": [],
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((d, v), ("embed_nofsdp", "vocab"))
    if cfg.rope_kind == "learned":
        specs["pos_embed"] = ParamSpec((cfg.max_seq_len, d), (None, "embed"))
    with_cross = cfg.is_encdec
    for kind in cfg.pattern:
        blk = _block_specs(cfg, kind, with_cross=with_cross)
        specs["blocks"].append(
            jax.tree.map(
                lambda s: _stack_spec(s, cfg.cycles),
                blk,
                is_leaf=lambda x: isinstance(x, ParamSpec),
            )
        )
    for kind in cfg.remainder:
        specs["rem_blocks"].append(_block_specs(cfg, kind, with_cross=with_cross))
    if cfg.is_encdec:
        enc_blk = _block_specs(cfg, ATTN)
        specs["encoder"] = {
            "blocks": jax.tree.map(
                lambda s: _stack_spec(s, cfg.encoder_layers),
                enc_blk,
                is_leaf=lambda x: isinstance(x, ParamSpec),
            ),
            "final_norm": norm_specs(cfg.norm_kind, d),
            "pos_embed": ParamSpec((1 << 16, d), (None, "embed")),
        }
    return specs


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    return init_from_specs(param_specs(cfg), key, dtype)


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------


def _norms(p: dict) -> dict:
    return {k[5:]: v for k, v in p.items() if k.startswith("norm_")}


def _attn_part(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    cache: Optional[dict],
    decode_positions: Optional[jax.Array],
) -> tuple[jax.Array, dict]:
    """Attention sublayer.  Returns (residual-added x, built/updated cache)."""
    h = apply_norm(cfg.norm_kind, _norms(p), x)
    q, k_, v_ = qkv_project(cfg, p, h)
    if cache is None:
        q, k_ = position_encode(cfg, q, k_, positions)
        out = attention(
            q, k_, v_, causal=causal, window=window, max_full_seq=cfg.full_attn_max_seq
        )
        new_cache = {"k": k_, "v": v_}  # full-sequence kv = prefill-built cache
    else:
        pos = decode_positions  # (B,)
        q, k_ = position_encode(cfg, q, k_, pos[:, None])
        ck, cv = kv.update_kv(cache["k"], cache["v"], k_, v_, pos)
        out = decode_attention(q, ck, cv, pos + 1, window=window)
        new_cache = {"k": ck, "v": cv}
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return x, new_cache


def _cross_part(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    encoder_out: Optional[jax.Array],
    cross_cache: Optional[dict],
) -> tuple[jax.Array, Optional[dict]]:
    h = apply_norm(cfg.norm_kind, _norms(p), x)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    if cross_cache is not None:
        ck, cv = cross_cache["k"], cross_cache["v"]
    else:
        assert encoder_out is not None
        ck = jnp.einsum("bsd,dhk->bshk", encoder_out, p["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", encoder_out, p["wv"])
    out = full_attention(q, ck, cv, causal=False)
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return x, {"k": ck, "v": cv}


def block_forward(
    cfg: ModelConfig,
    kind: str,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Optional[dict] = None,
    decode_positions: Optional[jax.Array] = None,
    encoder_out: Optional[jax.Array] = None,
    cross_cache: Optional[dict] = None,
    causal: bool = True,
) -> tuple[jax.Array, dict, jax.Array, Optional[dict]]:
    """Returns (x, built/updated cache, aux_loss, built cross cache).

    In sequence mode (cache=None) the returned cache is the *built* decode
    cache (full-sequence kv for attention kinds, final state for recurrent
    kinds); in decode mode it is the updated cache.
    """
    aux = jnp.zeros((), jnp.float32)
    new_cross: Optional[dict] = None

    if kind in (ATTN, ATTN_MOE, LOCAL_ATTN):
        window = cfg.local_window if kind == LOCAL_ATTN else None
        x, new_cache = _attn_part(
            cfg,
            p["attn"],
            x,
            positions,
            causal=causal,
            window=window,
            cache=cache,
            decode_positions=decode_positions,
        )
        if "cross" in p:
            x, new_cross = _cross_part(
                cfg, p["cross"], x, encoder_out=encoder_out, cross_cache=cross_cache
            )
        if kind == ATTN_MOE:
            h = apply_norm(cfg.norm_kind, _norms(p["moe"]), x)
            moe_out, stats = moe_forward(cfg, p["moe"], h, return_router_stats=True)
            x = x + moe_out
            # Router z-loss-style aux kept tiny; recorded for the controller.
            aux = aux + 1e-3 * jnp.mean(
                jnp.square(jax.nn.logsumexp(stats["router_logits"], axis=-1))
            )
        else:
            h = apply_norm(cfg.norm_kind, _norms(p["mlp"]), x)
            x = x + mlp_forward(cfg, p["mlp"], h)
    elif kind == RGLRU:
        x, new_cache = rglru_block(cfg, p["rglru"], x, cache=cache)
        h = apply_norm(cfg.norm_kind, _norms(p["mlp"]), x)
        x = x + mlp_forward(cfg, p["mlp"], h)
    elif kind == MLSTM:
        x, new_cache = mlstm_block(cfg, p["mlstm"], x, cache=cache)
    elif kind == SLSTM:
        x, new_cache = slstm_block(cfg, p["slstm"], x, cache=cache)
    else:
        raise ValueError(kind)
    return x, new_cache, aux, new_cross


# ---------------------------------------------------------------------------
# Whole-model forward
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # -- embedding ---------------------------------------------------------
    def embed(self, params: dict, tokens: jax.Array) -> jax.Array:
        return params["embed"][tokens].astype(jnp.dtype(self.cfg.dtype))

    def unembed(self, params: dict, x: jax.Array) -> jax.Array:
        w = params["embed"].T if self.cfg.tie_embeddings else params["unembed"]
        logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
        return softcap(logits, self.cfg.logits_softcap)

    # -- encoder (whisper) ---------------------------------------------------
    def encode(self, params: dict, encoder_embeds: jax.Array) -> jax.Array:
        cfg = self.cfg
        enc = params["encoder"]
        s = encoder_embeds.shape[1]
        x = encoder_embeds + enc["pos_embed"][:s].astype(encoder_embeds.dtype)
        positions = jnp.arange(s)[None, :]

        def body(xc, layer_params):
            xc, _, _, _ = block_forward(
                cfg, ATTN, layer_params, xc, positions, causal=False
            )
            return xc, None

        body = _maybe_remat(cfg, body)
        x, _ = jax.lax.scan(body, x, enc["blocks"])
        return apply_norm(cfg.norm_kind, enc["final_norm"], x)

    # -- full-sequence forward (train / prefill) ------------------------------
    def forward(
        self,
        params: dict,
        *,
        tokens: Optional[jax.Array] = None,
        inputs_embeds: Optional[jax.Array] = None,
        encoder_embeds: Optional[jax.Array] = None,
        build_cache: bool = False,
        cache_capacity: Optional[int] = None,
    ) -> tuple[jax.Array, Optional[dict], jax.Array]:
        """Returns (logits, cache_or_None, aux_loss)."""
        cfg = self.cfg
        if inputs_embeds is not None:
            x = inputs_embeds.astype(jnp.dtype(cfg.dtype))
        else:
            x = self.embed(params, tokens)
        b, s = x.shape[:2]
        positions = jnp.arange(s)[None, :]
        if cfg.rope_kind == "learned":
            x = x + params["pos_embed"][:s].astype(x.dtype)

        encoder_out = None
        if cfg.is_encdec:
            assert encoder_embeds is not None
            encoder_out = self.encode(params, encoder_embeds)

        aux_total = jnp.zeros((), jnp.float32)

        x = constrain(x, "batch", "seq", None)

        # Scanned cycles.
        def cycle(carry, cycle_params):
            xc, aux = carry
            xc = constrain(xc, "batch", "seq", None)
            built_list = []
            cross_list = []
            for j, kind in enumerate(cfg.pattern):
                xc, built, a, cross = block_forward(
                    cfg,
                    kind,
                    cycle_params[j],
                    xc,
                    positions,
                    encoder_out=encoder_out,
                    causal=True,
                )
                aux = aux + a
                built_list.append(built)
                cross_list.append(cross)
            ys = (built_list, cross_list) if build_cache else None
            return (xc, aux), ys

        cycle = _maybe_remat(cfg, cycle)
        blocks_stacked = _as_tuple_tree(params["blocks"])
        (x, aux_total), ys = jax.lax.scan(cycle, (x, aux_total), blocks_stacked)

        # Remainder blocks.
        rem_built = []
        for j, kind in enumerate(cfg.remainder):
            x, built, a, _ = block_forward(
                cfg,
                kind,
                params["rem_blocks"][j],
                x,
                positions,
                encoder_out=encoder_out,
                causal=True,
            )
            aux_total = aux_total + a
            rem_built.append(built)

        x = apply_norm(cfg.norm_kind, params["final_norm"], x)
        x = constrain(x, "batch", "seq", None)
        logits = self.unembed(params, x)
        logits = constrain(logits, "batch", "seq", "vocab")

        cache = None
        if build_cache:
            cache = self._cache_from_built(ys, rem_built, s, cache_capacity or s)
        return logits, cache, aux_total

    def _cache_from_built(self, ys, rem_built, s, capacity) -> dict:
        """Assemble a decode cache from prefill by-products.

        Attention kinds: the full-sequence k/v *is* the cache (capacity ==
        prefill length for the assigned decode shapes); LOCAL_ATTN keeps the
        last ``window`` tokens, rolled so token t sits in ring slot t % W.
        Recurrent kinds: the final state returned by the block.
        """
        cfg = self.cfg
        cache: dict[str, Any] = {"scan": [], "rem": []}
        built_list, cross_list = ys if ys is not None else ([], [])

        def fix_local(entry: dict, stacked: bool) -> dict:
            w = min(cfg.local_window, capacity)
            seq_ax = 2 if stacked else 1
            out = {}
            for n in ("k", "v"):
                sliced = jax.lax.slice_in_dim(entry[n], s - w, s, axis=seq_ax)
                out[n] = jnp.roll(sliced, s % w, axis=seq_ax)
            return out

        def fix_full(entry: dict, stacked: bool) -> dict:
            # Grow the cache to `capacity` so decode at position s does not
            # wrap onto slot 0 (capacity == s would overwrite token 0).
            if capacity <= s:
                return entry
            seq_ax = 2 if stacked else 1
            pad = [(0, 0)] * entry["k"].ndim
            pad[seq_ax] = (0, capacity - s)
            return {n: jnp.pad(entry[n], pad) for n in ("k", "v")}

        for j, kind in enumerate(cfg.pattern):
            entry = built_list[j]
            if kind == LOCAL_ATTN:
                entry = fix_local(entry, stacked=True)
            elif kind in (ATTN, ATTN_MOE):
                entry = fix_full(entry, stacked=True)
            cache["scan"].append(entry)
        for j, kind in enumerate(cfg.remainder):
            entry = rem_built[j]
            if kind == LOCAL_ATTN:
                entry = fix_local(entry, stacked=False)
            elif kind in (ATTN, ATTN_MOE):
                entry = fix_full(entry, stacked=False)
            cache["rem"].append(entry)
        if cfg.is_encdec and cross_list and cross_list[0] is not None:
            cache["cross"] = cross_list[0]
        return cache

    # -- decode step -----------------------------------------------------------
    def decode_step(
        self,
        params: dict,
        cache: dict,
        tokens: jax.Array,
        positions: jax.Array,
    ) -> tuple[jax.Array, dict]:
        """One-token decode.  tokens (B,1); positions (B,)."""
        cfg = self.cfg
        x = self.embed(params, tokens)
        if cfg.rope_kind == "learned":
            x = x + params["pos_embed"][positions][:, None].astype(x.dtype)

        x = constrain(x, "cache_batch", None, None)

        def cycle(xc, inp):
            cycle_params, cycle_cache, cycle_cross = inp
            xc = constrain(xc, "cache_batch", None, None)
            new_caches = []
            for j, kind in enumerate(cfg.pattern):
                xc, nc, _, _ = block_forward(
                    cfg,
                    kind,
                    cycle_params[j],
                    xc,
                    positions[:, None],
                    cache=cycle_cache[j],
                    decode_positions=positions,
                    cross_cache=cycle_cross,
                )
                new_caches.append(nc)
            return xc, new_caches

        blocks_stacked = _as_tuple_tree(params["blocks"])
        cache_stacked = _as_tuple_tree(cache["scan"])
        cross = cache.get("cross")
        xs = (blocks_stacked, cache_stacked, cross)
        x, new_scan = jax.lax.scan(cycle, x, xs)

        new_rem = []
        for j, kind in enumerate(cfg.remainder):
            x, nc, _, _ = block_forward(
                cfg,
                kind,
                params["rem_blocks"][j],
                x,
                positions[:, None],
                cache=cache["rem"][j],
                decode_positions=positions,
                cross_cache=None,
            )
            new_rem.append(nc)

        x = apply_norm(cfg.norm_kind, params["final_norm"], x)
        logits = self.unembed(params, x)
        new_cache = {"scan": new_scan, "rem": new_rem}
        if cross is not None:
            new_cache["cross"] = cross
        return logits, new_cache

    # -- loss ---------------------------------------------------------------
    def loss(self, params: dict, batch: dict) -> jax.Array:
        logits, _, aux = self.forward(
            params,
            tokens=batch.get("tokens"),
            inputs_embeds=batch.get("inputs_embeds"),
            encoder_embeds=batch.get("encoder_embeds"),
        )
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return nll.mean() + aux


def _as_tuple_tree(lst: list) -> tuple:
    """lax.scan xs must be a pytree with arrays at leaves; lists are fine but
    convert to tuple for hashability of the structure."""
    return tuple(lst)


def _maybe_remat(cfg: ModelConfig, fn: Callable) -> Callable:
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Step builders (jit targets for training / dry-run)
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, optimizer) -> Callable:
    """(params, opt_state, batch) → (params, opt_state, metrics)."""
    model = Model(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
        gnorm = optimizer.global_norm(grads)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    model = Model(cfg)

    def prefill_step(params, batch):
        logits, cache, _ = model.forward(
            params,
            tokens=batch.get("tokens"),
            inputs_embeds=batch.get("inputs_embeds"),
            encoder_embeds=batch.get("encoder_embeds"),
            build_cache=True,
        )
        return logits[:, -1:], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    model = Model(cfg)

    def serve_step(params, cache, tokens, positions):
        return model.decode_step(params, cache, tokens, positions)

    return serve_step

"""Shared model primitives: norms, RoPE variants, initializers, and the
logical-axis sharding rules that map parameters onto the production mesh.

Sharding convention (GSPMD, MaxText-style): parameters carry *logical* axis
names; `logical_spec` resolves them to mesh axes via a rules table.  The
default rules implement TP over ``model`` + FSDP over ``data`` (ZeRO-3-ish:
params and optimizer state sharded over the data axis, all-gathered per layer
by XLA), with the ``pod`` axis as pure DP for gradients.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical axis rules
# ---------------------------------------------------------------------------

# logical axis name → mesh axis (or None = replicated)
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),  # activation batch
    "seq": None,  # sequence (sharded only under SP rules)
    "embed": "data",  # model width — FSDP shard
    "embed_nofsdp": None,
    "vocab": "model",  # vocab — TP shard
    "heads": "model",  # attention heads — TP shard
    "kv_heads": None,  # kv heads (often < model axis; replicate by default)
    "head_dim": None,
    "ff": "model",  # MLP hidden — TP shard
    "expert": "model",  # MoE experts — EP shard
    "layers": None,  # scan-stacked layer dim
    "lru": "model",  # recurrence width — TP shard
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "cache_heads": "model",
}

# Sequence-parallel override used by long-context shapes (see launch/dryrun).
SP_RULES = dict(DEFAULT_RULES, seq="model", cache_seq="model", cache_heads=None)


def logical_spec(
    axes: tuple[Optional[str], ...], rules: dict[str, Any] | None = None
) -> P:
    rules = rules or DEFAULT_RULES
    resolved = []
    for ax in axes:
        if ax is None:
            resolved.append(None)
        else:
            resolved.append(rules.get(ax))
    return P(*resolved)


# ---------------------------------------------------------------------------
# Activation sharding constraints (set during distributed lowering)
# ---------------------------------------------------------------------------

_ACTIVATION_RULES: list[Optional[dict]] = [None]
_ACTIVATION_MESH: list[Any] = [None]


class activation_rules:
    """Context manager: enable with_sharding_constraint on activations.

    The dry-run / production launchers trace step functions inside this
    context so GSPMD propagation stays pinned to the intended layouts (found
    necessary: tied-embedding contractions otherwise de-shard the batch axis
    and cascade full-batch all-reduces through the backward scan — §Perf
    iteration 1).  Also carries the mesh: ``get_abstract_mesh()`` is empty
    inside a jit trace under a plain ``with mesh:`` block, so shard_map-based
    layers (MoE expert parallelism) read the mesh from here.  On
    single-device CPU (tests) the context is never entered and `constrain`
    is a no-op.
    """

    def __init__(self, rules: dict, mesh: Any = None):
        self.rules = rules
        self.mesh = mesh

    def __enter__(self):
        _ACTIVATION_RULES.append(self.rules)
        _ACTIVATION_MESH.append(self.mesh)
        return self

    def __exit__(self, *exc):
        _ACTIVATION_RULES.pop()
        _ACTIVATION_MESH.pop()
        return False


def current_mesh():
    return _ACTIVATION_MESH[-1]


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    rules = _ACTIVATION_RULES[-1]
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_spec(tuple(axes), rules))


@dataclasses.dataclass
class ParamSpec:
    """A parameter: shape, dtype, logical axes, initializer."""

    shape: tuple[int, ...]
    logical: tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float = 1.0

    def initializer(self, key: jax.Array, dtype: jnp.dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        std = self.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape) * std).astype(dtype)


def tree_logical(tree_specs: Any) -> Any:
    """Map a tree of ParamSpec to its logical axes (for sharding resolution)."""
    return jax.tree.map(
        lambda s: s.logical, tree_specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def init_from_specs(tree_specs: Any, key: jax.Array, dtype: jnp.dtype) -> Any:
    leaves, treedef = jax.tree.flatten(
        tree_specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [s.initializer(k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    normed = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(
    x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(x.dtype)


def make_norm(kind: str) -> Callable[..., jax.Array]:
    return rms_norm if kind == "rmsnorm" else layer_norm


def norm_specs(kind: str, d: int) -> dict[str, ParamSpec]:
    if kind == "rmsnorm":
        return {"scale": ParamSpec((d,), (None,), init="zeros")}
    return {
        "scale": ParamSpec((d,), (None,), init="ones"),
        "bias": ParamSpec((d,), (None,), init="zeros"),
    }


def apply_norm(kind: str, params: dict, x: jax.Array) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params["bias"])


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # (..., S, 1, hd/2) → broadcast over heads
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(
        x.dtype
    )


def mrope_sections(head_dim: int) -> tuple[int, int, int]:
    """Split hd/2 rotary dims into (t, h, w) sections — qwen2-vl uses 16/24/24
    for hd=128; generalize proportionally."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return t, h, w


def apply_mrope(x: jax.Array, positions_thw: jax.Array, theta: float) -> jax.Array:
    """M-RoPE: positions_thw (..., S, 3) with temporal/height/width ids."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (half,)
    t, h, w = mrope_sections(hd)
    sec = jnp.concatenate(
        [jnp.zeros(t, jnp.int32), jnp.ones(h, jnp.int32), jnp.full(w, 2, jnp.int32)]
    )  # (half,) → which position stream drives each rotary dim
    pos = positions_thw.astype(jnp.float32)[..., sec]  # (..., S, half)
    angles = pos * freqs  # (..., S, half)
    angles = angles[..., None, :]  # broadcast over heads
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(
        x.dtype
    )


def text_mrope_positions(positions: jax.Array) -> jax.Array:
    """Text-only M-RoPE: all three streams share the token index."""
    return jnp.stack([positions] * 3, axis=-1)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    return jnp.tanh(logits / cap) * cap

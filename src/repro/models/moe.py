"""Mixture-of-Experts FFN with TPU-native sort-based dispatch.

Hardware adaptation (DESIGN.md §2): GPU MoE implementations scatter tokens
with CUDA kernels; the TPU-idiomatic equivalent is sort-based dispatch —
argsort tokens by expert, bucket into per-expert capacity slots, run a
batched (E, C, d) × (E, d, f) einsum on the MXU (repro.kernels.moe_gemm),
and combine with gather + weighted scatter-add.

Distribution: **expert parallelism via partial-manual shard_map** over the
``model`` mesh axis.  Tokens stay replicated across the model axis (their
batch dim is data-sharded by GSPMD's auto mode); each model shard routes all
tokens locally, computes only its E/ep local experts, and one psum over
``model`` combines contributions — the same per-layer collective volume as a
row-parallel dense MLP.  A pure-GSPMD formulation was measured first and
rejected: the global argsort de-shards the token stream and the dispatch
gather crosses the expert-sharded dim, costing ~23× useful FLOPs (§Perf log).

This is also where the paper's technique becomes first-class for the MoE
architectures: experts are *key groups* (repro.core), ``tokens_per_expert``
statistics feed ``gLoad_k``, and the controller's expert-placement decisions
permute the expert→shard assignment (repro/launch/serve.py).

Dispatch is per sequence (vmapped over batch): capacity C = S·k/E · cf per
row; overflow beyond C drops that expert's contribution for the token
(standard capacity-factor semantics); gates renormalized over the top-k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import (
    _ACTIVATION_RULES,
    ParamSpec,
    current_mesh,
    norm_specs,
)


def moe_specs(cfg: ModelConfig) -> dict[str, ParamSpec]:
    assert cfg.moe is not None
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    specs: dict[str, ParamSpec] = {
        "router": ParamSpec((d, e), ("embed_nofsdp", None)),
        # Expert weights: EP over "model" AND FSDP over "data" — without the
        # data shard, dbrx's expert optimizer state is 80 GB/device.
        "w_up": ParamSpec((e, d, f), ("expert", "embed", None)),
        "w_down": ParamSpec((e, f, d), ("expert", None, "embed")),
        **{f"norm_{k}": v for k, v in norm_specs(cfg.norm_kind, d).items()},
    }
    if cfg.mlp_kind in ("swiglu", "geglu"):
        specs["w_gate"] = ParamSpec((e, d, f), ("expert", "embed", None))
    return specs


def _activation(cfg: ModelConfig, gate: jax.Array, up: jax.Array) -> jax.Array:
    if cfg.mlp_kind == "swiglu":
        return jax.nn.silu(gate) * up
    if cfg.mlp_kind == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    return jax.nn.gelu(up, approximate=True)


def _row_dispatch_compute(
    cfg: ModelConfig,
    tokens: jax.Array,  # (S, d) one sequence
    router: jax.Array,
    w_gate: jax.Array | None,  # (E_loc, d, f)
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    lo: jax.Array | int,
    e_local: int,
    capacity: int,
) -> jax.Array:
    """Sort-based dispatch + compute for the experts in [lo, lo+e_local)."""
    moe = cfg.moe
    assert moe is not None
    s, d = tokens.shape
    e, k = moe.num_experts, moe.top_k

    logits = (tokens @ router).astype(jnp.float32)  # (S, E)
    gates, chosen = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gates, axis=-1)

    flat_e = chosen.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # Position within the expert bucket (stable sort ⇒ earlier tokens win).
    pos = jnp.arange(s * k) - jnp.searchsorted(se, se, side="left")

    local = (se >= lo) & (se < lo + e_local) & (pos < capacity)
    n_slots = e_local * capacity
    # Out-of-range writes use index n_slots and are dropped.
    slot = jnp.where(local, (se - lo) * capacity + pos, n_slots)
    used = jnp.zeros((n_slots,), bool).at[slot].set(True, mode="drop")
    gate_slot = jnp.zeros((n_slots,), jnp.float32).at[slot].set(sg, mode="drop")
    tok_slot = jnp.zeros((n_slots,), jnp.int32).at[slot].set(st, mode="drop")

    xin = tokens[tok_slot] * used[:, None].astype(tokens.dtype)
    xin = xin.reshape(e_local, capacity, d)
    up = jnp.einsum("ecd,edf->ecf", xin, w_up)
    if w_gate is not None:
        h = _activation(cfg, jnp.einsum("ecd,edf->ecf", xin, w_gate), up)
    else:
        h = _activation(cfg, up, up)
    expert_out = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(n_slots, d)

    contrib = expert_out.astype(jnp.float32) * (gate_slot * used)[:, None]
    out = jnp.zeros((s, d), jnp.float32).at[tok_slot].add(contrib)
    return out.astype(tokens.dtype)


def _moe_local(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Portable single-shard path (all experts local)."""
    moe = cfg.moe
    b, s, d = x.shape
    capacity = max(int(s * moe.top_k / moe.num_experts * moe.capacity_factor), 1)
    row = lambda tokens: _row_dispatch_compute(
        cfg,
        tokens,
        p["router"],
        p.get("w_gate"),
        p["w_up"],
        p["w_down"],
        lo=0,
        e_local=moe.num_experts,
        capacity=capacity,
    )
    return jax.vmap(row)(x)


def _moe_expert_parallel(
    cfg: ModelConfig, p: dict, x: jax.Array, rules: dict
) -> jax.Array:
    """Expert parallelism: fully-manual shard_map over the whole mesh.

    Layout (no GSPMD freedom — a pure-GSPMD and a partial-manual variant were
    both measured to all-reduce the f32 expert hiddens over data, 2.1 TB/layer
    on dbrx; see §Perf log):

      x        P(batch_axes, None, None)   tokens local to their data shard
      router   P()                          replicated (d×E is tiny)
      w_*      P("model", "data", None)     EP over model + ZeRO over data
      body:    all_gather w over "data"  →  (e_loc, d, f)     [ZeRO gather]
               sort-dispatch + expert einsums for local experts
               psum over "model"            [row-parallel combine]
    """
    moe = cfg.moe
    mesh = current_mesh()
    ep = mesh.shape["model"]
    e_local = moe.num_experts // ep
    s = x.shape[1]
    capacity = max(int(s * moe.top_k / moe.num_experts * moe.capacity_factor), 1)
    batch_axes = rules.get("batch")
    fsdp = rules.get("embed") is not None

    w_gate = p.get("w_gate")
    has_gate = w_gate is not None
    w_spec = P("model", "data" if fsdp else None, None)

    def body(x_, router, *ws):
        if fsdp:
            ws = tuple(jax.lax.all_gather(w, "data", axis=1, tiled=True) for w in ws)
        if has_gate:
            wg, wu, wd = ws
        else:
            wg, (wu, wd) = None, ws
        lo = jax.lax.axis_index("model") * e_local
        row = lambda tokens: _row_dispatch_compute(
            cfg, tokens, router, wg, wu, wd,
            lo=lo, e_local=e_local, capacity=capacity,
        )
        out = jax.vmap(row)(x_)
        return jax.lax.psum(out, "model")

    weights = (w_gate, p["w_up"], p["w_down"]) if has_gate else (p["w_up"], p["w_down"])
    in_specs = (P(batch_axes, None, None), P(), *([w_spec] * len(weights)))
    out_specs = P(batch_axes, None, None)
    if hasattr(jax, "shard_map"):
        mapped = jax.shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            axis_names=set(mesh.axis_names),
            check_vma=False,
        )
    else:  # jax < 0.6: shard_map lives in experimental, check flag named check_rep
        from jax.experimental.shard_map import shard_map as _shard_map

        mapped = _shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
        )
    return mapped(x, p["router"], *weights)


def moe_forward(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    return_router_stats: bool = False,
):
    """x: (B, S, d) → (B, S, d) [, router stats for the controller]."""
    moe = cfg.moe
    assert moe is not None

    rules = _ACTIVATION_RULES[-1]
    mesh = current_mesh()
    use_ep = (
        rules is not None
        and rules.get("expert") == "model"
        and mesh is not None
        and "model" in mesh.shape
        and moe.num_experts % mesh.shape["model"] == 0
    )
    if use_ep:
        out = _moe_expert_parallel(cfg, p, x, rules)
    else:
        out = _moe_local(cfg, p, x)

    if return_router_stats:
        logits = (x.reshape(-1, x.shape[-1]) @ p["router"]).astype(jnp.float32)
        _, chosen = jax.lax.top_k(logits, moe.top_k)
        tokens_per_expert = jnp.bincount(chosen.reshape(-1), length=moe.num_experts)
        return out, {"tokens_per_expert": tokens_per_expert, "router_logits": logits}
    return out


def load_balancing_loss(
    router_logits: jax.Array, chosen: jax.Array, e: int
) -> jax.Array:
    """Switch-style auxiliary loss (density × mean gate probability)."""
    probs = jax.nn.softmax(router_logits, axis=-1)  # (T, E)
    density = jnp.mean(jax.nn.one_hot(chosen[..., 0], e, dtype=probs.dtype), axis=0)
    return e * jnp.sum(density * probs.mean(axis=0))

"""The compiled operator tier: ``fn_jit`` bodies as jitted segment programs.

This module is the runtime behind ``OperatorSpec.fn_jit`` — the third
execution tier after the per-run ``fn`` and the segment-vectorized numpy
``fn_seg``.  A jit-tier operator's body is a *pure JAX function over column
arrays*; the runtime

* keeps the operator's declared :class:`~repro.engine.topology.StateSchema`
  in preallocated **device columns** — per-key-group scalar vectors and
  keyed-accumulator tables — instead of the python ``store`` dicts,
* compiles each body **once per (operator, padding bucket)**: segment tuple
  counts and run counts are padded to power-of-two buckets, so a long run
  with varied batch sizes compiles O(#buckets) programs, not O(#ticks)
  (``EngineMetrics.jit_compiles`` pins this),
* executes a node's whole drained contiguous slice in **one ``jax.jit``
  call** per (node, operator) with the state pytree donated (tables update
  in place), and
* when a mesh is configured, runs the same body as **one ``shard_map``
  shard per node-axis device**: the segment's runs are sharded across the
  axis (run → key group → disjoint state rows), per-shard state/output
  deltas are merged with ``psum``-of-masked selects, so the merged result
  is bit-identical to the unsharded call.

Coherence with the interpreted tiers: the python ``store`` dict and the
device columns hold the *same* state in two layouts.  Exactly one of them is
authoritative per key group at any time.  A jit call flips its key groups to
column-authoritative (pushing any dict-authoritative state in first); the
engine's per-run ``fn`` fallbacks (partial budgets, non-contiguous
migration rebuilds) and the migration codec call :meth:`JitRuntime.ensure_dict`
first, which materializes the columns back into the dict — including the
keyed tables' **insertion order** (each entry carries its insertion sequence
number) — so σ_k pickles, ``kg_state_bytes`` and the conformance state
comparison see exactly the dict the per-run oracle would have produced.

Float-tolerance policy: integer columns, single float operations and the
first addend of every running sum are bit-exact; *multi-term float
reductions* (``jnp.cumsum`` inside :func:`keyed_running_sum`) may diverge
from the oracle's strict left-to-right association in the last bits — the
conformance harness compares the jit configuration with a documented
``rtol=1e-9`` on floats for exactly this reason (see tests/conformance.py
and docs/operator_authoring.md).
"""

from __future__ import annotations

import time
import weakref
from typing import NamedTuple, Optional

import numpy as np

import jax

# The jit tier carries the engine's float64/int64 payloads through XLA
# unchanged; without x64 every f8 column would silently truncate to f32 and
# no tolerance policy could be honest about it.  NOTE: this flips dtype
# semantics PROCESS-WIDE for all jax code — which is why the engine imports
# this module eagerly at ``Engine(use_fn_jit=True)`` construction (the
# explicit opt-in), never lazily mid-run, and why in-repo kernels pin their
# accumulator dtypes (see keygroup_partition's histogram sum).
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402  (after the x64 flag, deliberately)

from repro.engine.topology import StateField, Topology  # noqa: E402

# Sentinel for unused table slots and padding tuple codes.  Real codes must
# be < EMPTY_CODE (any keyed state built from finite attributes is).
EMPTY_CODE = np.iinfo(np.int64).max

_MIN_TUPLE_BUCKET = 16
_MIN_RUN_BUCKET = 4
_MIN_TABLE_CAP = 64


def _bucket(x: int, lo: int) -> int:
    b = lo
    while b < x:
        b <<= 1
    return b


class TableState(NamedTuple):
    """One keyed-accumulator state field: a flat append-ordered table.

    Entries of *all* key groups share one capacity-``S`` slab (codes are
    globally unique — a code determines its key group — so no per-key-group
    partitioning is needed): ``codes``/``vals``/``owner`` hold the entries
    in insertion order (``cnt`` used, :data:`EMPTY_CODE` beyond), ``seq``
    carries ``epoch << 32 | first_position`` — monotone in insertion order
    across calls, which is what reproduces the oracle dicts' insertion
    order without ranking new entries per key group — and ``perm`` is the
    code-sorted permutation of the slab, maintained *incrementally* by the
    merge in :func:`keyed_running_sum` (new codes arrive pre-sorted from
    the segment sort, so keeping the view sorted costs searchsorted +
    prefix sums, never a table-sized sort).
    """

    codes: jax.Array  # (S,) int64, insertion order
    vals: jax.Array  # (S,) value dtype
    seq: jax.Array  # (S,) int64: epoch << 32 | first position
    owner: jax.Array  # (S,) int32 key group of each entry
    perm: jax.Array  # (S,) int32: slab indices in code-sorted order
    cnt: jax.Array  # () int32 used entries
    epoch: jax.Array  # () int64 call counter (seq high bits)


class VectorState(NamedTuple):
    """One bounded-window state field: a per-key-group ring of cells.

    ``data[k, :cnt[k]]`` holds key group ``k``'s window oldest-first — the
    exact list the per-run oracle keeps (``{name: [..]}``), so
    materialization is a slice, not a reconstruction.  Bodies shift the
    window with gathers (see the conformance suite's sliding-count-window
    port); under key-group-sharded ``shard_map`` both leaves carry the
    key-group leading axis and merge by the touched mask like scalars.
    """

    data: jax.Array  # (K, length) value dtype, oldest-first per key group
    cnt: jax.Array  # (K,) int32 occupancy


# --------------------------------------------------------------------------
# fn_jit authoring helpers (pure JAX; shape-polymorphic over padding and
# shard_map run-slices — validity always derives from the run bounds).
# --------------------------------------------------------------------------


def tuple_valid(starts: jax.Array, ends: jax.Array, nb: int) -> jax.Array:
    """Per-position validity of the (padded) tuple arrays.

    Runs tile a contiguous slice, and padding runs (``start == end`` at the
    real tuple count) are a suffix, so the valid positions are exactly
    ``[starts[0], ends[-1])`` — under shard_map run-sharding each shard's
    slice of the run arrays yields exactly its own tuple range.
    """
    pos = jnp.arange(nb)
    return (pos >= starts[0]) & (pos < ends[-1])


def run_of_tuples(ends: jax.Array, nb: int) -> jax.Array:
    """Run index per tuple position (meaningful where ``tuple_valid``)."""
    pos = jnp.arange(nb)
    idx = jnp.searchsorted(ends, pos, side="right")
    return jnp.minimum(idx, ends.shape[0] - 1)


def count_runs(col: jax.Array, kgs, starts, ends) -> jax.Array:
    """Scalar-counter update: add each run's length to its key group's cell.

    Padding runs carry ``kg == K`` (out of range → dropped) and zero length.
    """
    return col.at[kgs].add(
        (ends - starts).astype(col.dtype), mode="drop"
    )


def keyed_running_sum(
    table: TableState,
    codes: jax.Array,
    kg: jax.Array,
    addends: jax.Array,
    valid: jax.Array,
    order: Optional[jax.Array] = None,
) -> tuple[TableState, jax.Array]:
    """Grouped running sums over one segment, against the keyed table.

    For every tuple ``i``: looks up ``codes[i]`` in the flat table, adds the
    within-segment prefix of its group's ``addends`` and returns the
    per-tuple running totals; new codes are appended to the slab with
    ``seq = epoch << 32 | first_position`` — monotone in first-occurrence
    order, which is exactly the order the per-run oracle inserts them into
    its dicts.  Requirements: equal codes always map to the same key group
    (key the table by the operator's partition key), and real codes are
    non-negative and < 2^63 − 1.

    Cost: ONE stable sort of the segment (the only comparison sort — the
    table's code-sorted view is maintained incrementally by merging the
    segment's pre-sorted new codes: searchsorted + prefix sums), plus
    O(segment + capacity) gathers/scatters.  The within-group prefix is
    computed via ``jnp.cumsum`` — the one place the jit tier's floats may
    diverge from the oracle's left-to-right association (module docstring's
    tolerance policy); group heads take ``base + addend`` directly, so
    singleton groups (mostly-unique keys) stay bit-exact end to end.

    ``order`` is the *pre-sorted order handoff*: the caller may pass the
    stable argsort of ``where(valid, codes, EMPTY_CODE)`` computed outside
    the trace (on CPU, numpy's radix argsort via
    ``repro.kernels.radix_sort.bucket_argsort`` beats XLA's comparison
    sort), and the kernel skips its own sort.  The permutation must be
    exactly the stable argsort — stability defines it uniquely, so any
    conforming producer yields bit-identical results.
    """
    nb = codes.shape[0]
    cap = table.codes.shape[0]
    mcodes = jnp.where(valid, codes, EMPTY_CODE)
    if order is None:
        order = jnp.argsort(mcodes)  # stable: ties keep original tuple order
    sc = mcodes[order]
    real = sc != EMPTY_CODE
    sk = jnp.where(real, kg[order], 0)
    sa = jnp.where(real, addends[order], jnp.zeros((), addends.dtype))
    head = jnp.concatenate([jnp.ones(1, bool), sc[1:] != sc[:-1]])
    # Lookup through the maintained code-sorted view.
    scodes = table.codes[table.perm]  # (cap,) sorted, EMPTY tail
    pos = jnp.minimum(jnp.searchsorted(scodes, sc), cap - 1)
    fidx = table.perm[pos].astype(jnp.int64)  # candidate slab index
    has = (scodes[pos] == sc) & real
    base = jnp.where(has, table.vals[fidx], jnp.zeros((), table.vals.dtype))
    # Within-group inclusive prefix of the addends.
    csum = jnp.cumsum(sa)
    seg = jnp.cumsum(head) - 1  # group index per sorted position
    gstart = (
        jnp.zeros(nb, csum.dtype)
        .at[jnp.where(head, seg, nb)]
        .set(jnp.where(head, csum - sa, 0), mode="drop")
    )
    running_sorted = jnp.where(head, base + sa, base + (csum - gstart[seg]))
    running = jnp.zeros(nb, running_sorted.dtype).at[order].set(running_sorted)
    # ---- table update ----------------------------------------------------
    tail = jnp.concatenate([head[1:], jnp.ones(1, bool)])
    newhead = head & real & ~has
    nc_in = jnp.cumsum(newhead.astype(jnp.int64))  # inclusive new count
    total_new = nc_in[-1]
    rank = nc_in - 1  # code-order rank among new codes (valid at newheads)
    dest = table.cnt.astype(jnp.int64) + rank  # slab append position
    # Slab index per group (existing: the hit; new: the append slot),
    # broadcast from heads to the whole group.
    slab_head = jnp.where(has, fidx, dest)
    slabarr = (
        jnp.zeros(nb, slab_head.dtype)
        .at[jnp.where(head, seg, nb)]
        .set(jnp.where(head, slab_head, 0), mode="drop")
    )
    widx_all = slabarr[seg]
    wvalid = tail & real
    widx = jnp.where(wvalid, widx_all, cap)  # out of range → dropped
    codes2 = table.codes.at[widx].set(sc, mode="drop")
    vals2 = table.vals.at[widx].set(running_sorted, mode="drop")
    # seq/owner only change for new entries (scatter at newheads).
    nidx = jnp.where(newhead, dest, cap)
    seq_new = (table.epoch << jnp.int64(32)) | order
    seq2 = table.seq.at[nidx].set(seq_new, mode="drop")
    owner2 = table.owner.at[nidx].set(sk.astype(jnp.int32), mode="drop")
    # Merge the pre-sorted new codes into the sorted view.  Invariant: the
    # EMPTY tail of ``perm`` is ascending by slab index, so the entries the
    # append consumes are exactly the FIRST ``total_new`` EMPTY pointers.
    ncex = jnp.concatenate([jnp.zeros(1, jnp.int64), nc_in])  # exclusive
    is_empty_old = scodes == EMPTY_CODE
    shift_real = ncex[jnp.searchsorted(sc, scodes, side="left")]
    jemp = jnp.cumsum(is_empty_old.astype(jnp.int64)) - 1
    arange_cap = jnp.arange(cap)
    oldpos = jnp.where(
        is_empty_old,
        jnp.where(jemp < total_new, cap, arange_cap),  # consumed → dropped
        arange_cap + shift_real,
    )
    perm2 = (
        jnp.zeros(cap, table.perm.dtype)
        .at[oldpos]
        .set(table.perm, mode="drop")
    )
    npos = jnp.where(
        newhead, jnp.searchsorted(scodes, sc, side="left") + rank, cap
    )
    perm2 = perm2.at[npos].set(dest.astype(table.perm.dtype), mode="drop")
    return (
        TableState(
            codes2,
            vals2,
            seq2,
            owner2,
            perm2,
            table.cnt + total_new.astype(table.cnt.dtype),
            table.epoch + 1,
        ),
        running,
    )


# --------------------------------------------------------------------------
# Compile caches.  Keyed by the fn_jit *object* — declare bodies at module
# level (or memoize the factory) so topology factories reuse one identity
# and every engine in the process shares the compiled programs.
# --------------------------------------------------------------------------

_JITTED: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _jitted_plain(fn):
    entry = _JITTED.setdefault(fn, {})
    if "plain" not in entry:
        entry["plain"] = jax.jit(fn, donate_argnums=0)
    return entry["plain"]


def _shard_map(fn, mesh, in_specs, out_specs):
    """Version-portable shard_map (mirrors repro.models.moe's shim)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def _jitted_sharded(fn, mesh, axis):
    entry = _JITTED.setdefault(fn, {})
    key = ("shard", id(mesh), axis)
    if key not in entry:
        from jax.sharding import PartitionSpec as P

        def call(state, kgs, starts, ends, keys, values, ts):
            def shard(state_in, kgs_l, st_l, en_l, keys_r, values_r, ts_r):
                nb = keys_r.shape[0]
                state2, outputs, out_counts = fn(
                    state_in, kgs_l, st_l, en_l, keys_r, values_r, ts_r
                )
                if out_counts is not None:
                    raise ValueError(
                        "shard_map execution requires 1:1 (or output-free) "
                        "fn_jit bodies — out_counts must be None"
                    )
                leaves = jax.tree_util.tree_leaves(state_in)
                if leaves:
                    num_kg = leaves[0].shape[0]
                    touched = (
                        jnp.zeros(num_kg, bool).at[kgs_l].set(True, mode="drop")
                    )
                    t_any = (
                        jax.lax.psum(touched.astype(jnp.int32), axis) > 0
                    )

                    def merge(orig, new):
                        t = touched.reshape(
                            (num_kg,) + (1,) * (new.ndim - 1)
                        )
                        summed = jax.lax.psum(
                            jnp.where(t, new, jnp.zeros((), new.dtype)), axis
                        )
                        ta = t_any.reshape((num_kg,) + (1,) * (new.ndim - 1))
                        return jnp.where(ta, summed, orig)

                    state_m = jax.tree_util.tree_map(merge, state_in, state2)
                else:
                    state_m = state2
                if outputs is None:
                    return state_m, None
                ok = tuple_valid(st_l, en_l, nb)

                def omerge(o):
                    return jax.lax.psum(
                        jnp.where(ok, o, jnp.zeros((), o.dtype)), axis
                    )

                return state_m, jax.tree_util.tree_map(omerge, outputs)

            state_m, outputs = _shard_map(
                shard,
                mesh,
                in_specs=(P(), P(axis), P(axis), P(axis), P(), P(), P()),
                out_specs=P(),
            )(state, kgs, starts, ends, keys, values, ts)
            return state_m, outputs, None

        entry[key] = jax.jit(call)
    return entry[key]


def _jitted_sharded_tables(fn, mesh, axis, table_names):
    """Run-sharded execution for keyed-table operators.

    Tables are **key-group-sharded**: every :class:`TableState` leaf
    carries a leading shard axis ``(d, ...)`` and shard ``s`` owns exactly
    the key groups ``{k : k*d // nkg == s}``.  The host lays the run
    arrays out shard-major (each shard's runs tile a contiguous tuple
    block), so a shard updates only its own sub-table — no cross-shard
    merge is needed for tables, ownership *is* the merge.  Scalar columns
    and outputs merge exactly like :func:`_jitted_sharded` (psum of
    touched/valid-masked selects); a key group's runs land on one shard
    only, so the duplicate-key-group hazard of plain run-sharding cannot
    occur here.
    """
    entry = _JITTED.setdefault(fn, {})
    key = ("shard_tab", id(mesh), axis, table_names)
    if key not in entry:
        from jax.sharding import PartitionSpec as P

        def call(state, kgs, starts, ends, keys, values, ts):
            state_spec = {
                name: (
                    TableState(*([P(axis)] * 7))
                    if name in table_names
                    else P()
                )
                for name in state
            }

            def shard(state_in, kgs_l, st_l, en_l, keys_r, values_r, ts_r):
                nb = keys_r.shape[0]
                st_loc = {
                    name: (
                        TableState(*(x[0] for x in v))
                        if name in table_names
                        else v
                    )
                    for name, v in state_in.items()
                }
                state2, outputs, out_counts = fn(
                    st_loc, kgs_l, st_l, en_l, keys_r, values_r, ts_r
                )
                if out_counts is not None:
                    raise ValueError(
                        "shard_map execution requires 1:1 (or output-free) "
                        "fn_jit bodies — out_counts must be None"
                    )
                touched = None
                t_any = None
                out_state = {}
                for name, new in state2.items():
                    if name in table_names:
                        out_state[name] = TableState(*(x[None] for x in new))
                        continue
                    orig = state_in[name]
                    leaves_o = jax.tree_util.tree_leaves(orig)
                    num_kg = leaves_o[0].shape[0]
                    if touched is None:
                        touched = (
                            jnp.zeros(num_kg, bool)
                            .at[kgs_l]
                            .set(True, mode="drop")
                        )
                        t_any = (
                            jax.lax.psum(touched.astype(jnp.int32), axis) > 0
                        )

                    def merge(o, nw):
                        t = touched.reshape(
                            (num_kg,) + (1,) * (nw.ndim - 1)
                        )
                        summed = jax.lax.psum(
                            jnp.where(t, nw, jnp.zeros((), nw.dtype)), axis
                        )
                        ta = t_any.reshape((num_kg,) + (1,) * (nw.ndim - 1))
                        return jnp.where(ta, summed, o)

                    out_state[name] = jax.tree_util.tree_map(merge, orig, new)
                if outputs is None:
                    return out_state, None
                ok = tuple_valid(st_l, en_l, nb)

                def omerge(o):
                    return jax.lax.psum(
                        jnp.where(ok, o, jnp.zeros((), o.dtype)), axis
                    )

                return out_state, jax.tree_util.tree_map(omerge, outputs)

            state_m, outputs = _shard_map(
                shard,
                mesh,
                in_specs=(state_spec, P(axis), P(axis), P(axis), P(), P(), P()),
                out_specs=(state_spec, P()),
            )(state, kgs, starts, ends, keys, values, ts)
            return state_m, outputs, None

        entry[key] = jax.jit(call)
    return entry[key]


# --------------------------------------------------------------------------
# Per-operator runtime state.
# --------------------------------------------------------------------------


class _OpState:
    __slots__ = (
        "op",
        "spec",
        "base",
        "nkg",
        "fields",
        "has_tables",
        "has_vectors",
        "shards",
        "cols",
        "caps",
        "cnt_host",
        "col_auth",
        "value_names",
        "out_dtype",
        "out_names",
        "seen_keys",
    )

    def __init__(self, op: int, spec, base: int, shards: int = 0) -> None:
        self.op = op
        self.spec = spec
        self.base = base
        self.nkg = spec.num_keygroups
        self.fields: tuple[StateField, ...] = (
            spec.state_schema.fields if spec.state_schema is not None else ()
        )
        self.has_tables = any(f.kind == "table" for f in self.fields)
        self.has_vectors = any(f.kind == "vector" for f in self.fields)
        # > 0 ⇒ keyed tables live key-group-sharded: every TableState leaf
        # carries a leading (shards,) axis and cnt_host tracks per shard.
        self.shards = shards if self.has_tables else 0
        self.caps: dict[str, int] = {}
        self.cnt_host: dict[str, object] = {}
        self.col_auth = np.zeros(self.nkg, dtype=bool)
        cols = {}
        for f in self.fields:
            if f.kind == "scalar":
                cols[f.name] = jnp.full(self.nkg, f.init, dtype=f.dtype)
            elif f.kind == "vector":
                cols[f.name] = VectorState(
                    data=jnp.zeros((self.nkg, f.length), dtype=f.dtype),
                    cnt=jnp.zeros(self.nkg, dtype=jnp.int32),
                )
            else:
                cap = _MIN_TABLE_CAP
                self.caps[f.name] = cap
                if self.shards:
                    self.cnt_host[f.name] = np.zeros(self.shards, np.int64)
                    cols[f.name] = _stack_tables(
                        [_empty_table(cap, f.dtype)] * self.shards
                    )
                else:
                    self.cnt_host[f.name] = 0
                    cols[f.name] = _empty_table(cap, f.dtype)
        self.cols = cols
        self.value_names = (
            spec.schema.value.names if spec.schema is not None else None
        )
        out_schema = spec.out_schema
        self.out_dtype = None if out_schema is None else out_schema.value
        self.out_names = (
            None if out_schema is None else out_schema.value.names
        )
        self.seen_keys: set = set()

    def shard_of(self, lkgs: np.ndarray) -> np.ndarray:
        """Owning shard per local key group (monotone in the key group)."""
        return (np.asarray(lkgs, dtype=np.int64) * self.shards) // self.nkg


def _empty_table(cap: int, dtype) -> TableState:
    return TableState(
        codes=jnp.full(cap, EMPTY_CODE, dtype=jnp.int64),
        vals=jnp.zeros(cap, dtype=dtype),
        seq=jnp.zeros(cap, dtype=jnp.int64),
        owner=jnp.zeros(cap, dtype=jnp.int32),
        perm=jnp.arange(cap, dtype=jnp.int32),
        cnt=jnp.zeros((), dtype=jnp.int32),
        epoch=jnp.ones((), dtype=jnp.int64),
    )


def _stack_tables(tables: list[TableState]) -> TableState:
    """Stack per-shard sub-tables along a new leading shard axis."""
    return TableState(
        *(jnp.stack(leaf) for leaf in zip(*tables))
    )


class JitRuntime:
    """Executes fn_jit operators over device state columns for one Engine."""

    def __init__(
        self,
        topology: Topology,
        store,
        metrics,
        kg_op: np.ndarray,
        *,
        mesh=None,
        mesh_axis: Optional[str] = None,
    ) -> None:
        self._store = store
        self._metrics = metrics
        self._kg_op = kg_op
        self._mesh = mesh
        if mesh is not None and mesh_axis is None:
            mesh_axis = mesh.axis_names[0]
        self._mesh_axis = mesh_axis
        if mesh is not None:
            d = int(mesh.shape[mesh_axis])
            if d & (d - 1):
                raise ValueError("jit mesh axis size must be a power of two")
        self.compile_seconds = 0.0
        shards = 0 if mesh is None else int(mesh.shape[mesh_axis])
        self._by_op: dict[int, _OpState] = {}
        for op, spec in enumerate(topology.operators):
            if spec.fn_jit is not None:
                self._by_op[op] = _OpState(
                    op, spec, topology.kg_base(op), shards=shards
                )

    # ------------------------------------------------------------ execution
    def execute(self, op, kgs, starts, ends, keys, values, ts):
        """Run one contiguous (node, operator) segment through the jit tier.

        ``kgs`` are global key-group ids; ``starts``/``ends`` are bounds
        relative to the ``keys``/``values``/``ts`` slice.  Returns
        ``(outputs, out_counts)`` exactly like an ``fn_seg`` call.
        """
        ost = self._by_op[op]
        n = len(keys)
        r = len(kgs)
        # One host↔device boundary per call (dispatch + output fetch).
        self._metrics.jit_host_syncs += 1
        if ost.has_vectors and len(set(kgs)) != r:
            # Window rings read pre-call occupancy per key group, so a call
            # with duplicate key groups (a budget-leftover segment
            # concatenated with a fresh one after a migration replay) would
            # shift from a stale ring.  Fall back to the numpy fn_seg tier
            # on the oracle dicts for this call.
            if ost.spec.fn_seg is None:
                raise ValueError(
                    f"operator {ost.spec.name!r} declares vector state but "
                    "no fn_seg fallback for duplicate-key-group segments"
                )
            for kg in set(int(k) for k in kgs):
                self.ensure_dict(kg)
            self._metrics.seg_calls += 1
            self._metrics.seg_tuples += n
            return ost.spec.fn_seg(
                self._store.raw(), list(kgs), list(starts), list(ends),
                keys, values, ts,
            )
        nb = _bucket(n, _MIN_TUPLE_BUCKET)
        rb = _bucket(r, _MIN_RUN_BUCKET)
        if self._mesh is not None:
            rb = _bucket(rb, int(self._mesh.shape[self._mesh_axis]))
        lkgs = np.asarray(kgs, dtype=np.int64) - ost.base
        st_arr = np.asarray(starts, dtype=np.int64)
        en_arr = np.asarray(ends, dtype=np.int64)
        if ost.fields:
            self._prepare_state(ost, lkgs, n)
        if ost.shards:
            return self._execute_sharded_tables(
                ost, lkgs, st_arr, en_arr, keys, values, ts, n, r
            )
        # Fresh padded buffers per call: jax zero-copies numpy on CPU, so a
        # reused scratch could be read after we overwrite it.
        kg_pad = np.full(rb, ost.nkg, dtype=np.int64)
        kg_pad[:r] = lkgs
        s_pad = np.full(rb, n, dtype=np.int64)
        s_pad[:r] = st_arr
        e_pad = np.full(rb, n, dtype=np.int64)
        e_pad[:r] = en_arr
        key_pad = np.zeros(nb, dtype=keys.dtype)
        key_pad[:n] = keys
        ts_pad = np.zeros(nb, dtype=np.float64)
        ts_pad[:n] = ts
        if ost.value_names is None:
            v_arg = np.zeros(nb, dtype=values.dtype)
            v_arg[:n] = values
        else:
            v_arg = {}
            for name in ost.value_names:
                col = values[name]
                pad = np.zeros(nb, dtype=col.dtype)
                pad[:n] = col
                v_arg[name] = pad
        fn = ost.spec.fn_jit
        use_shard = (
            self._mesh is not None
            and not ost.has_tables
            and len(set(kgs)) == r
        )
        if use_shard:
            # Plain run-sharding merges per-shard state by key-group
            # ownership — sound for per-key-group columns (table operators
            # take the key-group-sharded path above instead).  Duplicate key
            # groups in one call (budget-leftover segments concatenated with
            # a fresh batch) must not shard-split: two shards would both
            # update the kg from the same base and the merge would
            # double-count it — fall back to the plain call.
            jitted = _jitted_sharded(fn, self._mesh, self._mesh_axis)
        else:
            jitted = _jitted_plain(fn)
        key = (nb, rb, tuple(sorted(ost.caps.items())), use_shard)
        first = key not in ost.seen_keys
        if first:
            ost.seen_keys.add(key)
            self._metrics.jit_compiles += 1
            t0 = time.perf_counter()
        result = jitted(ost.cols, kg_pad, s_pad, e_pad, key_pad, v_arg, ts_pad)
        if first:
            jax.block_until_ready(result)
            self.compile_seconds += time.perf_counter() - t0
        state_new, outputs, out_counts = result
        ost.cols = state_new
        for f in ost.fields:
            if f.kind == "table":
                ost.cnt_host[f.name] = int(state_new[f.name].cnt)
        ost.col_auth[lkgs] = True
        self._metrics.jit_calls += 1
        self._metrics.jit_tuples += n
        if outputs is None:
            return None, None
        ok, ov, ot = outputs
        if out_counts is None:
            total, lens = n, None
        else:
            lens_arr = np.asarray(out_counts)[:r]
            total = int(lens_arr.sum())
            lens = lens_arr.tolist()
        ok_np = np.asarray(ok)[:total]
        ot_np = np.asarray(ot)[:total]
        if isinstance(ov, dict):
            if ost.out_dtype is None:
                raise ValueError(
                    f"fn_jit of operator {ost.spec.name!r} returned record "
                    "columns but the operator declares no out_schema"
                )
            ov_np = np.empty(total, dtype=ost.out_dtype)
            for name in ost.out_names:
                ov_np[name] = np.asarray(ov[name])[:total]
        else:
            ov_np = np.asarray(ov)[:total]
        return (ok_np, ov_np, ot_np), lens

    def _execute_sharded_tables(
        self, ost, lkgs, st_arr, en_arr, keys, values, ts, n, r
    ):
        """Key-group-sharded execution of a keyed-table operator.

        The host lays the call out shard-major: runs stable-sorted by their
        owning shard, tuples gathered run-major so every shard's runs tile
        a contiguous block, each shard padded to a common run bucket.  Per
        key group, run order and within-run tuple order are preserved
        (stable sort; a key group lives wholly on one shard), and equal
        codes share a key group — so the per-code tie-break order inside
        :func:`keyed_running_sum` is unchanged and the result is
        bit-identical to the plain call, modulo the documented cumsum
        float policy.  Outputs come back positionally over the permuted
        tuples and are ungathered on the host (1:1 bodies only).
        """
        d = ost.shards
        shard_ids = ost.shard_of(lkgs)
        order_runs = np.argsort(shard_ids, kind="stable")
        lens = en_arr - st_arr
        if r:
            perm = np.concatenate(
                [np.arange(st_arr[i], en_arr[i]) for i in order_runs]
            )
        else:
            perm = np.empty(0, np.int64)
        rs_per = np.bincount(shard_ids, minlength=d)
        rbs = _bucket(int(rs_per.max()) if r else 1, _MIN_RUN_BUCKET)
        nb = _bucket(n, _MIN_TUPLE_BUCKET)
        new_lens = lens[order_runs]
        new_ends = np.cumsum(new_lens)
        new_starts = new_ends - new_lens
        kg_pad = np.full(d * rbs, ost.nkg, dtype=np.int64)
        s_pad = np.empty(d * rbs, dtype=np.int64)
        e_pad = np.empty(d * rbs, dtype=np.int64)
        pos = 0
        off = 0
        for s in range(d):
            cnt_s = int(rs_per[s])
            blk_end = int(new_ends[pos + cnt_s - 1]) if cnt_s else off
            base_i = s * rbs
            s_pad[base_i : base_i + rbs] = blk_end
            e_pad[base_i : base_i + rbs] = blk_end
            if cnt_s:
                kg_pad[base_i : base_i + cnt_s] = lkgs[
                    order_runs[pos : pos + cnt_s]
                ]
                s_pad[base_i : base_i + cnt_s] = new_starts[pos : pos + cnt_s]
                e_pad[base_i : base_i + cnt_s] = new_ends[pos : pos + cnt_s]
            pos += cnt_s
            off = blk_end
        key_pad = np.zeros(nb, dtype=keys.dtype)
        key_pad[:n] = np.asarray(keys)[perm]
        ts_pad = np.zeros(nb, dtype=np.float64)
        ts_pad[:n] = np.asarray(ts)[perm]
        if ost.value_names is None:
            v_arg = np.zeros(nb, dtype=values.dtype)
            v_arg[:n] = np.asarray(values)[perm]
        else:
            v_arg = {}
            for name in ost.value_names:
                col = values[name]
                pad = np.zeros(nb, dtype=col.dtype)
                pad[:n] = np.asarray(col)[perm]
                v_arg[name] = pad
        fn = ost.spec.fn_jit
        table_names = tuple(
            sorted(f.name for f in ost.fields if f.kind == "table")
        )
        jitted = _jitted_sharded_tables(
            fn, self._mesh, self._mesh_axis, table_names
        )
        cache_key = (
            nb, rbs, tuple(sorted(ost.caps.items())), "shard_tab"
        )
        first = cache_key not in ost.seen_keys
        if first:
            ost.seen_keys.add(cache_key)
            self._metrics.jit_compiles += 1
            t0 = time.perf_counter()
        result = jitted(ost.cols, kg_pad, s_pad, e_pad, key_pad, v_arg, ts_pad)
        if first:
            jax.block_until_ready(result)
            self.compile_seconds += time.perf_counter() - t0
        state_new, outputs, _ = result
        ost.cols = state_new
        for f in ost.fields:
            if f.kind == "table":
                ost.cnt_host[f.name] = np.asarray(
                    state_new[f.name].cnt, dtype=np.int64
                )
        ost.col_auth[lkgs] = True
        self._metrics.jit_calls += 1
        self._metrics.jit_tuples += n
        if outputs is None:
            return None, None
        ok, ov, ot = outputs
        inv = np.empty(n, dtype=np.int64)
        inv[perm] = np.arange(n)
        ok_np = np.asarray(ok)[:n][inv]
        ot_np = np.asarray(ot)[:n][inv]
        if isinstance(ov, dict):
            if ost.out_dtype is None:
                raise ValueError(
                    f"fn_jit of operator {ost.spec.name!r} returned record "
                    "columns but the operator declares no out_schema"
                )
            ov_np = np.empty(n, dtype=ost.out_dtype)
            for name in ost.out_names:
                ov_np[name] = np.asarray(ov[name])[:n][inv]
        else:
            ov_np = np.asarray(ov)[:n][inv]
        return (ok_np, ov_np, ot_np), None

    # ----------------------------------------------------- state coherence
    def _prepare_state(self, ost: _OpState, lkgs: np.ndarray, n: int) -> None:
        """Push dict-authoritative state, then size tables for this call."""
        pend = lkgs[~ost.col_auth[lkgs]]
        if len(pend):
            self._push(ost, pend)
        for f in ost.fields:
            if f.kind != "table":
                continue
            # The segment can insert at most one entry per tuple (per
            # shard, when sharded — every shard sizes for the worst case).
            cnt = ost.cnt_host[f.name]
            need = (int(np.max(cnt)) if ost.shards else cnt) + n
            if need > ost.caps[f.name]:
                self._grow(ost, f, need)

    def _grow(self, ost: _OpState, f: StateField, cap_needed: int) -> None:
        """Extend the slab; the sorted view's EMPTY tail (ascending by slab
        index) extends with the fresh indices — no re-sort needed."""
        new_cap = _bucket(cap_needed, _MIN_TABLE_CAP)
        t = ost.cols[f.name]
        old = ost.caps[f.name]
        pad = new_cap - old
        if ost.shards:
            d = ost.shards
            codes = np.full((d, new_cap), EMPTY_CODE, dtype=np.int64)
            codes[:, :old] = np.asarray(t.codes)
            tail = jnp.broadcast_to(
                jnp.arange(old, new_cap, dtype=t.perm.dtype), (d, pad)
            )
            ost.cols[f.name] = TableState(
                codes=jnp.asarray(codes),
                vals=jnp.pad(t.vals, ((0, 0), (0, pad))),
                seq=jnp.pad(t.seq, ((0, 0), (0, pad))),
                owner=jnp.pad(t.owner, ((0, 0), (0, pad))),
                perm=jnp.concatenate([t.perm, tail], axis=1),
                cnt=t.cnt,
                epoch=t.epoch,
            )
            ost.caps[f.name] = new_cap
            return
        codes = np.full(new_cap, EMPTY_CODE, dtype=np.int64)
        codes[:old] = np.asarray(t.codes)
        ost.cols[f.name] = TableState(
            codes=jnp.asarray(codes),
            vals=jnp.pad(t.vals, (0, pad)),
            seq=jnp.pad(t.seq, (0, pad)),
            owner=jnp.pad(t.owner, (0, pad)),
            perm=jnp.concatenate(
                [t.perm, jnp.arange(old, new_cap, dtype=t.perm.dtype)]
            ),
            cnt=t.cnt,
            epoch=t.epoch,
        )
        ost.caps[f.name] = new_cap

    def _push(self, ost: _OpState, pend: np.ndarray) -> None:
        """Rebuild the columns with the pushed key groups' dict state.

        Scalar fields scatter; table fields rebuild the packed slab host
        side (stale entries of the pushed key groups drop, their dict
        entries re-append with fresh sequence numbers above every kept
        one, and the sorted view is a host argsort — stable, so the EMPTY
        tail stays ascending by slab index).
        """
        store = self._store.raw()
        m = len(pend)
        for f in ost.fields:
            if f.kind == "scalar":
                rows = np.fromiter(
                    (
                        store[ost.base + int(lk)].get(f.name, f.init)
                        for lk in pend
                    ),
                    dtype=f.dtype,
                    count=m,
                )
                ost.cols[f.name] = (
                    ost.cols[f.name].at[jnp.asarray(pend)].set(rows)
                )
                continue
            if f.kind == "vector":
                v = ost.cols[f.name]
                data = np.zeros((m, f.length), dtype=f.dtype)
                cnt = np.zeros(m, dtype=np.int32)
                for j, lk in enumerate(pend):
                    ring = store[ost.base + int(lk)].get(f.name, [])
                    cnt[j] = len(ring)
                    data[j, : len(ring)] = ring
                idx = jnp.asarray(pend)
                ost.cols[f.name] = VectorState(
                    data=v.data.at[idx].set(data),
                    cnt=v.cnt.at[idx].set(cnt),
                )
                continue
            if ost.shards:
                self._push_sharded_table(ost, f, pend)
                continue
            t = ost.cols[f.name]
            cnt = ost.cnt_host[f.name]
            codes = np.asarray(t.codes)[:cnt]
            vals = np.asarray(t.vals)[:cnt]
            seq = np.asarray(t.seq)[:cnt]
            owner = np.asarray(t.owner)[:cnt]
            keep = ~np.isin(owner, pend)
            new_c, new_v, new_o = [], [], []
            enc = f.key_encode
            for lk in pend:
                d = store[ost.base + int(lk)].get(f.name, {})
                for key, val in d.items():
                    new_c.append(enc(key))
                    new_v.append(val)
                    new_o.append(lk)
            n_keep = int(keep.sum())
            total = n_keep + len(new_c)
            cap = ost.caps[f.name]
            if total > cap:
                cap = _bucket(total, _MIN_TABLE_CAP)
                ost.caps[f.name] = cap
            pc = np.full(cap, EMPTY_CODE, dtype=np.int64)
            pv = np.zeros(cap, dtype=f.dtype)
            ps = np.zeros(cap, dtype=np.int64)
            po = np.zeros(cap, dtype=np.int32)
            pc[:n_keep] = codes[keep]
            pv[:n_keep] = vals[keep]
            ps[:n_keep] = seq[keep]
            po[:n_keep] = owner[keep]
            base_seq = int(ps[:n_keep].max()) + 1 if n_keep else 0
            if new_c:
                pc[n_keep:total] = new_c
                pv[n_keep:total] = new_v
                ps[n_keep:total] = base_seq + np.arange(len(new_c))
                po[n_keep:total] = new_o
            max_seq = int(ps[:total].max()) if total else 0
            epoch = max(int(t.epoch), (max_seq >> 32) + 1)
            ost.cols[f.name] = TableState(
                codes=jnp.asarray(pc),
                vals=jnp.asarray(pv),
                seq=jnp.asarray(ps),
                owner=jnp.asarray(po),
                perm=jnp.asarray(
                    np.argsort(pc, kind="stable").astype(np.int32)
                ),
                cnt=jnp.asarray(np.int32(total)),
                epoch=jnp.asarray(np.int64(epoch)),
            )
            ost.cnt_host[f.name] = total
        ost.col_auth[pend] = True

    def _push_sharded_table(
        self, ost: _OpState, f: StateField, pend: np.ndarray
    ) -> None:
        """Per-shard restatement of the flat table rebuild: only the shards
        owning pushed key groups are rebuilt; the rest copy through (their
        EMPTY perm tail extends with fresh indices on a capacity bump)."""
        store = self._store.raw()
        d = ost.shards
        t = ost.cols[f.name]
        cnt_arr = np.asarray(ost.cnt_host[f.name], dtype=np.int64).copy()
        codes_h = np.asarray(t.codes)
        vals_h = np.asarray(t.vals)
        seq_h = np.asarray(t.seq)
        owner_h = np.asarray(t.owner)
        perm_h = np.asarray(t.perm)
        epoch_h = np.asarray(t.epoch).copy()
        shard_ids = ost.shard_of(pend)
        old_cap = codes_h.shape[1]
        cap = ost.caps[f.name]
        enc = f.key_encode
        per_shard = {}
        for s in sorted(set(shard_ids.tolist())):
            kgs_s = pend[shard_ids == s]
            cnt = int(cnt_arr[s])
            keep = ~np.isin(owner_h[s, :cnt], kgs_s)
            new_c, new_v, new_o = [], [], []
            for lk in kgs_s:
                dct = store[ost.base + int(lk)].get(f.name, {})
                for key, val in dct.items():
                    new_c.append(enc(key))
                    new_v.append(val)
                    new_o.append(lk)
            total = int(keep.sum()) + len(new_c)
            per_shard[s] = (cnt, keep, new_c, new_v, new_o, total)
            if total > cap:
                cap = _bucket(total, _MIN_TABLE_CAP)
        ost.caps[f.name] = cap
        pc = np.full((d, cap), EMPTY_CODE, dtype=np.int64)
        pv = np.zeros((d, cap), dtype=f.dtype)
        ps = np.zeros((d, cap), dtype=np.int64)
        po = np.zeros((d, cap), dtype=np.int32)
        pp = np.zeros((d, cap), dtype=np.int32)
        for s in range(d):
            if s not in per_shard:
                pc[s, :old_cap] = codes_h[s]
                pv[s, :old_cap] = vals_h[s]
                ps[s, :old_cap] = seq_h[s]
                po[s, :old_cap] = owner_h[s]
                pp[s, :old_cap] = perm_h[s]
                pp[s, old_cap:] = np.arange(old_cap, cap)
                continue
            cnt, keep, new_c, new_v, new_o, total = per_shard[s]
            n_keep = int(keep.sum())
            pc[s, :n_keep] = codes_h[s, :cnt][keep]
            pv[s, :n_keep] = vals_h[s, :cnt][keep]
            ps[s, :n_keep] = seq_h[s, :cnt][keep]
            po[s, :n_keep] = owner_h[s, :cnt][keep]
            base_seq = int(ps[s, :n_keep].max()) + 1 if n_keep else 0
            if new_c:
                pc[s, n_keep:total] = new_c
                pv[s, n_keep:total] = new_v
                ps[s, n_keep:total] = base_seq + np.arange(len(new_c))
                po[s, n_keep:total] = new_o
            pp[s] = np.argsort(pc[s], kind="stable").astype(np.int32)
            max_seq = int(ps[s, :total].max()) if total else 0
            epoch_h[s] = max(int(epoch_h[s]), (max_seq >> 32) + 1)
            cnt_arr[s] = total
        ost.cols[f.name] = TableState(
            codes=jnp.asarray(pc),
            vals=jnp.asarray(pv),
            seq=jnp.asarray(ps),
            owner=jnp.asarray(po),
            perm=jnp.asarray(pp),
            cnt=jnp.asarray(cnt_arr.astype(np.int32)),
            epoch=jnp.asarray(epoch_h),
        )
        ost.cnt_host[f.name] = cnt_arr

    def _to_dict(self, ost: _OpState, lk: int, host: dict) -> dict:
        """Materialize one key group's columns as the oracle state dict."""
        out: dict = {}
        for f in ost.fields:
            if f.kind == "scalar":
                out[f.name] = f.py(host[f.name][lk])
            elif f.kind == "vector":
                data, cnt = host[f.name]
                out[f.name] = [
                    f.py(x) for x in data[lk][: int(cnt[lk])]
                ]
            else:
                codes, vals, seq, owner = host[f.name]
                mine = np.flatnonzero(owner == lk)
                order = mine[np.argsort(seq[mine], kind="stable")]
                dec = f.key_decode
                py = f.py
                d = {}
                for j in order.tolist():
                    d[dec(int(codes[j]))] = py(vals[j])
                out[f.name] = d
        return out

    def _host_cols(self, ost: _OpState) -> dict:
        host = {}
        for f in ost.fields:
            if f.kind == "scalar":
                host[f.name] = np.asarray(ost.cols[f.name])
            elif f.kind == "vector":
                v = ost.cols[f.name]
                host[f.name] = (np.asarray(v.data), np.asarray(v.cnt))
            elif ost.shards:
                # Flatten the per-shard slabs into one valid-entry view; a
                # key group's entries live wholly in its owning shard, so
                # the per-key-group seq order is preserved.
                t = ost.cols[f.name]
                cnt = ost.cnt_host[f.name]
                codes_h = np.asarray(t.codes)
                vals_h = np.asarray(t.vals)
                seq_h = np.asarray(t.seq)
                owner_h = np.asarray(t.owner)
                host[f.name] = tuple(
                    np.concatenate(
                        [arr[s, : int(cnt[s])] for s in range(ost.shards)]
                    )
                    for arr in (codes_h, vals_h, seq_h, owner_h)
                )
            else:
                t = ost.cols[f.name]
                cnt = ost.cnt_host[f.name]
                host[f.name] = (
                    np.asarray(t.codes)[:cnt],
                    np.asarray(t.vals)[:cnt],
                    np.asarray(t.seq)[:cnt],
                    np.asarray(t.owner)[:cnt],
                )
        return host

    def ensure_dict(self, kg: int) -> None:
        """Make the python store dict authoritative for one key group.

        Called by the engine before any per-run ``fn`` fallback or state
        serialization touches a jit-tier operator's key group.
        """
        op = int(self._kg_op[kg])
        ost = self._by_op.get(op)
        if ost is None or not ost.fields:
            return
        lk = kg - ost.base
        if not ost.col_auth[lk]:
            return
        self._store.raw()[kg] = self._to_dict(ost, lk, self._host_cols(ost))
        ost.col_auth[lk] = False

    def invalidate(self, kg: int) -> None:
        """Dict state was externally replaced (migration install)."""
        op = int(self._kg_op[kg])
        ost = self._by_op.get(op)
        if ost is not None and ost.fields:
            ost.col_auth[kg - ost.base] = False

    def sync_store(self) -> None:
        """Refresh the store dicts of every column-authoritative key group
        (columns stay authoritative — this is the read-only statistics /
        conformance snapshot taken at ``end_period``)."""
        store = self._store.raw()
        for ost in self._by_op.values():
            if not ost.fields:
                continue
            lks = np.flatnonzero(ost.col_auth)
            if not len(lks):
                continue
            host = self._host_cols(ost)
            for lk in lks.tolist():
                store[ost.base + lk] = self._to_dict(ost, lk, host)

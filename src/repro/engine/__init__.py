"""PSPE substrate: a keyed, stateful streaming engine the paper's controller
reconfigures at runtime.

The engine executes real operator logic (JAX/numpy) over key-group-partitioned
state on a set of *logical nodes* (device shards on TPU; timeshared on CPU),
maintains SPL statistics, and exposes direct state migration — everything
:mod:`repro.core` needs to run Algorithm 1 against a live job.
"""

from repro.engine.topology import (
    OperatorSpec,
    Schema,
    StateField,
    StateSchema,
    Topology,
)
from repro.engine.state import KeyedStore
from repro.engine.router import Router
from repro.engine.executor import Engine, EngineMetrics
from repro.engine.controller import Controller, ControllerConfig
from repro.engine.workqueue import DequeWorkQueue, SoAWorkQueue

__all__ = [
    "Controller",
    "ControllerConfig",
    "DequeWorkQueue",
    "Engine",
    "EngineMetrics",
    "KeyedStore",
    "OperatorSpec",
    "Router",
    "Schema",
    "SoAWorkQueue",
    "StateField",
    "StateSchema",
    "Topology",
]

"""PSPE substrate: a keyed, stateful streaming engine the paper's controller
reconfigures at runtime.

The engine executes real operator logic (JAX/numpy) over key-group-partitioned
state on a set of *logical nodes* (device shards on TPU; timeshared on CPU),
maintains SPL statistics, and exposes direct state migration — everything
:mod:`repro.core` needs to run Algorithm 1 against a live job.

How a topology executes is one value — :class:`ExecutionConfig` — and
:func:`make_engine` dispatches it: ``num_workers == 1`` builds the
single-process :class:`Engine`, ``num_workers > 1`` the multi-worker
:class:`repro.engine.cluster.ClusterEngine` (real OS worker processes,
imported lazily).
"""

from typing import Optional

from repro.engine.config import ExecutionConfig
from repro.engine.controller import Controller, ControllerConfig
from repro.engine.executor import Engine, EngineMetrics
from repro.engine.router import Router
from repro.engine.serde import Envelope
from repro.engine.state import KeyedStore
from repro.engine.topology import (
    OperatorSpec,
    Schema,
    StateField,
    StateSchema,
    Topology,
)
from repro.engine.workqueue import DequeWorkQueue, SoAWorkQueue


def make_engine(
    topology: Topology,
    num_nodes: int,
    *,
    config: Optional[ExecutionConfig] = None,
    **kwargs,
):
    """Build the engine an :class:`ExecutionConfig` selects.

    The one construction path that covers every execution tier including
    ``ExecutionConfig.workers(n)`` — the multi-worker runtime is imported
    only when asked for (it forks worker processes at construction).
    """
    if config is not None and config.num_workers > 1:
        from repro.engine.cluster import ClusterEngine

        return ClusterEngine(topology, num_nodes, config=config, **kwargs)
    return Engine(topology, num_nodes, config=config, **kwargs)


__all__ = [
    "Controller",
    "ControllerConfig",
    "DequeWorkQueue",
    "Engine",
    "EngineMetrics",
    "Envelope",
    "ExecutionConfig",
    "KeyedStore",
    "OperatorSpec",
    "Router",
    "Schema",
    "SoAWorkQueue",
    "StateField",
    "StateSchema",
    "Topology",
    "make_engine",
]

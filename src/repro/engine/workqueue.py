"""Per-node work queues: the structure-of-arrays data plane (and its oracle).

A node's queue holds *runs* — contiguous (operator, key group) slices of a
routed batch — in FIFO order.  Two implementations share one interface:

:class:`SoAWorkQueue`
    The production layout.  A push appends one *segment*: a reference to the
    routed batch's key/value/ts arrays (shared, never copied — every node's
    runs are views into the same argsort-permuted arrays) plus parallel
    plain-Python run-index lists ``(kgs, starts, ends, costs)``.  Draining
    walks the run lists with a cursor instead of popping per-(op, key group)
    Python queue entries, so per-run overhead is a couple of list indexings
    and three array slices.

:class:`DequeWorkQueue`
    A straightforward deque of per-run ``[op, kg, batch, cost]`` entries in
    push order, kept as the equivalence oracle — it drains exactly the runs
    the SoA queue drains, one pop at a time.  The routing-equivalence tests
    run both implementations on identical inputs and require bit-identical
    tuple flow and SPL statistics under any service budget.

Both support ``extract_keygroup`` — masked slicing of one key group's queued
tuples out of the queue in FIFO order — which the engine uses during direct
state migration so in-flight work follows σ_k to its new node (packed into
the serialize envelope as raw buffer slices on schema-typed edges).

Queues are representation-agnostic: a segment's key/value arrays are
whatever the routed batch carried — native structured records on
schema-typed edges (slicing stays a fixed-width view, no per-element
refcounting) or object arrays on undeclared ones.

The fused superstep runtime (:mod:`repro.engine.superstep`) additionally
pushes *shadow segments*: run metadata (kgs/starts/ends/costs, with bounds
absolute into the routed arrays) whose key/value/ts slots are ``None``
because the routed tuples stayed resident on the device.  Shadow segments
carry exact cost accounting — backpressure, budgets and queue-cost
trajectories are bit-identical to real segments — but cannot be sliced;
every engine path that touches segment arrays (``extract_keygroup``,
``clear`` on migration/failure, any classic drain) runs only after
``SuperstepRuntime.flush_to_host()`` fills the ``None`` slots in place.
"""

from __future__ import annotations

from collections import deque

import numpy as np

from repro.engine.router import concat_batches
from repro.engine.topology import Batch

# Segment layout (plain list for speed): shared tuple arrays + run indices.
# `contig` is True when the runs are adjacent slices (starts[i+1] == ends[i])
# — the engine's segment-vectorized paths require it.
(
    _S_KEYS,
    _S_VALUES,
    _S_TS,
    _S_OP,
    _S_KGS,
    _S_STARTS,
    _S_ENDS,
    _S_COSTS,
    _S_CUR,
    _S_CONTIG,
) = range(10)


class SoAWorkQueue:
    """Structure-of-arrays FIFO of (op, key group) runs for one node."""

    __slots__ = ("_segs", "cost")

    def __init__(self) -> None:
        self._segs: deque[list] = deque()
        self.cost = 0.0  # queued work in cost-units (backpressure input)

    def __bool__(self) -> bool:
        return bool(self._segs)

    def __len__(self) -> int:  # pending runs (diagnostics/tests)
        return sum(len(s[_S_KGS]) - s[_S_CUR] for s in self._segs)

    def push_runs(
        self,
        op: int,
        keys: np.ndarray,
        values: np.ndarray,
        ts: np.ndarray,
        kgs: list[int],
        starts: list[int],
        ends: list[int],
        costs: list[float],
        contig: bool = False,
    ) -> float:
        """Append one segment of runs; arrays are shared, not copied.

        Returns the total cost admitted (also added to ``self.cost``) —
        summed left to right so both queue implementations account
        bit-identically.  ``contig`` asserts the runs are adjacent slices.
        """
        total = 0.0
        for c in costs:
            total += c
        self._segs.append([keys, values, ts, op, kgs, starts, ends, costs, 0, contig])
        self.cost += total
        return total

    def push_batch(self, op: int, kg: int, batch: Batch, cost: float) -> None:
        """Append a single-run segment (migration replay path)."""
        k, v, t = batch
        self._segs.append([k, v, t, op, [kg], [0], [len(k)], [cost], 0, True])
        self.cost += cost

    def drain(
        self, budget: float, process, node: int, out_kgs: list, out_costs: list
    ) -> None:
        """Consume runs in FIFO order until the budget is exhausted.

        ``process(node, op, kg, keys, values, ts)`` is called per run; the
        consumed (kg, cost) pairs are appended to ``out_kgs``/``out_costs``
        so the caller can charge CPU statistics in one vectorized scatter.
        Matches the deque semantics: the run that exhausts the budget is
        still processed (one-entry overshoot).
        """
        segs = self._segs
        while segs and budget > 0:
            seg = segs[0]
            keys, values, ts, op = seg[_S_KEYS], seg[_S_VALUES], seg[_S_TS], seg[_S_OP]
            kgs, starts, ends, costs = (
                seg[_S_KGS],
                seg[_S_STARTS],
                seg[_S_ENDS],
                seg[_S_COSTS],
            )
            cur, nruns = seg[_S_CUR], len(kgs)
            while cur < nruns:
                c = costs[cur]
                kg = kgs[cur]
                a, z = starts[cur], ends[cur]
                cur += 1
                budget -= c
                self.cost -= c
                out_kgs.append(kg)
                out_costs.append(c)
                process(node, op, kg, keys[a:z], values[a:z], ts[a:z])
                if budget <= 0:
                    break
            if cur < nruns:
                seg[_S_CUR] = cur
                return
            segs.popleft()

    def extract_keygroup(self, kg: int) -> tuple[list[Batch], float]:
        """Masked slicing: remove and return one key group's queued batches.

        FIFO order is preserved; the removed cost is subtracted from
        ``self.cost`` and returned alongside the batches.
        """
        out: list[Batch] = []
        removed = 0.0
        kept_segs: deque[list] = deque()
        for seg in self._segs:
            kgs = seg[_S_KGS]
            cur = seg[_S_CUR]
            if kg not in kgs[cur:]:
                kept_segs.append(seg)
                continue
            keys, values, ts = seg[_S_KEYS], seg[_S_VALUES], seg[_S_TS]
            starts, ends, costs = seg[_S_STARTS], seg[_S_ENDS], seg[_S_COSTS]
            nk, ns, ne, nc = [], [], [], []
            for j in range(cur, len(kgs)):
                a, z = starts[j], ends[j]
                if kgs[j] == kg:
                    out.append((keys[a:z], values[a:z], ts[a:z]))
                    removed += costs[j]
                else:
                    nk.append(kgs[j])
                    ns.append(a)
                    ne.append(z)
                    nc.append(costs[j])
            if nk:
                # Removal may break run adjacency: conservatively mark the
                # rebuilt segment non-contiguous (per-run drain handles it).
                kept_segs.append(
                    [keys, values, ts, seg[_S_OP], nk, ns, ne, nc, 0, False]
                )
        self._segs = kept_segs
        self.cost -= removed
        return out, removed

    def clear(self) -> None:
        self._segs.clear()
        self.cost = 0.0


# Deque entry layout: [op, kg, Batch, cost] — one entry per pushed run, in
# push order, exactly the granularity the SoA queue drains at (same-tick
# same-(op, kg) pushes stay separate entries on both implementations, so the
# two drain identical runs under any service budget).
_QE_OP, _QE_KG, _QE_BATCH, _QE_COST = range(4)


class DequeWorkQueue:
    """Per-run deque queue — the equivalence oracle for SoAWorkQueue."""

    __slots__ = ("_q", "cost")

    def __init__(self) -> None:
        self._q: deque[list] = deque()
        self.cost = 0.0

    def __bool__(self) -> bool:
        return bool(self._q)

    def __len__(self) -> int:
        return len(self._q)

    def push_runs(
        self, op, keys, values, ts, kgs, starts, ends, costs, contig=False
    ) -> float:
        total = 0.0
        for j in range(len(kgs)):
            a, z = starts[j], ends[j]
            self._q.append([op, kgs[j], (keys[a:z], values[a:z], ts[a:z]), costs[j]])
            total += costs[j]
        self.cost += total
        return total

    def push_batch(self, op, kg, batch, cost) -> None:
        self._q.append([op, kg, batch, cost])
        self.cost += cost

    def drain(self, budget, process, node, out_kgs, out_costs) -> None:
        q = self._q
        while q and budget > 0:
            op, kg, batch, cost = q.popleft()
            self.cost -= cost
            budget -= cost
            out_kgs.append(kg)
            out_costs.append(cost)
            process(node, op, kg, batch[0], batch[1], batch[2])

    def extract_keygroup(self, kg: int) -> tuple[list[Batch], float]:
        out: list[Batch] = []
        removed = 0.0
        kept: deque[list] = deque()
        for entry in self._q:
            if entry[_QE_KG] == kg:
                out.append(entry[_QE_BATCH])
                removed += entry[_QE_COST]
            else:
                kept.append(entry)
        self._q = kept
        self.cost -= removed
        return out, removed

    def clear(self) -> None:
        self._q.clear()
        self.cost = 0.0


QUEUE_IMPLS = {"soa": SoAWorkQueue, "deque": DequeWorkQueue}

"""Keyed state store: the σ_k of every key group, with direct-migration codecs.

State is a plain dict per key group (operators put whatever they need in it —
counters, windows, jnp arrays).  Serialization uses pickle over a numpy-
friendly normal form; sizes feed the migration cost model mc_k = α·|σ_k|.
This codec covers the *state* half of a migration blob only — the engine
wraps it in an envelope that also carries the key group's queued segments
(repro.engine.serde), without affecting the |σ_k| sizes measured here.
"""

from __future__ import annotations

import pickle
from typing import Iterator

import numpy as np


class KeyedStore:
    """σ_k for all key groups of a job, owned by logical nodes."""

    def __init__(self, num_keygroups: int) -> None:
        self._state: list[dict] = [dict() for _ in range(num_keygroups)]
        self._sizes = np.zeros(num_keygroups)  # cached |σ_k| estimates

    def get(self, kg: int) -> dict:
        return self._state[kg]

    def put(self, kg: int, state: dict) -> None:
        self._state[kg] = state

    def raw(self) -> list[dict]:
        """The underlying per-key-group state list (hot-path access)."""
        return self._state

    def serialize(self, kg: int) -> bytes:
        blob = pickle.dumps(self._state[kg], protocol=pickle.HIGHEST_PROTOCOL)
        self._sizes[kg] = len(blob)
        return blob

    def deserialize(self, kg: int, blob: bytes) -> None:
        self._state[kg] = pickle.loads(blob)
        self._sizes[kg] = len(blob)

    def state_bytes(self, refresh: bool = False) -> np.ndarray:
        """|σ_k| vector.  `refresh` re-measures every key group (slow path)."""
        if refresh:
            for kg in range(len(self._state)):
                try:
                    self._sizes[kg] = len(
                        pickle.dumps(self._state[kg], protocol=pickle.HIGHEST_PROTOCOL)
                    )
                except Exception:
                    self._sizes[kg] = 64.0
        return np.maximum(self._sizes, 64.0)  # floor: even empty state has framing

    def items(self) -> Iterator[tuple[int, dict]]:
        return enumerate(self._state)

    def __len__(self) -> int:
        return len(self._state)

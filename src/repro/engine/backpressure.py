"""Short-term fluctuation handling: bounded queues + credit-based backpressure.

The paper (§3, "Workload Fluctuations") distinguishes short-term spikes —
handled by buffering/backpressure — from the long-term balance its optimizer
maintains.  This module provides the short-term half so the engine exhibits
the same dynamics: an overloaded node grows a queue, queueing latency rises,
and sources are throttled when depth crosses the high watermark.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CreditController:
    """Grants per-tick source credits from global queue depth.

    Credits scale linearly from `full_credit` (all queues empty) to 0 (any
    node at `high_wm` cost-units of queued work).
    """

    num_nodes: int
    high_wm: float = 500.0
    full_credit: int = 10_000

    def credits(self, queue_costs: np.ndarray) -> int:
        worst = float(queue_costs.max()) if len(queue_costs) else 0.0
        return self.credits_from_worst(worst)

    def credits_from_worst(self, worst: float) -> int:
        """Scalar form: credits given the deepest queue's cost-units."""
        frac = max(0.0, 1.0 - worst / self.high_wm)
        return int(self.full_credit * frac)


@dataclasses.dataclass
class LatencyTracker:
    """Queueing-latency samples (ticks) with cheap percentile queries.

    Samples are stored as (value, weight) pairs — weight is the number of
    tuples the sample covers, capped at 16 — and expanded only at query time,
    so the record path is one list append per admission.
    """

    samples: list[tuple[float, int]] = dataclasses.field(default_factory=list)

    def record(self, latency_ticks: float, weight: int = 1) -> None:
        self.samples.append((latency_ticks, min(weight, 16)))

    def summary(self) -> dict[str, float]:
        if not self.samples:
            return {"avg": 0.0, "p50": 0.0, "p99": 0.0, "max": 0.0}
        vals = np.fromiter(
            (v for v, _ in self.samples),
            np.float64,
            count=len(self.samples),
        )
        wts = np.fromiter(
            (w for _, w in self.samples),
            np.int64,
            count=len(self.samples),
        )
        arr = np.repeat(vals, wts)
        return {
            "avg": float(arr.mean()),
            "p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "max": float(arr.max()),
        }

    def reset(self) -> None:
        self.samples.clear()

"""Routing table: key group → node, with redirect/buffer for direct migration.

During a migration of g_k from n1 to n2 (paper §3):

  * `redirect(k, n2)` flips the table immediately — upstream sends for g_k now
    land at n2 and are *buffered* there (n2 does not own σ_k yet); the work
    already queued at n1 is extracted engine-side and ships inside the
    serialize envelope instead (see repro.engine.serde);
  * `install(...)` (driven by the engine's StateMover) hands σ_k over, after
    which `complete(k)` returns the buffered tuples for replay — behind the
    shipped backlog, preserving FIFO — and the key group resumes at n2.
"""

from __future__ import annotations

import numpy as np

from repro.engine.topology import Batch, empty_batch


class Router:
    def __init__(self, num_keygroups: int, initial_alloc: np.ndarray) -> None:
        if len(initial_alloc) != num_keygroups:
            raise ValueError("alloc length mismatch")
        self.table = np.asarray(initial_alloc, dtype=np.int64).copy()
        # Bumped on every table mutation — consumers that cache a derived
        # view of the table (the superstep runtime keeps device-resident
        # copies) re-read when the version moves; this is the per-superstep
        # reconfiguration hook.
        self.version = 0
        self._buffers: dict[int, list[Batch]] = {}
        self._in_flight: set[int] = set()
        self._in_flight_arr = np.empty(0, dtype=np.int64)  # sorted cache

    # -- routing -------------------------------------------------------------
    def node_of(self, kg: int) -> int:
        return int(self.table[kg])

    def nodes_of(self, kgs: np.ndarray) -> np.ndarray:
        """Vectorized table lookup: target node per key group."""
        return self.table[kgs]

    def has_in_flight(self) -> bool:
        return bool(self._in_flight)

    def is_in_flight(self, kg: int) -> bool:
        return kg in self._in_flight

    def in_flight_mask(self, kgs: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`is_in_flight` over an array of key-group ids."""
        return np.isin(kgs, self._in_flight_arr)

    def buffer(self, kg: int, batch: Batch) -> None:
        """Hold a batch for a key group whose migration is in flight."""
        self._buffers.setdefault(kg, []).append(batch)

    # -- migration protocol ----------------------------------------------------
    def redirect(self, kg: int, dst: int) -> None:
        self.table[kg] = dst
        self.version += 1
        self._in_flight.add(kg)
        self._in_flight_arr = np.fromiter(self._in_flight, dtype=np.int64)
        self._buffers.setdefault(kg, [])

    def complete(self, kg: int) -> list[Batch]:
        """State installed at dst: stop buffering, return tuples to replay."""
        self._in_flight.discard(kg)
        self._in_flight_arr = np.fromiter(self._in_flight, dtype=np.int64)
        return self._buffers.pop(kg, [])

    @property
    def in_flight(self) -> set[int]:
        return set(self._in_flight)

    def keygroups_on(self, node: int) -> np.ndarray:
        return np.where(self.table == node)[0]

    # -- recovery --------------------------------------------------------------
    def reset(self, table: np.ndarray) -> None:
        """Adopt ``table`` wholesale and drop every transient (restore path).

        Buffered batches and in-flight markers describe migrations that no
        longer exist after a checkpoint rewind — the replacement state comes
        from the checkpoint envelopes, not from a serialize handoff.
        """
        if len(table) != len(self.table):
            raise ValueError("reset table length mismatch")
        self.table[:] = np.asarray(table, dtype=np.int64)
        self.version += 1
        self._buffers.clear()
        self._in_flight.clear()
        self._in_flight_arr = np.empty(0, dtype=np.int64)


def concat_batches(batches: list[Batch]) -> Batch:
    if not batches:
        return empty_batch()
    ks = np.concatenate([b[0] for b in batches])
    vs = np.concatenate([b[1] for b in batches])
    ts = np.concatenate([b[2] for b in batches])
    return ks, vs, ts

"""Execution configuration: one frozen dataclass instead of eight kwargs.

Six PRs of execution tiers left ``Engine.__init__`` with eight interacting
execution kwargs (``queue_impl``, ``use_fn_seg``, ``use_schema``,
``use_fn_jit``, ``superstep``, ``jit_mesh``, ``jit_mesh_axis``,
``kernel_stats``).  :class:`ExecutionConfig` consolidates them — plus the
multi-worker dimension (``num_workers``) the parallel host runtime adds —
into one validated value object with named presets, so the configuration
matrix is spelled once:

======================  =====================================================
preset                  meaning
======================  =====================================================
``.oracle()``           legacy deque queue, per-run ``fn`` only — the
                        semantic oracle every other tier is pinned against
``.seg()``              SoA queues + segment-vectorized ``fn_seg``, schemas
                        stripped (object-array edges)
``.typed()``            ``.seg()`` plus declared schemas honored (columnar
                        structured-array edges) — the default
``.jit()``              ``.typed()`` plus the compiled tier (``fn_jit``
                        bodies over device state columns)
``.superstep()``        ``.jit()`` plus whole-tick fusion
                        (route → drain → ``fn_jit`` in one device program)
``.workers(n)``         ``.typed()`` sharded over ``n`` OS worker processes
                        (:class:`repro.engine.cluster.ClusterEngine`)
======================  =====================================================

``Engine(topology, num_nodes, config=...)`` is the construction path; the
old kwargs are still accepted for one release through a
``DeprecationWarning`` shim that maps them onto this dataclass (see
:meth:`ExecutionConfig.from_legacy_kwargs`).

The determinism contract per configuration is documented in
``docs/execution_tiers.md``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

#: Default capacity (bytes) of one shared-memory exchange lane — the
#: documented ``ExecutionConfig.workers(n, shm=...)`` default.
SHM_LANE_BYTES = 1 << 20

#: Engine kwargs replaced by :class:`ExecutionConfig` (still accepted, with a
#: DeprecationWarning, for one release).
LEGACY_EXECUTION_KWARGS = (
    "queue_impl",
    "use_fn_seg",
    "use_schema",
    "use_fn_jit",
    "superstep",
    "jit_mesh",
    "jit_mesh_axis",
    "kernel_stats",
)


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Periodic engine checkpoints (see docs/fault_tolerance.md).

    The coordinator snapshots the routing table, every key group's state
    envelope, split cursors, the partial SPL window and the ingestion cursor
    under one atomic manifest every :attr:`every` SPL periods, via
    :class:`repro.checkpoint.CheckpointManager` rooted at :attr:`directory`.
    """

    directory: str
    #: Checkpoint every N ``end_period()`` calls (N >= 1).
    every: int = 2
    #: Complete checkpoints retained on disk (older ones are pruned).
    keep: int = 3

    def __post_init__(self) -> None:
        if not self.directory:
            raise ValueError("CheckpointPolicy.directory must be a path")
        if self.every < 1:
            raise ValueError("CheckpointPolicy.every must be >= 1")
        if self.keep < 1:
            raise ValueError("CheckpointPolicy.keep must be >= 1")


@dataclasses.dataclass(frozen=True)
class SupervisionPolicy:
    """Worker supervision: liveness deadlines and bounded respawn.

    Workers heartbeat over their report queue after every command; a worker
    with outstanding commands that stays silent for ``hb_interval_s *
    hb_misses`` seconds is presumed wedged and escalated to SIGKILL (wedged
    is not dead — escalation turns it into a clean death the respawn path
    handles).  Dead workers are respawned with bounded exponential backoff
    and their key groups restored from the latest checkpoint (recovery *is*
    reconfiguration: orphans are re-homed through the allocator).
    """

    hb_interval_s: float = 5.0
    #: Consecutive missed heartbeat intervals before SIGKILL escalation.
    hb_misses: int = 6
    #: Respawn dead workers (False → supervise liveness only; a death
    #: permanently fails the worker's nodes, PR 7 semantics).
    respawn: bool = True
    #: Give up on a worker after this many respawns without an intervening
    #: completed checkpoint.
    max_respawns: int = 3
    #: Exponential backoff before the k-th respawn: min(base * 2**k, cap).
    backoff_base_s: float = 0.0
    backoff_cap_s: float = 5.0
    #: How recovered key groups are re-homed: "albic" (Algorithm 2),
    #: "milp" (solve_allocation), or "keep" (checkpointed placement as-is).
    rehome: str = "albic"

    def __post_init__(self) -> None:
        if self.hb_interval_s <= 0:
            raise ValueError("hb_interval_s must be > 0")
        if self.hb_misses < 1:
            raise ValueError("hb_misses must be >= 1")
        if self.max_respawns < 0:
            raise ValueError("max_respawns must be >= 0")
        if self.backoff_base_s < 0 or self.backoff_cap_s < 0:
            raise ValueError("backoff times must be >= 0")
        if self.rehome not in ("albic", "milp", "keep"):
            raise ValueError(f"unknown rehome strategy {self.rehome!r}")

    @property
    def deadline_s(self) -> float:
        """Silence (with outstanding commands) that triggers escalation."""
        return self.hb_interval_s * self.hb_misses


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """How a topology executes: queue layout, operator tier, worker count.

    Attributes mirror the legacy kwargs one to one, except ``superstep``
    which is carried as :attr:`use_superstep` (the name ``superstep`` is
    taken by the preset constructor).
    """

    queue_impl: str = "soa"
    use_fn_seg: bool = True
    use_schema: bool = True
    use_fn_jit: bool = False
    use_superstep: bool = False
    jit_mesh: Any = None
    jit_mesh_axis: Optional[str] = None
    # None → auto-detect (Pallas partition kernel only when jax is already
    # initialized on TPU); see Engine._auto_kernel_stats.
    kernel_stats: Optional[bool] = None
    num_workers: int = 1
    #: Bytes per (sender → receiver) shared-memory exchange lane in the
    #: multi-worker runtime (see docs/execution_tiers.md).  The default —
    #: :data:`SHM_LANE_BYTES` = 1 MiB — comfortably holds several ticks of
    #: typical exchange traffic per lane; a full ring falls back to the
    #: queue path (correct, just slower).  ``0`` disables shm lanes
    #: entirely (pure pickled-queue exchange, PR 7's transport).
    shm_lane_bytes: int = SHM_LANE_BYTES
    #: Hot-key splitting (partial-key-grouping style): ``split_degree >= 2``
    #: enables ``Engine.split_keygroup`` — a hot key group's tuples fan
    #: round-robin across ``split_degree`` replica key groups (the parent
    #: plus ``split_degree - 1`` slots reserved from ``split_reserve``),
    #: each with its own partial σ, node placement and statistics, merged
    #: downstream by the operator's declared ``merge_state`` contract (see
    #: docs/workloads.md).  0 = disabled (no reserve slots are allocated,
    #: the data plane is byte-identical to the unsplit configuration).
    split_degree: int = 0
    #: Replica key-group slots reserved when ``split_degree > 0`` (bounds
    #: how many concurrent splits fit: each split consumes degree−1 slots).
    split_reserve: int = 16
    #: Periodic checkpoint cadence (None disables checkpoints).  Applies to
    #: the coordinator only — worker shards never checkpoint themselves.
    checkpoint: Optional[CheckpointPolicy] = None
    #: Worker supervision (heartbeat deadlines + respawn).  Multi-worker
    #: runtime only; None disables supervision (PR 7 death semantics).
    supervision: Optional[SupervisionPolicy] = None

    def __post_init__(self) -> None:
        if self.queue_impl not in ("soa", "deque"):
            raise ValueError(f"unknown queue_impl {self.queue_impl!r}")
        if self.use_fn_jit and (self.queue_impl != "soa" or not self.use_schema):
            raise ValueError(
                "use_fn_jit requires queue_impl='soa' and use_schema=True "
                "(the jit tier executes native columns over SoA segments)"
            )
        if self.use_superstep and not self.use_fn_jit:
            raise ValueError(
                "use_superstep requires use_fn_jit=True (the fused tick "
                "compiles fn_jit bodies)"
            )
        if self.num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        if self.shm_lane_bytes < 0:
            raise ValueError("shm_lane_bytes must be >= 0 (0 disables shm lanes)")
        if 0 < self.shm_lane_bytes < 64:
            raise ValueError(
                "shm_lane_bytes must be 0 or >= 64 (a ring smaller than one "
                "record header can never deliver)"
            )
        if self.num_workers > 1 and (self.use_fn_jit or self.use_superstep):
            raise ValueError(
                "the multi-worker runtime runs the numpy tiers only "
                "(use_fn_jit/use_superstep are single-process; see "
                "docs/execution_tiers.md)"
            )
        if self.split_degree:
            if self.split_degree < 2:
                raise ValueError(
                    "split_degree must be 0 (disabled) or >= 2 (a split fans "
                    "a key group across at least two replicas)"
                )
            if self.split_reserve < self.split_degree - 1:
                raise ValueError(
                    "split_reserve must fit at least one split "
                    "(split_degree - 1 replica slots)"
                )
            if self.num_workers > 1 or self.use_fn_jit:
                raise ValueError(
                    "hot-key splitting runs on the single-process numpy "
                    "tiers only (replica key groups live outside the jit "
                    "tier's per-operator column space; see docs/workloads.md)"
                )
        if self.split_reserve < 0:
            raise ValueError("split_reserve must be >= 0")
        if self.supervision is not None and self.num_workers == 1:
            raise ValueError(
                "supervision requires the multi-worker runtime "
                "(num_workers > 1); the single-process engine has no worker "
                "processes to supervise"
            )
        if (
            self.supervision is not None
            and self.supervision.respawn
            and self.checkpoint is None
        ):
            raise ValueError(
                "supervision with respawn=True requires a CheckpointPolicy "
                "(a respawned worker restores its key groups from the "
                "latest checkpoint)"
            )

    # -- presets --------------------------------------------------------------
    @classmethod
    def oracle(cls) -> "ExecutionConfig":
        """Legacy deque queue, per-run ``fn`` only — the semantic oracle."""
        return cls(queue_impl="deque", use_fn_seg=False, use_schema=False)

    @classmethod
    def seg(cls) -> "ExecutionConfig":
        """SoA queues + ``fn_seg``, schemas stripped (object-array edges)."""
        return cls(use_schema=False)

    @classmethod
    def typed(cls) -> "ExecutionConfig":
        """SoA + ``fn_seg`` + declared schemas — the default configuration."""
        return cls()

    @classmethod
    def jit(cls, *, mesh: Any = None, mesh_axis: Optional[str] = None):
        """``.typed()`` plus the compiled ``fn_jit`` tier."""
        return cls(use_fn_jit=True, jit_mesh=mesh, jit_mesh_axis=mesh_axis)

    @classmethod
    def superstep(cls, *, mesh: Any = None, mesh_axis: Optional[str] = None):
        """``.jit()`` plus whole-tick fusion into one device program."""
        return cls(
            use_fn_jit=True,
            use_superstep=True,
            jit_mesh=mesh,
            jit_mesh_axis=mesh_axis,
        )

    @classmethod
    def workers(
        cls,
        n: int,
        *,
        shm: int = SHM_LANE_BYTES,
        checkpoint: Optional[CheckpointPolicy] = None,
        supervision: Optional[SupervisionPolicy] = None,
    ) -> "ExecutionConfig":
        """``.typed()`` sharded over ``n`` OS worker processes.

        ``shm`` sizes each (sender → receiver) shared-memory exchange lane
        in bytes (default 1 MiB; see :data:`SHM_LANE_BYTES`).  ``shm=0``
        disables the shm lanes and exchanges everything over the pickled
        queue path.  ``checkpoint``/``supervision`` enable the self-healing
        layer (docs/fault_tolerance.md).
        """
        return cls(
            num_workers=int(n),
            shm_lane_bytes=int(shm),
            checkpoint=checkpoint,
            supervision=supervision,
        )

    @classmethod
    def split(cls, degree: int = 2, *, reserve: int = 16) -> "ExecutionConfig":
        """``.typed()`` plus hot-key splitting enabled at ``degree`` replicas
        per split (``reserve`` bounds concurrent splits — see
        :attr:`split_reserve`)."""
        return cls(split_degree=int(degree), split_reserve=int(reserve))

    # -- plumbing -------------------------------------------------------------
    @classmethod
    def from_legacy_kwargs(cls, legacy: dict) -> "ExecutionConfig":
        """Map the deprecated Engine kwargs onto a config (shim helper)."""
        unknown = set(legacy) - set(LEGACY_EXECUTION_KWARGS)
        if unknown:
            raise TypeError(f"unknown execution kwargs: {sorted(unknown)}")
        mapped = dict(legacy)
        if "superstep" in mapped:
            mapped["use_superstep"] = mapped.pop("superstep")
        return cls(**mapped)

    def replace(self, **changes) -> "ExecutionConfig":
        return dataclasses.replace(self, **changes)

    @property
    def name(self) -> str:
        """Short display name (the conformance harness's config labels)."""
        parts = [self.queue_impl, "seg" if self.use_fn_seg else "fn"]
        if self.use_schema:
            parts.append("schema")
        if self.use_fn_jit:
            parts.append("jit")
        if self.use_superstep:
            parts.append("superstep")
        if self.num_workers > 1:
            parts.append("workers")
        if self.split_degree:
            parts.append(f"split{self.split_degree}")
        if self.checkpoint is not None:
            parts.append(f"ckpt{self.checkpoint.every}")
        if self.supervision is not None:
            parts.append("supervised")
        return "+".join(parts)

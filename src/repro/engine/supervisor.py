"""Cluster supervision: liveness, periodic checkpoints, worker respawn.

The :class:`Supervisor` rides inside the coordinator and closes the loop
the fault-injection tests open:

* **Liveness** — every worker heartbeats over its report queue after each
  command (:func:`repro.engine.cluster._worker_main`).  A worker with
  outstanding commands and no message for ``hb_interval_s * hb_misses``
  seconds is *wedged, not dead* — the supervisor escalates it to SIGKILL
  (``escalate_wedged``), turning a hang into the crash the recovery path
  already handles.  The deadline must exceed the worst legitimate tick
  time: a worker mid-tick is silent by design (see
  docs/fault_tolerance.md).
* **Checkpoints** — every ``CheckpointPolicy.every``-th period boundary,
  ``note_period`` assembles one consistent payload from worker exports
  (σ + parked backlog per key group, non-destructively), the routing
  table, the folded :class:`~repro.core.stats.ClusterState` and the
  ingestion cursor, and commits it through the atomic stage-and-rename
  manifest (:mod:`repro.engine.checkpointing`).  The coordinator's replay
  buffer is pruned to admissions after the cut.
* **Recovery = reconfiguration** — on worker death, ``recover`` rewinds
  the whole cluster to the latest checkpoint: barrier on in-flight ticks,
  bounded-backoff respawn over fresh exchange lanes, survivors re-attach
  via ``peer_up``, every worker adopts the checkpoint table (re-homed
  through ALBIC or the MILP — the same allocators that drive planned
  reconfiguration, with orphan state bytes zeroed since their envelopes
  ship from the checkpoint, not a live node), envelopes reinstall at
  their new homes, and the coordinator replays buffered post-checkpoint
  admissions one tick each.  Everything after the recovery's sink mark is
  bit-identical to a fresh engine restored from the same checkpoint and
  fed the same batches (pinned by tests/test_supervisor.py).

A rewind is not amnesia: sinks emitted between the checkpoint and the
crash stay in ``metrics.sink_outputs`` and are re-emitted by the replay —
recovery is at-least-once across the cut, and the duplicate/loss
accounting is measured by ``benchmarks/fault_recovery.py``.
"""

from __future__ import annotations

import copy
import dataclasses
import time
from typing import Optional

import numpy as np

from repro.engine import checkpointing
from repro.engine.checkpointing import EngineCheckpointer


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """What one recovery attempt did (``ClusterEngine.recoveries``)."""

    worker: int
    cause: str  # "kill" | "hang" | "delay" | "wedged" | "crash"
    respawn_attempt: int
    mttr_s: float  # death detection → cluster serving again
    gave_up: bool = False  # respawn budget exhausted: fail_node semantics
    restored_step: int = -1  # checkpoint step rewound to (-1: from scratch)
    restored_cursor: int = 0  # admissions covered by the checkpoint
    restored_sink_len: int = 0  # sink mark: tail after this is oracle-equal
    orphans: int = 0  # key groups homed on the dead worker at the cut
    rehomed: int = 0  # key groups the allocator moved during recovery
    replayed_batches: int = 0  # buffered admissions re-shipped after rewind


class Supervisor:
    """Per-cluster supervision state machine (coordinator-side, no threads).

    All hooks run on the coordinator's thread at deterministic points —
    ``note_*`` from the report loop, ``escalate_wedged`` from the receive
    poll, ``recover`` from the safe-point scheduler — so supervision never
    races the data plane.
    """

    def __init__(self, cluster) -> None:
        self.cluster = cluster
        self.policy = cluster.config.supervision
        self.checkpointer: Optional[EngineCheckpointer] = (
            EngineCheckpointer(cluster.config.checkpoint)
            if cluster.config.checkpoint is not None
            else None
        )
        self.last_activity: dict[int, float] = {}
        self.last_done: dict[int, int] = {}
        self.cause: dict[int, str] = {}
        self.attempts: dict[int, int] = {}
        for w in range(cluster.num_workers):
            self.note_spawn(w)

    # ------------------------------------------------------------- liveness
    def note_spawn(self, wid: int) -> None:
        self.last_activity[wid] = time.monotonic()
        self.last_done[wid] = 0

    def note_activity(self, wid: int) -> None:
        self.last_activity[wid] = time.monotonic()

    def note_hb(self, wid: int, done: int) -> None:
        self.last_activity[wid] = time.monotonic()
        self.last_done[wid] = done
        if done >= self.cluster.pool.sent_counts[wid] and self.cause.get(
            wid
        ) in ("hang", "delay"):
            # Caught up: an injected hang/delay that ran to completion is
            # no longer this worker's cause of anything.  A noted "kill"
            # sticks — the victim's final heartbeat (drained at death)
            # legitimately shows it caught up.
            self.cause.pop(wid)

    def note_fault(self, wid: int, event) -> None:
        self.cause[wid] = event.kind

    def escalate_wedged(self) -> bool:
        """SIGKILL workers with outstanding commands past the deadline.

        Wedged ≠ dead: the process is alive but its command loop has gone
        silent.  Escalation converts it into the crash the recovery path
        handles.  Returns True if anyone was killed (the caller re-runs
        death detection).
        """
        if self.policy is None:
            return False
        c = self.cluster
        now = time.monotonic()
        overdue = []
        for w in c._alive_workers():
            if c.pool.sent_counts[w] <= self.last_done.get(w, 0):
                continue  # no outstanding work: silence is idleness
            silence = now - self.last_activity.get(w, now)
            if silence <= self.policy.deadline_s:
                continue
            if not c.pool.alive(w):
                continue  # already dead; the poll loop handles it
            overdue.append((silence, w))
        if not overdue:
            return False
        # One victim per pass — the longest-silent worker.  A peer blocked
        # in the BSP exchange *on the victim* advertises liveness with
        # ``hb_wait`` messages (waiting ≠ wedged), so under normal delivery
        # only the true wedge is ever overdue.  The single-victim rule is
        # the backstop for delayed wait-heartbeats: restart everyone else's
        # clock; a genuinely wedged peer goes silent again and is next.
        _, victim = max(overdue)
        self.cause.setdefault(victim, "wedged")
        c.pool.kill(victim)
        for w in c._alive_workers():
            if w != victim:
                self.note_activity(w)
        return True

    # ----------------------------------------------------------- checkpoints
    def note_period(self, state) -> None:
        """Checkpoint cadence hook — called once per ``end_period`` fold."""
        ck = self.checkpointer
        if ck is None:
            return
        ck.periods_seen += 1
        if ck.periods_seen % ck.policy.every:
            return
        payload = self._cluster_payload(state)
        ck.save(None, payload=payload)
        cut = int(payload["ingest_cursor"])
        c = self.cluster
        c._replay = [e for e in c._replay if e[0] > cut]
        # A committed checkpoint is forward progress: reopen the full
        # respawn budget for future failures.
        self.attempts.clear()

    def _cluster_payload(self, state) -> dict:
        """Assemble the engine-checkpoint payload from worker exports.

        Called right after the ``end_period`` fold, so worker windows are
        freshly reset — the checkpointed window is empty and
        ``ticks_this_period`` is 0 by construction, exactly what a
        single-process engine checkpointing at the same boundary records.
        """
        c = self.cluster
        g = c.topology.num_keygroups
        owner = c.node_worker[c.router.table]
        wids = c._alive_workers()
        for w in wids:
            kgs = [int(k) for k in np.flatnonzero(owner == w)]
            c.pool.send(w, ("export_all", kgs))
        envelopes: dict[int, bytes] = {}
        for blobs in c._await_acks(wids, "export_all").values():
            envelopes.update(blobs)
        return {
            "version": checkpointing.PAYLOAD_VERSION,
            "table": c.router.table.copy(),
            "alive": c.alive.copy(),
            "capacity": c.capacity.copy(),
            "num_nodes": int(c.num_nodes),
            "envelopes": envelopes,
            # The cluster runtime never splits hot keys worker-side; the
            # trivial split state keeps the payload oracle-restorable.
            "split": {"map": {}, "rr": {}, "free": [], "kg_op": c._kg_op.copy()},
            "window": checkpointing.empty_window_peek(g, c._window_resources),
            "ticks_this_period": 0,
            "ticks": int(c.metrics.ticks),
            "ingest_cursor": int(c.ingest_cursor),
            "sink_len": len(c.metrics.sink_outputs),
            # The fold that triggered this checkpoint — recovery re-homes
            # against the loads the cluster actually had at the cut.
            "folded_state": state,
        }

    # -------------------------------------------------------------- recovery
    def recover(self, wid: int) -> None:
        """Respawn ``wid`` and rewind the cluster to the latest checkpoint.

        Runs only at safe points (no tick in flight once the barrier
        drains).  If a *second* worker dies mid-recovery the partial work
        is abandoned — the next scheduled recovery redoes the global
        rewind from the same checkpoint, which is idempotent.
        """
        c = self.cluster
        death = c._death_ts.get(wid, time.monotonic())
        cause = self.cause.pop(wid, "crash")
        attempt = self.attempts.get(wid, 0) + 1
        self.attempts[wid] = attempt
        if attempt > self.policy.max_respawns:
            c.recoveries.append(
                RecoveryReport(
                    worker=wid,
                    cause=cause,
                    respawn_attempt=attempt,
                    mttr_s=time.monotonic() - death,
                    gave_up=True,
                )
            )
            return  # stays dead: plain fail_node semantics from here on
        try:
            self._recover(wid, cause, attempt, death)
        except Exception:
            if c._needs_recovery:
                # Another death landed mid-recovery.  Abandon this pass;
                # make sure wid is rescheduled if it never respawned.
                if wid in c._dead_workers and wid not in c._needs_recovery:
                    c._needs_recovery.append(wid)
                return
            raise

    def _recover(self, wid: int, cause: str, attempt: int, death: float) -> None:
        c = self.cluster
        # Barrier: every commanded tick must merge before the rewind, so
        # no exchange is in flight anywhere (survivors' rings are drained,
        # their stashes empty) and `_merge_ready_ticks` never waits on the
        # replacement for a tick commanded to the dead incarnation.
        if c._pending_ticks:
            c._wait_tick(c._pending_ticks[-1])
        payload: Optional[dict] = None
        if self.checkpointer is not None:
            payload, _ = self.checkpointer.latest_payload()
        g = c.topology.num_keygroups
        if payload is None:
            # No checkpoint committed yet: rewind to T0 — the replay
            # buffer holds every admission since start.
            restored_step = -1
            payload = {
                "table": c._initial_alloc.copy(),
                "alive": np.ones(c.num_nodes, dtype=bool),
                "envelopes": {},
                "window": checkpointing.empty_window_peek(
                    g, c._window_resources
                ),
                "ticks_this_period": 0,
                "ingest_cursor": 0,
                "folded_state": None,
            }
        else:
            restored_step = int(payload.get("ticks", -1))
            if int(payload["num_nodes"]) != c.num_nodes:
                raise RuntimeError(
                    "recovery across an elastic resize is not supported: "
                    f"checkpoint has {payload['num_nodes']} nodes, "
                    f"cluster has {c.num_nodes}"
                )
        ck_table = np.asarray(payload["table"], dtype=np.int64)
        orphans = np.flatnonzero(c.node_worker[ck_table] == wid)
        # Alive mask after recovery: the checkpoint's view, minus nodes of
        # workers that are (still) dead.  Explicit fail_node calls after
        # the cut are forgotten — a rewind resurrects what the checkpoint
        # saw (documented in docs/fault_tolerance.md).
        new_alive = np.asarray(payload["alive"], dtype=bool).copy()
        for w2 in c._dead_workers:
            if w2 != wid:
                new_alive[c.node_worker == w2] = False
        # Re-home against post-recovery capacity (the respawn brings the
        # dead worker's nodes back): the allocator decides whether orphans
        # return home or spread.
        new_table, rehomed = self._rehome(payload, orphans, new_alive)
        # Bounded exponential backoff before the fork (a crash-looping
        # replacement must not melt the host).
        delay = min(
            self.policy.backoff_cap_s,
            self.policy.backoff_base_s * (2 ** (attempt - 1)),
        )
        if delay > 0:
            time.sleep(delay)
        spec = c.pool.spec
        spec["initial_alloc"] = new_table.copy()
        spec["dead_peers"] = sorted(c._dead_workers - {wid})
        spec["start_dead_nodes"] = np.flatnonzero(~new_alive).tolist()
        in_names, out_names = c.pool.respawn(wid)
        c._dead_workers.discard(wid)
        c.alive[: len(new_alive)] = new_alive
        c._worst[wid] = 0.0
        # Stale acks from the dead incarnation must not satisfy waits on
        # the replacement.
        c._stashed_acks = {
            k: v for k, v in c._stashed_acks.items() if k[0] != wid
        }
        c._last_hb.pop(wid, None)
        self.note_spawn(wid)
        # Survivors first: re-attach fresh lanes and mark the returned
        # nodes alive, *before* any restore traffic routes to them.
        mine = c.node_worker == wid
        up_nodes = np.flatnonzero(mine & new_alive).tolist()
        survivors = [w for w in c._alive_workers() if w != wid]
        for w in survivors:
            c.pool.send(
                w,
                (
                    "peer_up",
                    wid,
                    up_nodes,
                    in_names[w] if in_names is not None else None,
                    out_names[w] if out_names is not None else None,
                ),
            )
        c._await_acks(survivors, "peer_up")
        # Global rewind: every replica table adopts the recovered
        # allocation, every transient drops, σ reinstalls from envelopes.
        c.router.reset(new_table)
        c._command_all(("restore", new_table.copy()), "restore")
        per_worker: dict[int, dict[int, bytes]] = {}
        for kg, blob in payload["envelopes"].items():
            w = int(c.node_worker[new_table[int(kg)]])
            if w not in c._dead_workers:
                per_worker.setdefault(w, {})[int(kg)] = blob
        for w, blobs in per_worker.items():
            c.pool.send(w, ("install_bulk", blobs))
        c._await_acks(sorted(per_worker), "install_bulk")
        c._window_base = payload["window"]
        c._ticks_this_period = int(payload["ticks_this_period"])
        restored_cursor = int(payload["ingest_cursor"])
        sink_mark = len(c.metrics.sink_outputs)
        # Replay: re-ship each buffered post-checkpoint admission in its
        # own tick (the drive shape the conformance harness uses).  These
        # are re-emissions of work already admitted — no credit check.
        replay = [e for e in c._replay if e[0] > restored_cursor]
        for _, oid, batch in replay:
            c._ship_batch(oid, batch)
            c.tick()
        c.recoveries.append(
            RecoveryReport(
                worker=wid,
                cause=cause,
                respawn_attempt=attempt,
                mttr_s=time.monotonic() - death,
                restored_step=restored_step,
                restored_cursor=restored_cursor,
                restored_sink_len=sink_mark,
                orphans=int(len(orphans)),
                rehomed=rehomed,
                replayed_batches=len(replay),
            )
        )
        c._death_ts.pop(wid, None)

    def _rehome(self, payload: dict, orphans: np.ndarray, alive: np.ndarray):
        """Recovery *is* reconfiguration: place the checkpoint's key groups
        through the same allocators that drive planned reconfiguration.

        Orphan state bytes are zeroed first — their envelopes ship from
        the checkpoint, not a live node, so moving them is free (the same
        treatment ``Controller.handle_node_failure`` applies).
        """
        table = np.asarray(payload["table"], dtype=np.int64).copy()
        mode = self.policy.rehome if self.policy is not None else "keep"
        state = payload.get("folded_state")
        if mode == "keep" or state is None or not len(orphans):
            return table, 0
        state = copy.deepcopy(state)
        state.alloc = table.copy()
        state.kg_state_bytes = np.asarray(
            state.kg_state_bytes, dtype=float
        ).copy()
        state.kg_state_bytes[orphans] = 0.0
        state.alive = np.asarray(alive, dtype=bool).copy()
        if mode == "milp":
            from repro.core.milp import solve_allocation

            new = np.asarray(solve_allocation(state).alloc, dtype=np.int64)
        else:
            from repro.core.albic import albic

            new = np.asarray(albic(state).plan.alloc, dtype=np.int64)
        return new.copy(), int((new != table).sum())

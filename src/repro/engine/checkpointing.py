"""Engine checkpoints: one consistent snapshot under one atomic manifest.

A checkpoint captures everything the control plane needs to rebuild a
consistent engine at a period-ish boundary:

* the routing table (including replica slots),
* every key group's state envelope — σ_k plus any *parked* migration
  backlog, exported non-destructively (unlike ``Engine.serialize`` this
  never pops the backlog: checkpointing must not mutate the engine),
* hot-key split topology and the round-robin fan-out cursors (replica
  placement is bit-exact across a restore — the cursor is part of the
  data-plane state),
* the partial SPL window (usage, arrivals, pair sends) and the period's
  tick count, so the first post-restore ``end_period`` folds the same
  statistics the original would have,
* the ingestion cursor — how many source batches were admitted — so a
  supervisor can replay exactly the admissions after the cut.

What it deliberately does **not** capture: tuples sitting in work queues
or router in-flight buffers at the cut.  Their effects up to the cut are
in σ; re-processing after a rewind is covered by replaying admissions
*after* the cursor.  Queued-but-unprocessed tuples from admissions
*before* the cursor are the loss bound of a recovery — bounded by the
credit window, see docs/fault_tolerance.md.

The snapshot is a plain dict pickled into one uint8 leaf of a
:func:`repro.checkpoint.checkpoint.save_pytree` tree, so the existing
atomic stage-and-rename commit (manifest written last) applies unchanged.
Both the single-process :class:`~repro.engine.executor.Engine` and the
multi-worker coordinator produce this payload shape — recovery conformance
tests restore a cluster-written checkpoint into a single-process oracle.
"""

from __future__ import annotations

import pickle
from typing import Any, Optional

import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.engine import serde
from repro.engine.config import CheckpointPolicy

PAYLOAD_VERSION = 1


# -- building blocks ----------------------------------------------------------
def keygroup_blob(engine, kg: int) -> bytes:
    """Non-destructive checkpoint envelope for one key group.

    ``Engine.serialize`` *pops* the parked migration backlog into the blob
    (migration hand-off semantics); a checkpoint must leave the engine
    untouched, so the backlog is copied, never popped.
    """
    if getattr(engine, "_jit", None) is not None:
        engine._jit.ensure_dict(kg)
    return serde.encode_migration(
        engine.store.serialize(kg), list(engine._backlog.get(kg, []))
    )


def window_peek(window) -> dict:
    """Copy the partial SPL window without folding or resetting it."""
    pairs = window.pair_counts()  # compacts in place; non-destructive
    return {
        "usage": {r: u.copy() for r, u in window.kg_usage.items()},
        "arrivals": window.kg_arrivals.copy(),
        "pairs": (pairs.src.copy(), pairs.dst.copy(), pairs.rate.copy()),
        "samples": int(window.samples),
    }


def window_restore(window, peek: dict) -> None:
    window.reset()
    for r, u in peek["usage"].items():
        window.kg_usage[r][:] = u
    window.kg_arrivals[:] = peek["arrivals"]
    src, dst, rate = peek["pairs"]
    if len(src):
        window.record_send_counts(src, dst, rate)
    window.samples = int(peek["samples"])


def window_merge(into: dict, part: dict) -> None:
    """Fold one worker's window peek into an accumulating peek dict."""
    for r, u in part["usage"].items():
        into["usage"][r] = into["usage"].get(r, 0) + u
    into["arrivals"] = into["arrivals"] + part["arrivals"]
    src, dst, rate = part["pairs"]
    isrc, idst, irate = into["pairs"]
    into["pairs"] = (
        np.concatenate([isrc, src]),
        np.concatenate([idst, dst]),
        np.concatenate([irate, rate]),
    )
    into["samples"] = int(into.get("samples", 0)) + int(part["samples"])


def empty_window_peek(g: int, resources=("cpu", "network", "memory")) -> dict:
    z = np.zeros(0, dtype=np.int64)
    return {
        "usage": {r: np.zeros(g) for r in resources},
        "arrivals": np.zeros(g),
        "pairs": (z, z, np.zeros(0)),
        "samples": 0,
    }


def split_state(engine) -> dict:
    return {
        "map": {int(p): [int(s) for s in fam] for p, fam in engine._split_map.items()},
        "rr": {int(p): int(c) for p, c in engine._split_rr.items()},
        "free": [int(s) for s in engine._free_slots],
        "kg_op": engine._kg_op.copy(),
    }


# -- single-process snapshot / restore ---------------------------------------
def snapshot_payload(engine, *, ingest_cursor: Optional[int] = None) -> dict:
    """One consistent snapshot of a single-process engine (a dict).

    The multi-worker coordinator assembles the same shape from worker
    exports (see :mod:`repro.engine.supervisor`).
    """
    if getattr(engine, "_superstep", None) is not None:
        engine._superstep.flush_to_host()
    if getattr(engine, "_jit", None) is not None:
        engine._jit.sync_store()
    g_eff = len(engine.router.table)
    cursor = engine.ingest_cursor if ingest_cursor is None else int(ingest_cursor)
    return {
        "version": PAYLOAD_VERSION,
        "table": engine.router.table.copy(),
        "alive": engine.alive.copy(),
        "capacity": engine.capacity.copy(),
        "num_nodes": int(engine.num_nodes),
        "envelopes": {kg: keygroup_blob(engine, kg) for kg in range(g_eff)},
        "split": split_state(engine),
        "window": window_peek(engine.window),
        "ticks_this_period": int(engine._ticks_this_period),
        "ticks": int(engine.metrics.ticks),
        "ingest_cursor": cursor,
        "sink_len": len(engine.metrics.sink_outputs),
    }


def restore_engine(engine, payload: dict) -> None:
    """Rewind a single-process engine to a checkpoint payload, in place.

    The engine must have been built from the same topology/config family
    (same extended key-group space).  Transients — queued runs, parked
    backlogs, pending outputs, router buffers — are dropped; σ comes from
    the envelopes, statistics from the window peek.  Cumulative metrics
    and collected sinks are left alone (a restore is not amnesia: emitted
    duplicates are measured, not erased).
    """
    if payload.get("version") != PAYLOAD_VERSION:
        raise ValueError(f"unknown checkpoint payload version {payload.get('version')}")
    g_eff = len(engine.router.table)
    if len(payload["table"]) != g_eff:
        raise ValueError(
            "checkpoint key-group space mismatch: "
            f"{len(payload['table'])} != {g_eff}"
        )
    if getattr(engine, "_superstep", None) is not None:
        engine._superstep.flush_to_host()
    if int(payload["num_nodes"]) > engine.num_nodes:
        engine.add_nodes(int(payload["num_nodes"]) - engine.num_nodes)
    for q in engine._queues:
        q.clear()
    engine._backlog.clear()
    engine._out_pending.clear()
    engine.router.reset(payload["table"])
    engine.alive[: len(payload["alive"])] = payload["alive"]
    engine.capacity[: len(payload["capacity"])] = payload["capacity"]
    engine._capacity_list = engine.capacity.tolist()
    # Split topology + fan-out cursors before installs (kg → operator).
    sp = payload["split"]
    engine._split_map = {int(p): list(f) for p, f in sp["map"].items()}
    engine._split_parent = {
        int(s): int(p) for p, fam in sp["map"].items() for s in fam
    }
    engine._split_rr = {int(p): int(c) for p, c in sp["rr"].items()}
    engine._free_slots = list(sp["free"])
    engine._kg_op = np.asarray(sp["kg_op"], dtype=np.int64).copy()
    engine._rebuild_split_tables()
    # σ: wipe, then install every envelope at its checkpointed node.
    table = payload["table"]
    for kg in range(g_eff):
        engine.store.put(kg, {})
    for kg in sorted(payload["envelopes"]):
        engine.install(int(kg), int(table[kg]), payload["envelopes"][kg])
    window_restore(engine.window, payload["window"])
    engine._ticks_this_period = int(payload["ticks_this_period"])
    engine.ingest_cursor = int(payload["ingest_cursor"])


# -- manifest plumbing --------------------------------------------------------
def payload_to_tree(payload: dict) -> dict:
    """Pack the payload as a one-leaf pytree for ``save_pytree``."""
    return {"payload_u8": np.frombuffer(pickle.dumps(payload), dtype=np.uint8)}


def payload_from_tree(tree: Any) -> dict:
    leaf = np.asarray(tree["payload_u8"], dtype=np.uint8)
    return pickle.loads(leaf.tobytes())


class EngineCheckpointer:
    """Drives :class:`CheckpointManager` from a :class:`CheckpointPolicy`.

    ``note_period`` is the cadence hook — call it once per ``end_period``;
    every ``policy.every``-th call commits a checkpoint synchronously (the
    atomic stage-and-rename is the commit point).  ``step`` is the engine's
    cumulative tick count: unique, monotone, and meaningful in logs.
    """

    def __init__(self, policy: CheckpointPolicy) -> None:
        self.policy = policy
        self.manager = CheckpointManager(policy.directory, keep=policy.keep)
        self.periods_seen = 0

    def note_period(self, engine) -> Optional[int]:
        self.periods_seen += 1
        if self.periods_seen % self.policy.every:
            return None
        return self.save(engine)

    def save(self, engine, *, payload: Optional[dict] = None) -> int:
        payload = snapshot_payload(engine) if payload is None else payload
        step = int(payload["ticks"])
        self.manager.save(
            step,
            payload_to_tree(payload),
            metadata={
                "period": self.periods_seen,
                "ingest_cursor": int(payload["ingest_cursor"]),
                "sink_len": int(payload["sink_len"]),
            },
        )
        return step

    def latest_payload(self) -> tuple[Optional[dict], dict]:
        """(payload, metadata) of the newest complete checkpoint, or (None, {})."""
        if self.manager.latest_step() is None:
            return None, {}
        tree, meta = self.manager.restore()
        return payload_from_tree(tree), meta

"""The controller (paper §3): collects statistics, runs Algorithm 1, applies
migrations and scaling against the live engine.

One `period()` call = one SPL: run ``ticks_per_period`` engine ticks (the
caller feeds sources between ticks), fold statistics, adapt, migrate, and
append a metrics row — the rows are exactly the series plotted in the paper's
Figures 6–14 (load distance, #migrations, collocation factor, load index).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.framework import AdaptationFramework, AdaptationResult
from repro.core.migration import execute_plan
from repro.core.stats import ClusterState
from repro.engine.executor import Engine


@dataclasses.dataclass
class ControllerConfig:
    ticks_per_period: int = 20
    warmup_periods: int = 1  # discarded, like the paper's JIT warm-up window


@dataclasses.dataclass
class PeriodMetrics:
    period: int
    load_distance: float
    collocation_factor: float
    system_load: float
    load_index: float
    num_migrations: int
    migration_cost: float
    migration_pause_s: float
    latency: dict[str, float]
    num_nodes_alive: int
    scaling_added: int
    scaling_marked: int
    solver_seconds: float
    # Hot-key splitting activity this period (0 without a splitter policy).
    num_splits: int = 0
    num_unsplits: int = 0
    #: Worker recoveries (supervised respawn + rewind) completed this period.
    num_recoveries: int = 0


class Controller:
    """Periodic adaptation driver for a live :class:`Engine`."""

    def __init__(
        self,
        engine: Engine,
        framework: AdaptationFramework,
        config: ControllerConfig | None = None,
        feeder: Optional[Callable[[Engine, int], None]] = None,
    ) -> None:
        self.engine = engine
        self.framework = framework
        self.config = config or ControllerConfig()
        self.feeder = feeder  # called before each tick to push source data
        self.history: list[PeriodMetrics] = []
        self._period = 0
        self._baseline_system_load: Optional[float] = None

    def run_ticks(self, ticks: int) -> None:
        for t in range(ticks):
            if self.feeder is not None:
                self.feeder(self.engine, self.engine.metrics.ticks)
            self.engine.tick()

    def period(self, *, adapt: bool = True) -> PeriodMetrics:
        """One SPL: execute ticks, snapshot stats, adapt, migrate, record."""
        recoveries_before = len(getattr(self.engine, "recoveries", ()))
        self.run_ticks(self.config.ticks_per_period)
        snapshot = self.engine.end_period()

        result: Optional[AdaptationResult] = None
        pause_s = 0.0
        num_splits = num_unsplits = 0
        if adapt and self._period >= self.config.warmup_periods:
            splitting = self.framework.splitter is not None
            result = self.framework.adapt(
                snapshot,
                split_families=(
                    self.engine.split_families() if splitting else None
                ),
                split_eligible=(
                    self.engine.split_eligible() if splitting else None
                ),
            )
            # Elastic scaling against the engine.
            if result.scaling.add_nodes:
                self.engine.add_nodes(result.scaling.add_nodes)
            # Terminated nodes: drop from engine liveness.
            for node in result.terminated:
                self.engine.alive[node] = False
            # Direct state migration over the engine (StateMover protocol).
            report = execute_plan(result.migration_plan, self.engine)
            pause_s = report.pause_seconds
            # Apply the advisory split decision after the migrations: the
            # plan ran synchronously, so no family member is in flight, and
            # new replicas become ordinary key groups in the next snapshot.
            if result.split is not None:
                degree = self.engine.config.split_degree
                for kg in result.split.unsplit:
                    self.engine.unsplit_keygroup(kg)
                    num_unsplits += 1
                for kg in result.split.split:
                    if self.engine.split_slots_free < degree - 1:
                        break  # reserve exhausted; retry next period
                    self.engine.split_keygroup(kg)
                    num_splits += 1

        alloc = self.engine.router.table
        # Post-adaptation view: after scaling, `snapshot` predates the new
        # nodes while `alloc` may already reference them.
        if result is not None:
            snapshot = result.state
        # Measured kg_load already embeds serialization CPU (the engine charges
        # it per cross-node tuple), so no analytic ser term is added here.
        sys_load = snapshot.system_load(alloc, ser_cost=0.0)
        warmed = self._period >= self.config.warmup_periods
        if self._baseline_system_load is None and warmed:
            self._baseline_system_load = max(sys_load, 1e-9)
        load_index = (
            100.0 * sys_load / self._baseline_system_load
            if self._baseline_system_load
            else 100.0
        )

        metrics = PeriodMetrics(
            period=self._period,
            load_distance=snapshot.load_distance(alloc),
            collocation_factor=snapshot.collocation_factor(alloc),
            system_load=sys_load,
            load_index=load_index,
            num_migrations=result.migration_plan.num_migrations if result else 0,
            migration_cost=result.migration_plan.total_cost if result else 0.0,
            migration_pause_s=pause_s,
            latency=self.engine.latency.summary(),
            num_nodes_alive=int(np.sum(self.engine.alive)),
            scaling_added=result.scaling.add_nodes if result else 0,
            scaling_marked=len(result.scaling.mark_for_removal) if result else 0,
            solver_seconds=result.plan.solve_seconds if result else 0.0,
            num_splits=num_splits,
            num_unsplits=num_unsplits,
            num_recoveries=(
                len(getattr(self.engine, "recoveries", ())) - recoveries_before
            ),
        )
        self.engine.latency.reset()
        self.history.append(metrics)
        self._period += 1
        return metrics

    # -- fault tolerance ------------------------------------------------------
    def handle_node_failure(
        self, node: int, snapshot: ClusterState
    ) -> AdaptationResult:
        """Crash path: orphan the node's key groups and re-plan immediately.

        `snapshot` is the last folded statistics (or checkpointed) state; the
        failed node is marked dead so the MILP excludes it, and the orphaned
        key groups' migration cost is zeroed (their state is restored from the
        checkpoint, not serialized from the dead node).
        """
        orphans = self.engine.fail_node(node)
        snap = snapshot.copy()
        snap.alive[node] = False
        snap.kg_state_bytes = snap.kg_state_bytes.copy()
        snap.kg_state_bytes[orphans] = 0.0  # recovery is not a migration cost
        # Reallocate: a plan must exist, so lift the budget for the emergency.
        saved_cost = self.framework.max_migr_cost
        saved_migr = self.framework.max_migrations
        self.framework.max_migr_cost, self.framework.max_migrations = None, None
        try:
            result = self.framework.adapt(snap)
        finally:
            self.framework.max_migr_cost, self.framework.max_migrations = (
                saved_cost,
                saved_migr,
            )
        # Apply routing for orphans without serialize (state from checkpoint).
        for kg in orphans:
            dst = int(result.state.alloc[kg])
            self.engine.router.redirect(int(kg), dst)
            self.engine.install(int(kg), dst, self.engine.store.serialize(int(kg)))
        # Remaining moves use the normal mover path.
        orphan_set = set(orphans)
        rest = [
            m for m in result.migration_plan.moves if m.keygroup not in orphan_set
        ]
        for m in rest:
            self.engine.redirect(m.keygroup, m.dst)
            self.engine.install(m.keygroup, m.dst, self.engine.serialize(m.keygroup))
        return result

"""Device-resident superstep: route → drain → fn_jit fused into one jit call.

The per-operator compiled tier (:mod:`repro.engine.jitexec`) crosses the
host↔device boundary once per operator per tick: the host drains segments,
dispatches one padded program per operator, downloads the outputs, hashes
and sorts them on the host and pushes the runs back into numpy queues.  For
a linear chain of 1:1 ``fn_jit`` operators all of that inter-operator
traffic is avoidable — the routing hash is :func:`repro.engine.topology.mix32`
(pure integer arithmetic), the routing sort is a bucketed stable argsort
(:mod:`repro.kernels.radix_sort`), and the drained runs of tick ``t`` are
exactly the runs routed at tick ``t-1``.

This module fuses the whole tick for such chains:

* **Fused tick** (:meth:`SuperstepRuntime.try_fused_tick`) — one donated
  ``jax.jit`` call executes every fused operator's body *and* the device-side
  routing of its outputs (hash → stable bucketed argsort → gather).  Routed
  outputs stay on the device as *pending columns*; the queues hold **shadow
  segments** — run metadata (key groups, bounds, costs) with ``None`` arrays
  — so drain accounting, budgets, backpressure and migration bookkeeping
  replay bit-exactly on the host from the downloaded per-edge
  (source key group × destination key group) count matrices.  One host
  crossing per tick (``metrics.jit_host_syncs``), independent of chain depth.

* **K-tick scan** (:meth:`SuperstepRuntime.run_supersteps`) — steady-state
  mode: ``lax.scan`` wraps K fused ticks so the host boundary is crossed
  once per K supersteps.  Source batches are staged (hashed, radix-sorted,
  padded) up front.  When every non-terminal fused operator declares
  ``OperatorSpec.jit_key_map``, the entire routing schedule — every hop's
  hash, stable radix permutation and per-edge count matrix — is a pure
  function of the staged keys and is evaluated host-side during staging
  (numpy's radix path, ~35× faster than XLA's CPU comparison sort), so the
  compiled scan body carries no sorts at all; otherwise the scan routes on
  device and returns per-tick pair matrices as scan outputs.  Either way
  the statistics are folded into the engine in aggregate.  This is the
  throughput path benchmarked by ``engine_throughput/superstep_jit``; it
  reproduces
  every pinned aggregate (metrics, states, sink outputs, arrivals, usage,
  send pairs, queue costs) but records no per-admission latency samples and
  performs no per-tick credit checks — use :meth:`Engine.tick` when those
  matter.

Reconfiguration hook: every fused tick re-reads ``Router.table`` (cached on
``Router.version``), falls back to the classic tick — after
:meth:`flush_to_host` materializes the pending device columns into real
segment arrays — whenever a migration is in flight, a node is dead, a
budget would bind mid-segment, or the queues hold anything the fused replay
cannot express.  ``redirect``/``serialize``/``fail_node`` flush first, so
migration envelopes (:mod:`repro.engine.serde`) are byte-identical to the
interpreted oracle's at any superstep boundary.

Eligibility is static (checked once per engine): a single source followed by
a linear chain of ``jit_fusible`` 1:1 ``fn_jit`` operators with declared
matching schemas, identity partition keys of integer dtype and scalar-only
state fields.  Anything else simply never fuses — the engine behaves exactly
like the per-operator tier.
"""

from __future__ import annotations

import time
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.engine import jitexec as jx
from repro.engine.router import concat_batches
from repro.engine.topology import (
    _MASK31,
    _MIX_C1,
    _MIX_C2,
    _identity_key,
    _mixed_keygroups,
    mix32,
)
from repro.kernels.radix_sort import bucket_argsort, bucket_argsort_jax

__all__ = ["SuperstepRuntime", "mix32_jax", "local_keygroups_jax", "plan_chain"]


# --------------------------------------------------------------------------
# Device replica of the routing hash (bit-identical to topology.mix32).
# --------------------------------------------------------------------------


def mix32_jax(x: jax.Array) -> jax.Array:
    """Traceable :func:`repro.engine.topology.mix32`: int array → uint32.

    ``astype(uint64)`` sign-extends negative int32/int64 lanes exactly like
    numpy's ``astype`` (value mod 2^64), so every step below matches the
    host mix bit for bit.
    """
    u = x.astype(jnp.uint64)
    h = ((u ^ (u >> jnp.uint64(32))) & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(_MIX_C1)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(_MIX_C2)
    h = h ^ (h >> jnp.uint32(16))
    return h


def local_keygroups_jax(keys: jax.Array, nkg: int) -> jax.Array:
    """Traceable local key-group ids (``topology._mixed_keygroups`` − base)."""
    h = mix32_jax(keys) & jnp.uint32(_MASK31)
    if nkg & (nkg - 1) == 0:
        loc = h & jnp.uint32(nkg - 1)
    else:
        loc = h % jnp.uint32(nkg)
    return loc.astype(jnp.int64)


# --------------------------------------------------------------------------
# Static fusion plan.
# --------------------------------------------------------------------------


class _Plan:
    """Static description of the fusible chain: source, then fused ops."""

    __slots__ = ("source", "fops", "fset", "specs", "nkg", "base",
                 "key_maps", "static_route")

    def __init__(self, source, fops, specs, nkg, base):
        self.source = source
        self.fops = fops  # fused operator ids, chain order
        self.fset = frozenset(fops)
        self.specs = specs
        self.nkg = nkg
        self.base = base
        # Host-evaluable key transforms (OperatorSpec.jit_key_map) for the
        # non-terminal fused operators.  When every one is declared, the
        # K-tick scan's routing schedule (hash → stable radix permutation →
        # pair-count matrices) is a pure function of the staged input keys,
        # so run_supersteps evaluates it on the host and the compiled scan
        # body carries no sorts at all.
        self.key_maps = [s.jit_key_map for s in specs[:-1]]
        self.static_route = all(m is not None for m in self.key_maps)


def plan_chain(engine) -> Optional[_Plan]:
    """Static superstep eligibility; ``None`` → this engine never fuses."""
    topo = engine.topology
    if engine.kernel_stats or engine._jit_mesh is not None:
        return None
    if not engine.use_schema:
        return None
    downs, ups = topo.downstream(), topo.upstream()
    sources = [i for i, o in enumerate(topo.operators) if o.is_source]
    if len(sources) != 1:
        return None
    src = sources[0]
    if topo.operators[src].fn is not None or topo.operators[src].schema is None:
        return None
    chain = [src]
    cur = src
    while downs[cur]:
        if len(downs[cur]) != 1:
            return None
        nxt = downs[cur][0]
        if len(ups[nxt]) != 1:
            return None
        chain.append(nxt)
        cur = nxt
    if len(chain) < 2 or len(chain) != topo.num_operators:
        return None
    if not engine._op_terminal[chain[-1]]:
        return None
    prev_out = topo.operators[src].schema
    for pos, op in enumerate(chain[1:]):
        spec = topo.operators[op]
        terminal = op == chain[-1]
        if engine._op_fn_jit[op] is None or not spec.jit_fusible:
            return None
        if spec.fn is None or spec.schema is None:
            return None
        if spec.key_fn is not _identity_key or spec.key_by_value is not None:
            return None
        if not np.issubdtype(spec.schema.key, np.integer):
            return None
        fields = spec.state_schema.fields if spec.state_schema is not None else ()
        if any(f.kind != "scalar" for f in fields):
            return None
        # The routed edge must be conformance-free: producer output layout
        # identical to this operator's declared input layout.
        if prev_out is None:
            return None
        if spec.schema.key != prev_out.key or spec.schema.value != prev_out.value:
            return None
        if not terminal:
            if spec.out_schema is None:
                return None
            prev_out = spec.out_schema
    fops = chain[1:]
    return _Plan(
        src,
        fops,
        [topo.operators[o] for o in fops],
        [topo.operators[o].num_keygroups for o in fops],
        [topo.kg_base(o) for o in fops],
    )


class _DevicePending:
    """Routed-but-undrained tuples of one operator, resident on device.

    ``keys``/``values``/``ts`` are the comp-sorted padded columns produced by
    the fused routing step (valid rows ``[0, n)``, garbage tail beyond —
    safe under the ``jit_fusible`` run-bounds contract); the matching shadow
    segments in the node queues carry the run metadata referencing them.
    """

    __slots__ = ("keys", "values", "ts", "n")

    def __init__(self, keys, values, ts, n):
        self.keys = keys
        self.values = values
        self.ts = ts
        self.n = n


class SuperstepRuntime:
    """Fused superstep execution for one :class:`repro.engine.Engine`."""

    def __init__(self, engine) -> None:
        self.engine = engine
        self.plan = plan_chain(engine)
        self._pending: dict[int, Optional[_DevicePending]] = {}
        self._fused_cache: dict = {}
        self._scan_cache: dict = {}
        self._seen_keys: set = set()
        self._tables_version = -1
        self._tables: list = []

    # ------------------------------------------------------------ plumbing
    def _jrt(self):
        eng = self.engine
        if eng._jit is None:
            from repro.engine.jitexec import JitRuntime

            eng._jit = JitRuntime(
                eng.topology, eng.store, eng.metrics, eng._kg_op,
                mesh=eng._jit_mesh, mesh_axis=eng._jit_mesh_axis,
            )
        return eng._jit

    def _dev_tables(self):
        """Per fused edge, the downstream operator's router-table slice on
        device — re-uploaded only when ``Router.version`` moved (the per-
        superstep reconfiguration hook)."""
        eng = self.engine
        router = eng.router
        if router.version != self._tables_version:
            self._tables = [
                jnp.asarray(
                    router.table[self.plan.base[i + 1]:
                                 self.plan.base[i + 1] + self.plan.nkg[i + 1]]
                )
                for i in range(len(self.plan.fops) - 1)
            ]
            self._tables_version = router.version
        return self._tables

    def flush_to_host(self) -> None:
        """Materialize pending device columns into their shadow segments.

        Run metadata (bounds, costs, queue order) is already exact; only the
        ``None`` array slots are filled, so a subsequent classic tick drains
        precisely what the fused tick would have.  Idempotent and cheap when
        nothing is pending.
        """
        if not self._pending:
            return
        eng = self.engine
        mats = {}
        for op, p in self._pending.items():
            if p is None:
                continue
            keys_np = np.asarray(p.keys)
            ts_np = np.asarray(p.ts)
            if isinstance(p.values, dict):
                dt = eng._op_schema[op].value
                vals_np = np.empty(len(keys_np), dtype=dt)
                for nm in dt.names:
                    vals_np[nm] = np.asarray(p.values[nm])
            else:
                vals_np = np.asarray(p.values)
            mats[op] = (keys_np, vals_np, ts_np)
        for q in eng._queues:
            for seg in q._segs:
                if seg[0] is None and seg[3] in mats:
                    k, v, t = mats[seg[3]]
                    seg[0], seg[1], seg[2] = k, v, t
        self._pending = {}

    # ----------------------------------------------------- dynamic gating
    def _collect(self):
        """Validate this tick for fusion and collect the drain layout.

        Read-only: replicates every branch decision of the classic SoA drain
        (whole-budget eligibility, contiguity, FIFO order) without mutating
        anything, so a ``None`` return falls back to the classic tick with
        the queues untouched.
        """
        eng = self.engine
        plan = self.plan
        if plan is None:
            return None
        if eng.router.has_in_flight() or eng._backlog or not bool(eng.alive.all()):
            return None
        src, fset = plan.source, plan.fset
        entries: dict[int, list] = {op: [] for op in plan.fops}
        src_segs: list = []
        mode: dict[int, Optional[str]] = {op: None for op in plan.fops}
        nonempty = 0
        for node, q in enumerate(eng._queues):
            if not q:
                continue
            nonempty += 1
            budget = eng.service_rate * eng._capacity_list[node]
            segs = q._segs
            last = segs[-1]
            for seg in segs:
                if seg[8] != 0 or not seg[9]:  # partially drained / non-contig
                    return None
                op = seg[3]
                if op == src:
                    if seg[0] is None:
                        return None
                    src_segs.append((node, seg))
                elif op in fset:
                    m = "shadow" if seg[0] is None else "real"
                    if m == "shadow" and self._pending.get(op) is None:
                        return None
                    if mode[op] is None:
                        mode[op] = m
                    elif mode[op] != m:
                        return None  # mixed real+shadow (post-migration)
                    entries[op].append((node, seg))
                else:
                    return None
                costs = seg[7]
                rem = 0.0
                for c in costs:
                    rem += c
                if budget < rem:
                    return None  # classic would partial-drain this segment
                for c in costs:
                    budget -= c
                if budget <= 0 and seg is not last:
                    return None  # classic would stop draining this node
        for op, p in self._pending.items():
            if p is not None and mode.get(op) != "shadow":
                return None  # pending exists but its segments are gone
        return nonempty, src_segs, entries, mode

    # ------------------------------------------------------- fused device
    def _traced(self, key, active, nbs):
        """Build (or fetch) the fused whole-tick program for one shape key."""
        cached = self._fused_cache.get(key)
        if cached is not None:
            return cached
        plan = self.plan
        eng = self.engine
        num_nodes = eng.num_nodes
        collect = eng.collect_sinks
        fops = plan.fops
        nkgs = plan.nkg
        fns = [s.fn_jit for s in plan.specs]
        last = len(fops) - 1

        def fused(states, runs, inputs, tables):
            new_states = {}
            pend = {}
            pairs = {}
            term = None
            for i in active:
                kg_pad, s_pad, e_pad = runs[i]
                keys, values, ts = inputs[i]
                st, out, oc = fns[i](
                    states[i], kg_pad, s_pad, e_pad, keys, values, ts
                )
                if oc is not None:
                    raise ValueError(
                        f"operator {plan.specs[i].name!r} is jit_fusible but "
                        "returned out_counts — fused operators must be 1:1"
                    )
                new_states[i] = st
                if i == last:
                    if collect and out is not None:
                        term = out
                    continue
                if out is None:
                    raise ValueError(
                        f"non-terminal fused operator {plan.specs[i].name!r} "
                        "emitted None"
                    )
                ok, ov, ot = out
                nb = nbs[i]
                nkg_n = nkgs[i + 1]
                valid = jx.tuple_valid(s_pad, e_pad, nb)
                dst = local_keygroups_jax(ok, nkg_n)
                node = tables[i][dst]
                sent = num_nodes * nkg_n
                comp = jnp.where(valid, node * nkg_n + dst, sent)
                order = bucket_argsort_jax(comp, sent + 1)
                pk = ok[order]
                pt = ot[order]
                if isinstance(ov, dict):
                    pv = {nm: col[order] for nm, col in ov.items()}
                else:
                    pv = ov[order]
                ridx = jx.run_of_tuples(e_pad, nb)
                src_l = kg_pad[ridx]
                dcol = jnp.where(valid, dst, nkg_n)
                pr = (
                    jnp.zeros((nkgs[i] + 1, nkg_n + 1), jnp.int64)
                    .at[src_l, dcol]
                    .add(1, mode="drop")
                )
                pairs[i] = pr
                pend[i] = (pk, pv, pt)
            return new_states, pend, pairs, term

        jitted = jax.jit(fused)
        self._fused_cache[key] = jitted
        return jitted

    # ---------------------------------------------------------- fused tick
    def try_fused_tick(self) -> bool:
        """Attempt one fully fused superstep; ``False`` → caller must flush
        pendings and run the classic tick instead."""
        colln = self._collect()
        if colln is None:
            return False
        eng = self.engine
        plan = self.plan
        metrics = eng.metrics
        nonempty, src_segs, entries, mode = colln
        eng.metrics.ticks += 1
        eng._ticks_this_period += 1
        if nonempty == 0:
            return True  # empty tick: counters only, no device call
        jrt = self._jrt()

        # -- drain replay: accounting + input collection (node-asc, FIFO) --
        drained_kgs: list = []
        drained_costs: list = []
        src_items: list = []
        processed = src_emitted = 0
        # per fused op, in drain order: (node, kgs, starts, ends, k, v, t)
        drains: dict[int, list] = {op: [] for op in plan.fops}
        for node, q in enumerate(eng._queues):
            if not q:
                continue
            qcost = q.cost
            segs = q._segs
            while segs:
                seg = segs[0]
                keys, values, ts, op, kgs, starts, ends, costs, _, _ = seg
                drained_kgs.extend(kgs)
                drained_costs.extend(costs)
                for c in costs:
                    qcost -= c
                a0, zn = starts[0], ends[-1]
                processed += zn - a0
                if op == plan.source:
                    # Source pass-through forwards its whole slice (and the
                    # classic drain counts that as an emission).
                    src_emitted += zn - a0
                    lens = np.subtract(ends, starts)
                    kg_arr = np.repeat(np.asarray(kgs, dtype=np.int64), lens)
                    src_items.append(
                        ((keys[a0:zn], values[a0:zn], ts[a0:zn]), kg_arr, node)
                    )
                else:
                    drains[op].append((node, kgs, starts, ends, keys, values, ts))
                segs.popleft()
            q.cost = qcost
        metrics.processed_tuples += processed
        metrics.emitted_tuples += src_emitted

        # -- assemble the device call ----------------------------------------
        fops = plan.fops
        active = [i for i, op in enumerate(fops) if drains[op]]
        runs_args: dict[int, tuple] = {}
        in_args: dict[int, tuple] = {}
        lkgs_by_i: dict[int, np.ndarray] = {}
        n_by_i: dict[int, int] = {}
        nbs: dict[int, int] = {}
        src_node_of: dict[int, np.ndarray] = {}
        for i in active:
            op = fops[i]
            ost = jrt._by_op[op]
            ents = drains[op]
            rk: list = []
            node_map = np.full(plan.nkg[i], -1, dtype=np.int64)
            if mode[op] == "shadow":
                p = self._pending[op]
                n = p.n
                rs: list = []
                re_: list = []
                for node, kgs, starts, ends, _, _, _ in ents:
                    rk.extend(kgs)
                    rs.extend(starts)
                    re_.extend(ends)
                    for kg in kgs:
                        node_map[kg - plan.base[i]] = node
                k_in, v_in, t_in = p.keys, p.values, p.ts
                nb = len(p.keys)
            else:
                # Real segments (e.g. first tick, or after a migration
                # flush): concatenate exactly like _flush_jit_batch and
                # upload padded host buffers.
                cat_k, cat_v, cat_t = [], [], []
                rs, re_ = [], []
                off = 0
                for node, kgs, starts, ends, keys, values, ts in ents:
                    a0, zn = starts[0], ends[-1]
                    rk.extend(kgs)
                    rs.extend(a - a0 + off for a in starts)
                    re_.extend(z - a0 + off for z in ends)
                    cat_k.append(keys[a0:zn])
                    cat_v.append(values[a0:zn])
                    cat_t.append(ts[a0:zn])
                    off += zn - a0
                    for kg in kgs:
                        node_map[kg - plan.base[i]] = node
                keys_c = cat_k[0] if len(cat_k) == 1 else np.concatenate(cat_k)
                vals_c = cat_v[0] if len(cat_v) == 1 else np.concatenate(cat_v)
                ts_c = cat_t[0] if len(cat_t) == 1 else np.concatenate(cat_t)
                n = off
                nb = jx._bucket(n, jx._MIN_TUPLE_BUCKET)
                k_in = np.zeros(nb, dtype=keys_c.dtype)
                k_in[:n] = keys_c
                t_in = np.zeros(nb, dtype=np.float64)
                t_in[:n] = ts_c
                if ost.value_names is None:
                    v_in = np.zeros(nb, dtype=vals_c.dtype)
                    v_in[:n] = vals_c
                else:
                    v_in = {}
                    for nm in ost.value_names:
                        col = vals_c[nm]
                        pad = np.zeros(nb, dtype=col.dtype)
                        pad[:n] = col
                        v_in[nm] = pad
            r = len(rk)
            rb = jx._bucket(r, jx._MIN_RUN_BUCKET)
            lkgs = np.asarray(rk, dtype=np.int64) - plan.base[i]
            if ost.fields:
                jrt._prepare_state(ost, lkgs, n)
            kg_pad = np.full(rb, ost.nkg, dtype=np.int64)
            kg_pad[:r] = lkgs
            s_pad = np.full(rb, n, dtype=np.int64)
            s_pad[:r] = np.asarray(rs, dtype=np.int64)
            e_pad = np.full(rb, n, dtype=np.int64)
            e_pad[:r] = np.asarray(re_, dtype=np.int64)
            runs_args[i] = (kg_pad, s_pad, e_pad)
            in_args[i] = (k_in, v_in, t_in)
            lkgs_by_i[i] = lkgs
            n_by_i[i] = n
            nbs[i] = nb
            src_node_of[i] = node_map

        key = (
            tuple(active),
            tuple(nbs[i] for i in active),
            tuple(len(runs_args[i][0]) for i in active),
            eng.num_nodes,
            eng.collect_sinks,
        )
        jitted = self._traced(key, tuple(active), nbs)
        states = {i: jrt._by_op[fops[i]].cols for i in active}
        tables = {
            i: t
            for i, t in enumerate(self._dev_tables())
            if i in runs_args
        }
        first = key not in self._seen_keys
        if first:
            self._seen_keys.add(key)
            metrics.jit_compiles += 1
            t0 = time.perf_counter()
        result = jitted(states, runs_args, in_args, tables)
        if first:
            jax.block_until_ready(result)
            jrt.compile_seconds += time.perf_counter() - t0
        new_states, pend_dev, pairs_dev, term = result
        last = len(fops) - 1
        for i in active:
            ost = jrt._by_op[fops[i]]
            ost.cols = new_states[i]
            ost.col_auth[lkgs_by_i[i]] = True
            metrics.jit_calls += 1
            metrics.jit_tuples += n_by_i[i]
        metrics.jit_host_syncs += 1

        # -- emission accounting + sink download (mirrors _flush_jit_batch) --
        for i in active:
            n = n_by_i[i]
            if n == 0:
                continue
            if i == last:
                if term is None and not eng.collect_sinks:
                    # Terminal output exists but was not fetched.
                    spec = plan.specs[i]
                    # Emission counts still mirror the classic path: a 1:1
                    # terminal operator emits its input count (None-output
                    # sinks like pure counters emit nothing).
                    if _emits(spec):
                        metrics.emitted_tuples += n
                        metrics.sink_tuples += n
                elif term is not None:
                    metrics.emitted_tuples += n
                    metrics.sink_tuples += n
                    ost = jrt._by_op[fops[i]]
                    ok, ov, ot = term
                    ok_np = np.asarray(ok)[:n]
                    ot_np = np.asarray(ot)[:n]
                    if isinstance(ov, dict):
                        ov_np = np.empty(n, dtype=ost.out_dtype)
                        for nm in ost.out_names:
                            ov_np[nm] = np.asarray(ov[nm])[:n]
                    else:
                        ov_np = np.asarray(ov)[:n]
                    metrics.sink_outputs.extend(
                        zip(ok_np.tolist(), ov_np.tolist(), ot_np.tolist())
                    )
            else:
                metrics.emitted_tuples += n

        if drained_kgs:
            np.add.at(eng._cpu_usage, drained_kgs, drained_costs)

        # -- routing replay, in sorted destination-operator order ------------
        producers: dict[int, tuple] = {}
        if src_items:
            producers[fops[0]] = ("source", None)
        for i in active:
            if i != last:
                producers[fops[i + 1]] = ("pairs", i)
        for i in range(last):
            # Downstream of an inactive/empty producer gets no new pending.
            if i not in pairs_dev:
                if fops[i + 1] not in producers:
                    self._pending[fops[i + 1]] = None
        for dop in sorted(producers):
            kind, i = producers[dop]
            if kind == "source":
                self._route_source_items(dop, src_items)
            else:
                pairs = np.asarray(pairs_dev[i])[
                    : plan.nkg[i], : plan.nkg[i + 1]
                ]
                self._replay_route(
                    i, dop, pairs, pend_dev.get(i), src_node_of[i]
                )
        return True

    def _route_source_items(self, dop: int, items: list) -> None:
        """Deliver the source's pass-through batches through the real
        router — identical to ``Engine._flush_outputs`` for one operator."""
        eng = self.engine
        schema = eng._op_schema[dop]
        if len(items) == 1:
            batch, src_kg, src_node = items[0]
            batch = eng._conform_batch(batch, schema)
            n = len(batch[0])
            src_kgs = src_kg
            src_nodes = np.full(n, src_node, dtype=np.int64)
        else:
            batches, kg_t, nd_t = zip(*items)
            batch = concat_batches(
                [eng._conform_batch(b, schema) for b in batches]
            )
            m = len(items)
            lens = np.fromiter((len(b[0]) for b in batches), np.int64, count=m)
            src_kgs = np.concatenate(list(kg_t))
            src_nodes = np.repeat(np.fromiter(nd_t, np.int64, count=m), lens)
        eng._route_batch(dop, batch, src_kgs=src_kgs, src_nodes=src_nodes)

    def _replay_route(self, i, dop, pairs, pend, src_node_of) -> None:
        """Host replay of ``_route_batch`` for a device-routed edge.

        ``pairs[src_lkg, dst_lkg]`` counts this tick's tuples on the edge;
        together with the router table and the producer's drain-node map it
        reproduces every statistic the classic route records — send pairs,
        cross/intra splits, serialization charges, arrivals, admissions —
        and pushes shadow segments whose costs walk the queues' float
        trajectories bit-exactly.
        """
        eng = self.engine
        plan = self.plan
        metrics = eng.metrics
        window = eng.window
        total = int(pairs.sum())
        if total == 0:
            self._pending[dop] = None
            return
        metrics.typed_batches += 1
        base_s, base_d = plan.base[i], plan.base[i + 1]
        nkg_d = plan.nkg[i + 1]
        sl, dl = np.nonzero(pairs)
        cnt = pairs[sl, dl]
        window.record_send_counts(sl + base_s, dl + base_d, cnt)
        dst_nodes_l = eng.router.table[base_d: base_d + nkg_d]
        cross = src_node_of[sl] != dst_nodes_l[dl]
        n_cross = int(cnt[cross].sum())
        if n_cross:
            g = len(eng._arrivals)
            both = np.zeros(g, dtype=np.int64)
            np.add.at(both, sl[cross] + base_s, cnt[cross])
            np.add.at(both, dl[cross] + base_d, cnt[cross])
            eng._cpu_usage += both * eng.ser_cost
            window.kg_usage["network"] += both
        metrics.cross_node_tuples += n_cross
        metrics.intra_node_tuples += total - n_cross
        counts_l = pairs.sum(axis=0)
        nzl = np.flatnonzero(counts_l)
        comp_l = dst_nodes_l[nzl] * nkg_d + nzl
        ordr = np.argsort(comp_l)  # distinct comps: plain argsort is exact
        nzl = nzl[ordr]
        counts = counts_l[nzl]
        ends = np.cumsum(counts)
        starts = ends - counts
        run_nodes = dst_nodes_l[nzl]
        uniq = nzl + base_d
        np.add.at(eng._arrivals, uniq, counts)
        costs = counts * eng._cost_per_tuple[dop]
        self._pending[dop] = _DevicePending(pend[0], pend[1], pend[2], total)
        queues = eng._queues
        if len(uniq) == 1:
            node = int(run_nodes[0])
            queues[node].push_runs(
                dop, None, None, None,
                uniq.tolist(), starts.tolist(), ends.tolist(), costs.tolist(),
                contig=True,
            )
            eng._record_admission(node, int(counts[0]))
            return
        gstarts = np.flatnonzero(
            np.concatenate(([True], run_nodes[1:] != run_nodes[:-1]))
        )
        unodes = run_nodes[gstarts].tolist()
        gends = np.append(gstarts[1:], len(run_nodes))
        kg_l, st_l = uniq.tolist(), starts.tolist()
        en_l, co_l = ends.tolist(), costs.tolist()
        node_counts = np.add.reduceat(counts, gstarts).tolist()
        service_rate = eng.service_rate
        caps = eng._capacity_list
        lat_append = eng.latency.samples.append
        gsl, gel = gstarts.tolist(), gends.tolist()
        for j in range(len(unodes)):
            a, z = gsl[j], gel[j]
            node = unodes[j]
            q = queues[node]
            q.push_runs(
                dop, None, None, None,
                kg_l[a:z], st_l[a:z], en_l[a:z], co_l[a:z],
                contig=True,
            )
            admitted = node_counts[j]
            lat_append(
                (
                    q.cost / max(service_rate * caps[node], 1e-9),
                    admitted if admitted < 16 else 16,
                )
            )

    # ------------------------------------------------------- K-tick scan
    def run_supersteps(self, batches) -> int:
        """Steady-state mode: K source batches through one ``lax.scan``.

        Batch ``t`` is ingested at the source (hash, typed conversion and
        the pass-through hop pre-applied host-side), reaches the first fused
        operator at scan step ``t`` and flows one chain hop per step; the
        host boundary is crossed once for all K ticks
        (``metrics.jit_host_syncs += 1``).  Aggregate statistics (metrics,
        arrivals, usage, send pairs, queue costs, states, sink outputs) are
        folded in exactly; per-admission latency samples and per-tick credit
        checks are not recorded — this is the throughput API, documented in
        ``docs/operator_authoring.md``.

        Requires empty queues (run ``tick()`` until drained first); leaves
        the final in-flight pendings materialized as real segments so
        subsequent classic ticks drain them.  Returns K.
        """
        eng = self.engine
        plan = self.plan
        if plan is None:
            raise RuntimeError("topology is not superstep-fusible")
        if self._pending:
            self.flush_to_host()
        if any(bool(q) for q in eng._queues):
            raise RuntimeError(
                "run_supersteps requires empty queues — tick() until drained"
            )
        if eng.router.has_in_flight() or not bool(eng.alive.all()):
            raise RuntimeError(
                "run_supersteps cannot run during a migration or with dead "
                "nodes — use tick()"
            )
        K = len(batches)
        if K == 0:
            return 0
        topo = eng.topology
        metrics = eng.metrics
        jrt = self._jrt()
        src, fops = plan.source, plan.fops
        op1 = fops[0]
        base1, nkg1 = plan.base[0], plan.nkg[0]
        schema = topo.operators[src].schema
        table = eng.router.table
        num_nodes = eng.num_nodes
        g = len(eng._arrivals)
        # Backpressure guard: the scan performs no per-tick credit checks,
        # so refuse workloads a single node's budget could not absorb.
        nmax = max(len(b[0]) for b in batches)
        worst = nmax * (
            eng._cost_per_tuple[src]
            + sum(eng._cost_per_tuple[o] for o in fops)
        )
        min_budget = eng.service_rate * min(eng._capacity_list)
        if worst >= min_budget:
            raise RuntimeError(
                "run_supersteps: a superstep's worst-case cost "
                f"({worst:.3g}) reaches the smallest node budget "
                f"({min_budget:.3g}); backpressure would bind — use tick()"
            )
        nb1 = jx._bucket(nmax, jx._MIN_TUPLE_BUCKET)
        arrivals_agg = np.zeros(g, dtype=np.int64)
        usage_agg = np.zeros(g, dtype=np.float64)
        pair_src_l: list = []
        pair_dst_l: list = []
        pair_cnt_l: list = []
        # -- stage the source hop (typed conversion, hash, radix sort) ------
        v_names = schema.value.names
        xs_k = np.zeros((K, nb1), dtype=schema.key)
        xs_t = np.zeros((K, nb1), dtype=np.float64)
        if v_names is None:
            xs_v = np.zeros((K, nb1), dtype=schema.value)
        else:
            xs_v = {
                nm: np.zeros((K, nb1), dtype=schema.value[nm]) for nm in v_names
            }
        xs_c = np.zeros((K, nkg1), dtype=np.int64)
        processed = emitted = 0
        cross_total = intra_total = 0
        # -- run layouts: every local kg, comp-sorted (static per table) ----
        perms = []
        for i, op in enumerate(fops):
            nk = plan.nkg[i]
            tl = table[plan.base[i]: plan.base[i] + nk]
            perms.append(np.argsort(tl * nk + np.arange(nk)))
        # -- host routing schedule (static_route chains only) ---------------
        # Every non-terminal fused op declares jit_key_map, so hop i's
        # routing of batch t is a pure function of the staged keys: evaluate
        # the hashes, stable radix permutations and per-edge count matrices
        # here with the host radix sort (~35× faster than XLA's CPU
        # comparison sort) and feed them to the scan as inputs.  Batch t
        # crosses hop i at scan step t+i, so row s of ord_x[i]/cnt_x[i]
        # holds batch s-i's schedule (identity/zeros during pipeline fill).
        static = plan.static_route
        nhops = len(fops) - 1
        if static:
            ns = np.zeros(K, dtype=np.int64)
            ord_x = [
                np.tile(np.arange(nb1, dtype=np.int64), (K, 1))
                for _ in range(nhops)
            ]
            cnt_x = [
                np.zeros((K, plan.nkg[i + 1]), dtype=np.int64)
                for i in range(nhops)
            ]
            pr_sum = [
                np.zeros((plan.nkg[i], plan.nkg[i + 1]), dtype=np.int64)
                for i in range(nhops)
            ]
            pr_last = [np.zeros_like(p) for p in pr_sum]
            pend_cnt = [
                np.zeros(plan.nkg[i + 1], dtype=np.int64) for i in range(nhops)
            ]
        for t, (bk, bv, bt) in enumerate(batches):
            n = len(bk)
            keys = np.asarray(bk, dtype=schema.key)
            values = schema.typed_values(bv)
            ts = np.asarray(bt, dtype=np.float64)
            src_kgs = topo.keygroups_of(src, keys, values)
            np.add.at(
                usage_agg, src_kgs, np.full(n, eng._cost_per_tuple[src])
            )
            np.add.at(arrivals_agg, src_kgs, 1)
            processed += n
            emitted += n  # source pass-through forwards every tuple
            kg1 = topo.keygroups_of(op1, keys, values)
            l1 = kg1 - base1
            comp = table[kg1] * nkg1 + l1
            nbkt = num_nodes * nkg1
            order = bucket_argsort(
                comp.astype(np.int16) if nbkt <= 32767 else comp, nbkt
            )
            np.add.at(arrivals_agg, kg1, 1)
            codes = src_kgs * np.int64(g) + kg1
            ucodes, ucnt = np.unique(codes, return_counts=True)
            usl, udl = ucodes // g, ucodes % g
            pair_src_l.append(usl)
            pair_dst_l.append(udl)
            pair_cnt_l.append(ucnt)
            cr = table[usl] != table[udl]
            ncr = int(ucnt[cr].sum())
            cross_total += ncr
            intra_total += n - ncr
            if ncr:
                both = np.zeros(g, dtype=np.int64)
                np.add.at(both, usl[cr], ucnt[cr])
                np.add.at(both, udl[cr], ucnt[cr])
                usage_agg += both * eng.ser_cost
                eng.window.kg_usage["network"] += both
            metrics.typed_batches += 1
            xs_k[t, :n] = keys[order]
            xs_t[t, :n] = ts[order]
            if v_names is None:
                xs_v[t, :n] = values[order]
            else:
                sv = values[order]
                for nm in v_names:
                    xs_v[nm][t, :n] = sv[nm]
            xs_c[t] = np.bincount(l1, minlength=nkg1)
            if not static:
                continue
            ns[t] = n
            # Walk batch t down the chain: op i's input keys (in its run
            # layout) determine op i's emitted keys via jit_key_map, hence
            # the hop-i routing permutation and counts.  Hops beyond
            # K-1-t never execute inside this scan (the batch is still in
            # flight when it ends), so stop there.
            kcur = xs_k[t, :n]
            ccur = xs_c[t]
            for i in range(min(nhops - 1, K - 1 - t) + 1):
                kout = np.asarray(plan.key_maps[i](kcur))
                nkg_n = plan.nkg[i + 1]
                tl_n = table[plan.base[i + 1]: plan.base[i + 1] + nkg_n]
                dst = _mixed_keygroups(mix32(kout), 0, nkg_n)
                comph = tl_n[dst] * nkg_n + dst
                sent = num_nodes * nkg_n
                oh = bucket_argsort(
                    comph.astype(np.int16) if sent < 32767 else comph,
                    sent + 1,
                )
                src_l = np.repeat(perms[i], ccur[perms[i]])
                pr = np.bincount(
                    src_l * nkg_n + dst, minlength=plan.nkg[i] * nkg_n
                ).reshape(plan.nkg[i], nkg_n)
                pr_sum[i] += pr
                cnext = pr.sum(axis=0)
                ord_x[i][t + i, :n] = oh
                if t + i + 1 <= K - 1:
                    cnt_x[i][t + i + 1] = cnext
                else:
                    # Routed at the final step: stays pending, becomes the
                    # materialized segment counts after the scan.
                    pr_last[i] = pr
                    pend_cnt[i] = cnext
                kcur = kout[oh]
                ccur = cnext
        # K routed batches reach the first fused operator (typed edge).
        metrics.typed_batches += K
        # -- prepare state columns: any kg can receive tuples mid-scan ------
        for i, op in enumerate(fops):
            ost = jrt._by_op[op]
            if ost.fields:
                jrt._prepare_state(ost, np.arange(ost.nkg, dtype=np.int64), 0)
        key = (K, nb1, eng.collect_sinks, eng.router.version)
        scan_fn = self._scan_cache.get(key)
        if scan_fn is None:
            scan_fn = self._build_scan(K, nb1, perms, static)
            self._scan_cache[key] = scan_fn
        states0 = tuple(jrt._by_op[op].cols for op in fops)
        pend0 = []
        for i in range(len(fops) - 1):
            nxt = plan.specs[i].out_schema
            zk = jnp.zeros(nb1, dtype=nxt.key)
            zt = jnp.zeros(nb1, dtype=jnp.float64)
            if nxt.value.names is None:
                zv = jnp.zeros(nb1, dtype=nxt.value)
            else:
                zv = {
                    nm: jnp.zeros(nb1, dtype=nxt.value[nm])
                    for nm in nxt.value.names
                }
            if static:
                pend0.append((zk, zv, zt))
            else:
                pend0.append(
                    (zk, zv, zt, jnp.zeros(plan.nkg[i + 1], dtype=jnp.int64))
                )
        if static:
            xs = (xs_k, xs_v, xs_t, tuple([xs_c] + cnt_x), tuple(ord_x))
        else:
            xs = (xs_k, xs_v, xs_t, xs_c)
        first = key not in self._seen_keys
        if first:
            self._seen_keys.add(key)
            metrics.jit_compiles += 1
            t0 = time.perf_counter()
        (statesK, pendK), ys = scan_fn(states0, tuple(pend0), xs)
        jax.block_until_ready((statesK, pendK, ys))
        if first:
            jrt.compile_seconds += time.perf_counter() - t0
        if static:
            # Routing statistics were computed host-side during staging —
            # the scan only returns states, pendings and sink outputs.
            ys_pairs = term_counts = None
            term_out = ys
        else:
            ys_pairs, term_counts, term_out = ys
        # -- fold the scan outputs into the engine ---------------------------
        metrics.ticks += K
        eng._ticks_this_period += K
        metrics.jit_host_syncs += 1
        metrics.jit_calls += K * len(fops)
        last = len(fops) - 1
        for i, op in enumerate(fops):
            ost = jrt._by_op[op]
            ost.cols = statesK[i]
            if i == 0:
                in_agg = xs_c.sum(axis=0)
            elif static:
                in_agg = pr_sum[i - 1].sum(axis=0)
            else:
                in_agg = np.asarray(ys_pairs[i - 1]).sum(axis=(0, 1))
            touched = np.flatnonzero(in_agg)
            ost.col_auth[touched] = True
            drained = int(in_agg.sum())
            if i > 0:
                # The last tick's routed tuples stay queued, undrained.
                if static:
                    lastp = pr_last[i - 1].sum(axis=0)
                else:
                    lastp = np.asarray(ys_pairs[i - 1][K - 1]).sum(axis=0)
                drained -= int(lastp.sum())
                dr = in_agg - lastp
            else:
                dr = in_agg
            idx = np.flatnonzero(dr)
            np.add.at(
                usage_agg, idx + plan.base[i],
                dr[idx] * eng._cost_per_tuple[op],
            )
            processed += drained
            metrics.jit_tuples += drained
            if i == last:
                # A None-output sink (pure counter) emits nothing at all.
                if _emits(plan.specs[i]):
                    if static:
                        sunk = int(ns[: max(K - last, 0)].sum())
                    else:
                        sunk = int(np.asarray(term_counts).sum())
                    metrics.sink_tuples += sunk
                    emitted += sunk
            else:
                if static:
                    emitted += int(pr_sum[i].sum())
                else:
                    emitted += int(np.asarray(ys_pairs[i]).sum())
        metrics.processed_tuples += processed
        metrics.emitted_tuples += emitted
        # edge statistics (aggregate, exact integer sums)
        for i in range(last):
            pr = pr_sum[i] if static else np.asarray(ys_pairs[i]).sum(axis=0)
            sl, dl = np.nonzero(pr)
            if len(sl):
                pair_src_l.append(sl + plan.base[i])
                pair_dst_l.append(dl + plan.base[i + 1])
                pair_cnt_l.append(pr[sl, dl])
                tl_s = table[plan.base[i]: plan.base[i] + plan.nkg[i]]
                tl_d = table[plan.base[i + 1]:
                             plan.base[i + 1] + plan.nkg[i + 1]]
                cr = tl_s[sl] != tl_d[dl]
                cnt = pr[sl, dl]
                ncr = int(cnt[cr].sum())
                cross_total += ncr
                intra_total += int(cnt.sum()) - ncr
                if ncr:
                    both = np.zeros(g, dtype=np.int64)
                    np.add.at(both, sl[cr] + plan.base[i], cnt[cr])
                    np.add.at(both, dl[cr] + plan.base[i + 1], cnt[cr])
                    usage_agg += both * eng.ser_cost
                    eng.window.kg_usage["network"] += both
                np.add.at(
                    arrivals_agg, dl + plan.base[i + 1], pr[sl, dl]
                )
                metrics.typed_batches += K
        metrics.cross_node_tuples += cross_total
        metrics.intra_node_tuples += intra_total
        eng._arrivals += arrivals_agg
        eng._cpu_usage += usage_agg
        if pair_src_l:
            eng.window.record_send_counts(
                np.concatenate(pair_src_l),
                np.concatenate(pair_dst_l),
                np.concatenate(pair_cnt_l),
            )
        # sink outputs, tick order
        if eng.collect_sinks and term_out is not None:
            if static:
                # The sink at step t processes batch t-last (zero during
                # the pipeline-fill steps).
                cnts = np.zeros(K, dtype=np.int64)
                if K > last:
                    cnts[last:] = ns[: K - last]
            else:
                cnts = np.asarray(term_counts)
            ost = jrt._by_op[fops[last]]
            ok_all = np.asarray(term_out[0])
            ot_all = np.asarray(term_out[2])
            ov = term_out[1]
            if isinstance(ov, dict):
                ov_all = np.empty(ok_all.shape, dtype=ost.out_dtype)
                for nm in ost.out_names:
                    ov_all[nm] = np.asarray(ov[nm])
            else:
                ov_all = np.asarray(ov)
            for t in range(K):
                c = int(cnts[t])
                if c:
                    metrics.sink_outputs.extend(
                        zip(
                            ok_all[t, :c].tolist(),
                            ov_all[t, :c].tolist(),
                            ot_all[t, :c].tolist(),
                        )
                    )
        # -- materialize the final pendings as real segments ----------------
        for i in range(last):
            dop = fops[i + 1]
            if static:
                pk, pv, pt = pendK[i]
                counts_l = pend_cnt[i]
            else:
                pk, pv, pt, counts_dev = pendK[i]
                counts_l = np.asarray(counts_dev)
            total = int(counts_l.sum())
            if total == 0:
                continue
            keys_np = np.asarray(pk)
            ts_np = np.asarray(pt)
            if isinstance(pv, dict):
                dt = eng._op_schema[dop].value
                vals_np = np.empty(len(keys_np), dtype=dt)
                for nm in dt.names:
                    vals_np[nm] = np.asarray(pv[nm])
            else:
                vals_np = np.asarray(pv)
            nk = plan.nkg[i + 1]
            perm = perms[i + 1]
            cp = counts_l[perm]
            ends_all = np.cumsum(cp)
            starts_all = ends_all - cp
            nz = cp > 0
            kgs = perm[nz] + plan.base[i + 1]
            starts = starts_all[nz]
            ends = ends_all[nz]
            counts = cp[nz]
            tl_d = table[plan.base[i + 1]: plan.base[i + 1] + nk]
            run_nodes = tl_d[perm[nz]]
            costs = counts * eng._cost_per_tuple[dop]
            for node in np.unique(run_nodes):
                m = run_nodes == node
                eng._queues[int(node)].push_runs(
                    dop, keys_np, vals_np, ts_np,
                    kgs[m].tolist(), starts[m].tolist(), ends[m].tolist(),
                    costs[m].tolist(), contig=True,
                )
        return K

    def _build_scan(self, K: int, nb: int, perms: list, static: bool) -> object:
        """Trace the K-tick scan for the current shapes/table layout.

        ``static`` (chains where every non-terminal operator declares
        ``jit_key_map``): the routing schedule — per-step run counts and
        gather permutations — arrives precomputed in the xs, so each step
        is counts-gather → cumsum → fn_jit → gather, with no device sort
        and no pair-matrix scatter.  Otherwise the body routes on device
        (hash → stable bucketed argsort) and returns the pair matrices as
        scan outputs.
        """
        eng = self.engine
        plan = self.plan
        fops = plan.fops
        nkgs = plan.nkg
        fns = [s.fn_jit for s in plan.specs]
        num_nodes = eng.num_nodes
        collect = eng.collect_sinks
        last = len(fops) - 1
        tables = [
            jnp.asarray(
                eng.router.table[plan.base[i + 1]:
                                 plan.base[i + 1] + plan.nkg[i + 1]]
            )
            for i in range(last)
        ]
        perms_dev = [jnp.asarray(p) for p in perms]

        def body(carry, x):
            states, pends = carry
            if static:
                xk, xv, xt, cnts, ords = x
            else:
                xk, xv, xt, xc = x
            new_states = []
            new_pends = []
            ys_pairs = []
            term_cnt = jnp.zeros((), jnp.int64)
            term_out = None
            for i in range(len(fops)):
                if i == 0:
                    keys, values, ts = xk, xv, xt
                    counts = cnts[0] if static else xc
                elif static:
                    keys, values, ts = pends[i - 1]
                    counts = cnts[i]
                else:
                    keys, values, ts, counts = pends[i - 1]
                perm = perms_dev[i]
                cp = counts[perm]
                e_run = jnp.cumsum(cp)
                s_run = e_run - cp
                st, out, oc = fns[i](
                    states[i], perm, s_run, e_run, keys, values, ts
                )
                if oc is not None:
                    raise ValueError(
                        "superstep scan requires 1:1 fused operators"
                    )
                new_states.append(st)
                total = e_run[-1]
                if i == last:
                    term_cnt = total.astype(jnp.int64)
                    if collect and out is not None:
                        term_out = out
                    continue
                ok, ov, ot = out
                if static:
                    order = ords[i]
                else:
                    nkg_n = nkgs[i + 1]
                    valid = jnp.arange(nb) < total
                    dst = local_keygroups_jax(ok, nkg_n)
                    node = tables[i][dst]
                    sent = num_nodes * nkg_n
                    comp = jnp.where(valid, node * nkg_n + dst, sent)
                    order = bucket_argsort_jax(comp, sent + 1)
                pk = ok[order]
                pt = ot[order]
                if isinstance(ov, dict):
                    pv = {nm: col[order] for nm, col in ov.items()}
                else:
                    pv = ov[order]
                if static:
                    new_pends.append((pk, pv, pt))
                    continue
                src_l = perm[jx.run_of_tuples(e_run, nb)]
                dcol = jnp.where(valid, dst, nkg_n)
                pr = (
                    jnp.zeros((nkgs[i] + 1, nkg_n + 1), jnp.int64)
                    .at[src_l, dcol]
                    .add(1, mode="drop")
                )
                ys_pairs.append(pr[: nkgs[i], :nkg_n])
                dcounts = pr[: nkgs[i], :nkg_n].sum(axis=0)
                new_pends.append((pk, pv, pt, dcounts))
            if static:
                y = term_out
            else:
                y = (tuple(ys_pairs), term_cnt, term_out)
            return (tuple(new_states), tuple(new_pends)), y

        def run(states0, pends0, xs):
            return jax.lax.scan(body, (states0, pends0), xs)

        return jax.jit(run)


def _emits(spec) -> bool:
    """Whether a fused terminal operator's fn_jit emits outputs.

    Probed statically by tracing against the declared shapes is overkill —
    the convention in this codebase is that counting sinks return
    ``(state, None, None)``; anything with an out_schema or a declared
    ``schema`` emitting body returns arrays.  We probe with jax's shape
    inference once per spec.
    """
    cached = getattr(spec, "_superstep_emits", None)
    if cached is not None:
        return cached

    def probe():
        import numpy as _np

        nkg = spec.num_keygroups
        key_dt = spec.schema.key
        kg = jnp.zeros(1, jnp.int64)
        s = jnp.zeros(1, jnp.int64)
        e = jnp.ones(1, jnp.int64)
        keys = jnp.zeros(1, key_dt)
        ts = jnp.zeros(1, jnp.float64)
        if spec.schema.value.names is None:
            values = jnp.zeros(1, spec.schema.value)
        else:
            values = {
                nm: jnp.zeros(1, spec.schema.value[nm])
                for nm in spec.schema.value.names
            }
        fields = spec.state_schema.fields if spec.state_schema else ()
        state = {
            f.name: jnp.full(nkg + 1, f.init, dtype=f.dtype) for f in fields
        }
        _, out, _ = jax.eval_shape(
            lambda st, k, a, z, ky, v, t: spec.fn_jit(st, k, a, z, ky, v, t),
            state, kg, s, e, keys, values, ts,
        )
        return out is not None

    try:
        emits = probe()
    except Exception:
        emits = True
    try:
        spec._superstep_emits = emits
    except Exception:
        pass
    return emits

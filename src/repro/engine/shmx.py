"""Shared-memory exchange lanes: SPSC byte rings of typed segments.

The multi-worker runtime's steady-state cost on small hosts is the exchange
step — PR 7 shipped every cross-worker tick contribution as a pickled
``mp.Queue`` message (pipe write + pickle + pipe read + unpickle + copy).
This module replaces that hot path with one ``multiprocessing.shared_memory``
ring buffer per ``(sender → receiver)`` lane:

* **Single-writer, single-reader.**  Each ring has exactly one producer (the
  sending worker) and one consumer (the receiving worker), continuing the
  transport discipline that makes SIGKILL safe: two monotonically increasing
  64-bit sequence counters live in the segment header — ``write_seq``
  (written only by the producer) and ``read_seq`` (written only by the
  consumer) — and a record becomes visible *only* when the producer advances
  ``write_seq`` past it.  A worker SIGKILLed mid-write leaves an unpublished
  partial record that no reader will ever observe, and no lock any survivor
  needs.  (CPython stores each counter with a single aligned 8-byte write;
  on x86's total-store-order this publishes the record bytes before the
  sequence bump.  The engine targets the same POSIX/x86 class of host the
  ``fork`` requirement already pins.)

* **Coordinator-allocated, fork-inherited, coordinator-unlinked.**  The
  coordinator creates every segment before forking the pool, workers inherit
  the mappings, and only the coordinator ever calls ``unlink`` — on
  shutdown and on worker death — so a killed worker cannot leak a segment.
  Unlinking removes the *name* only; survivors' inherited mappings stay
  valid, which is what lets a peer drain a dead sender's ring during the
  final sweep.

* **Typed segments, not pickles.**  Records carry ``serde.encode_batch``'s
  raw column layout (see :class:`LaneSender`): the producer splices each
  column's buffer straight into the ring (one memcpy per column — the
  transfer itself, no intermediate ``bytes``), the consumer copies the
  record out once and decodes with ``frombuffer`` over its own writable
  buffer (``serde.batch_from_views`` — no defensive copy).  Dtype headers
  are interned per lane: the first batch of a schema ships a define record,
  every later batch ships a 4-byte id.

Ring-full overflow, object-dtype batches, and migration envelopes keep the
PR 7 queue path — :meth:`LaneSender.try_send` returns ``False`` and the
caller falls back, at whole-message granularity so a (sender, tick)
contribution travels on exactly one transport and per-tick merge order is
unaffected.  The protocol and its determinism contract are documented in
``docs/execution_tiers.md``.
"""

from __future__ import annotations

import pickle
import struct
from multiprocessing import shared_memory as _shared_memory

import numpy as np

from repro.engine import serde

_pack_preamble = struct.Struct("<QI").pack  # tick, nitems
_pack_item = struct.Struct("<IBI").pack  # dop, flags, hdr_id
_unpack_preamble = struct.Struct("<QI").unpack_from
_unpack_item = struct.Struct("<IBI").unpack_from
_pack_u32 = struct.Struct("<I").pack
_unpack_u32 = struct.Struct("<I").unpack_from

#: Segment header: write_seq (u64, producer-owned), read_seq (u64,
#: consumer-owned), capacity (u64, fixed at creation — ``SharedMemory``
#: rounds sizes up to a page, so the logical capacity travels in-band).
_HEADER_BYTES = 24

#: Prefix of every exchange-lane segment name; the fault suite scans
#: ``/dev/shm`` for it to prove the coordinator leaked nothing.
SEGMENT_PREFIX = "repro_xchg"

#: ``hdr_id`` flag bit: a define record (pickled dtype triple) follows.
_DEFINE = 0x80000000

#: Per-item flag: src_kgs / src_nodes arrays present.
_HAS_SRC = 0x01


class ShmRing:
    """One SPSC byte ring over one shared-memory segment.

    Records are ``[u32 length][payload]``, written wrap-around; sequence
    counters count bytes monotonically (position = seq % capacity), so
    ``write_seq - read_seq`` is the bytes in flight and full/empty are
    unambiguous without a spare slot.
    """

    def __init__(self, shm: _shared_memory.SharedMemory):
        self.shm = shm
        self._seq = np.frombuffer(shm.buf, dtype=np.uint64, count=3)
        self.capacity = int(self._seq[2])
        # Raw 'B'-format view of the data region: record bytes move through
        # plain memoryview slice assignment (one memcpy per part, no numpy
        # per-part overhead on the hot path).
        self._data = memoryview(shm.buf)[
            _HEADER_BYTES : _HEADER_BYTES + self.capacity
        ]

    @classmethod
    def create(cls, name: str, capacity: int) -> "ShmRing":
        shm = _shared_memory.SharedMemory(
            name=name, create=True, size=_HEADER_BYTES + capacity
        )
        ctrl = np.frombuffer(shm.buf, dtype=np.uint64, count=3)
        ctrl[:] = (0, 0, capacity)
        del ctrl
        return cls(shm)

    @classmethod
    def open(cls, name: str) -> "ShmRing":
        """Attach to an existing segment by name (respawn path).

        Fork inheritance covers the initial pool, but a worker that outlives
        a peer's respawn must map the *replacement* lanes, which were
        created after its own fork.  Ownership is unchanged: the coordinator
        created the segment and remains the only unlinker.  The attach-time
        resource-tracker registration (Python < 3.13 has no ``track``
        parameter) is harmless here: fork-children share the coordinator's
        tracker, whose cache is a set — the duplicate register is a no-op
        and the coordinator's eventual unlink clears the single entry.
        Explicitly unregistering instead would strip the creator's entry
        and make that unlink double-unregister.
        """
        return cls(_shared_memory.SharedMemory(name=name, create=False))

    # ------------------------------------------------------------- producer
    def try_send(self, parts: list) -> int | None:
        """Publish one record made of buffer parts → payload bytes written,
        or ``None`` when the ring lacks space.

        ``parts`` are bytes-like (``bytes`` or C-contiguous memoryviews);
        each is memcpy'd straight into the mapping — the only write-side
        copy is the transfer itself.
        """
        total = sum(map(len, parts))
        wseq = int(self._seq[0])
        used = wseq - int(self._seq[1])
        if 4 + total > self.capacity - used:
            return None
        # Inline wrap-aware copy loop: a record averages dozens of parts,
        # so per-part function-call overhead is measurable on the hot path.
        data = self._data
        cap = self.capacity
        off = wseq % cap
        for buf in (_pack_u32(total), *parts):
            mv = buf if type(buf) is memoryview else memoryview(buf)
            n = mv.nbytes
            end = off + n
            if end <= cap:
                data[off:end] = mv
                off = 0 if end == cap else end
            else:
                first = cap - off
                data[off:] = mv[:first]
                off = n - first
                data[:off] = mv[first:]
        self._seq[0] = np.uint64(wseq + 4 + total)  # publish
        return total

    # ------------------------------------------------------------- consumer
    def recv(self) -> memoryview | None:
        """Pop one record, or ``None`` when the ring is empty.

        Returns a memoryview over a *fresh writable* buffer (one memcpy out
        of the mapping), so zero-copy decodes of it yield ordinary writable
        arrays with an independent lifetime.
        """
        rseq = int(self._seq[1])
        if int(self._seq[0]) == rseq:
            return None
        off = rseq % self.capacity
        if off + 4 <= self.capacity:  # allocation-free length read
            (n,) = _unpack_u32(self._data, off)
        else:
            (n,) = _unpack_u32(self._read(rseq, 4), 0)
        payload = self._read(rseq + 4, n)
        self._seq[1] = np.uint64(rseq + 4 + n)  # release the bytes
        return payload

    def _read(self, seq: int, n: int) -> memoryview:
        out = np.empty(n, dtype=np.uint8).data
        off = seq % self.capacity
        first = min(n, self.capacity - off)
        out[:first] = self._data[off : off + first]
        if first < n:
            out[first:] = self._data[: n - first]
        return out

    # -------------------------------------------------------------- cleanup
    def close(self) -> None:
        """Drop this process's mapping (views first — mmap refuses while
        buffer exports exist).  Idempotent."""
        self._seq = None
        self._data = None
        try:
            self.shm.close()
        except BufferError:  # pragma: no cover - exported view still alive
            pass

    def unlink(self) -> None:
        """Remove the segment name (coordinator-only).  Idempotent — death
        cleanup and shutdown may both reach the same segment."""
        try:
            self.shm.unlink()
        except FileNotFoundError:
            pass


class LaneSender:
    """Producer-side codec for one exchange lane.

    One record per ``(tick, receiver)``: a meta block, then the buffers.
    The meta block is ``[tick u64][nitems u32]`` followed per item by
    ``[dop u32][flags u8][hdr_id u32]`` (+ ``[len u32][pickled dtype
    triple]`` when the ``_DEFINE`` bit is set — the first batch of a schema
    on this lane) and ``[n u32]``.  After the last meta come each item's
    raw key/value/ts column buffers in item order (``serde.column_views`` —
    byte-identical to ``encode_batch``'s column section) and, when flagged,
    the int64 ``src_kgs``/``src_nodes`` buffers.  Grouping the metas into
    one block keeps the splice count at one small write plus the column
    buffers themselves.

    Define records ride the ring only, so the receiver's intern table stays
    in sync by FIFO order alone; the queue fallback ships self-describing
    pickles and never consumes an id.
    """

    def __init__(self, ring: ShmRing):
        self.ring = ring
        self._hdr_ids: dict[tuple, int] = {}
        self.sent_msgs = 0
        self.bytes_copied = 0

    def try_send(self, tick: int, items: list) -> bool:
        """Encode and publish one tick's items, or refuse (fallback).

        Refuses when any batch has object-dtype columns (raw buffers would
        ship pointers) or the ring lacks space for the whole record —
        whole-message granularity, so one (tick, lane) contribution never
        splits across transports.
        """
        metas: list = [_pack_preamble(tick, len(items))]
        parts: list = [b""]  # placeholder: joined meta block goes first
        fresh: dict[tuple, int] = {}
        for dop, batch, sk, sn in items:
            if not serde.is_typed_batch(batch):
                return False
            keys, values, ts = batch
            triple = (keys.dtype, values.dtype, ts.dtype)
            hid = self._hdr_ids.get(triple, fresh.get(triple))
            define = b""
            if hid is None:
                hid = len(self._hdr_ids) + len(fresh)
                fresh[triple] = hid
                blob = pickle.dumps(triple, protocol=pickle.HIGHEST_PROTOCOL)
                define = _pack_u32(len(blob)) + blob
                hid |= _DEFINE
            flags = _HAS_SRC if sk is not None else 0
            metas.append(_pack_item(dop, flags, hid) + define + _pack_u32(len(keys)))
            parts.extend(serde.column_views(batch))
            if flags & _HAS_SRC:
                parts.append(
                    memoryview(np.ascontiguousarray(sk, dtype=np.int64)).cast("B")
                )
                parts.append(
                    memoryview(np.ascontiguousarray(sn, dtype=np.int64)).cast("B")
                )
        parts[0] = metas[0] if len(metas) == 1 else b"".join(metas)
        sent = self.ring.try_send(parts)
        if sent is None:
            return False  # defines not committed: retried next ring message
        self._hdr_ids.update(fresh)
        self.sent_msgs += 1
        self.bytes_copied += sent
        return True


class LaneReceiver:
    """Consumer-side codec for one exchange lane (see :class:`LaneSender`)."""

    def __init__(self, ring: ShmRing):
        self.ring = ring
        self._hdrs: dict[int, tuple] = {}
        self.recv_msgs = 0
        self.bytes_copied = 0

    def poll(self) -> tuple[int, list] | None:
        """Pop and decode one record → ``(tick, items)``, or ``None``."""
        view = self.ring.recv()
        if view is None:
            return None
        self.recv_msgs += 1
        self.bytes_copied += len(view)
        tick, nitems = _unpack_preamble(view, 0)
        off = 12
        metas = []
        for _ in range(nitems):
            dop, flags, hid = _unpack_item(view, off)
            off += 9
            if hid & _DEFINE:
                (plen,) = _unpack_u32(view, off)
                off += 4
                self._hdrs[hid & ~_DEFINE] = pickle.loads(view[off : off + plen])
                off += plen
                hid &= ~_DEFINE
            (n,) = _unpack_u32(view, off)
            off += 4
            metas.append((dop, flags, self._hdrs[hid], n))
        items = []
        for dop, flags, (kdt, vdt, tdt), n in metas:
            nbytes = n * (kdt.itemsize + vdt.itemsize + tdt.itemsize)
            batch = serde.batch_from_views(
                view[off : off + nbytes], kdt, vdt, tdt, n
            )
            off += nbytes
            sk = sn = None
            if flags & _HAS_SRC:
                sk = np.frombuffer(view[off : off + 8 * n], dtype=np.int64)
                off += 8 * n
                sn = np.frombuffer(view[off : off + 8 * n], dtype=np.int64)
                off += 8 * n
            items.append((dop, batch, sk, sn))
        return tick, items

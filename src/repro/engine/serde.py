"""Migration codecs: σ_k blobs that carry queued segments as raw buffers.

Direct state migration ships one blob per key group (paper §3, steps 3–4).
The blob is a versioned envelope of

* the pickled operator state (``KeyedStore`` owns that codec — its byte
  length is the ``kg_state_bytes`` the migration cost model consumes), and
* the key group's queued backlog — the runs ``redirect`` masked out of the
  source node's work queue — encoded per batch.

Schema-typed batches (native key/value/ts dtypes) encode as raw buffer
slices: a tiny pickled dtype header plus ``tobytes`` of each column, decoded
with ``frombuffer`` — no per-tuple python, no pickling of boxed tuples.
Object batches fall back to pickle so undeclared operators migrate through
the very same envelope.  ``decode_batch(encode_batch(b))`` is value- and
dtype-exact for both, which is what keeps the conformance harness able to
pin typed and untyped execution bit-identical across migrations.

The envelope is *versioned*: byte 4 of the header carries the layout
version as an ASCII digit (``b"RSE" + b"1"`` — so a v1 envelope is
byte-identical to the historical ``b"RSE1"`` magic and every blob ever
produced by ``serialize()`` still installs).  :func:`envelope_version`
reads the version without decoding; :func:`decode_migration` rejects
versions this build does not understand instead of misparsing them.  The
version rules are documented in ``docs/execution_tiers.md``; the public
migration API wrapping these blobs is ``Engine.export_keygroup(kg) ->
Envelope`` / ``Engine.import_keygroup(env)``, and the multi-worker runtime
(:mod:`repro.engine.cluster`) ships exactly these envelopes between worker
processes.

Blobs that do not start with the ``b"RSE"`` magic prefix are treated as
bare state pickles with an empty backlog — the pre-envelope format the
failure-recovery path still emits when restoring from a checkpoint.
"""

from __future__ import annotations

import dataclasses
import pickle

import numpy as np

from repro.engine.topology import Batch

_MAGIC_PREFIX = b"RSE"  # repro stream envelope
ENVELOPE_VERSION = 1  # current layout version (v1 = the original layout)
MAGIC = b"RSE1"  # full v1 magic, kept for external readers

_TYPED, _PICKLED = 0, 1


def envelope_version(blob: bytes) -> int | None:
    """Layout version of a migration blob, or None for bare state pickles."""
    if len(blob) < 4 or blob[:3] != _MAGIC_PREFIX:
        return None
    v = blob[3] - ord("0")
    if not 0 <= v <= 9:
        raise ValueError(f"malformed envelope version byte {blob[3:4]!r}")
    return v


@dataclasses.dataclass(frozen=True)
class Envelope:
    """One key group's migration payload: σ_k state + queued backlog.

    The documented unit of state transfer: ``Engine.export_keygroup`` emits
    one, ``Engine.import_keygroup`` installs one, and worker-to-worker
    migration in :mod:`repro.engine.cluster` ships the ``blob`` bytes
    verbatim — so a cross-worker round trip is byte-identical to the
    single-process envelope (pinned by the conformance harness).
    """

    keygroup: int
    blob: bytes

    @property
    def version(self) -> int | None:
        return envelope_version(self.blob)

    @property
    def nbytes(self) -> int:
        return len(self.blob)


def _contig(a: np.ndarray) -> np.ndarray:
    return a if a.flags.c_contiguous else np.ascontiguousarray(a)


def encode_batch(batch: Batch) -> bytes:
    """One queued batch → bytes (raw buffers when fully native, else pickle)."""
    keys, values, ts = batch
    if keys.dtype.kind != "O" and values.dtype.kind != "O":
        head = pickle.dumps(
            (_TYPED, keys.dtype, values.dtype, ts.dtype, len(keys)),
            protocol=pickle.HIGHEST_PROTOCOL,
        )
        return b"".join(
            (
                len(head).to_bytes(4, "little"),
                head,
                _contig(keys).tobytes(),
                _contig(values).tobytes(),
                _contig(ts).tobytes(),
            )
        )
    head = pickle.dumps((_PICKLED, None, None, None, len(keys)))
    body = pickle.dumps((keys, values, ts), protocol=pickle.HIGHEST_PROTOCOL)
    return len(head).to_bytes(4, "little") + head + body


def decode_batch(blob: bytes | memoryview) -> Batch:
    view = memoryview(blob)
    hlen = int.from_bytes(view[:4], "little")
    tag, kdt, vdt, tdt, n = pickle.loads(view[4 : 4 + hlen])
    body = view[4 + hlen :]
    if tag == _PICKLED:
        return pickle.loads(body)
    ko, vo = n * kdt.itemsize, n * (kdt.itemsize + vdt.itemsize)
    # .copy(): frombuffer over an immutable blob yields read-only arrays;
    # replayed batches must be ordinary writable arrays like any other.
    keys = np.frombuffer(body[:ko], dtype=kdt, count=n).copy()
    values = np.frombuffer(body[ko:vo], dtype=vdt, count=n).copy()
    ts = np.frombuffer(body[vo:], dtype=tdt, count=n).copy()
    return keys, values, ts


def encode_migration(
    state_blob: bytes, backlog: list[Batch], *, version: int = ENVELOPE_VERSION
) -> bytes:
    """σ_k state + queued backlog → one versioned migration envelope."""
    if version != ENVELOPE_VERSION:
        raise ValueError(
            f"cannot encode envelope version {version}; this build writes "
            f"v{ENVELOPE_VERSION}"
        )
    parts = [
        _MAGIC_PREFIX + b"%d" % version,
        len(state_blob).to_bytes(8, "little"),
        state_blob,
        len(backlog).to_bytes(4, "little"),
    ]
    for b in backlog:
        eb = encode_batch(b)
        parts.append(len(eb).to_bytes(8, "little"))
        parts.append(eb)
    return b"".join(parts)


def decode_migration(blob: bytes) -> tuple[bytes, list[Batch]]:
    """Envelope → (state blob, backlog batches); bare pickles pass through.

    Raises on envelope versions this build does not understand — an
    unknown layout must fail loudly, not deserialize garbage.
    """
    version = envelope_version(blob)
    if version is None:
        return blob, []
    if version != ENVELOPE_VERSION:
        raise ValueError(
            f"unsupported migration envelope version {version} "
            f"(this build reads v{ENVELOPE_VERSION})"
        )
    view = memoryview(blob)
    off = len(MAGIC)
    slen = int.from_bytes(view[off : off + 8], "little")
    off += 8
    state_blob = bytes(view[off : off + slen])
    off += slen
    count = int.from_bytes(view[off : off + 4], "little")
    off += 4
    backlog: list[Batch] = []
    for _ in range(count):
        blen = int.from_bytes(view[off : off + 8], "little")
        off += 8
        backlog.append(decode_batch(view[off : off + blen]))
        off += blen
    return state_blob, backlog

"""Migration codecs: σ_k blobs that carry queued segments as raw buffers.

Direct state migration ships one blob per key group (paper §3, steps 3–4).
The blob is a versioned envelope of

* the pickled operator state (``KeyedStore`` owns that codec — its byte
  length is the ``kg_state_bytes`` the migration cost model consumes), and
* the key group's queued backlog — the runs ``redirect`` masked out of the
  source node's work queue — encoded per batch.

Schema-typed batches (native key/value/ts dtypes) encode as raw buffer
slices: a tiny pickled dtype header — *interned*, so every batch of the
same schema shares the exact header bytes and the pickling cost is paid
once per schema — plus ``tobytes`` of each column, decoded with
``frombuffer`` — no per-tuple python, no pickling of boxed tuples.
Object batches fall back to pickle so undeclared operators migrate through
the very same envelope.  ``decode_batch(encode_batch(b))`` is value- and
dtype-exact for both, which is what keeps the conformance harness able to
pin typed and untyped execution bit-identical across migrations.

The envelope is *versioned*: byte 4 of the header carries the layout
version as an ASCII digit (``b"RSE" + b"1"`` — so a v1 envelope is
byte-identical to the historical ``b"RSE1"`` magic and every blob ever
produced by ``serialize()`` still installs).  :func:`envelope_version`
reads the version without decoding; :func:`decode_migration` rejects
versions this build does not understand instead of misparsing them.  The
version rules are documented in ``docs/execution_tiers.md``; the public
migration API wrapping these blobs is ``Engine.export_keygroup(kg) ->
Envelope`` / ``Engine.import_keygroup(env)``, and the multi-worker runtime
(:mod:`repro.engine.cluster`) ships exactly these envelopes between worker
processes.

Blobs that do not start with the ``b"RSE"`` magic prefix are treated as
bare state pickles with an empty backlog — the pre-envelope format the
failure-recovery path still emits when restoring from a checkpoint.
"""

from __future__ import annotations

import dataclasses
import pickle

import numpy as np

from repro.engine.topology import Batch

_MAGIC_PREFIX = b"RSE"  # repro stream envelope
ENVELOPE_VERSION = 1  # current layout version (v1 = the original layout)
MAGIC = b"RSE1"  # full v1 magic, kept for external readers

_TYPED, _PICKLED = 0, 1


def envelope_version(blob: bytes) -> int | None:
    """Layout version of a migration blob, or None for bare state pickles."""
    if len(blob) < 4 or blob[:3] != _MAGIC_PREFIX:
        return None
    v = blob[3] - ord("0")
    if not 0 <= v <= 9:
        raise ValueError(f"malformed envelope version byte {blob[3:4]!r}")
    return v


@dataclasses.dataclass(frozen=True)
class Envelope:
    """One key group's migration payload: σ_k state + queued backlog.

    The documented unit of state transfer: ``Engine.export_keygroup`` emits
    one, ``Engine.import_keygroup`` installs one, and worker-to-worker
    migration in :mod:`repro.engine.cluster` ships the ``blob`` bytes
    verbatim — so a cross-worker round trip is byte-identical to the
    single-process envelope (pinned by the conformance harness).
    """

    keygroup: int
    blob: bytes

    @property
    def version(self) -> int | None:
        return envelope_version(self.blob)

    @property
    def nbytes(self) -> int:
        return len(self.blob)


def _contig(a: np.ndarray) -> np.ndarray:
    return a if a.flags.c_contiguous else np.ascontiguousarray(a)


#: Interned typed headers: one pickled header per (key, value, ts) dtype
#: triple.  Batches sharing a schema therefore share the exact header bytes
#: (the "same schema ⇒ same bytes" contract the shm exchange lanes and the
#: conformance envelope pinning rely on), and the pickling cost is paid once
#: per schema instead of once per batch.  The batch length lives *outside*
#: the header as a fixed-width field so the header can be interned at all.
_HEADER_CACHE: dict[tuple, bytes] = {}


def typed_header(kdt: np.dtype, vdt: np.dtype, tdt: np.dtype) -> bytes:
    """The interned typed-batch header for one dtype triple."""
    key = (kdt, vdt, tdt)
    head = _HEADER_CACHE.get(key)
    if head is None:
        head = pickle.dumps((_TYPED, kdt, vdt, tdt), protocol=pickle.HIGHEST_PROTOCOL)
        _HEADER_CACHE[key] = head
    return head


def is_typed_batch(batch: Batch) -> bool:
    """True when every column is native (no object fields anywhere).

    ``dtype.hasobject`` rather than ``dtype.kind != "O"``: a *structured*
    dtype containing an object field has kind ``"V"`` but still cannot be
    encoded as raw buffers — ``tobytes``/``frombuffer`` would ship raw
    pointers.  Such batches take the pickle path.
    """
    keys, values, ts = batch
    return not (
        keys.dtype.hasobject or values.dtype.hasobject or ts.dtype.hasobject
    )


def column_views(batch: Batch) -> list[memoryview]:
    """Write-side zero-copy views of a typed batch's raw column buffers.

    The byte concatenation of these views equals the column section of
    ``encode_batch`` exactly; writers with their own framing (the shm
    exchange lanes) splice them straight into the destination buffer
    without materialising intermediate ``bytes``.
    """
    return [memoryview(_contig(col)).cast("B") for col in batch]


def batch_from_views(
    body: memoryview, kdt: np.dtype, vdt: np.dtype, tdt: np.dtype, n: int
) -> Batch:
    """Read-side zero-copy decode of the typed column layout.

    The caller owns ``body``'s lifetime and writability (the shm lanes hand
    over a freshly copied-out, writable buffer); the returned arrays alias
    it, so no defensive copy is taken.
    """
    ko, vo = n * kdt.itemsize, n * (kdt.itemsize + vdt.itemsize)
    to = vo + n * tdt.itemsize
    keys = np.frombuffer(body[:ko], dtype=kdt, count=n)
    values = np.frombuffer(body[ko:vo], dtype=vdt, count=n)
    ts = np.frombuffer(body[vo:to], dtype=tdt, count=n)
    return keys, values, ts


def encode_batch(batch: Batch) -> bytes:
    """One queued batch → bytes (raw buffers when fully native, else pickle)."""
    keys, values, ts = batch
    if is_typed_batch(batch):
        head = typed_header(keys.dtype, values.dtype, ts.dtype)
        return b"".join(
            (
                len(head).to_bytes(4, "little"),
                head,
                len(keys).to_bytes(4, "little"),
                _contig(keys).tobytes(),
                _contig(values).tobytes(),
                _contig(ts).tobytes(),
            )
        )
    head = pickle.dumps((_PICKLED, None, None, None, len(keys)))
    body = pickle.dumps((keys, values, ts), protocol=pickle.HIGHEST_PROTOCOL)
    return len(head).to_bytes(4, "little") + head + body


def decode_batch(blob: bytes | memoryview, *, copy: bool = True) -> Batch:
    """Bytes → batch.  ``copy=False`` skips the defensive copy and returns
    arrays aliasing ``blob`` — only for callers that own a writable buffer
    whose lifetime outlives the batch (the shm exchange lanes)."""
    view = memoryview(blob)
    hlen = int.from_bytes(view[:4], "little")
    header = pickle.loads(view[4 : 4 + hlen])
    if len(header) == 5:  # legacy layout: batch length inside the header
        tag, kdt, vdt, tdt, n = header
        body = view[4 + hlen :]
    else:
        tag, kdt, vdt, tdt = header
        n = int.from_bytes(view[4 + hlen : 8 + hlen], "little")
        body = view[8 + hlen :]
    if tag == _PICKLED:
        return pickle.loads(body)
    if copy:
        # One raw byte copy: frombuffer over the immutable blob would yield
        # read-only arrays, and per-column ndarray.copy() leaves structured
        # padding bytes uninitialized — a raw copy keeps the round trip
        # byte-exact and the arrays ordinarily writable.
        body = memoryview(bytearray(body))
    return batch_from_views(body, kdt, vdt, tdt, n)


def encode_migration(
    state_blob: bytes, backlog: list[Batch], *, version: int = ENVELOPE_VERSION
) -> bytes:
    """σ_k state + queued backlog → one versioned migration envelope."""
    if version != ENVELOPE_VERSION:
        raise ValueError(
            f"cannot encode envelope version {version}; this build writes "
            f"v{ENVELOPE_VERSION}"
        )
    parts = [
        _MAGIC_PREFIX + b"%d" % version,
        len(state_blob).to_bytes(8, "little"),
        state_blob,
        len(backlog).to_bytes(4, "little"),
    ]
    for b in backlog:
        eb = encode_batch(b)
        parts.append(len(eb).to_bytes(8, "little"))
        parts.append(eb)
    return b"".join(parts)


def decode_migration(blob: bytes) -> tuple[bytes, list[Batch]]:
    """Envelope → (state blob, backlog batches); bare pickles pass through.

    Raises on envelope versions this build does not understand — an
    unknown layout must fail loudly, not deserialize garbage.
    """
    version = envelope_version(blob)
    if version is None:
        return blob, []
    if version != ENVELOPE_VERSION:
        raise ValueError(
            f"unsupported migration envelope version {version} "
            f"(this build reads v{ENVELOPE_VERSION})"
        )
    view = memoryview(blob)
    off = len(MAGIC)
    slen = int.from_bytes(view[off : off + 8], "little")
    off += 8
    state_blob = bytes(view[off : off + slen])
    off += slen
    count = int.from_bytes(view[off : off + 4], "little")
    off += 4
    backlog: list[Batch] = []
    for _ in range(count):
        blen = int.from_bytes(view[off : off + 8], "little")
        off += 8
        backlog.append(decode_batch(view[off : off + blen]))
        off += blen
    return state_blob, backlog

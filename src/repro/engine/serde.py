"""Migration codecs: σ_k blobs that carry queued segments as raw buffers.

Direct state migration ships one blob per key group (paper §3, steps 3–4).
The blob is a versioned envelope of

* the pickled operator state (``KeyedStore`` owns that codec — its byte
  length is the ``kg_state_bytes`` the migration cost model consumes), and
* the key group's queued backlog — the runs ``redirect`` masked out of the
  source node's work queue — encoded per batch.

Schema-typed batches (native key/value/ts dtypes) encode as raw buffer
slices: a tiny pickled dtype header plus ``tobytes`` of each column, decoded
with ``frombuffer`` — no per-tuple python, no pickling of boxed tuples.
Object batches fall back to pickle so undeclared operators migrate through
the very same envelope.  ``decode_batch(encode_batch(b))`` is value- and
dtype-exact for both, which is what keeps the conformance harness able to
pin typed and untyped execution bit-identical across migrations.

Blobs that do not start with :data:`MAGIC` are treated as bare state
pickles with an empty backlog — the pre-envelope format the failure-recovery
path still emits when restoring from a checkpoint.
"""

from __future__ import annotations

import pickle

import numpy as np

from repro.engine.topology import Batch

MAGIC = b"RSE1"  # repro stream envelope, version 1

_TYPED, _PICKLED = 0, 1


def _contig(a: np.ndarray) -> np.ndarray:
    return a if a.flags.c_contiguous else np.ascontiguousarray(a)


def encode_batch(batch: Batch) -> bytes:
    """One queued batch → bytes (raw buffers when fully native, else pickle)."""
    keys, values, ts = batch
    if keys.dtype.kind != "O" and values.dtype.kind != "O":
        head = pickle.dumps(
            (_TYPED, keys.dtype, values.dtype, ts.dtype, len(keys)),
            protocol=pickle.HIGHEST_PROTOCOL,
        )
        return b"".join(
            (
                len(head).to_bytes(4, "little"),
                head,
                _contig(keys).tobytes(),
                _contig(values).tobytes(),
                _contig(ts).tobytes(),
            )
        )
    head = pickle.dumps((_PICKLED, None, None, None, len(keys)))
    body = pickle.dumps((keys, values, ts), protocol=pickle.HIGHEST_PROTOCOL)
    return len(head).to_bytes(4, "little") + head + body


def decode_batch(blob: bytes | memoryview) -> Batch:
    view = memoryview(blob)
    hlen = int.from_bytes(view[:4], "little")
    tag, kdt, vdt, tdt, n = pickle.loads(view[4 : 4 + hlen])
    body = view[4 + hlen :]
    if tag == _PICKLED:
        return pickle.loads(body)
    ko, vo = n * kdt.itemsize, n * (kdt.itemsize + vdt.itemsize)
    # .copy(): frombuffer over an immutable blob yields read-only arrays;
    # replayed batches must be ordinary writable arrays like any other.
    keys = np.frombuffer(body[:ko], dtype=kdt, count=n).copy()
    values = np.frombuffer(body[ko:vo], dtype=vdt, count=n).copy()
    ts = np.frombuffer(body[vo:], dtype=tdt, count=n).copy()
    return keys, values, ts


def encode_migration(state_blob: bytes, backlog: list[Batch]) -> bytes:
    """σ_k state + queued backlog → one migration envelope."""
    parts = [
        MAGIC,
        len(state_blob).to_bytes(8, "little"),
        state_blob,
        len(backlog).to_bytes(4, "little"),
    ]
    for b in backlog:
        eb = encode_batch(b)
        parts.append(len(eb).to_bytes(8, "little"))
        parts.append(eb)
    return b"".join(parts)


def decode_migration(blob: bytes) -> tuple[bytes, list[Batch]]:
    """Envelope → (state blob, backlog batches); bare pickles pass through."""
    if not blob.startswith(MAGIC):
        return blob, []
    view = memoryview(blob)
    off = len(MAGIC)
    slen = int.from_bytes(view[off : off + 8], "little")
    off += 8
    state_blob = bytes(view[off : off + slen])
    off += slen
    count = int.from_bytes(view[off : off + 4], "little")
    off += 4
    backlog: list[Batch] = []
    for _ in range(count):
        blen = int.from_bytes(view[off : off + 8], "little")
        off += 8
        backlog.append(decode_batch(view[off : off + blen]))
        off += blen
    return state_blob, backlog

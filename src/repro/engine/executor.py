"""The engine: executes a topology over logical nodes, measuring everything
the controller needs (paper §3 "Statistics", §5 metrics).

Execution is tick-based.  Per tick every node drains up to
``service_rate × capacity`` cost-units from its FIFO work queue; operator
outputs are routed by key to downstream key groups; cross-node sends charge
serialization cost to the sender and deserialization cost to the receiver
(the CPU overhead ALBIC's collocation removes) plus network bytes.  Queue
depth beyond the service budget becomes queueing latency and, via
credit-based backpressure, throttles the sources — reproducing the dynamics
that make long-term balance matter.

On TPU deployments the logical nodes map 1:1 onto mesh devices and operator
``fn``s are jitted shard_map shards; on CPU (tests, paper benchmarks) the
nodes timeshare the host.  The engine semantics are identical — that is the
point of keeping reconfiguration decisions as *data* (routing table) rather
than recompiles.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.core.stats import ClusterState, SPLWindow
from repro.engine.backpressure import CreditController, LatencyTracker
from repro.engine.router import Router, concat_batches
from repro.engine.state import KeyedStore
from repro.engine.topology import Batch, Topology, make_batch


@dataclasses.dataclass
class EngineMetrics:
    ticks: int = 0
    processed_tuples: int = 0
    emitted_tuples: int = 0
    cross_node_tuples: int = 0
    intra_node_tuples: int = 0
    dropped_credits: int = 0
    sink_outputs: list = dataclasses.field(default_factory=list)

    def throughput(self) -> float:
        return self.processed_tuples / max(self.ticks, 1)


class Engine:
    """Single-process execution of a Topology over ``num_nodes`` logical nodes."""

    def __init__(
        self,
        topology: Topology,
        num_nodes: int,
        *,
        initial_alloc: Optional[np.ndarray] = None,
        capacity: Optional[np.ndarray] = None,
        service_rate: float = 1_000.0,  # cost-units a reference node serves per tick
        ser_cost: float = 0.25,  # cost-units per cross-node tuple (each side)
        seed: int = 0,
    ) -> None:
        topology.validate()
        self.topology = topology
        self.num_nodes = num_nodes
        self.capacity = np.ones(num_nodes) if capacity is None else np.asarray(capacity)
        self.service_rate = service_rate
        self.ser_cost = ser_cost
        g = topology.num_keygroups
        rng = np.random.default_rng(seed)
        if initial_alloc is None:
            initial_alloc = rng.integers(0, num_nodes, size=g)
        self.store = KeyedStore(g)
        self.router = Router(g, initial_alloc)
        self.window = SPLWindow(g)
        self.metrics = EngineMetrics()
        self.latency = LatencyTracker()
        self.backpressure = CreditController(num_nodes, high_wm=50 * service_rate)
        # Per-node FIFO of (op, kg, batch, enqueue_tick); queue cost tracked.
        self._queues: list[deque] = [deque() for _ in range(num_nodes)]
        self._queue_cost = np.zeros(num_nodes)
        self._kg_op = topology.kg_operator()
        self._downstream = topology.downstream()
        self._ticks_this_period = 0
        self.alive = np.ones(num_nodes, dtype=bool)

    # ------------------------------------------------------------------ feed
    def source_credits(self) -> int:
        return self.backpressure.credits(self._queue_cost)

    def push_source(self, op: str | int, keys, values, ts) -> int:
        """Feed tuples into a source operator; returns tuples accepted."""
        oid = self.topology._resolve(op)
        spec = self.topology.operators[oid]
        if not spec.is_source:
            raise ValueError(f"{spec.name!r} is not a source")
        credits = self.source_credits()
        n = min(len(keys), credits)
        if n < len(keys):
            self.metrics.dropped_credits += len(keys) - n
        if n == 0:
            return 0
        batch = make_batch(keys[:n], values[:n], ts[:n])
        self._route_batch(oid, batch, src_kg=None, src_node=None)
        return n

    def _route_batch(
        self, op: int, batch: Batch, *, src_kg: Optional[int], src_node: Optional[int]
    ) -> None:
        """Partition a batch by the operator's key groups and enqueue."""
        keys, values, ts = batch
        if len(keys) == 0:
            return
        kgs = np.fromiter(
            (self.topology.keygroup_of(op, k, v) for k, v in zip(keys, values)),
            dtype=np.int64,
            count=len(keys),
        )
        for kg in np.unique(kgs):
            mask = kgs == kg
            sub = (keys[mask], values[mask], ts[mask])
            node, buffered = self.router.route(int(kg), sub)
            n_tuples = int(mask.sum())
            if src_kg is not None:
                self.window.record_send(src_kg, int(kg), n_tuples)
                if src_node is not None and src_node != node:
                    # Cross-node: serialization at src, deserialization at dst,
                    # plus network bytes on both (paper §4.3.2 rationale).
                    self.window.record_processing("cpu", src_kg, self.ser_cost * n_tuples)
                    self.window.record_processing("cpu", int(kg), self.ser_cost * n_tuples)
                    self.window.record_processing("network", src_kg, n_tuples)
                    self.window.record_processing("network", int(kg), n_tuples)
                    self.metrics.cross_node_tuples += n_tuples
                else:
                    self.metrics.intra_node_tuples += n_tuples
            if not buffered:
                self._enqueue(node, op, int(kg), sub)

    def _enqueue(self, node: int, op: int, kg: int, batch: Batch) -> None:
        cost = self.topology.operators[op].cost_per_tuple * len(batch[0])
        self._queues[node].append((op, kg, batch, self.metrics.ticks, cost))
        self._queue_cost[node] += cost
        # Queueing-latency estimate at admission: work ahead / service speed.
        budget = self.service_rate * self.capacity[node]
        self.latency.record(self._queue_cost[node] / max(budget, 1e-9), len(batch[0]))

    # ------------------------------------------------------------------ tick
    def tick(self) -> None:
        self.metrics.ticks += 1
        self._ticks_this_period += 1
        for node in range(self.num_nodes):
            if not self.alive[node]:
                continue
            budget = self.service_rate * self.capacity[node]
            q = self._queues[node]
            while q and budget > 0:
                op, kg, batch, _tick_in, cost = q.popleft()
                self._queue_cost[node] -= cost
                budget -= cost
                self._process(node, op, kg, batch)

    def _process(self, node: int, op: int, kg: int, batch: Batch) -> None:
        spec = self.topology.operators[op]
        keys, values, ts = batch
        n = len(keys)
        self.metrics.processed_tuples += n
        self.window.record_processing("cpu", kg, spec.cost_per_tuple * n)
        if spec.fn is None:  # source pass-through
            outputs = list(zip(keys.tolist(), values.tolist(), ts.tolist()))
        else:
            state = self.store.get(kg)
            state, outputs = spec.fn(state, keys, values, ts)
            self.store.put(kg, state)
        if not outputs:
            return
        self.metrics.emitted_tuples += len(outputs)
        if spec.is_sink or not self._downstream[op]:
            self.metrics.sink_outputs.extend(outputs)
            return
        out_keys = [o[0] for o in outputs]
        out_vals = [o[1] for o in outputs]
        out_ts = [o[2] for o in outputs]
        for dop in self._downstream[op]:
            self._route_batch(
                dop, make_batch(out_keys, out_vals, out_ts), src_kg=kg, src_node=node
            )

    # ------------------------------------------------------- SPL statistics
    def end_period(self) -> ClusterState:
        """Fold the SPL window into a ClusterState snapshot and reset it."""
        ticks = max(self._ticks_this_period, 1)
        scale = 100.0 / (ticks * self.service_rate)  # → % of a reference node
        kg_load, out_rates, _resource = self.window.fold(scale_to_percent=scale)
        state = ClusterState.create(
            self.num_nodes,
            self._kg_op,
            kg_load,
            self.router.table.copy(),
            kg_state_bytes=self.store.state_bytes(refresh=True),
            out_rates=out_rates,
            downstream=self._downstream,
            capacity=self.capacity.copy(),
        )
        state.alive = self.alive.copy()
        self.window.reset()
        self._ticks_this_period = 0
        return state

    # ------------------------------------------------- direct state migration
    # StateMover protocol (repro.core.migration).
    def redirect(self, keygroup: int, dst: int) -> None:
        self.router.redirect(keygroup, dst)

    def serialize(self, keygroup: int) -> bytes:
        return self.store.serialize(keygroup)

    def install(self, keygroup: int, dst: int, blob: bytes) -> None:
        self.store.deserialize(keygroup, blob)
        op = int(self._kg_op[keygroup])
        for batch in self.router.complete(keygroup):
            self._enqueue(dst, op, keygroup, batch)  # replay buffered tuples

    # --------------------------------------------------------------- elastic
    def add_nodes(self, count: int, capacity: float = 1.0) -> None:
        self.num_nodes += count
        self.capacity = np.concatenate([self.capacity, np.full(count, capacity)])
        self.alive = np.concatenate([self.alive, np.ones(count, dtype=bool)])
        self._queues.extend(deque() for _ in range(count))
        self._queue_cost = np.concatenate([self._queue_cost, np.zeros(count)])
        self.backpressure.num_nodes = self.num_nodes

    def fail_node(self, node: int) -> np.ndarray:
        """Simulate a node crash: queue lost, key groups orphaned.

        Returns the orphaned key groups; the controller reallocates them (their
        state is recovered from the last checkpoint — see repro.checkpoint).
        """
        self.alive[node] = False
        self._queues[node].clear()
        self._queue_cost[node] = 0.0
        return self.router.keygroups_on(node)

"""The engine: executes a topology over logical nodes, measuring everything
the controller needs (paper §3 "Statistics", §5 metrics).

The data plane is array-native end to end.  Tuples move through the system as
:class:`~repro.engine.topology.Batch` triples (key/value/ts parallel arrays),
never as per-tuple Python objects:

* routing hashes whole key arrays at once (`Topology.keygroups_of`, the same
  32-bit mix the Pallas ``keygroup_partition`` kernel runs on TPU) and splits
  a batch into per-key-group runs with one stable argsort — on TPU (or with
  ``kernel_stats=True``) the kernel computes the key-group ids *and* the
  per-key-group tuple histogram in one pass, and that histogram feeds SPL
  statistics directly; the numpy path (``np.bincount``) is the bit-identical
  CPU fallback;
* work queues are structure-of-arrays (:mod:`repro.engine.workqueue`): a
  routed batch is sorted once by the (destination node, key group)
  composite and pushed as one *segment* per node — a contiguous slice of
  the shared key/value/ts arrays plus parallel ``(kg, start, end, cost)``
  run-index lists — and ``tick()`` drains a node by walking those lists and
  slicing fat arrays instead of popping thousands of per-(op, key group)
  queue entries; CPU charges for the drained runs land in one vectorized
  scatter;
* operators may implement the segment-vectorized protocol
  (``OperatorSpec.fn_seg``): one call covers every key group a node drains
  for that operator in a tick, with the per-run ``fn`` as the required
  fallback for non-contiguous segments (in-flight migrations, partial
  budgets) and as the semantic oracle the equivalence tests pin against;
* operators may additionally implement the compiled tier
  (``OperatorSpec.fn_jit`` + declared ``StateSchema``, enabled with
  ``use_fn_jit=True``): contiguous whole-budget segments defer into one
  batched ``jax.jit`` call per operator per tick over device state columns
  (:mod:`repro.engine.jitexec`; placeholder cells keep output order
  identical to inline execution, and per-run fallbacks force-flush the
  deferred batch first so state updates stay in drain order);
* a tick is a BSP superstep: outputs produced while draining are accumulated
  per downstream operator and routed once, at the end of the tick, as one
  coalesced batch carrying per-tuple source attribution — so each (operator,
  key group) gets at most one segment push per tick and the next tick drains
  few, fat runs instead of thousands of fragments;
* SPL statistics — ``out(g_i, g_j)`` pair counts, per-key-group arrival
  histograms, serialization CPU, network bytes — are recorded as arrays
  (sparse pair codes, histograms, ``np.add.at`` scatters), never per-tuple
  Python calls;
* operators may declare a :class:`~repro.engine.topology.Schema` — a
  structured-numpy record layout for their input values plus a typed key
  dtype.  Schema-typed edges carry native structured arrays instead of
  object arrays: the routing permutation gathers fixed-width records, the
  SoA work queues slice native buffers, ``fn_seg`` sees column views
  (``values["field"]``), and sink collection is a structured ``tolist``.
  Undeclared operators keep the object-array path behind the same API;
  batches are conformed at edge boundaries (typed targets promote object
  outputs in one C-level conversion, untyped targets decay structured
  batches to the identical boxed tuples), and ``use_schema=False`` strips
  every declaration for the untyped oracle configuration;
* direct state migration moves a key group's *queued* work along with its
  state: ``redirect`` masks the key group's runs out of the source node's
  queue (``extract_keygroup``) into the migration backlog, ``serialize``
  ships σ_k plus that backlog in one envelope — schema-typed batches as raw
  ``tobytes`` buffer slices, object batches via pickle (see
  :mod:`repro.engine.serde`) — and ``install`` replays backlog then buffered
  arrivals at the destination in FIFO order.

Execution is tick-based.  Per tick every node drains up to
``service_rate × capacity`` cost-units from its FIFO work queue; operator
outputs are routed by key to downstream key groups; cross-node sends charge
serialization cost to the sender and deserialization cost to the receiver
(the CPU overhead ALBIC's collocation removes) plus network bytes.  Queue
depth beyond the service budget becomes queueing latency and, via
credit-based backpressure, throttles the sources — reproducing the dynamics
that make long-term balance matter.

``queue_impl="deque"`` selects the legacy per-entry queue, kept as the
equivalence oracle: tests/test_routing_equivalence.py runs both
implementations on identical inputs and requires bit-identical tuple flow
and SPL statistics.

Authoring operators
-------------------

Every non-source operator provides the per-run ``fn`` (the semantic oracle);
hot operators additionally implement ``fn_seg``, the segment-vectorized
protocol (see :data:`repro.engine.topology.SegmentFn`).  The contract:

* ``fn_seg(store, kgs, starts, ends, keys, values, ts)`` covers every key
  group a node drains for the operator in one tick.  ``store`` is the raw
  per-key-group state list (index with the *global* key-group ids in
  ``kgs``); ``starts``/``ends`` are slice bounds into the contiguous
  key/value/ts arrays, one run per key group, tiling ``[0, len(keys))``.
* It returns ``(outputs, out_counts)``: a Batch concatenated over the runs
  *in run order* (or None), and per-run output lengths (None when every run
  emits exactly its input length).
* It must be *bit-identical* to calling ``fn`` run by run: same emitted
  tuples in the same order, same per-key-group state (including dict
  insertion order — it decides tie-breaks and pickle bytes), same float
  trajectories (running sums must accumulate left to right, e.g. via
  ``np.cumsum`` over ``[base, d0, d1, ...]``).
* The engine falls back to ``fn`` for non-contiguous segments (in-flight
  migrations, extraction rebuilds) and partial-budget drains, so both paths
  interleave freely within one run of the job.

``Engine(..., use_fn_seg=False)`` disables the segment protocol wholesale
(the benchmark baseline); ``use_schema=False`` likewise strips declared
schemas so every edge carries object arrays (the untyped oracle).
``EngineMetrics.seg_calls``/``seg_tuples``/``typed_batches`` count how
often the vectorized and schema-typed paths actually fired.  New operators
(and new ``fn_seg`` ports or schema declarations) must be pinned by the
differential conformance harness in ``tests/conformance.py`` — see
``tests/test_real_jobs_conformance.py`` and ``docs/operator_authoring.md``.
"""

from __future__ import annotations

import dataclasses
import sys
import warnings
from typing import Optional

import numpy as np

from repro.core.stats import ClusterState, SPLWindow
from repro.engine import serde
from repro.engine.backpressure import CreditController, LatencyTracker
from repro.engine.config import LEGACY_EXECUTION_KWARGS, ExecutionConfig
from repro.engine.router import Router, concat_batches
from repro.engine.state import KeyedStore
from repro.engine.topology import (
    Batch,
    Schema,
    Topology,
    _identity_key,
    make_batch,
)
from repro.engine.workqueue import _S_CUR, QUEUE_IMPLS, SoAWorkQueue


@dataclasses.dataclass
class EngineMetrics:
    ticks: int = 0
    processed_tuples: int = 0
    emitted_tuples: int = 0
    cross_node_tuples: int = 0
    intra_node_tuples: int = 0
    dropped_credits: int = 0
    sink_tuples: int = 0
    # Segment-vectorized protocol usage: calls to an operator's fn_seg and
    # tuples processed through it (0 on the deque oracle / use_fn_seg=False).
    seg_calls: int = 0
    seg_tuples: int = 0
    # Batches routed to a schema-declared operator as native-dtype arrays
    # (0 with use_schema=False — the all-object oracle configuration).
    typed_batches: int = 0
    # Compiled-tier usage: fn_jit segment executions, tuples through them,
    # and actual program compilations (one per (operator, padding bucket) —
    # O(#buckets) across a run, never O(#ticks); pinned by
    # tests/test_jitexec.py).
    jit_calls: int = 0
    jit_tuples: int = 0
    jit_compiles: int = 0
    # Host↔device boundary crossings of the compiled tier: one per
    # per-operator jit call, one per fused superstep tick, one per
    # run_supersteps(K) scan — the metric that proves the superstep path's
    # O(1) crossings per K ticks against the per-operator tier's O(ops·K).
    jit_host_syncs: int = 0
    # Materialized sink tuples; only populated when the engine was built with
    # ``collect_sinks=True`` (unbounded growth otherwise — benchmarks disable
    # it so they measure the data plane, not list appends).
    sink_outputs: list = dataclasses.field(default_factory=list)
    # Hot-key observability, refreshed each end_period(): the top-k key
    # groups by per-period arrival count as (keygroup, tuples) pairs, and
    # the hottest key group's share of the period's arrivals.  The
    # multi-worker coordinator folds per-worker arrival partial sums before
    # computing these, so single- and multi-worker runs report the same
    # gauge for the same traffic.
    hot_keygroups: list = dataclasses.field(default_factory=list)
    max_kg_share: float = 0.0

    def throughput(self) -> float:
        return self.processed_tuples / max(self.ticks, 1)


#: Size of the EngineMetrics.hot_keygroups top-k gauge.
HOT_TOPK = 8


def hot_key_summary(
    arrivals: np.ndarray, topk: int = HOT_TOPK
) -> tuple[list[tuple[int, float]], float]:
    """Top-k (keygroup, tuples) by arrival count, plus the hottest share.

    Deterministic under ties (stable sort on descending counts — the lowest
    key-group id wins), zero-arrival entries dropped.  Shared by
    ``Engine.end_period`` and the cluster coordinator's fold.
    """
    total = float(arrivals.sum())
    if total <= 0.0:
        return [], 0.0
    order = np.argsort(-arrivals, kind="stable")[:topk]
    top = [(int(i), float(arrivals[i])) for i in order if arrivals[i] > 0]
    return top, float(arrivals[order[0]]) / total


def _as_batch(outputs) -> Optional[Batch]:
    """Normalize operator output to a Batch.

    A 3-tuple whose first element is an ndarray is the array-native protocol
    (keys array, values/ts arrays or sequences) — the ndarray requirement
    keeps a classic-protocol output that happens to hold exactly three
    (k, v, t) triples unambiguous.  Anything else iterable is the classic
    per-tuple protocol, transposed once.
    """
    if outputs is None:
        return None
    if (
        isinstance(outputs, tuple)
        and len(outputs) == 3
        and isinstance(outputs[0], np.ndarray)
    ):
        keys, values, ts = outputs
        if isinstance(values, np.ndarray) and isinstance(ts, np.ndarray):
            return outputs
        return make_batch(keys, values, ts)
    if not outputs:
        return None
    keys, values, ts = zip(*outputs)
    return make_batch(keys, values, ts)


def _auto_kernel_stats() -> bool:
    """Use the Pallas partition kernel only when jax is already up on TPU.

    Checked without importing jax: an engine on a CPU host must not pay jax
    initialization for a path it will never take.
    """
    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


_UNSET = object()  # legacy-kwarg sentinel: distinguishes "not passed"


class Engine:
    """Single-process execution of a Topology over ``num_nodes`` logical nodes.

    How the topology executes — queue layout, operator tier — is one value:
    ``Engine(topology, num_nodes, config=ExecutionConfig.<preset>())`` (see
    :mod:`repro.engine.config`).  The pre-config execution kwargs are still
    accepted for one release through a ``DeprecationWarning`` shim; a config
    with ``num_workers > 1`` is the multi-worker runtime's
    (:class:`repro.engine.cluster.ClusterEngine`) — this class rejects it.
    """

    def __init__(
        self,
        topology: Topology,
        num_nodes: int,
        *,
        config: Optional[ExecutionConfig] = None,
        initial_alloc: Optional[np.ndarray] = None,
        capacity: Optional[np.ndarray] = None,
        service_rate: float = 1_000.0,  # cost-units a reference node serves per tick
        ser_cost: float = 0.25,  # cost-units per cross-node tuple (each side)
        seed: int = 0,
        collect_sinks: bool = True,
        # Deprecated execution kwargs (one-release shim onto ExecutionConfig).
        queue_impl=_UNSET,
        kernel_stats=_UNSET,
        use_fn_seg=_UNSET,
        use_schema=_UNSET,
        use_fn_jit=_UNSET,
        superstep=_UNSET,
        jit_mesh=_UNSET,
        jit_mesh_axis=_UNSET,
    ) -> None:
        legacy = {
            k: v
            for k, v in (
                ("queue_impl", queue_impl),
                ("kernel_stats", kernel_stats),
                ("use_fn_seg", use_fn_seg),
                ("use_schema", use_schema),
                ("use_fn_jit", use_fn_jit),
                ("superstep", superstep),
                ("jit_mesh", jit_mesh),
                ("jit_mesh_axis", jit_mesh_axis),
            )
            if v is not _UNSET
        }
        if legacy:
            if config is not None:
                raise TypeError(
                    f"pass config=ExecutionConfig(...) or the legacy kwargs "
                    f"{sorted(legacy)}, not both"
                )
            warnings.warn(
                f"Engine execution kwargs {sorted(legacy)} are deprecated; "
                f"pass config=ExecutionConfig(...) instead "
                f"(see repro.engine.config)",
                DeprecationWarning,
                stacklevel=2,
            )
            config = ExecutionConfig.from_legacy_kwargs(legacy)
        if config is None:
            config = ExecutionConfig()
        if config.num_workers > 1:
            raise ValueError(
                "ExecutionConfig.workers(n) selects the multi-worker runtime: "
                "construct repro.engine.cluster.ClusterEngine (or use "
                "repro.engine.make_engine) instead of Engine"
            )
        self.config = config
        queue_impl = config.queue_impl
        kernel_stats = config.kernel_stats
        use_fn_seg = config.use_fn_seg
        use_schema = config.use_schema
        use_fn_jit = config.use_fn_jit
        superstep = config.use_superstep
        jit_mesh = config.jit_mesh
        jit_mesh_axis = config.jit_mesh_axis
        topology.validate()
        self.topology = topology
        self.num_nodes = num_nodes
        self.capacity = np.ones(num_nodes) if capacity is None else np.asarray(capacity)
        self.service_rate = service_rate
        self.ser_cost = ser_cost
        self.seed = seed
        g = topology.num_keygroups
        # Hot-key splitting reserves extra key-group slots: replicas live in
        # the extended id space [g, g + reserve) and behave as ordinary key
        # groups everywhere downstream of routing (queues, statistics,
        # allocation, migration) once a split assigns them to an operator.
        reserve = config.split_reserve if config.split_degree else 0
        self._g_base = g
        g_eff = g + reserve
        rng = np.random.default_rng(seed)
        if initial_alloc is None:
            initial_alloc = rng.integers(0, num_nodes, size=g)
        initial_alloc = np.asarray(initial_alloc, dtype=np.int64)
        if reserve and len(initial_alloc) == g:
            # Reserved slots park on node 0 until a split places them.
            initial_alloc = np.concatenate(
                [initial_alloc, np.zeros(reserve, dtype=np.int64)]
            )
        self.store = KeyedStore(g_eff)
        self.router = Router(g_eff, initial_alloc)
        self.window = SPLWindow(g_eff)
        self.metrics = EngineMetrics()
        self.latency = LatencyTracker()
        self.backpressure = CreditController(num_nodes, high_wm=50 * service_rate)
        self.collect_sinks = collect_sinks
        self.kernel_stats = (
            _auto_kernel_stats() if kernel_stats is None else bool(kernel_stats)
        )
        self._partition_kernel = None  # lazily imported when kernel_stats is on
        if queue_impl not in QUEUE_IMPLS:
            raise ValueError(f"unknown queue_impl {queue_impl!r}")
        self.queue_impl = queue_impl
        queue_cls = QUEUE_IMPLS[queue_impl]
        self._queues = [queue_cls() for _ in range(num_nodes)]
        # Outputs accumulated during the current tick's drain, flushed as one
        # routed batch per downstream operator: op -> [(batch, src_kg, src_node)].
        self._out_pending: dict[int, list[tuple[Batch, int, int]]] = {}
        self._kg_op = topology.kg_operator()
        if reserve:
            # Free replica slots carry operator 0 (zero load, zero pair
            # rates — inert to the allocators) until a split assigns them.
            self._kg_op = np.concatenate(
                [self._kg_op, np.zeros(reserve, dtype=np.int64)]
            )
        self._cost_per_tuple = [o.cost_per_tuple for o in topology.operators]
        self._op_fn = [o.fn for o in topology.operators]
        # use_fn_seg=False strips the segment protocol: every run takes the
        # per-run fn, giving the oracle data path on the SoA queue (the
        # conformance harness and benchmark baselines rely on this switch).
        self.use_fn_seg = use_fn_seg
        self._op_fn_seg = [o.fn_seg if use_fn_seg else None for o in topology.operators]
        # use_schema=False strips declared schemas: every edge carries the
        # object-array representation, giving the untyped oracle data path
        # the conformance harness pins the columnar path against.
        self.use_schema = use_schema
        self._op_schema: list[Optional[Schema]] = [
            o.schema if use_schema else None for o in topology.operators
        ]
        # use_fn_jit=True enables the compiled tier: operators declaring
        # fn_jit execute their contiguous whole-budget segments through
        # repro.engine.jitexec (one jax.jit call per node/operator, state in
        # device columns); everything else — and every fallback path —
        # behaves exactly as without the flag.  The tier needs native column
        # payloads and the SoA drain, hence the config requirements — but a
        # topology with zero fn_jit operators skips jitexec setup entirely
        # (no config constraint, no import, no process-wide x64 flip): the
        # flag is then a no-op, not a cost.
        self.use_fn_jit = use_fn_jit
        self._op_fn_jit = [
            o.fn_jit if use_fn_jit else None for o in topology.operators
        ]
        self._jit = None  # JitRuntime, built on first fn_jit execution
        self._jit_mesh = jit_mesh
        self._jit_mesh_axis = jit_mesh_axis
        self._jit_on = any(f is not None for f in self._op_fn_jit)
        if self._jit_on and (queue_impl != "soa" or not use_schema):
            raise ValueError(
                "use_fn_jit requires queue_impl='soa' and use_schema=True "
                "(the jit tier executes native columns over SoA segments)"
            )
        if self._jit_on:
            # Importing jitexec enables jax x64 process-wide (the tier's f8
            # columns must not silently truncate).  Import it NOW, at engine
            # construction — the explicit use_fn_jit=True opt-in — so the
            # dtype-semantics flip happens at a predictable time instead of
            # whenever the first segment hits the compiled tier mid-run.
            from repro.engine import jitexec  # noqa: F401

            # With jax already up, routing sorts go through the bucketed
            # radix-sort dispatcher (Pallas kernel on TPU; the CPU reference
            # is the bit-identical stable argsort numpy would have run).
            from repro.kernels.radix_sort import bucket_argsort

            self._bucket_argsort = bucket_argsort
        else:
            self._bucket_argsort = None
        # superstep=True fuses whole ticks of an eligible linear fn_jit
        # chain into single device programs (repro.engine.superstep); the
        # runtime falls back to the classic tick whenever a tick is not
        # fusible, so the flag never changes semantics — only the number of
        # host↔device crossings (metrics.jit_host_syncs).
        if superstep and not use_fn_jit:
            raise ValueError(
                "superstep=True requires use_fn_jit=True (the fused tick "
                "compiles fn_jit bodies)"
            )
        # With zero fn_jit operators the flag degrades to a no-op — the
        # engine must not import jax (same contract as use_fn_jit itself).
        self.superstep = bool(superstep) and self._jit_on
        self._superstep = None  # SuperstepRuntime, built on first tick
        # Deferred jit segments of the current tick: the drain collects
        # (accounting immediately, placeholder cells hold output order) and
        # one batched jax.jit call per operator executes at end of tick —
        # the BSP superstep makes the deferral invisible (outputs only ever
        # route at _flush_outputs), and a per-run fallback on a jit operator
        # force-flushes first so state updates stay in drain order.
        self._jit_batch: list = []
        self._had_sink_cells = False
        self._sink_tail_base = 0
        # Queued backlog extracted at redirect time, shipped inside the
        # serialize() envelope (raw buffer slices for schema-typed batches).
        self._backlog: dict[int, list[Batch]] = {}
        self._op_nkg = [o.num_keygroups for o in topology.operators]
        self._op_base = [topology.kg_base(i) for i in range(topology.num_operators)]
        # Hot-key splitting bookkeeping: parent → replica slots, slot →
        # parent, per-parent round-robin cursors, the free reserve, and the
        # per-operator extended routing tables (rebuilt on split/unsplit;
        # empty dicts keep the unsplit hot path untouched).
        self._split_map: dict[int, list[int]] = {}
        self._split_parent: dict[int, int] = {}
        self._split_rr: dict[int, int] = {}
        self._free_slots: list[int] = list(range(g, g_eff))
        self._split_ops: dict[int, dict[int, np.ndarray]] = {}
        self._op_ext: dict[int, tuple[np.ndarray, np.ndarray, int]] = {}
        self._op_terminal = [
            o.is_sink or not topology.downstream()[i]
            for i, o in enumerate(topology.operators)
        ]
        # SPLWindow's usage arrays are zeroed in place on reset, so these rows
        # can be cached for the per-tick charges.
        self._cpu_usage = self.window.kg_usage["cpu"]
        self._arrivals = self.window.kg_arrivals
        self._downstream = topology.downstream()
        self._capacity_list = self.capacity.tolist()
        self._ticks_this_period = 0
        self.alive = np.ones(num_nodes, dtype=bool)
        # Source batches admitted so far — the checkpoint/replay cursor
        # (docs/fault_tolerance.md).  Counts _admit_source calls.
        self.ingest_cursor = 0
        # Periodic checkpoints (config.checkpoint): the checkpointing module
        # pulls in repro.checkpoint (and thereby jax), so import it only on
        # the explicit opt-in — the engine must not import jax otherwise.
        self._checkpointer = None
        if config.checkpoint is not None and config.num_workers == 1:
            from repro.engine.checkpointing import EngineCheckpointer

            self._checkpointer = EngineCheckpointer(config.checkpoint)

    # ------------------------------------------------------------------ feed
    def source_credits(self) -> int:
        worst = max(q.cost for q in self._queues) if self._queues else 0.0
        return self.backpressure.credits_from_worst(worst)

    def push_source(self, op: str | int, keys, values, ts) -> int:
        """Feed tuples into a source operator; returns tuples accepted."""
        oid = self.topology._resolve(op)
        spec = self.topology.operators[oid]
        if not spec.is_source:
            raise ValueError(f"{spec.name!r} is not a source")
        credits = self.source_credits()
        n = min(len(keys), credits)
        if n < len(keys):
            self.metrics.dropped_credits += len(keys) - n
        if n == 0:
            return 0
        self._admit_source(oid, keys, values, ts, n)
        return n

    def _admit_source(self, oid: int, keys, values, ts, n: int) -> None:
        """Convert and route ``n`` already-admitted source tuples.

        Split from :meth:`push_source` so the multi-worker runtime can admit
        coordinator-approved slices without re-running the credit gate
        (cross-worker backpressure is decided once, at the coordinator).
        """
        schema = self._op_schema[oid]
        if schema is not None:
            # Ingestion is the one edge where boxed records still exist:
            # convert once, here, and the batch stays native end to end.
            # (Copy when the conversion aliased the caller's buffer — queued
            # batches must survive the caller refilling it, like make_batch.)
            tv = schema.typed_values(values[:n] if len(values) != n else values)
            if isinstance(values, np.ndarray) and np.shares_memory(tv, values):
                tv = tv.copy()
            batch = (
                np.array(keys[:n], dtype=schema.key),
                tv,
                np.asarray(ts[:n], dtype=np.float64),
            )
        else:
            batch = make_batch(keys[:n], values[:n], ts[:n])
        self.ingest_cursor += 1
        self._route_batch(oid, batch, src_kgs=None, src_nodes=None)

    # --------------------------------------------------------------- routing
    def _partition(self, op: int, keys, values) -> tuple[
        np.ndarray,
        Optional[np.ndarray],
    ]:
        """Key-group id per tuple, plus the arrival histogram when the kernel
        path computed it for free (None → caller falls back to np.bincount)."""
        if self.kernel_stats:
            spec = self.topology.operators[op]
            if (
                spec.key_by_value is None
                and spec.key_fn is _identity_key
                and isinstance(keys, np.ndarray)
                and np.issubdtype(keys.dtype, np.integer)
            ):
                if self._partition_kernel is None:
                    from repro.kernels.keygroup_partition import keygroup_partition

                    self._partition_kernel = keygroup_partition
                return self._partition_kernel(
                    keys, spec.num_keygroups, base=self.topology.kg_base(op)
                )
        return self.topology.keygroups_of(op, keys, values), None

    def _route_batch(
        self,
        op: int,
        batch: Batch,
        *,
        src_kgs: Optional[np.ndarray],
        src_nodes: Optional[np.ndarray],
    ) -> None:
        """Partition a batch by the operator's key groups and enqueue.

        One batched hash + one stable argsort; the sorted arrays are shared by
        every destination node's segment (runs are views, nothing is copied).
        ``src_kgs``/``src_nodes`` carry per-tuple source attribution (None for
        source-feed batches) so send statistics and serialization charges are
        exact yet fully scattered.
        """
        keys, values, ts = batch
        n = len(keys)
        if n == 0:
            return
        if self._op_schema[op] is not None:
            # Schema-typed edge: callers conform batches before routing, so
            # the object-dtype fallback never allocates on this path.
            if values.dtype.kind == "O" or keys.dtype.kind == "O":
                raise AssertionError(
                    f"object-dtype batch routed to schema-typed operator "
                    f"{self.topology.operators[op].name!r}"
                )
            self.metrics.typed_batches += 1
        kgs, hist = self._partition(op, keys, values)
        split = self._split_ops.get(op) if self._split_ops else None
        window = self.window
        base = self._op_base[op]
        if split is None:
            nkg = self._op_nkg[op]
            local = kgs - base
            glob_of = None
        else:
            # Hot-key splitting: fan split parents' tuples round-robin over
            # their replica families, then run the same composite sort over
            # the operator's extended (base + replica) local id space.
            kgs = self._fan_out(kgs, split)
            hist = None
            local_of, glob_of, nkg = self._op_ext[op]
            local = local_of[kgs]
        tup_nodes = self.router.nodes_of(kgs)
        if src_kgs is not None:
            window.record_send_pairs(src_kgs, kgs)
            cross = tup_nodes != src_nodes
            cs_src = src_kgs[cross]
            n_cross = len(cs_src)
            if n_cross:
                # Cross-node: serialization at src, deserialization at dst,
                # plus network bytes on both (paper §4.3.2 rationale) — one
                # histogram per side, then vector adds on the usage rows.
                g = len(self._arrivals)
                both = np.bincount(cs_src, minlength=g)
                both += np.bincount(kgs[cross], minlength=g)
                self._cpu_usage += both * self.ser_cost
                window.kg_usage["network"] += both
            self.metrics.cross_node_tuples += n_cross
            self.metrics.intra_node_tuples += n - n_cross
        # Sort tuples by the (destination node, key group) composite so each
        # node's work is ONE contiguous slice of the sorted arrays and runs
        # are adjacent within it — segments can then be drained with whole-
        # slice operations.  The composite fits int16 at benchmark scales,
        # where numpy's stable sort is radix (~4× the int64 comparison sort).
        comp = tup_nodes * nkg + local
        chist = np.bincount(comp)
        nz = np.flatnonzero(chist)  # one entry per (node, kg) == per kg
        counts = chist[nz]
        ends = np.cumsum(counts)
        starts = ends - counts
        run_nodes = nz // nkg
        uniq = nz % nkg + base if glob_of is None else glob_of[nz % nkg]
        if hist is None:
            np.add.at(self._arrivals, uniq, counts)
        else:
            window.kg_arrivals[base : base + nkg] += hist
        if len(uniq) == 1:  # common fast case: no permutation needed
            skeys, svalues, sts = keys, values, ts
        else:
            # The composite fits int16 at benchmark scales, where the stable
            # sort is radix over 2 bytes instead of 8.  With the jit tier on,
            # the bucketed radix-sort dispatcher takes over (Pallas kernel on
            # TPU, the identical stable argsort on CPU).
            small = self.num_nodes * nkg <= 32767
            if self._bucket_argsort is not None:
                order = self._bucket_argsort(
                    comp.astype(np.int16) if small else comp,
                    self.num_nodes * nkg,
                )
            elif small:
                order = np.argsort(comp.astype(np.int16), kind="stable")
            else:
                order = np.argsort(comp, kind="stable")
            skeys, svalues, sts = keys[order], values[order], ts[order]
        costs = counts * self._cost_per_tuple[op]
        # Runs for key groups whose migration is in flight divert to the
        # router's buffer; the rest flow to their nodes.  Removal can break
        # run adjacency, so those pushes are marked non-contiguous.
        contig = True
        if self.router.has_in_flight():
            infl = self.router.in_flight_mask(uniq)
            if infl.any():
                sl, el = starts.tolist(), ends.tolist()
                for j in np.flatnonzero(infl).tolist():
                    a, z = sl[j], el[j]
                    self.router.buffer(
                        int(uniq[j]),
                        (skeys[a:z], svalues[a:z], sts[a:z]),
                    )
                keep = ~infl
                uniq, starts, ends = uniq[keep], starts[keep], ends[keep]
                counts, costs = counts[keep], costs[keep]
                run_nodes = run_nodes[keep]
                contig = False
                if len(uniq) == 0:
                    return
        queues = self._queues
        service_rate = self.service_rate
        caps = self._capacity_list
        lat_append = self.latency.samples.append
        if len(uniq) == 1:  # single-run fast path
            node = int(run_nodes[0])
            q = queues[node]
            q.push_runs(
                op,
                skeys,
                svalues,
                sts,
                uniq.tolist(),
                starts.tolist(),
                ends.tolist(),
                costs.tolist(),
                contig=True,
            )
            self._record_admission(node, int(counts[0]))
            return
        # Runs arrive sorted by node: node groups are contiguous slices of
        # the run arrays (and of the tuple arrays — that is the point).
        gstarts = np.flatnonzero(
            np.concatenate(([True], run_nodes[1:] != run_nodes[:-1]))
        )
        unodes = run_nodes[gstarts].tolist()
        gends = np.append(gstarts[1:], len(run_nodes))
        kg_l = uniq.tolist()
        st_l = starts.tolist()
        en_l = ends.tolist()
        co_l = costs.tolist()
        node_counts = np.add.reduceat(counts, gstarts).tolist()
        gsl, gel = gstarts.tolist(), gends.tolist()
        for j in range(len(unodes)):
            a, z = gsl[j], gel[j]
            node = unodes[j]
            q = queues[node]
            q.push_runs(
                op,
                skeys,
                svalues,
                sts,
                kg_l[a:z],
                st_l[a:z],
                en_l[a:z],
                co_l[a:z],
                contig=contig,
            )
            admitted = node_counts[j]
            lat_append(
                (
                    q.cost / max(service_rate * caps[node], 1e-9),
                    admitted if admitted < 16 else 16,
                )
            )

    def _superstep_rt(self):
        """Lazily build the fused-superstep runtime (imports jax paths)."""
        rt = self._superstep
        if rt is None:
            from repro.engine.superstep import SuperstepRuntime

            rt = self._superstep = SuperstepRuntime(self)
        return rt

    def run_supersteps(self, batches) -> int:
        """Run K source batches as one ``lax.scan`` over fused supersteps.

        Steady-state throughput mode (one host↔device crossing for all K
        ticks); requires ``superstep=True`` and drained queues — see
        :meth:`repro.engine.superstep.SuperstepRuntime.run_supersteps` for
        the exact contract and which statistics it records.
        """
        if not self.superstep:
            raise RuntimeError(
                "run_supersteps requires Engine(..., superstep=True)"
            )
        return self._superstep_rt().run_supersteps(batches)

    def _record_admission(self, node: int, admitted: int) -> None:
        """Queueing-latency estimate at admission: work ahead / service speed."""
        budget = self.service_rate * self._capacity_list[node]
        self.latency.record(self._queues[node].cost / max(budget, 1e-9), admitted)

    # ------------------------------------------------------------------ tick
    def tick(self) -> None:
        """One BSP superstep: drain every node's queue, then deliver outputs.

        Operator outputs accumulate in ``_out_pending`` during the drain and
        are routed once per downstream operator at the end of the tick, so
        each (op, key group) receives at most one segment push per tick.  CPU
        charges for the drained runs are scattered once, at the end.

        With ``superstep=True`` the fused runtime first attempts to run the
        whole tick as one device program; any tick it cannot express falls
        back here after materializing its device-pending columns.
        """
        if self.superstep:
            rt = self._superstep_rt()
            if rt.try_fused_tick():
                return
            rt.flush_to_host()
        self.metrics.ticks += 1
        self._ticks_this_period += 1
        drained_kgs: list[int] = []
        drained_costs: list[float] = []
        service_rate = self.service_rate
        caps = self._capacity_list
        alive = self.alive.tolist()
        jit_on = self._jit_on
        if jit_on:
            self._sink_tail_base = len(self.metrics.sink_outputs)
        for node, q in enumerate(self._queues):
            if not q or not alive[node]:
                continue
            budget = service_rate * caps[node]
            if q.__class__ is SoAWorkQueue:
                self._drain_soa(node, q, budget, drained_kgs, drained_costs)
            else:
                q.drain(budget, self._process, node, drained_kgs, drained_costs)
        if jit_on:
            if self._jit_batch:
                self._flush_jit_batch()
            if self._had_sink_cells:
                self._expand_sink_cells()
        if drained_kgs:
            np.add.at(self._cpu_usage, drained_kgs, drained_costs)
        self._flush_outputs()

    def _drain_soa(
        self, node: int, q, budget: float, out_kgs: list, out_costs: list
    ) -> None:
        """SoA drain with the per-run processing fused into the walk.

        Semantically identical to ``q.drain(budget, self._process, ...)`` —
        the fusion exists to hoist every per-run attribute lookup out of the
        loop (at ~32-tuple runs the data plane is bounded by per-run Python
        overhead, not array math).
        """
        segs = q._segs
        qcost = q.cost
        op_fn = self._op_fn
        terminal = self._op_terminal
        downstream = self._downstream
        store = self.store.raw()
        pending = self._out_pending
        collect = self.collect_sinks
        metrics = self.metrics
        sink_outputs = metrics.sink_outputs
        processed = emitted = sink_n = 0
        seg_calls = seg_tuples = 0
        kg_append, cost_append = out_kgs.append, out_costs.append
        op_fn_seg = self._op_fn_seg
        op_fn_jit = self._op_fn_jit
        while segs and budget > 0:
            seg = segs[0]
            keys, values, ts, op, kgs, starts, ends, costs, cur, contig = seg
            fn = op_fn[op]
            fjit = op_fn_jit[op]
            term = terminal[op]
            downs = downstream[op]
            nruns = len(kgs)
            rem_cost = sum(costs[cur:])
            if budget >= rem_cost:
                # Whole segment fits the budget (the common case): consume
                # its accounting in bulk, then run the per-key-group state
                # transitions without per-run budget bookkeeping.  Budget and
                # queue cost are still subtracted run by run so the float
                # trajectory is bit-identical to the per-run (deque-oracle)
                # path even for non-dyadic operator costs.
                out_kgs.extend(kgs[cur:])
                out_costs.extend(costs[cur:])
                for c in costs[cur:]:
                    budget -= c
                    qcost -= c
                fseg = op_fn_seg[op]
                if contig and (
                    fn is None or fseg is not None or fjit is not None
                ):
                    # Contiguous segment: the runs tile one slice [A:Z) of
                    # the shared arrays, so the whole segment moves with a
                    # handful of array ops — pass-through forwards the slice
                    # as-is; fn_seg ops transform it in one vectorized call;
                    # fn_jit ops defer to the compiled tier's batched
                    # end-of-tick call (placeholder cells keep output order).
                    rk, rs, re_ = kgs[cur:], starts[cur:], ends[cur:]
                    a0, zn = rs[0], re_[-1]
                    n_seg = zn - a0
                    processed += n_seg
                    if fjit is not None and fn is not None:
                        rel_s = [a - a0 for a in rs] if a0 else rs
                        rel_e = [z - a0 for z in re_] if a0 else re_
                        if term:
                            cell = None
                            if collect:
                                cell = []
                                sink_outputs.append(cell)
                                self._had_sink_cells = True
                        else:
                            cell = []
                            for dop in downs:
                                try:
                                    pending[dop].append(cell)
                                except KeyError:
                                    pending[dop] = [cell]
                        self._jit_batch.append(
                            (
                                op,
                                rk,
                                rel_s,
                                rel_e,
                                keys[a0:zn],
                                values[a0:zn],
                                ts[a0:zn],
                                cell,
                                term,
                                node,
                                downs,
                            )
                        )
                        segs.popleft()
                        if budget <= 0:
                            break
                        continue
                    if fn is None:
                        outputs = (keys[a0:zn], values[a0:zn], ts[a0:zn])
                        out_lens = None
                    else:
                        rel_s = [a - a0 for a in rs] if a0 else rs
                        rel_e = [z - a0 for z in re_] if a0 else re_
                        outputs, out_lens = fseg(
                            store, rk, rel_s, rel_e,
                            keys[a0:zn], values[a0:zn], ts[a0:zn],
                        )
                        seg_calls += 1
                        seg_tuples += n_seg
                    if outputs is not None:
                        n_out = len(outputs[0])
                        if n_out:
                            emitted += n_out
                            if term:
                                sink_n += n_out
                                if collect:
                                    sink_outputs.extend(
                                        zip(
                                            outputs[0].tolist(),
                                            outputs[1].tolist(),
                                            outputs[2].tolist(),
                                        )
                                    )
                            else:
                                if out_lens is None:
                                    lens = np.subtract(re_, rs)
                                else:
                                    lens = np.asarray(out_lens, dtype=np.int64)
                                    if len(lens) != len(rk) or lens.sum() != n_out:
                                        raise ValueError(
                                            f"fn_seg of operator {op} returned "
                                            f"out_counts {out_lens!r} inconsistent "
                                            f"with its {n_out}-tuple output over "
                                            f"{len(rk)} runs"
                                        )
                                kg_arr = np.repeat(
                                    np.asarray(rk, dtype=np.int64), lens
                                )
                                item = (outputs, kg_arr, node)
                                for dop in downs:
                                    try:
                                        pending[dop].append(item)
                                    except KeyError:
                                        pending[dop] = [item]
                    segs.popleft()
                    if budget <= 0:
                        break
                    continue
                # Single-downstream fast path: bind the output list once.
                if not term and len(downs) == 1:
                    plist = pending.get(downs[0])
                    if plist is None:
                        plist = pending[downs[0]] = []
                    emit = plist.append
                else:
                    emit = None
                for kg, a, z in zip(kgs[cur:], starts[cur:], ends[cur:]):
                    k, v, t = keys[a:z], values[a:z], ts[a:z]
                    processed += z - a
                    if fn is None:
                        out = (k, v, t)
                    else:
                        if fjit is not None:
                            # Per-run fallback on a jit-tier operator: apply
                            # deferred jit segments first (state updates stay
                            # in drain order), then pull the key group's
                            # device columns into the dict.
                            if self._jit_batch:
                                self._flush_jit_batch()
                            if self._jit is not None:
                                self._jit.ensure_dict(kg)
                        state = store[kg]
                        state, outputs = fn(state, k, v, t)
                        store[kg] = state
                        if (
                            type(outputs) is tuple
                            and len(outputs) == 3
                            and isinstance(outputs[0], np.ndarray)
                            and isinstance(outputs[1], np.ndarray)
                            and isinstance(outputs[2], np.ndarray)
                        ):
                            out = outputs  # array-native fast protocol
                        else:
                            out = _as_batch(outputs)
                            if out is None:
                                continue
                    ok = out[0]
                    n_out = len(ok)
                    if n_out:
                        emitted += n_out
                        if emit is not None:
                            emit((out, kg, node))
                        elif term:
                            sink_n += n_out
                            if collect:
                                sink_outputs.extend(
                                    zip(ok.tolist(), out[1].tolist(), out[2].tolist())
                                )
                        else:
                            item = (out, kg, node)
                            for dop in downs:
                                try:
                                    pending[dop].append(item)
                                except KeyError:
                                    pending[dop] = [item]
                segs.popleft()
                if budget <= 0:
                    break
                continue
            for kg, a, z, c in zip(kgs[cur:], starts[cur:], ends[cur:], costs[cur:]):
                cur += 1
                budget -= c
                qcost -= c
                kg_append(kg)
                cost_append(c)
                k, v, t = keys[a:z], values[a:z], ts[a:z]
                processed += z - a
                if fn is None:  # source pass-through: forward the batch as-is
                    out = (k, v, t)
                else:
                    if fjit is not None:
                        if self._jit_batch:
                            self._flush_jit_batch()
                        if self._jit is not None:
                            self._jit.ensure_dict(kg)
                    state = store[kg]
                    state, outputs = fn(state, k, v, t)
                    store[kg] = state
                    if (
                        type(outputs) is tuple
                        and len(outputs) == 3
                        and isinstance(outputs[0], np.ndarray)
                        and isinstance(outputs[1], np.ndarray)
                        and isinstance(outputs[2], np.ndarray)
                    ):
                        out = outputs  # array-native fast protocol
                    else:
                        out = _as_batch(outputs)
                        if out is None:
                            if budget <= 0:
                                break
                            continue
                ok = out[0]
                n_out = len(ok)
                if n_out:
                    emitted += n_out
                    if term:
                        sink_n += n_out
                        if collect:
                            sink_outputs.extend(
                                zip(ok.tolist(), out[1].tolist(), out[2].tolist())
                            )
                    else:
                        item = (out, kg, node)
                        for dop in downs:
                            try:
                                pending[dop].append(item)
                            except KeyError:
                                pending[dop] = [item]
                if budget <= 0:
                    break
            if cur < nruns:
                seg[_S_CUR] = cur
                break
            segs.popleft()
        q.cost = qcost
        metrics.processed_tuples += processed
        metrics.emitted_tuples += emitted
        metrics.sink_tuples += sink_n
        metrics.seg_calls += seg_calls
        metrics.seg_tuples += seg_tuples

    def _flush_jit_batch(self) -> None:
        """Execute the tick's deferred jit segments, one call per operator.

        Segments collected across nodes concatenate into a single padded
        program execution per operator (runs stay in drain order; key groups
        are node-disjoint, so state updates commute across the concat), and
        the results are split back into the placeholder cells the drain left
        in ``_out_pending`` / ``sink_outputs`` — output order is therefore
        exactly what per-segment inline execution would have produced.
        """
        batch, self._jit_batch = self._jit_batch, []
        by_op: dict[int, list] = {}
        for entry in batch:
            try:
                by_op[entry[0]].append(entry)
            except KeyError:
                by_op[entry[0]] = [entry]
        metrics = self.metrics
        for op, entries in by_op.items():
            if len(entries) == 1:
                (_, rk, rs, re_, keys, values, ts, _, _, _, _) = entries[0]
                outputs, out_lens = self._jit_exec(
                    op, rk, rs, re_, keys, values, ts
                )
                parts = [(entries[0], outputs, out_lens)]
            else:
                cat_k = np.concatenate([e[4] for e in entries])
                cat_v = np.concatenate([e[5] for e in entries])
                cat_t = np.concatenate([e[6] for e in entries])
                rk, rs, re_ = [], [], []
                off = 0
                bounds = []
                for e in entries:
                    rk.extend(e[1])
                    rs.extend(a + off for a in e[2])
                    re_.extend(z + off for z in e[3])
                    bounds.append((len(e[1]), len(e[4])))
                    off += len(e[4])
                outputs, out_lens = self._jit_exec(
                    op, rk, rs, re_, cat_k, cat_v, cat_t
                )
                # Split the concatenated output back per source segment.
                parts = []
                run0 = 0
                pos = 0
                for e, (nrun, n_in) in zip(entries, bounds):
                    if outputs is None:
                        parts.append((e, None, None))
                    elif out_lens is None:
                        parts.append(
                            (
                                e,
                                tuple(o[pos : pos + n_in] for o in outputs),
                                None,
                            )
                        )
                        pos += n_in
                    else:
                        lens_e = out_lens[run0 : run0 + nrun]
                        n_out = int(sum(lens_e))
                        parts.append(
                            (
                                e,
                                tuple(o[pos : pos + n_out] for o in outputs),
                                lens_e,
                            )
                        )
                        pos += n_out
                    run0 += nrun
            for e, outputs, out_lens in parts:
                (_, rk, rs, re_, _, _, _, cell, term, node, downs) = e
                if outputs is None:
                    continue
                n_out = len(outputs[0])
                if n_out == 0:
                    continue
                metrics.emitted_tuples += n_out
                if term:
                    metrics.sink_tuples += n_out
                    if cell is not None:
                        cell.extend(
                            zip(
                                outputs[0].tolist(),
                                outputs[1].tolist(),
                                outputs[2].tolist(),
                            )
                        )
                else:
                    if out_lens is None:
                        lens = np.subtract(re_, rs)
                    else:
                        lens = np.asarray(out_lens, dtype=np.int64)
                    kg_arr = np.repeat(np.asarray(rk, dtype=np.int64), lens)
                    cell.append((outputs, kg_arr, node))

    def _expand_sink_cells(self) -> None:
        """Flatten this tick's sink placeholder cells in place (cells were
        appended in drain order; only the tick's tail is rebuilt)."""
        self._had_sink_cells = False
        outs = self.metrics.sink_outputs
        base = self._sink_tail_base
        tail = outs[base:]
        del outs[base:]
        for item in tail:
            if type(item) is list:
                outs.extend(item)
            else:
                outs.append(item)

    def _jit_exec(self, op, kgs, starts, ends, keys, values, ts):
        """Hand one contiguous segment to the compiled tier (lazy runtime).

        The JitRuntime (and jax itself) is only imported/constructed when an
        fn_jit operator actually executes, so engines that never take the
        jit path pay nothing for it.
        """
        jrt = self._jit
        if jrt is None:
            from repro.engine.jitexec import JitRuntime

            jrt = self._jit = JitRuntime(
                self.topology,
                self.store,
                self.metrics,
                self._kg_op,
                mesh=self._jit_mesh,
                mesh_axis=self._jit_mesh_axis,
            )
        return jrt.execute(op, kgs, starts, ends, keys, values, ts)

    def _process(self, node: int, op: int, kg: int, keys, values, ts) -> None:
        metrics = self.metrics
        metrics.processed_tuples += len(keys)
        fn = self._op_fn[op]
        if fn is None:  # source pass-through: forward the batch as-is
            out_batch: Optional[Batch] = (keys, values, ts)
        else:
            state = self.store.get(kg)
            state, outputs = fn(state, keys, values, ts)
            self.store.put(kg, state)
            out_batch = _as_batch(outputs)
        if out_batch is None:
            return
        ok = out_batch[0]
        n_out = len(ok)
        if n_out == 0:
            return
        metrics.emitted_tuples += n_out
        if self._op_terminal[op]:
            metrics.sink_tuples += n_out
            if self.collect_sinks:
                metrics.sink_outputs.extend(
                    zip(ok.tolist(), out_batch[1].tolist(), out_batch[2].tolist())
                )
            return
        item = (out_batch, kg, node)
        pending = self._out_pending
        for dop in self._downstream[op]:
            try:
                pending[dop].append(item)
            except KeyError:
                pending[dop] = [item]

    def _conform_batch(self, batch: Batch, schema: Optional[Schema]) -> Batch:
        """Fit a batch to the destination operator's declared edge layout.

        Typed target: object batches (fn-oracle outputs, gradual-typing
        boundaries) are promoted into the structured layout in one C-level
        conversion; native batches pass through untouched.  Untyped target:
        structured batches decay to the object representation — the tuples an
        undeclared operator's ``fn`` iterates are then identical whether the
        producer ran columnar or boxed.
        """
        keys, values, ts = batch
        if schema is None:
            if isinstance(values, np.ndarray) and values.dtype.names is not None:
                obj = np.empty(len(values), dtype=object)
                obj[:] = values.tolist()
                return keys, obj, ts
            return batch
        if keys.dtype != schema.key:
            keys = np.asarray(keys, dtype=schema.key)
        if not (isinstance(values, np.ndarray) and values.dtype == schema.value):
            values = schema.typed_values(values)
        return keys, values, ts

    def _flush_outputs(self) -> None:
        """Route this tick's accumulated outputs, one batch per operator.

        An item's source-kg attribution is a scalar (one run) or an array
        (a contiguous segment spanning several key groups).  Each item is
        conformed to the destination's declared schema (or decayed to the
        object path) before batches are concatenated.

        Destinations flush in operator-id order, NOT dict-insertion order:
        the drain paths create ``_out_pending`` keys at different moments
        (the per-run fast path pre-binds its downstream list before any
        emission; the segment path only on first emission), and insertion-
        order flushing would let the same tick push identical segments to a
        node's queue in different FIFO order across execution paths —
        divergent drain trajectories under a binding budget.
        """
        if not self._out_pending:
            return
        pending, self._out_pending = self._out_pending, {}
        op_schema = self._op_schema
        jit_on = self._jit_on
        for dop in sorted(pending):
            items = pending[dop]
            if jit_on:
                # Expand jit placeholder cells (a cell is a list holding the
                # segment's delivered item, empty when it emitted nothing).
                items = [
                    x
                    for it in items
                    for x in (it if type(it) is list else (it,))
                ]
            if not items:  # list pre-bound by the drain fast path, unused
                continue
            schema = op_schema[dop]
            if len(items) == 1:
                batch, src_kg, src_node = items[0]
                batch = self._conform_batch(batch, schema)
                n = len(batch[0])
                if type(src_kg) is np.ndarray:
                    src_kgs = src_kg
                else:
                    src_kgs = np.full(n, src_kg, dtype=np.int64)
                src_nodes = np.full(n, src_node, dtype=np.int64)
            else:
                batches, kg_t, nd_t = zip(*items)
                batch = concat_batches(
                    [self._conform_batch(b, schema) for b in batches]
                )
                m = len(items)
                lens = np.fromiter((len(b[0]) for b in batches), np.int64, count=m)
                if any(type(k) is np.ndarray for k in kg_t):
                    src_kgs = np.concatenate(
                        [
                            k
                            if type(k) is np.ndarray
                            else np.full(int(ln), k, dtype=np.int64)
                            for k, ln in zip(kg_t, lens)
                        ]
                    )
                else:
                    src_kgs = np.repeat(np.fromiter(kg_t, np.int64, count=m), lens)
                src_nodes = np.repeat(np.fromiter(nd_t, np.int64, count=m), lens)
            self._dispatch_batch(dop, batch, src_kgs, src_nodes)

    def _dispatch_batch(self, dop, batch, src_kgs, src_nodes) -> None:
        """Deliver one gathered per-operator batch (the flush → route seam).

        The multi-worker shard engine overrides this to split the batch by
        owning worker and exchange the remote slices before routing — the
        single-process path routes directly.
        """
        self._route_batch(dop, batch, src_kgs=src_kgs, src_nodes=src_nodes)

    # ------------------------------------------------------- SPL statistics
    def end_period(self) -> ClusterState:
        """Fold the SPL window into a ClusterState snapshot and reset it."""
        if self._jit is not None:
            # Statistics (and any external reader of the store) see dicts:
            # refresh every column-authoritative key group before |σ_k| is
            # re-measured below.
            self._jit.sync_store()
        ticks = max(self._ticks_this_period, 1)
        scale = 100.0 / (ticks * self.service_rate)  # → % of a reference node
        kg_load, out_pairs, _resource = self.window.fold(scale_to_percent=scale)
        state = ClusterState.create(
            self.num_nodes,
            self._kg_op,
            kg_load,
            self.router.table.copy(),
            kg_state_bytes=self.store.state_bytes(refresh=True),
            out_rates=out_pairs,
            downstream=self._downstream,
            capacity=self.capacity.copy(),
            kg_tuple_rate=self.window.kg_arrivals / ticks,
        )
        state.alive = self.alive.copy()
        self.metrics.hot_keygroups, self.metrics.max_kg_share = hot_key_summary(
            self.window.kg_arrivals
        )
        self.window.reset()
        self._ticks_this_period = 0
        if self._checkpointer is not None:
            # Cadence hook: every policy.every-th period commits a snapshot
            # (post-fold — the checkpointed window is the new, empty one).
            self._checkpointer.note_period(self)
        return state

    # ------------------------------------------------- direct state migration
    # StateMover protocol (repro.core.migration).
    def redirect(self, keygroup: int, dst: int) -> None:
        """Flip routing for the key group and pull its queued work along.

        The key group's pending runs are masked out of its current node's
        queue into the migration backlog; ``serialize`` ships that backlog
        inside the σ_k envelope (raw buffer slices on schema-typed edges —
        see :mod:`repro.engine.serde`) and ``install`` replays it ahead of
        anything the router buffered during the migration, so the key
        group's outstanding tuples resume at the destination in FIFO order.
        """
        if self._superstep is not None:
            # Shadow segments hold no arrays to extract: materialize the
            # fused runtime's device pendings before touching the queues.
            self._superstep.flush_to_host()
        src = self.router.node_of(keygroup)
        self.router.redirect(keygroup, dst)
        batches, _removed = self._queues[src].extract_keygroup(keygroup)
        if batches:
            self._backlog.setdefault(keygroup, []).extend(batches)

    def serialize(self, keygroup: int) -> bytes:
        if self._superstep is not None:
            # The key group's backlog may reference device-pending columns;
            # flushing first keeps the envelope byte-identical to the
            # interpreted oracle's at any superstep boundary.
            self._superstep.flush_to_host()
        if self._jit is not None:
            # σ_k may live in jit-tier device columns: materialize the dict
            # (insertion order included) so the blob is the oracle's pickle.
            self._jit.ensure_dict(keygroup)
        backlog = self._backlog.pop(keygroup, [])
        return serde.encode_migration(self.store.serialize(keygroup), backlog)

    def install(self, keygroup: int, dst: int, blob: bytes) -> None:
        state_blob, backlog = serde.decode_migration(blob)
        self.store.deserialize(keygroup, state_blob)
        if self._jit is not None:
            # The installed dict is now authoritative; stale device columns
            # will be re-pushed on the key group's next jit execution.
            self._jit.invalidate(keygroup)
        op = int(self._kg_op[keygroup])
        # Any backlog still parked engine-side replays too: a blob that did
        # not come from serialize() (bare checkpoint pickles in failure
        # recovery) must not strand the tuples redirect extracted.  The two
        # backlog sources are mutually exclusive — serialize() pops the
        # engine-side list into the blob — so nothing replays twice.
        replay = backlog + self._backlog.pop(keygroup, []) + self.router.complete(
            keygroup
        )
        if replay:
            # Replay the shipped backlog plus everything buffered during the
            # migration as one batch, in FIFO order.
            batch = concat_batches(replay)
            cost = self._cost_per_tuple[op] * len(batch[0])
            self._queues[dst].push_batch(op, keygroup, batch, cost)
            self._record_admission(dst, len(batch[0]))

    def export_keygroup(self, keygroup: int) -> serde.Envelope:
        """The documented migration export: σ_k + parked backlog as a
        versioned :class:`~repro.engine.serde.Envelope`.

        For a live migration call this after :meth:`redirect` (the redirect
        parks the key group's queued runs into the backlog the envelope
        carries); called standalone it snapshots state plus whatever backlog
        is parked, leaving still-queued runs in place (the checkpoint
        shape).  Worker-to-worker transfer in :mod:`repro.engine.cluster`
        ships exactly these envelopes.
        """
        return serde.Envelope(keygroup, self.serialize(keygroup))

    def import_keygroup(
        self, envelope: serde.Envelope, dst: Optional[int] = None
    ) -> None:
        """Install an exported envelope; ``dst`` defaults to the key group's
        current routed node (i.e. the post-``redirect`` destination)."""
        if dst is None:
            dst = self.router.node_of(envelope.keygroup)
        self.install(envelope.keygroup, dst, envelope.blob)

    # ----------------------------------------------------- hot-key splitting
    def _fan_out(
        self, kgs: np.ndarray, split: dict[int, np.ndarray]
    ) -> np.ndarray:
        """Remap split parents' tuples round-robin over their families.

        Round-robin with a cursor persisted across batches — not a key
        sub-hash — because the point of partial-key-grouping is that even a
        *single* hot key spreads across the replicas; per-key affinity would
        pin it to one.  The operator's ``merge_state`` contract (commutative
        monoid state, delta emission) is exactly the license for the
        reordering this introduces.
        """
        if not kgs.flags.writeable:
            kgs = kgs.copy()
        for parent, family in split.items():
            idx = np.flatnonzero(kgs == parent)
            hits = len(idx)
            if not hits:
                continue
            cur = self._split_rr[parent]
            d = len(family)
            kgs[idx] = family[(cur + np.arange(hits)) % d]
            self._split_rr[parent] = (cur + hits) % d
        return kgs

    def split_keygroup(
        self,
        keygroup: int,
        degree: Optional[int] = None,
        nodes: Optional[list[int]] = None,
    ) -> list[int]:
        """Split a hot key group across replicas (partial key grouping).

        Assigns ``degree - 1`` reserved replica key groups to the parent's
        operator and fans the parent's future tuples round-robin across the
        family.  Each replica is an ordinary key group downstream of
        routing — its own partial σ, node placement, queue runs, SPL
        statistics rows (``kg_tuple_rate`` included) — so the allocators
        and the migration machinery balance replicas individually without
        knowing about splitting.  ``nodes`` places the replicas explicitly
        (default: round-robin over the nodes after the parent's).  Returns
        the assigned replica slot ids.

        Requires ``ExecutionConfig(split_degree=...)`` and an operator that
        declares :attr:`~repro.engine.topology.OperatorSpec.merge_state`;
        splitting a non-mergeable operator would silently change its
        semantics, so it is an error instead.
        """
        if not self.config.split_degree:
            raise ValueError(
                "hot-key splitting is disabled: construct the engine with "
                "ExecutionConfig(split_degree=...) — e.g. "
                "ExecutionConfig.split(2)"
            )
        kg = int(keygroup)
        if kg in self._split_parent:
            raise ValueError(
                f"key group {kg} is a replica slot; split its parent "
                f"{self._split_parent[kg]} instead"
            )
        if not 0 <= kg < self._g_base:
            raise ValueError(f"key group {kg} out of range [0, {self._g_base})")
        if kg in self._split_map:
            raise ValueError(f"key group {kg} is already split")
        if self.router.is_in_flight(kg):
            raise ValueError(
                f"key group {kg} has a migration in flight; split it after "
                "the period's migration plan completes"
            )
        op = int(self._kg_op[kg])
        spec = self.topology.operators[op]
        if spec.fn is None:
            raise ValueError(f"cannot split source operator {spec.name!r}")
        if spec.merge_state is None:
            raise ValueError(
                f"operator {spec.name!r} is not split-mergeable: splitting "
                "fans one key group's tuples across replicas with "
                "independent partial states, which is only sound for "
                "commutative/associative delta-emitting operators — declare "
                "OperatorSpec.merge_state to opt in (see docs/workloads.md)"
            )
        d = int(degree) if degree is not None else self.config.split_degree
        if d < 2:
            raise ValueError("split degree must be >= 2")
        if len(self._free_slots) < d - 1:
            raise ValueError(
                f"split reserve exhausted: need {d - 1} replica slots, "
                f"{len(self._free_slots)} free — raise "
                "ExecutionConfig.split_reserve or unsplit a family"
            )
        slots = [self._free_slots.pop(0) for _ in range(d - 1)]
        home = self.router.node_of(kg)
        if nodes is None:
            nodes = [(home + 1 + j) % self.num_nodes for j in range(d - 1)]
        self._kg_op[slots] = op
        # Direct table writes, not Router.redirect: the slots carried no
        # traffic yet, so there is nothing in flight to buffer.
        for slot, node in zip(slots, nodes):
            self.router.table[slot] = int(node)
        self.router.version += 1
        self._split_map[kg] = slots
        for slot in slots:
            self._split_parent[slot] = kg
        self._split_rr[kg] = 0
        self._rebuild_split_tables()
        return slots

    def unsplit_keygroup(self, keygroup: int) -> None:
        """Fold a split family back into its parent.

        Replica partial states merge into the parent's σ through the
        operator's ``merge_state``; queued replica runs re-enqueue under the
        parent at its node; the slots return to the free reserve (operator
        0, node 0 — the inert parked configuration).
        """
        kg = int(keygroup)
        slots = self._split_map.get(kg)
        if slots is None:
            raise ValueError(f"key group {kg} is not split")
        if self.router.is_in_flight(kg) or any(
            self.router.is_in_flight(s) for s in slots
        ):
            raise ValueError(
                f"key group {kg}'s family has a migration in flight; "
                "unsplit after it completes"
            )
        del self._split_map[kg]
        op = int(self._kg_op[kg])
        merge = self.topology.operators[op].merge_state
        home = self.router.node_of(kg)
        cost_per_tuple = self._cost_per_tuple[op]
        for slot in slots:
            node = self.router.node_of(slot)
            batches, _removed = self._queues[node].extract_keygroup(slot)
            backlog = self._backlog.pop(slot, [])
            if backlog or batches:
                batch = concat_batches(backlog + batches)
                self._queues[home].push_batch(
                    op, kg, batch, cost_per_tuple * len(batch[0])
                )
            self.store.put(kg, merge(self.store.get(kg), self.store.get(slot)))
            self.store.put(slot, {})
            self._kg_op[slot] = 0
            self.router.table[slot] = 0
            del self._split_parent[slot]
        self.router.version += 1
        del self._split_rr[kg]
        self._free_slots.extend(slots)
        self._free_slots.sort()
        self._rebuild_split_tables()

    def split_families(self) -> dict[int, list[int]]:
        """Active splits: parent key group → replica slot ids (copies)."""
        return {k: list(v) for k, v in self._split_map.items()}

    @property
    def split_slots_free(self) -> int:
        """Unassigned replica slots remaining in the reserve."""
        return len(self._free_slots)

    def split_eligible(self) -> np.ndarray:
        """Boolean mask over key groups whose operator can split (declares
        ``merge_state`` and is not a source) — the splitter policy's input,
        so it never proposes a split the engine would reject.  Free replica
        slots are ineligible (they park on operator 0, a source)."""
        op_ok = np.array(
            [
                o.merge_state is not None and o.fn is not None
                for o in self.topology.operators
            ],
            dtype=bool,
        )
        mask = op_ok[self._kg_op]
        if self._split_parent:
            mask[sorted(self._split_parent)] = False  # replicas split via parent
        return mask

    def _rebuild_split_tables(self) -> None:
        """Recompute the per-operator fan-out dicts and extended routing
        tables (global id ↔ extended local index) after a split/unsplit."""
        by_op: dict[int, dict[int, np.ndarray]] = {}
        slots_of_op: dict[int, list[int]] = {}
        for parent in sorted(self._split_map):
            op = int(self._kg_op[parent])
            family = [parent] + self._split_map[parent]
            by_op.setdefault(op, {})[parent] = np.asarray(family, dtype=np.int64)
            slots_of_op.setdefault(op, []).extend(self._split_map[parent])
        op_ext: dict[int, tuple[np.ndarray, np.ndarray, int]] = {}
        g_eff = len(self._kg_op)
        for op, slots in slots_of_op.items():
            base, nkg = self._op_base[op], self._op_nkg[op]
            glob_of = np.concatenate(
                [
                    np.arange(base, base + nkg, dtype=np.int64),
                    np.asarray(sorted(slots), dtype=np.int64),
                ]
            )
            local_of = np.full(g_eff, -1, dtype=np.int64)
            local_of[glob_of] = np.arange(len(glob_of))
            op_ext[op] = (local_of, glob_of, len(glob_of))
        self._op_ext = op_ext
        self._split_ops = by_op

    # --------------------------------------------------------------- elastic
    def add_nodes(self, count: int, capacity: float = 1.0) -> None:
        self.num_nodes += count
        self.capacity = np.concatenate([self.capacity, np.full(count, capacity)])
        self.alive = np.concatenate([self.alive, np.ones(count, dtype=bool)])
        queue_cls = QUEUE_IMPLS[self.queue_impl]
        self._queues.extend(queue_cls() for _ in range(count))
        self._capacity_list = self.capacity.tolist()
        self.backpressure.num_nodes = self.num_nodes

    def fail_node(self, node: int) -> np.ndarray:
        """Simulate a node crash: queue lost, key groups orphaned.

        Returns the orphaned key groups; the controller reallocates them (their
        state is recovered from the last checkpoint — see repro.checkpoint).
        """
        if self._superstep is not None:
            # clear() below must see real segments, and surviving nodes'
            # shadow segments must not dangle on dropped device pendings.
            self._superstep.flush_to_host()
        self.alive[node] = False
        self._queues[node].clear()
        return self.router.keygroups_on(node)

    # ------------------------------------------------------------- inspection
    def queue_costs(self) -> list[float]:
        """Per-node queued work in cost-units (index = node id)."""
        return [q.cost for q in self._queues]

    def finalize(self) -> None:
        """Release execution resources; results stay readable.

        A no-op for the single-process engine — the multi-worker runtime
        overrides it to gather worker-side results and shut the pool down —
        so drivers (the conformance harness, benchmarks) can call it
        unconditionally.
        """

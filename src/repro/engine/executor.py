"""The engine: executes a topology over logical nodes, measuring everything
the controller needs (paper §3 "Statistics", §5 metrics).

The data plane is array-native end to end.  Tuples move through the system as
:class:`~repro.engine.topology.Batch` triples (key/value/ts parallel arrays),
never as per-tuple Python objects:

* routing hashes whole key arrays at once (`Topology.keygroups_of`, the same
  32-bit mix the Pallas ``keygroup_partition`` kernel runs on TPU) and splits
  a batch into per-key-group slices with one stable argsort — O(B log B)
  instead of the per-unique-group mask scan's O(groups × B);
* operator outputs stay arrays: ``fn`` may return a Batch directly (the fast
  protocol) or a list of (key, value, ts) tuples (converted once, not per
  downstream edge);
* a tick is a BSP superstep: outputs produced while draining are accumulated
  per downstream operator and routed once, at the end of the tick, as one
  coalesced batch carrying per-tuple source attribution — so each (operator,
  key group) gets at most one enqueue per tick and the next tick drains few,
  fat batches instead of thousands of fragments;
* SPL statistics (``out(g_i, g_j)``, serialization CPU, network bytes) are
  recorded with ``np.add.at`` scatters over those per-tuple source/destination
  arrays instead of per-tuple Python calls — same numbers, no loop.

Execution is tick-based.  Per tick every node drains up to
``service_rate × capacity`` cost-units from its FIFO work queue; operator
outputs are routed by key to downstream key groups; cross-node sends charge
serialization cost to the sender and deserialization cost to the receiver
(the CPU overhead ALBIC's collocation removes) plus network bytes.  Queue
depth beyond the service budget becomes queueing latency and, via
credit-based backpressure, throttles the sources — reproducing the dynamics
that make long-term balance matter.

On TPU deployments the logical nodes map 1:1 onto mesh devices and operator
``fn``s are jitted shard_map shards; on CPU (tests, paper benchmarks) the
nodes timeshare the host.  The engine semantics are identical — that is the
point of keeping reconfiguration decisions as *data* (routing table) rather
than recompiles.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.core.stats import ClusterState, SPLWindow
from repro.engine.backpressure import CreditController, LatencyTracker
from repro.engine.router import Router, concat_batches
from repro.engine.state import KeyedStore
from repro.engine.topology import Batch, Topology, make_batch


@dataclasses.dataclass
class EngineMetrics:
    ticks: int = 0
    processed_tuples: int = 0
    emitted_tuples: int = 0
    cross_node_tuples: int = 0
    intra_node_tuples: int = 0
    dropped_credits: int = 0
    sink_outputs: list = dataclasses.field(default_factory=list)

    def throughput(self) -> float:
        return self.processed_tuples / max(self.ticks, 1)


def _as_batch(outputs) -> Optional[Batch]:
    """Normalize operator output to a Batch.

    A 3-tuple whose first element is an ndarray is the array-native protocol
    (keys array, values/ts arrays or sequences) — the ndarray requirement
    keeps a classic-protocol output that happens to hold exactly three
    (k, v, t) triples unambiguous.  Anything else iterable is the classic
    per-tuple protocol, transposed once.
    """
    if outputs is None:
        return None
    if (
        isinstance(outputs, tuple)
        and len(outputs) == 3
        and isinstance(outputs[0], np.ndarray)
    ):
        keys, values, ts = outputs
        if isinstance(values, np.ndarray) and isinstance(ts, np.ndarray):
            return outputs
        return make_batch(keys, values, ts)
    if not outputs:
        return None
    keys, values, ts = zip(*outputs)
    return make_batch(keys, values, ts)


# Coalescible node-queue entry: [op, kg, list[Batch], enqueue_tick, cost].
_QE_OP, _QE_KG, _QE_BATCHES, _QE_TICK, _QE_COST = range(5)


class Engine:
    """Single-process execution of a Topology over ``num_nodes`` logical nodes."""

    def __init__(
        self,
        topology: Topology,
        num_nodes: int,
        *,
        initial_alloc: Optional[np.ndarray] = None,
        capacity: Optional[np.ndarray] = None,
        service_rate: float = 1_000.0,  # cost-units a reference node serves per tick
        ser_cost: float = 0.25,  # cost-units per cross-node tuple (each side)
        seed: int = 0,
    ) -> None:
        topology.validate()
        self.topology = topology
        self.num_nodes = num_nodes
        self.capacity = np.ones(num_nodes) if capacity is None else np.asarray(capacity)
        self.service_rate = service_rate
        self.ser_cost = ser_cost
        g = topology.num_keygroups
        rng = np.random.default_rng(seed)
        if initial_alloc is None:
            initial_alloc = rng.integers(0, num_nodes, size=g)
        self.store = KeyedStore(g)
        self.router = Router(g, initial_alloc)
        self.window = SPLWindow(g)
        self.metrics = EngineMetrics()
        self.latency = LatencyTracker()
        self.backpressure = CreditController(num_nodes, high_wm=50 * service_rate)
        # Per-node FIFO of coalescible entries, plus an index of the queued
        # (op, kg) entries so same-destination enqueues merge; queue cost
        # tracked per node.
        self._queues: list[deque] = [deque() for _ in range(num_nodes)]
        self._pending: list[dict[tuple[int, int], list]] = [
            {} for _ in range(num_nodes)
        ]
        # Outputs accumulated during the current tick's drain, flushed as one
        # routed batch per downstream operator: op -> [(batch, src_kg, src_node)].
        self._out_pending: dict[int, list[tuple[Batch, int, int]]] = {}
        self._queue_cost = np.zeros(num_nodes)
        self._kg_op = topology.kg_operator()
        self._cost_per_tuple = [o.cost_per_tuple for o in topology.operators]
        # SPLWindow's usage arrays are zeroed in place on reset, so the cpu
        # row can be cached for the per-batch charge in _process.
        self._cpu_usage = self.window.kg_usage["cpu"]
        self._downstream = topology.downstream()
        self._ticks_this_period = 0
        self.alive = np.ones(num_nodes, dtype=bool)

    # ------------------------------------------------------------------ feed
    def source_credits(self) -> int:
        return self.backpressure.credits(self._queue_cost)

    def push_source(self, op: str | int, keys, values, ts) -> int:
        """Feed tuples into a source operator; returns tuples accepted."""
        oid = self.topology._resolve(op)
        spec = self.topology.operators[oid]
        if not spec.is_source:
            raise ValueError(f"{spec.name!r} is not a source")
        credits = self.source_credits()
        n = min(len(keys), credits)
        if n < len(keys):
            self.metrics.dropped_credits += len(keys) - n
        if n == 0:
            return 0
        batch = make_batch(keys[:n], values[:n], ts[:n])
        self._route_batch(oid, batch, src_kgs=None, src_nodes=None)
        return n

    def _route_batch(
        self,
        op: int,
        batch: Batch,
        *,
        src_kgs: Optional[np.ndarray],
        src_nodes: Optional[np.ndarray],
    ) -> None:
        """Partition a batch by the operator's key groups and enqueue.

        One batched hash + one stable argsort; per-group work is a slice of
        the permuted arrays.  ``src_kgs``/``src_nodes`` carry per-tuple source
        attribution (None for source-feed batches) so send statistics and
        serialization charges are exact yet fully scattered.
        """
        keys, values, ts = batch
        n = len(keys)
        if n == 0:
            return
        kgs = self.topology.keygroups_of(op, keys, values)
        if src_kgs is not None:
            self.window.record_send_pairs(src_kgs, kgs)
            dst_nodes = self.router.nodes_of(kgs)
            cross = dst_nodes != src_nodes
            n_cross = int(cross.sum())
            if n_cross:
                # Cross-node: serialization at src, deserialization at dst,
                # plus network bytes on both (paper §4.3.2 rationale).
                cs_src, cs_dst = src_kgs[cross], kgs[cross]
                self.window.record_processing_many("cpu", cs_src, self.ser_cost)
                self.window.record_processing_many("cpu", cs_dst, self.ser_cost)
                self.window.record_processing_many("network", cs_src, 1.0)
                self.window.record_processing_many("network", cs_dst, 1.0)
            self.metrics.cross_node_tuples += n_cross
            self.metrics.intra_node_tuples += n - n_cross
        order = np.argsort(kgs, kind="stable")
        sorted_kgs = kgs[order]
        starts = np.flatnonzero(
            np.concatenate(([True], sorted_kgs[1:] != sorted_kgs[:-1]))
        )
        uniq = sorted_kgs[starts]
        if len(uniq) == 1:  # common fast case: no permutation needed
            skeys, svalues, sts = keys, values, ts
        else:
            skeys, svalues, sts = keys[order], values[order], ts[order]
        ends = np.append(starts[1:], n)
        nodes = self.router.nodes_of(uniq)
        # Enqueue loop over unique groups: plain-int lists (one bulk tolist
        # instead of per-element numpy scalar unboxing), hoisted lookups.
        ul, nl = uniq.tolist(), nodes.tolist()
        sl, el = starts.tolist(), ends.tolist()
        cpt = self._cost_per_tuple[op]
        queues, pending, qcost = self._queues, self._pending, self._queue_cost
        check_inflight = self.router.has_in_flight()
        tick_now = self.metrics.ticks
        touched: dict[int, int] = {}  # node -> tuples admitted this call
        for j in range(len(ul)):
            kg, a, z = ul[j], sl[j], el[j]
            sub = (skeys[a:z], svalues[a:z], sts[a:z])
            if check_inflight and self.router.is_in_flight(kg):
                self.router.buffer(kg, sub)
                continue
            node = nl[j]
            cost = cpt * (z - a)
            entry = pending[node].get((op, kg))
            if entry is not None and entry[_QE_TICK] == tick_now:
                # Coalesce only within the current tick: merging into an entry
                # that survived a drain would let one pop blow through the
                # service budget with a multi-tick backlog.
                entry[_QE_BATCHES].append(sub)
                entry[_QE_COST] += cost
            else:
                entry = [op, kg, [sub], tick_now, cost]
                queues[node].append(entry)
                pending[node][(op, kg)] = entry
            qcost[node] += cost
            touched[node] = touched.get(node, 0) + (z - a)
        # Queueing-latency estimate at admission: work ahead / service speed,
        # one sample per touched node.
        for node, admitted in touched.items():
            budget = self.service_rate * self.capacity[node]
            self.latency.record(qcost[node] / max(budget, 1e-9), admitted)

    def _enqueue(self, node: int, op: int, kg: int, batch: Batch) -> None:
        cost = self._cost_per_tuple[op] * len(batch[0])
        entry = self._pending[node].get((op, kg))
        if entry is not None and entry[_QE_TICK] == self.metrics.ticks:
            # Same-tick coalesce only (see _route_batch).
            entry[_QE_BATCHES].append(batch)
            entry[_QE_COST] += cost
        else:
            entry = [op, kg, [batch], self.metrics.ticks, cost]
            self._queues[node].append(entry)
            self._pending[node][(op, kg)] = entry
        self._queue_cost[node] += cost
        # Queueing-latency estimate at admission: work ahead / service speed.
        budget = self.service_rate * self.capacity[node]
        self.latency.record(self._queue_cost[node] / max(budget, 1e-9), len(batch[0]))

    # ------------------------------------------------------------------ tick
    def tick(self) -> None:
        """One BSP superstep: drain every node's queue, then deliver outputs.

        Operator outputs accumulate in ``_out_pending`` during the drain and
        are routed once per downstream operator at the end of the tick, so
        each (op, key group) receives at most one coalesced enqueue per tick.
        """
        self.metrics.ticks += 1
        self._ticks_this_period += 1
        for node in range(self.num_nodes):
            if not self.alive[node]:
                continue
            budget = self.service_rate * self.capacity[node]
            q = self._queues[node]
            pending = self._pending[node]
            while q and budget > 0:
                entry = q.popleft()
                op, kg, batches, _tick_in, cost = entry
                # A newer same-(op, kg) entry may own the pending slot when
                # this one survived an earlier drain — only clear our own.
                if pending.get((op, kg)) is entry:
                    del pending[(op, kg)]
                self._queue_cost[node] -= cost
                budget -= cost
                batch = batches[0] if len(batches) == 1 else concat_batches(batches)
                self._process(node, op, kg, batch)
        self._flush_outputs()

    def _process(self, node: int, op: int, kg: int, batch: Batch) -> None:
        spec = self.topology.operators[op]
        keys, values, ts = batch
        n = len(keys)
        self.metrics.processed_tuples += n
        self._cpu_usage[kg] += spec.cost_per_tuple * n
        if spec.fn is None:  # source pass-through: forward the batch as-is
            out_batch: Optional[Batch] = batch
        else:
            state = self.store.get(kg)
            state, outputs = spec.fn(state, keys, values, ts)
            self.store.put(kg, state)
            out_batch = _as_batch(outputs)
        if out_batch is None or len(out_batch[0]) == 0:
            return
        self.metrics.emitted_tuples += len(out_batch[0])
        if spec.is_sink or not self._downstream[op]:
            ok, ov, ot = out_batch
            self.metrics.sink_outputs.extend(zip(ok.tolist(), ov.tolist(), ot.tolist()))
            return
        for dop in self._downstream[op]:
            self._out_pending.setdefault(dop, []).append((out_batch, kg, node))

    def _flush_outputs(self) -> None:
        """Route this tick's accumulated outputs, one batch per operator."""
        if not self._out_pending:
            return
        pending, self._out_pending = self._out_pending, {}
        for dop, items in pending.items():
            if len(items) == 1:
                batch, src_kg, src_node = items[0]
                n = len(batch[0])
                src_kgs = np.full(n, src_kg, dtype=np.int64)
                src_nodes = np.full(n, src_node, dtype=np.int64)
            else:
                batch = concat_batches([b for b, _, _ in items])
                m = len(items)
                lens = np.fromiter((len(b[0]) for b, _, _ in items), np.int64, count=m)
                src_kgs = np.repeat(
                    np.fromiter((kg for _, kg, _ in items), np.int64, count=m), lens
                )
                src_nodes = np.repeat(
                    np.fromiter((nd for _, _, nd in items), np.int64, count=m), lens
                )
            self._route_batch(dop, batch, src_kgs=src_kgs, src_nodes=src_nodes)

    # ------------------------------------------------------- SPL statistics
    def end_period(self) -> ClusterState:
        """Fold the SPL window into a ClusterState snapshot and reset it."""
        ticks = max(self._ticks_this_period, 1)
        scale = 100.0 / (ticks * self.service_rate)  # → % of a reference node
        kg_load, out_rates, _resource = self.window.fold(scale_to_percent=scale)
        state = ClusterState.create(
            self.num_nodes,
            self._kg_op,
            kg_load,
            self.router.table.copy(),
            kg_state_bytes=self.store.state_bytes(refresh=True),
            out_rates=out_rates,
            downstream=self._downstream,
            capacity=self.capacity.copy(),
        )
        state.alive = self.alive.copy()
        self.window.reset()
        self._ticks_this_period = 0
        return state

    # ------------------------------------------------- direct state migration
    # StateMover protocol (repro.core.migration).
    def redirect(self, keygroup: int, dst: int) -> None:
        self.router.redirect(keygroup, dst)

    def serialize(self, keygroup: int) -> bytes:
        return self.store.serialize(keygroup)

    def install(self, keygroup: int, dst: int, blob: bytes) -> None:
        self.store.deserialize(keygroup, blob)
        op = int(self._kg_op[keygroup])
        buffered = self.router.complete(keygroup)
        if buffered:
            # Replay everything buffered during the migration as one batch.
            self._enqueue(dst, op, keygroup, concat_batches(buffered))

    # --------------------------------------------------------------- elastic
    def add_nodes(self, count: int, capacity: float = 1.0) -> None:
        self.num_nodes += count
        self.capacity = np.concatenate([self.capacity, np.full(count, capacity)])
        self.alive = np.concatenate([self.alive, np.ones(count, dtype=bool)])
        self._queues.extend(deque() for _ in range(count))
        self._pending.extend({} for _ in range(count))
        self._queue_cost = np.concatenate([self._queue_cost, np.zeros(count)])
        self.backpressure.num_nodes = self.num_nodes

    def fail_node(self, node: int) -> np.ndarray:
        """Simulate a node crash: queue lost, key groups orphaned.

        Returns the orphaned key groups; the controller reallocates them (their
        state is recovered from the last checkpoint — see repro.checkpoint).
        """
        self.alive[node] = False
        self._queues[node].clear()
        self._pending[node].clear()
        self._queue_cost[node] = 0.0
        return self.router.keygroups_on(node)

"""Deterministic fault injection for the multi-worker runtime.

A :class:`FaultPlan` is a seeded, fully materialized schedule of fault
events — SIGKILL a worker, wedge it in a busy-hang, or delay its command
loop — keyed on coordinator-observed progress (tick number or SPL period
boundary).  Because the coordinator applies events at deterministic points
of its own control flow, a plan plus an engine seed reproduces the same
failure interleaving run after run: the 25-run fault soak becomes a chaos
*suite*, not a dice roll.

Injection points (see :class:`repro.engine.cluster.ClusterEngine`):

* ``at_tick=t``   — applied immediately before tick ``t`` is commanded.
* ``at_period=p`` — applied at the end of the ``p``-th ``end_period()``
  call (1-indexed), *after* the window fold and any checkpoint, so a kill
  lands between periods the way a real mid-stream crash does.

Kills are raw ``SIGKILL`` from the coordinator (no cooperation from the
victim); hangs and delays ship to the worker as a ``("fault", ...)``
command it executes in-line, which is exactly what a wedged or slow
command loop looks like from the outside.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

#: Supported fault kinds.
KINDS = ("kill", "hang", "delay")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault against one worker.

    Exactly one of ``at_tick`` / ``at_period`` must be set.  ``seconds``
    sizes hangs and delays (ignored for kills); ``ignore_term`` makes a
    hang also ignore SIGTERM — the shutdown-escalation worst case.
    """

    kind: str
    worker: int
    at_tick: Optional[int] = None
    at_period: Optional[int] = None
    seconds: float = 60.0
    ignore_term: bool = False

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if (self.at_tick is None) == (self.at_period is None):
            raise ValueError("exactly one of at_tick/at_period must be set")
        if self.worker < 0:
            raise ValueError("worker must be >= 0")
        if self.seconds < 0:
            raise ValueError("seconds must be >= 0")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of :class:`FaultEvent`\\ s."""

    events: tuple[FaultEvent, ...] = ()

    @classmethod
    def of(cls, events: Iterable[FaultEvent]) -> "FaultPlan":
        return cls(events=tuple(events))

    @classmethod
    def kill_at_period(cls, worker: int, period: int) -> "FaultPlan":
        """The canonical scenario: SIGKILL one worker at a period boundary."""
        return cls(events=(FaultEvent("kill", worker, at_period=period),))

    @classmethod
    def random(
        cls,
        seed: int,
        *,
        num_workers: int,
        periods: int,
        events: int = 3,
        kinds: tuple[str, ...] = ("kill", "hang", "delay"),
        hang_seconds: float = 0.5,
    ) -> "FaultPlan":
        """Draw a reproducible plan: ``events`` faults over ``periods``.

        Workers are drawn uniformly; worker 0 is a valid victim like any
        other.  Events are sorted by period so application order matches
        schedule order.  ``hang_seconds`` bounds hang/delay durations so a
        seeded chaos run stays bounded even when escalation is disabled.
        """
        if num_workers < 2:
            raise ValueError("fault plans target the multi-worker runtime")
        rng = np.random.default_rng(
            [np.uint32(seed), np.uint32(0xFA17)]  # domain-separated stream
        )
        drawn = []
        for _ in range(events):
            kind = kinds[int(rng.integers(0, len(kinds)))]
            drawn.append(
                FaultEvent(
                    kind=kind,
                    worker=int(rng.integers(0, num_workers)),
                    at_period=int(rng.integers(1, periods + 1)),
                    seconds=float(rng.uniform(0.05, hang_seconds)),
                )
            )
        drawn.sort(key=lambda e: (e.at_period, e.worker, e.kind))
        return cls(events=tuple(drawn))

    def at_tick(self, tick: int) -> list[FaultEvent]:
        return [e for e in self.events if e.at_tick == tick]

    def at_period(self, period: int) -> list[FaultEvent]:
        return [e for e in self.events if e.at_period == period]

"""Multi-worker host runtime: real OS processes behind the Engine surface.

The single-process :class:`~repro.engine.executor.Engine` timeshares N
logical nodes inside one Python loop, so aggregate throughput, migration
cost and backpressure are single-core fictions.  This module runs the same
topology over a :class:`WorkerPool` of real ``multiprocessing`` worker
processes — each worker owns a **contiguous block of nodes** and hosts a
full engine shard (:class:`_ShardEngine`) — coordinated by a
:class:`ClusterEngine` that keeps the Engine API (``push_source`` /
``tick`` / ``redirect`` / ``serialize`` / ``install`` / ``end_period``) so
the controller, the adaptation framework and the conformance harness drive
it unchanged.

Execution stays a BSP superstep per tick, now distributed:

1. **Ingestion** — the coordinator admits source batches against
   credit-based backpressure computed from the *global* worst queue depth
   (each tick report carries the worker's deepest local queue; lockstep
   drivers refresh synchronously, the pipelined driver uses the latest
   report — credits replace any in-loop budget coupling between workers),
   converts them to the declared schema, partitions by key group and ships
   each worker exactly the slice destined to its nodes.
2. **Drain** — every worker drains its own nodes concurrently (real
   parallelism; the numpy operator tiers run outside any shared lock).
3. **Exchange** — instead of routing its tick outputs directly, a shard
   splits each downstream operator's gathered batch by owning worker
   (:meth:`_ShardEngine._dispatch_batch`) and sends the remote slices to
   its peers: raw ``serde``-layout columns spliced into the per-lane
   shared-memory ring (:mod:`repro.engine.shmx` — zero pickling, one
   memcpy each side), falling back to the pickled-queue lane for
   ring-full overflow and object-dtype batches, at whole-message
   granularity so a (tick, lane) contribution travels on exactly one
   transport.  Each worker then concatenates the per-operator
   contributions *in ascending worker id order* (its own slice in its own
   slot) and routes the merged batch once.

Because node blocks are contiguous and ascending in worker id, that merge
order equals the single-process engine's node-ascending flush order — so
per-node queues, per-key-group state trajectories, SPL statistics, sink
tuples *and their order*, and migration envelopes are **bit-identical** to
the single-process run (pinned by the ``soa+seg+schema+workers``
conformance configuration).  The contract and its limits (what degrades
after worker failure) are documented in ``docs/execution_tiers.md``.

In-flight migration between live workers follows the paper's direct state
migration across real processes: ``redirect`` flips every replica routing
table (the redirect-time owner parks the key group's queued runs),
``serialize`` exports the versioned :class:`~repro.engine.serde.Envelope`
on worker A, ``install`` ships it to worker B which replays backlog then
buffered arrivals in FIFO order.  The coordinator folds per-worker SPL
windows (key-group loads, arrival rates, sparse pair rates, state bytes)
into one :class:`~repro.core.stats.ClusterState` each period, so
ALBIC/MILP plan against exactly the signals the single-process engine
reports.

The runtime requires the ``fork`` start method (operator closures are
inherited, never pickled) and therefore POSIX.  Transport is strictly
single-writer — per-worker command and report queues, per-``(sender →
receiver)`` exchange lanes (one shm ring plus one fallback queue each,
both single-producer/single-consumer), coordinator-owned death Events
(see :class:`WorkerPool`) — so a SIGKILLed worker cannot orphan a lock
any survivor needs, and every blocking wait is deadline-guarded so a
wedged pool fails the run fast instead of deadlocking it.  The shm
segments are coordinator-allocated before the fork and coordinator-owned
thereafter: only the coordinator ever ``unlink``\\ s them — on shutdown
and on worker death — so a killed worker cannot leak a segment.
"""

from __future__ import annotations

import multiprocessing
import os
import pickle
import queue as _queue_mod
import uuid
from multiprocessing import connection as mp_connection
import time
import traceback
from typing import Optional

import numpy as np

from repro.core.stats import ClusterState, PairRates
from repro.engine import serde, shmx
from repro.engine.backpressure import CreditController
from repro.engine.config import ExecutionConfig
from repro.engine.executor import Engine, EngineMetrics, hot_key_summary
from repro.engine.router import Router, concat_batches
from repro.engine.state import KeyedStore
from repro.engine.topology import Topology, make_batch

#: Seconds a coordinator/worker blocking wait may stall before the run is
#: declared wedged (overridable via the REPRO_CLUSTER_TIMEOUT env var).
DEFAULT_TIMEOUT = float(os.environ.get("REPRO_CLUSTER_TIMEOUT", "120"))

_METRIC_SUM_FIELDS = (
    "processed_tuples",
    "emitted_tuples",
    "cross_node_tuples",
    "intra_node_tuples",
    "sink_tuples",
    "seg_calls",
    "seg_tuples",
    "typed_batches",
)

#: Per-worker exchange counters, summed into ``ClusterEngine.exchange_stats``
#: at finalize (the benchmark's encode+decode and bytes-copied columns).
_EXCHANGE_STAT_FIELDS = (
    "enc_s",
    "dec_s",
    "shm_msgs",
    "queue_msgs",
    "shm_bytes_out",
    "shm_bytes_in",
)


def contiguous_node_worker(num_nodes: int, num_workers: int) -> np.ndarray:
    """Node → worker map as contiguous ascending blocks.

    Contiguity in ascending worker order is what makes the exchange's
    worker-major merge equal the single-process node-major flush order —
    the determinism contract depends on this map staying monotone.
    """
    return (np.arange(num_nodes) * num_workers) // max(num_nodes, 1)


def worker_rng(seed: int, wid: int) -> np.random.Generator:
    """Per-worker RNG derived from the engine's single seed."""
    return np.random.default_rng([np.uint32(seed), np.uint32(wid)])


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------


class _ShardEngine(Engine):
    """One worker's engine shard: full topology, full routing table, but it
    drains only its own nodes and exchanges remote-destined outputs instead
    of enqueuing them."""

    def __init__(self, *args, wid: int, node_worker: np.ndarray, **kw):
        super().__init__(*args, **kw)
        self._wid = wid
        self._node_worker = node_worker
        # Per-tick exchange state: dop → [(batch, src_kgs, src_nodes)] for
        # my own nodes, and per-peer outboxes for everyone else's.
        self._xchg_local: dict[int, list] = {}
        self._xchg_out: dict[int, dict[int, list]] = {}
        self.rng = worker_rng(self.seed, wid)

    def _dispatch_batch(self, dop, batch, src_kgs, src_nodes) -> None:
        keys, values, ts = batch
        kgs, _ = self._partition(dop, keys, values)
        owners = self._node_worker[self.router.table[kgs]]
        for w in np.unique(owners):
            mask = owners == w
            if mask.all():
                sub, sk, sn = batch, src_kgs, src_nodes
            else:
                sub = (keys[mask], values[mask], ts[mask])
                sk = src_kgs[mask] if src_kgs is not None else None
                sn = src_nodes[mask] if src_nodes is not None else None
            w = int(w)
            if w == self._wid:
                self._xchg_local.setdefault(dop, []).append((sub, sk, sn))
            else:
                self._xchg_out.setdefault(w, {}).setdefault(dop, []).append(
                    (sub, sk, sn)
                )

    def take_exchange(self):
        local, self._xchg_local = self._xchg_local, {}
        out, self._xchg_out = self._xchg_out, {}
        return local, out

    def route_merged(self, per_dop: dict[int, list]) -> None:
        """Route each operator's worker-order-merged contribution once —
        the distributed half of ``_flush_outputs`` (same sorted-operator
        order, same single concatenated batch per operator)."""
        for dop in sorted(per_dop):
            items = per_dop[dop]
            if len(items) == 1:
                batch, sk, sn = items[0]
            else:
                batch = concat_batches([it[0] for it in items])
                sk = np.concatenate([it[1] for it in items])
                sn = np.concatenate([it[2] for it in items])
            Engine._route_batch(self, dop, batch, src_kgs=sk, src_nodes=sn)

    def worst_cost(self) -> float:
        my = self._node_worker == self._wid
        costs = [q.cost for n, q in enumerate(self._queues) if my[n]]
        return max(costs, default=0.0)

    def owned_keygroups(self) -> np.ndarray:
        return np.flatnonzero(self._node_worker[self.router.table] == self._wid)


def _encode_items(items):
    return [
        (dop, serde.encode_batch(batch), sk, sn)
        for dop, batch, sk, sn in items
    ]


def _worker_main(wid, spec):
    """Worker process body (fork-inherited arguments, nothing pickled)."""
    eng = _ShardEngine(
        spec["topology"],
        spec["num_nodes"],
        config=spec["config"],
        initial_alloc=spec["initial_alloc"],
        capacity=spec["capacity"],
        service_rate=spec["service_rate"],
        ser_cost=spec["ser_cost"],
        seed=spec["seed"],
        collect_sinks=spec["collect_sinks"],
        wid=wid,
        node_worker=spec["node_worker"].copy(),
    )
    cmd_q = spec["cmd_queues"][wid]
    rep_q = spec["report_queues"][wid]
    inboxes = spec["inboxes"]  # inboxes[receiver][sender]
    rings = spec["rings"]  # rings[receiver][sender] (ShmRing or None)
    dead_events = spec["dead_events"]
    num_workers = spec["num_workers"]
    timeout = spec["timeout"]
    dead: set[int] = set()
    # Lane codecs over the fork-inherited rings: senders[peer] writes my
    # (wid → peer) ring, receivers[peer] reads the (peer → wid) ring.
    senders = [
        shmx.LaneSender(rings[w][wid]) if rings[w][wid] is not None else None
        for w in range(num_workers)
    ]
    receivers = [
        shmx.LaneReceiver(rings[wid][w]) if rings[wid][w] is not None else None
        for w in range(num_workers)
    ]
    xchg = dict.fromkeys(_EXCHANGE_STAT_FIELDS, 0)
    # stash[sender][tick] → ("s", decoded items) | ("q", encoded items)
    # (per-sender lanes deliver in tick order, but a fast peer can run
    # ahead in pipelined mode, and one sender's ticks may alternate between
    # the shm ring and the queue fallback).
    stash: dict[int, dict[int, tuple]] = {}
    sink_cursor = 0

    def drain_lanes(sender):
        """Move every delivered (sender → me) message into the stash."""
        per = stash.setdefault(sender, {})
        rx = receivers[sender]
        if rx is not None:
            while True:
                t0 = time.perf_counter()
                got = rx.poll()
                if got is None:
                    break
                xchg["dec_s"] += time.perf_counter() - t0
                per[got[0]] = ("s", got[1])
        lane = inboxes[wid][sender]
        while True:
            # Timed around the successful get too: the queue path pays a
            # pipe read plus wrapper unpickle per message — real decode
            # cost of that transport, attributed where it is paid.
            t0 = time.perf_counter()
            try:
                blob = lane.get_nowait()
            except _queue_mod.Empty:
                break
            mt, enc = pickle.loads(blob)
            xchg["dec_s"] += time.perf_counter() - t0
            per[mt] = ("q", enc)

    def recv_exchange(t, sender):
        per = stash.setdefault(sender, {})
        deadline = time.monotonic() + timeout
        while t not in per:
            drain_lanes(sender)
            if t in per:
                break
            if dead_events[sender].is_set():
                # Final sweep: a contribution published between our poll
                # and the peer's death still counts (the ring mapping
                # outlives the coordinator's unlink).
                drain_lanes(sender)
                if t in per:
                    break
                # Peer died before contributing this tick: its tuples
                # are lost (fail_node semantics) — drain with nothing.
                dead.add(sender)
                return None
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"worker {wid}: exchange wait for peer {sender} "
                    f"tick {t} timed out"
                )
            time.sleep(0.0005)
        kind, payload = per.pop(t)
        if kind == "s":
            return payload
        t0 = time.perf_counter()
        items = [
            (dop, serde.decode_batch(enc), sk, sn)
            for dop, enc, sk, sn in payload
        ]
        xchg["dec_s"] += time.perf_counter() - t0
        return items

    def send_exchange(t, w, items):
        """Ship one tick's contribution to peer ``w``: shm ring when it
        fits and every batch is native, else the pickled queue lane.

        The fallback pickles to bytes *inline* (not via the queue's feeder
        thread) so the exchange counters attribute the serialization cost
        where it is actually paid.
        """
        tx = senders[w]
        if tx is not None:
            t0 = time.perf_counter()
            sent = tx.try_send(t, items)
            xchg["enc_s"] += time.perf_counter() - t0
            if sent:
                xchg["shm_msgs"] += 1
                return
        t0 = time.perf_counter()
        blob = pickle.dumps(
            (t, _encode_items(items)), protocol=pickle.HIGHEST_PROTOCOL
        )
        xchg["enc_s"] += time.perf_counter() - t0
        inboxes[w][wid].put(blob)
        xchg["queue_msgs"] += 1

    def do_tick(t):
        nonlocal sink_cursor
        eng.tick()  # drain + flush → exchange stashes
        local, out = eng.take_exchange()
        peers = [w for w in range(num_workers) if w != wid and w not in dead]
        for w in peers:
            send_exchange(t, w, [
                (dop, batch, sk, sn)
                for dop, items in sorted(out.get(w, {}).items())
                for batch, sk, sn in items
            ])
        contribs: dict[int, list] = {wid: [
            (dop, batch, sk, sn)
            for dop, items in sorted(local.items())
            for batch, sk, sn in items
        ]}
        for w in peers:
            contribs[w] = recv_exchange(t, w) or []
        per_dop: dict[int, list] = {}
        for w in sorted(contribs):
            for dop, batch, sk, sn in contribs[w]:
                per_dop.setdefault(dop, []).append((batch, sk, sn))
        eng.route_merged(per_dop)
        sinks = None
        if eng.collect_sinks:
            outs = eng.metrics.sink_outputs
            sinks = outs[sink_cursor:]
            sink_cursor = len(outs)
        rep_q.put(("tick", t, wid, eng.worst_cost(), sinks))

    try:
        while True:
            cmd = cmd_q.get()
            op = cmd[0]
            if op == "push":
                _, oid, keys, values, ts = cmd
                eng._route_batch(oid, (keys, values, ts), src_kgs=None,
                                 src_nodes=None)
            elif op == "tick":
                do_tick(cmd[1])
            elif op == "costs":
                rep_q.put(("ack", wid, "costs", eng.worst_cost()))
            elif op == "redirect":
                _, kg, dst = cmd
                eng.redirect(kg, dst)
                rep_q.put(("ack", wid, "redirect", None))
            elif op == "serialize":
                env = eng.export_keygroup(cmd[1])
                rep_q.put(("ack", wid, "serialize", env.blob))
            elif op == "install":
                _, kg, dst, blob = cmd
                eng.import_keygroup(serde.Envelope(kg, blob), dst)
                rep_q.put(("ack", wid, "install", None))
            elif op == "complete":
                eng.router.complete(cmd[1])  # never buffered here: discard
                rep_q.put(("ack", wid, "complete", None))
            elif op == "set_alloc":
                _, kgs, dst = cmd
                eng.router.table[np.asarray(kgs, dtype=np.int64)] = dst
                eng.router.version += 1
                rep_q.put(("ack", wid, "set_alloc", None))
            elif op == "export":
                rep_q.put(("ack", wid, "export", eng.export_keygroup(cmd[1]).blob))
            elif op == "node_down":
                for node in cmd[1]:
                    if eng.alive[node]:
                        eng.fail_node(node)
                rep_q.put(("ack", wid, "node_down", None))
            elif op == "peer_dead":
                dead.add(cmd[1])
            elif op == "add_nodes":
                _, count, capacity, owner = cmd
                eng.add_nodes(count, capacity)
                eng._node_worker = np.concatenate(
                    [eng._node_worker, np.full(count, owner, dtype=np.int64)]
                )
                rep_q.put(("ack", wid, "add_nodes", None))
            elif op == "end_period":
                win = eng.window
                pairs = win.pair_counts()
                payload = {
                    "usage": {r: u.copy() for r, u in win.kg_usage.items()},
                    "arrivals": win.kg_arrivals.copy(),
                    "pairs": (pairs.src, pairs.dst, pairs.rate),
                    "state_bytes": eng.store.state_bytes(refresh=True),
                    "ticks": eng._ticks_this_period,
                }
                win.reset()
                eng._ticks_this_period = 0
                rep_q.put(("ack", wid, "end_period", payload))
            elif op == "gather":
                owned_kgs = eng.owned_keygroups()
                my_nodes = np.flatnonzero(eng._node_worker == wid)
                xchg["shm_bytes_out"] = sum(
                    s.bytes_copied for s in senders if s is not None
                )
                xchg["shm_bytes_in"] = sum(
                    r.bytes_copied for r in receivers if r is not None
                )
                payload = {
                    "metrics": {
                        f: getattr(eng.metrics, f) for f in _METRIC_SUM_FIELDS
                    },
                    "states": {
                        int(kg): eng.store.get(int(kg)) for kg in owned_kgs
                    },
                    "queue_costs": {
                        int(n): eng._queues[n].cost for n in my_nodes
                    },
                    "exchange": dict(xchg),
                }
                rep_q.put(("ack", wid, "gather", payload))
            elif op == "stop":
                rep_q.put(("ack", wid, "stop", None))
                break
            else:  # pragma: no cover - protocol error
                raise RuntimeError(f"worker {wid}: unknown command {op!r}")
    except BaseException:  # pragma: no cover - surfaced coordinator-side
        rep_q.put(("error", wid, traceback.format_exc()))
        raise


# ---------------------------------------------------------------------------
# Coordinator side
# ---------------------------------------------------------------------------


class WorkerPool:
    """Owns the worker processes and their channels (fork context).

    Every channel has exactly ONE writer — per-worker command queues
    (written by the coordinator), per-worker report queues (written by that
    worker), and per-``(sender → receiver)`` exchange lanes: one shm ring
    (:class:`repro.engine.shmx.ShmRing`, single-producer/single-consumer
    by construction) plus one fallback queue each.  The
    discipline is what makes ``kill()`` safe: a SIGKILLed process can die
    holding only locks no survivor ever takes (an ``mp.Queue`` shared by
    two writers serializes them on one pipe lock, and a process killed
    between its pipe write and the lock release — a wide window on a
    loaded single-CPU host — wedges every other writer forever).  Worker
    death is signalled to peers through per-worker Events (set by the
    coordinator only), never by injecting messages into another writer's
    channel.
    """

    def __init__(
        self,
        num_workers: int,
        spec: dict,
        timeout: float,
        *,
        shm_lane_bytes: int = 0,
    ):
        ctx = multiprocessing.get_context("fork")
        self.num_workers = num_workers
        self.timeout = timeout
        self.cmd_queues = [ctx.Queue() for _ in range(num_workers)]
        self.report_queues = [ctx.Queue() for _ in range(num_workers)]
        # inboxes[receiver][sender]: the (sender → receiver) exchange lane's
        # fallback queue (ring-full overflow, object-dtype batches).
        self.inboxes = [
            [ctx.Queue() if s != r else None for s in range(num_workers)]
            for r in range(num_workers)
        ]
        # rings[receiver][sender]: the lane's shm ring — allocated here,
        # BEFORE the fork, so workers inherit the mappings; unlinked only
        # by the coordinator (shutdown / worker death).
        self.rings: list[list] = [
            [None] * num_workers for _ in range(num_workers)
        ]
        if shm_lane_bytes:
            uid = uuid.uuid4().hex[:8]
            try:
                for r in range(num_workers):
                    for s in range(num_workers):
                        if s != r:
                            self.rings[r][s] = shmx.ShmRing.create(
                                f"{shmx.SEGMENT_PREFIX}_{os.getpid()}"
                                f"_{uid}_{s}to{r}",
                                shm_lane_bytes,
                            )
            except OSError:
                # No usable /dev/shm on this host: run on the queue path.
                self._destroy_rings()
        self.dead_events = [ctx.Event() for _ in range(num_workers)]
        spec = dict(
            spec,
            cmd_queues=self.cmd_queues,
            report_queues=self.report_queues,
            inboxes=self.inboxes,
            rings=self.rings,
            dead_events=self.dead_events,
            num_workers=num_workers,
            timeout=timeout,
        )
        self.processes = [
            ctx.Process(target=_worker_main, args=(w, spec), daemon=True)
            for w in range(num_workers)
        ]
        for p in self.processes:
            p.start()

    def _destroy_rings(self) -> None:
        for row in self.rings:
            for s, ring in enumerate(row):
                if ring is not None:
                    ring.unlink()
                    ring.close()
                    row[s] = None

    def release_worker_lanes(self, wid: int) -> None:
        """Unlink every segment a dead worker touches (coordinator-owned
        cleanup).  Survivors' inherited mappings stay valid, so a peer can
        still drain the dead sender's ring during its final sweep — only
        the *name* goes away, which is what prevents the leak."""
        for r in range(self.num_workers):
            for s in range(self.num_workers):
                if wid in (r, s) and self.rings[r][s] is not None:
                    self.rings[r][s].unlink()

    def send(self, wid: int, msg) -> None:
        self.cmd_queues[wid].put(msg)

    def alive(self, wid: int) -> bool:
        return self.processes[wid].is_alive()

    def kill(self, wid: int) -> None:
        p = self.processes[wid]
        if p.is_alive():
            p.kill()
            p.join(timeout=5)

    def shutdown(self) -> None:
        for p in self.processes:
            if p.is_alive():
                p.kill()
        for p in self.processes:
            p.join(timeout=5)
        for q in (
            *self.cmd_queues,
            *self.report_queues,
            *(q for row in self.inboxes for q in row if q is not None),
        ):
            q.close()
            q.cancel_join_thread()
        self._destroy_rings()


class ClusterEngine:
    """Coordinator for the multi-worker runtime; Engine-compatible surface.

    Drives a :class:`WorkerPool` in lockstep (``push_source`` / ``tick`` —
    the conformance shape, bit-identical to single-process) or pipelined
    (:meth:`run_stream` — the throughput shape, no per-tick coordinator
    barrier).  Implements the ``StateMover`` protocol, so
    ``repro.core.migration.execute_plan`` migrates key groups *between live
    worker processes* exactly as it does between logical nodes.
    """

    def __init__(
        self,
        topology: Topology,
        num_nodes: int,
        *,
        config: Optional[ExecutionConfig] = None,
        initial_alloc: Optional[np.ndarray] = None,
        capacity: Optional[np.ndarray] = None,
        service_rate: float = 1_000.0,
        ser_cost: float = 0.25,
        seed: int = 0,
        collect_sinks: bool = True,
        timeout: float = DEFAULT_TIMEOUT,
    ) -> None:
        if config is None:
            config = ExecutionConfig.workers(2)
        if config.num_workers < 2:
            raise ValueError("ClusterEngine needs ExecutionConfig.workers(n >= 2)")
        if "fork" not in multiprocessing.get_all_start_methods():
            raise RuntimeError(
                "the multi-worker runtime requires the 'fork' start method "
                "(operator closures are inherited, not pickled)"
            )
        topology.validate()
        self.topology = topology
        self.num_nodes = num_nodes
        self.config = config
        self.num_workers = config.num_workers
        self.service_rate = service_rate
        self.ser_cost = ser_cost
        self.seed = seed
        self.collect_sinks = collect_sinks
        self.capacity = (
            np.ones(num_nodes) if capacity is None else np.asarray(capacity)
        )
        g = topology.num_keygroups
        rng = np.random.default_rng(seed)  # Engine's exact alloc draw
        if initial_alloc is None:
            initial_alloc = rng.integers(0, num_nodes, size=g)
        self._initial_alloc = np.asarray(initial_alloc, dtype=np.int64).copy()
        self.router = Router(g, self._initial_alloc)
        self.node_worker = contiguous_node_worker(num_nodes, self.num_workers)
        self.alive = np.ones(num_nodes, dtype=bool)
        self.metrics = EngineMetrics()
        self.store = KeyedStore(g)  # populated at finalize()
        self.backpressure = CreditController(
            num_nodes, high_wm=50 * service_rate
        )
        self.ingest_rng = np.random.default_rng(
            [np.uint32(seed), np.uint32(0xC1)]
        )
        self._kg_op = topology.kg_operator()
        self._downstream = topology.downstream()
        self._op_schema = [
            o.schema if config.use_schema else None for o in topology.operators
        ]
        self._worker_config = config.replace(num_workers=1)
        self._timeout = timeout
        worker_cfg = self._worker_config
        self.pool = WorkerPool(
            self.num_workers,
            dict(
                topology=topology,
                num_nodes=num_nodes,
                config=worker_cfg,
                initial_alloc=self._initial_alloc,
                capacity=self.capacity,
                service_rate=service_rate,
                ser_cost=ser_cost,
                seed=seed,
                collect_sinks=collect_sinks,
                node_worker=self.node_worker,
            ),
            timeout,
            shm_lane_bytes=config.shm_lane_bytes,
        )
        #: Folded per-worker exchange counters (populated at finalize):
        #: encode/decode seconds, shm vs queue message counts, bytes copied.
        self.exchange_stats: dict[str, float] = dict.fromkeys(
            _EXCHANGE_STAT_FIELDS, 0
        )
        self._dead_workers: set[int] = set()
        self._worst = np.zeros(self.num_workers)
        self._tick_no = 0
        self._ticks_this_period = 0
        self._mig_src: dict[int, int] = {}
        # Pipelined-mode report reassembly: (tick → {wid: (worst, sinks)}).
        self._tick_reports: dict[int, dict[int, tuple]] = {}
        self._merged_through = -1
        self._pending_ticks: list[int] = []
        self._stashed_acks: dict[tuple[int, str], object] = {}
        self._queue_costs: Optional[list[float]] = None
        self._closed = False
        self._finalized = False

    # ------------------------------------------------------------- plumbing
    def _alive_workers(self) -> list[int]:
        return [
            w for w in range(self.num_workers) if w not in self._dead_workers
        ]

    def worker_of_node(self, node: int) -> int:
        return int(self.node_worker[node])

    def _recv(self):
        """One report message (any worker), with death detection and deadline.

        Polls every worker's report queue — including a dead worker's, whose
        already-flushed reports are still deliverable — in worker-id order.
        """
        deadline = time.monotonic() + self._timeout
        readers = [q._reader for q in self.pool.report_queues]
        while True:
            for w in range(self.num_workers):
                try:
                    msg = self.pool.report_queues[w].get_nowait()
                except _queue_mod.Empty:
                    continue
                if msg[0] == "error":
                    raise RuntimeError(
                        f"worker {msg[1]} crashed:\n{msg[2]}"
                    )
                return msg
            for w in self._alive_workers():
                if not self.pool.alive(w):
                    self._on_worker_death(w)
                    return None
            if time.monotonic() > deadline:
                raise RuntimeError(
                    "cluster coordinator: wait on worker reports timed "
                    "out (wedged pool?)"
                )
            mp_connection.wait(readers, timeout=0.05)

    def _handle_tick_report(self, msg) -> None:
        _, t, wid, worst, sinks = msg
        self._worst[wid] = worst
        self._tick_reports.setdefault(t, {})[wid] = (worst, sinks)
        self._merge_ready_ticks()

    def _merge_ready_ticks(self) -> None:
        """Fold completed ticks' sink deltas in (tick, worker) order."""
        while self._pending_ticks:
            t = self._pending_ticks[0]
            reports = self._tick_reports.get(t, {})
            expected = [
                w for w in range(self.num_workers)
                if w not in self._dead_workers or w in reports
            ]
            if not all(w in reports for w in expected):
                return
            for w in sorted(reports):
                _, sinks = reports[w]
                if sinks:
                    self.metrics.sink_outputs.extend(sinks)
            del self._tick_reports[t]
            self._pending_ticks.pop(0)
            self._merged_through = t

    def _await_acks(self, wids: list[int], tag: str):
        """Collect one tagged ack per worker; returns {wid: payload}.

        The stash is re-checked every iteration, not just on entry: a
        worker-death detour (``_on_worker_death`` → ``node_down`` ack wait)
        nested inside this wait consumes the report stream and stashes this
        tag's acks — entry-only checking would then wait forever for a
        message already consumed.
        """
        out = {}
        while True:
            for w in wids:
                key = (w, tag)
                if w not in out and key in self._stashed_acks:
                    out[w] = self._stashed_acks.pop(key)
            if len(out) >= len(
                [w for w in wids if w not in self._dead_workers]
            ):
                return out
            msg = self._recv()
            if msg is None:  # a worker died; re-evaluate expectations
                continue
            if msg[0] == "tick":
                self._handle_tick_report(msg)
                continue
            _, wid, mtag, payload = msg
            if mtag == tag and wid in wids:
                out[wid] = payload
            else:
                self._stashed_acks[(wid, mtag)] = payload

    def _command_all(self, msg, tag: str):
        wids = self._alive_workers()
        for w in wids:
            self.pool.send(w, msg)
        return self._await_acks(wids, tag)

    def _command_one(self, wid: int, msg, tag: str):
        if wid in self._dead_workers:
            raise RuntimeError(f"worker {wid} is dead")
        self.pool.send(wid, msg)
        return self._await_acks([wid], tag)[wid]

    def _on_worker_death(self, wid: int) -> None:
        """A worker vanished: unwedge peers, mark its nodes failed.

        Survivors stuck in the current tick's exchange see the dead
        worker's Event and drain with an empty contribution; future ticks
        skip it via ``peer_dead``.  The dead worker's queued work and
        un-reported tick output are lost — exactly a node crash
        (``fail_node`` semantics); recovery reinstalls its key groups from
        checkpoint envelopes via :meth:`import_keygroup` (see
        tests/test_cluster_faults.py).
        """
        if wid in self._dead_workers:
            return
        self._dead_workers.add(wid)
        dead_nodes = np.flatnonzero(self.node_worker == wid)
        self.alive[dead_nodes] = False
        # Coordinator-owned shm cleanup: a SIGKILLed worker can't unlink
        # its own lanes, so its segments are released here (names only —
        # survivors' mappings stay valid for the final drain).
        self.pool.release_worker_lanes(wid)
        # Unblock survivors stuck on the dead worker's exchange: the Event
        # is coordinator-owned, so no channel the dead process might have
        # wedged is involved (see WorkerPool).
        self.pool.dead_events[wid].set()
        survivors = self._alive_workers()
        for w in survivors:
            self.pool.send(w, ("peer_dead", wid))
        self._command_all(("node_down", dead_nodes.tolist()), "node_down")
        self._merge_ready_ticks()

    # ------------------------------------------------------------------ feed
    def source_credits(self, *, refresh: bool = True) -> int:
        """Global credits from the worst per-worker queue depth.

        ``refresh=True`` (the lockstep default) round-trips to the workers
        for the exact instantaneous depths; ``refresh=False`` uses the
        latest tick reports (the pipelined mode's credit loop).
        """
        return self.backpressure.credits_from_worst(
            self.worst_queue_cost(refresh=refresh)
        )

    def worst_queue_cost(self, *, refresh: bool = True) -> float:
        """Deepest queue across alive workers (drives credits; drain loops
        poll it to detect quiescence without a full gather)."""
        if refresh:
            for w, worst in self._command_all(("costs",), "costs").items():
                self._worst[w] = worst
        return max(
            (float(self._worst[w]) for w in self._alive_workers()), default=0.0
        )

    def push_source(self, op, keys, values, ts, *, refresh: bool = True) -> int:
        oid = self.topology._resolve(op)
        spec = self.topology.operators[oid]
        if not spec.is_source:
            raise ValueError(f"{spec.name!r} is not a source")
        credits = self.source_credits(refresh=refresh)
        n = min(len(keys), credits)
        if n < len(keys):
            self.metrics.dropped_credits += len(keys) - n
        if n == 0:
            return 0
        self._split_and_push(oid, keys, values, ts, n)
        return n

    def _split_and_push(self, oid, keys, values, ts, n: int) -> None:
        """Schema-convert the admitted slice and ship per-worker splits."""
        schema = self._op_schema[oid]
        if schema is not None:
            tv = schema.typed_values(values[:n] if len(values) != n else values)
            if isinstance(values, np.ndarray) and np.shares_memory(tv, values):
                tv = tv.copy()
            batch = (
                np.array(keys[:n], dtype=schema.key),
                tv,
                np.asarray(ts[:n], dtype=np.float64),
            )
        else:
            batch = make_batch(keys[:n], values[:n], ts[:n])
        bk, bv, bt = batch
        kgs = self.topology.keygroups_of(oid, bk, bv)
        owners = self.node_worker[self.router.table[kgs]]
        for w in np.unique(owners):
            w = int(w)
            if w in self._dead_workers:
                continue  # tuples to dead nodes are lost, as on fail_node
            mask = owners == w
            if mask.all():
                sub = batch
            else:
                sub = (bk[mask], bv[mask], bt[mask])
            self.pool.send(w, ("push", oid, *sub))

    # ------------------------------------------------------------------ tick
    def tick(self) -> None:
        """Lockstep BSP tick: command all workers, await all reports."""
        t = self._tick_no
        self._tick_no += 1
        self._pending_ticks.append(t)
        for w in self._alive_workers():
            self.pool.send(w, ("tick", t))
        self._wait_tick(t)
        self.metrics.ticks += 1
        self._ticks_this_period += 1

    def _wait_tick(self, t: int) -> None:
        while self._merged_through < t:
            msg = self._recv()
            if msg is None:
                continue
            if msg[0] == "tick":
                self._handle_tick_report(msg)
            else:
                _, wid, mtag, payload = msg
                self._stashed_acks[(wid, mtag)] = payload

    def run_stream(self, op, batches, *, window: int = 4,
                   shuffle: bool = False) -> int:
        """Pipelined throughput mode: stream (push, tick) pairs without a
        per-tick coordinator barrier.

        ``batches`` is an iterable of ``(keys, values, ts)`` source batches,
        one tick each; at most ``window`` ticks run ahead of the last
        merged report, and credits come from the latest reports (the
        asynchronous credit loop).  ``shuffle=True`` permutes batch order
        with the seed-derived ingestion RNG (reproducible from
        ``Engine(seed=...)`` alone).  Returns tuples accepted.
        """
        oid = self.topology._resolve(op)
        batches = list(batches)
        if shuffle:
            batches = [batches[i] for i in self.ingest_rng.permutation(len(batches))]
        accepted = 0
        for keys, values, ts in batches:
            while self._tick_no - self._merged_through - 1 >= window:
                msg = self._recv()
                if msg is None:
                    continue
                if msg[0] == "tick":
                    self._handle_tick_report(msg)
            credits = self.source_credits(refresh=False)
            n = min(len(keys), credits)
            if n < len(keys):
                self.metrics.dropped_credits += len(keys) - n
            if n:
                self._split_and_push(oid, keys, values, ts, n)
                accepted += n
            t = self._tick_no
            self._tick_no += 1
            self._pending_ticks.append(t)
            for w in self._alive_workers():
                self.pool.send(w, ("tick", t))
        if self._tick_no:
            self._wait_tick(self._tick_no - 1)
        self.metrics.ticks += len(batches)
        self._ticks_this_period += len(batches)
        return accepted

    # ------------------------------------------------------- SPL statistics
    def end_period(self) -> ClusterState:
        """Fold every worker's SPL window into one ClusterState snapshot."""
        payloads = self._command_all(("end_period",), "end_period")
        g = self.topology.num_keygroups
        order = sorted(payloads)
        usage = {
            r: np.zeros(g)
            for r in (payloads[order[0]]["usage"] if order else {"cpu": None})
        }
        arrivals = np.zeros(g)
        psrc, pdst, prate = [], [], []
        state_bytes = np.full(g, 64.0)
        owner_of_kg = self.node_worker[self.router.table]
        for w in order:
            p = payloads[w]
            for r, u in p["usage"].items():
                usage[r] += u
            arrivals += p["arrivals"]
            s, d, r_ = p["pairs"]
            psrc.append(s)
            pdst.append(d)
            prate.append(r_)
            mine = owner_of_kg == w
            state_bytes[mine] = p["state_bytes"][mine]
        totals = {r: float(u.sum()) for r, u in usage.items()}
        resource = max(totals, key=totals.get)
        ticks = max(self._ticks_this_period, 1)
        scale = 100.0 / (ticks * self.service_rate)
        if psrc and sum(len(s) for s in psrc):
            src = np.concatenate(psrc)
            dst = np.concatenate(pdst)
            rate = np.concatenate(prate)
            pairs = PairRates.from_codes(src * g + dst, rate, g)
        else:
            pairs = PairRates.empty(g)
        state = ClusterState.create(
            self.num_nodes,
            self._kg_op,
            usage[resource] * scale,
            self.router.table.copy(),
            kg_state_bytes=state_bytes,
            out_rates=pairs,
            downstream=self._downstream,
            capacity=self.capacity.copy(),
            kg_tuple_rate=arrivals / ticks,
        )
        state.alive = self.alive.copy()
        # Hot-key observability over the cross-worker fold: the gauge sees
        # the same totals a single-process run of the same traffic would,
        # because `arrivals` is the sum of every worker's partial counts.
        self.metrics.hot_keygroups, self.metrics.max_kg_share = hot_key_summary(
            arrivals
        )
        self._ticks_this_period = 0
        return state

    # ------------------------------------------------- direct state migration
    # StateMover protocol — migrations now move state between live worker
    # processes, through the versioned serde envelopes.
    def redirect(self, keygroup: int, dst: int) -> None:
        src_worker = self.worker_of_node(self.router.node_of(keygroup))
        self.router.redirect(keygroup, dst)
        self._mig_src[keygroup] = src_worker
        self._command_all(("redirect", keygroup, dst), "redirect")

    def serialize(self, keygroup: int) -> bytes:
        w = self._mig_src.pop(
            keygroup, self.worker_of_node(self.router.node_of(keygroup))
        )
        return self._command_one(w, ("serialize", keygroup), "serialize")

    def install(self, keygroup: int, dst: int, blob: bytes) -> None:
        w_dst = self.worker_of_node(dst)
        if w_dst in self._dead_workers:
            raise RuntimeError(
                f"cannot install key group {keygroup}: node {dst}'s worker "
                f"{w_dst} is dead"
            )
        wids = self._alive_workers()
        for w in wids:
            if w == w_dst:
                self.pool.send(w, ("install", keygroup, dst, blob))
            else:
                self.pool.send(w, ("complete", keygroup))
        self._await_acks(
            [w for w in wids if w != w_dst], "complete"
        )
        if w_dst not in self._dead_workers:
            self._await_acks([w_dst], "install")
        self.router.complete(keygroup)

    def export_keygroup(self, keygroup: int) -> serde.Envelope:
        w = self.worker_of_node(self.router.node_of(keygroup))
        blob = self._command_one(w, ("export", keygroup), "export")
        return serde.Envelope(keygroup, blob)

    def import_keygroup(
        self, envelope: serde.Envelope, dst: Optional[int] = None
    ) -> None:
        if dst is None:
            dst = self.router.node_of(envelope.keygroup)
        if int(self.router.table[envelope.keygroup]) != dst:
            self.set_alloc([envelope.keygroup], dst)
        self.install(envelope.keygroup, dst, envelope.blob)

    def set_alloc(self, keygroups, dst: int) -> None:
        """Point key groups at ``dst`` on every replica table (no in-flight
        semantics — the recovery path's table rewrite)."""
        self.router.table[np.asarray(keygroups, dtype=np.int64)] = dst
        self.router.version += 1
        self._command_all(("set_alloc", list(keygroups), dst), "set_alloc")

    # --------------------------------------------------------------- elastic
    def add_nodes(self, count: int, capacity: float = 1.0) -> None:
        """Append nodes, owned by the last worker (keeps the node → worker
        map monotone, which the determinism contract requires)."""
        owner = max(self._alive_workers())
        self.num_nodes += count
        self.capacity = np.concatenate([self.capacity, np.full(count, capacity)])
        self.alive = np.concatenate([self.alive, np.ones(count, dtype=bool)])
        self.node_worker = np.concatenate(
            [self.node_worker, np.full(count, owner, dtype=np.int64)]
        )
        self.backpressure.num_nodes = self.num_nodes
        self._command_all(("add_nodes", count, capacity, owner), "add_nodes")

    def fail_worker(self, wid: int) -> np.ndarray:
        """Kill a worker process outright (fault injection).

        Returns the orphaned key groups; their queued work and state on the
        dead worker are gone — reinstall from checkpoints via
        :meth:`import_keygroup` (see tests/test_cluster_faults.py).
        """
        dead_nodes = np.flatnonzero(self.node_worker == wid)
        orphans = np.flatnonzero(np.isin(self.router.table, dead_nodes))
        self.pool.kill(wid)
        self._on_worker_death(wid)
        return orphans

    # ------------------------------------------------------------- inspection
    def queue_costs(self) -> list[float]:
        if self._queue_costs is not None:
            return self._queue_costs
        costs = [0.0] * self.num_nodes
        for w, payload in self._command_all(("gather",), "gather").items():
            for node, c in payload["queue_costs"].items():
                costs[node] = c
        return costs

    def finalize(self) -> None:
        """Gather worker-side results onto the coordinator and stop the pool.

        After this, ``metrics`` (counters + merged sink outputs), ``store``
        (every key group's state, taken from its owning worker) and
        ``queue_costs()`` read exactly like a single-process engine's.
        """
        if self._finalized:
            return
        payloads = self._command_all(("gather",), "gather")
        costs = [0.0] * self.num_nodes
        for w in sorted(payloads):
            p = payloads[w]
            for f in _METRIC_SUM_FIELDS:
                setattr(
                    self.metrics, f, getattr(self.metrics, f) + p["metrics"][f]
                )
            for kg, state in p["states"].items():
                if state:
                    self.store.put(kg, state)
            for node, c in p["queue_costs"].items():
                costs[node] = c
            for f in _EXCHANGE_STAT_FIELDS:
                self.exchange_stats[f] += p.get("exchange", {}).get(f, 0)
        self._queue_costs = costs
        self._finalized = True
        self.close()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            for w in self._alive_workers():
                self.pool.send(w, ("stop",))
            self._await_acks(self._alive_workers(), "stop")
        except Exception:
            pass
        self.pool.shutdown()

    def __enter__(self) -> "ClusterEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - belt and braces
        try:
            if not getattr(self, "_closed", True):
                self.pool.shutdown()
        except Exception:
            pass

"""Multi-worker host runtime: real OS processes behind the Engine surface.

The single-process :class:`~repro.engine.executor.Engine` timeshares N
logical nodes inside one Python loop, so aggregate throughput, migration
cost and backpressure are single-core fictions.  This module runs the same
topology over a :class:`WorkerPool` of real ``multiprocessing`` worker
processes — each worker owns a **contiguous block of nodes** and hosts a
full engine shard (:class:`_ShardEngine`) — coordinated by a
:class:`ClusterEngine` that keeps the Engine API (``push_source`` /
``tick`` / ``redirect`` / ``serialize`` / ``install`` / ``end_period``) so
the controller, the adaptation framework and the conformance harness drive
it unchanged.

Execution stays a BSP superstep per tick, now distributed:

1. **Ingestion** — the coordinator admits source batches against
   credit-based backpressure computed from the *global* worst queue depth
   (each tick report carries the worker's deepest local queue; lockstep
   drivers refresh synchronously, the pipelined driver uses the latest
   report — credits replace any in-loop budget coupling between workers),
   converts them to the declared schema, partitions by key group and ships
   each worker exactly the slice destined to its nodes.
2. **Drain** — every worker drains its own nodes concurrently (real
   parallelism; the numpy operator tiers run outside any shared lock).
3. **Exchange** — instead of routing its tick outputs directly, a shard
   splits each downstream operator's gathered batch by owning worker
   (:meth:`_ShardEngine._dispatch_batch`) and sends the remote slices to
   its peers: raw ``serde``-layout columns spliced into the per-lane
   shared-memory ring (:mod:`repro.engine.shmx` — zero pickling, one
   memcpy each side), falling back to the pickled-queue lane for
   ring-full overflow and object-dtype batches, at whole-message
   granularity so a (tick, lane) contribution travels on exactly one
   transport.  Each worker then concatenates the per-operator
   contributions *in ascending worker id order* (its own slice in its own
   slot) and routes the merged batch once.

Because node blocks are contiguous and ascending in worker id, that merge
order equals the single-process engine's node-ascending flush order — so
per-node queues, per-key-group state trajectories, SPL statistics, sink
tuples *and their order*, and migration envelopes are **bit-identical** to
the single-process run (pinned by the ``soa+seg+schema+workers``
conformance configuration).  The contract and its limits (what degrades
after worker failure) are documented in ``docs/execution_tiers.md``.

In-flight migration between live workers follows the paper's direct state
migration across real processes: ``redirect`` flips every replica routing
table (the redirect-time owner parks the key group's queued runs),
``serialize`` exports the versioned :class:`~repro.engine.serde.Envelope`
on worker A, ``install`` ships it to worker B which replays backlog then
buffered arrivals in FIFO order.  The coordinator folds per-worker SPL
windows (key-group loads, arrival rates, sparse pair rates, state bytes)
into one :class:`~repro.core.stats.ClusterState` each period, so
ALBIC/MILP plan against exactly the signals the single-process engine
reports.

The runtime requires the ``fork`` start method (operator closures are
inherited, never pickled) and therefore POSIX.  Transport is strictly
single-writer — per-worker command and report queues, per-``(sender →
receiver)`` exchange lanes (one shm ring plus one fallback queue each,
both single-producer/single-consumer), coordinator-owned death Events
(see :class:`WorkerPool`) — so a SIGKILLed worker cannot orphan a lock
any survivor needs, and every blocking wait is deadline-guarded so a
wedged pool fails the run fast instead of deadlocking it.  The shm
segments are coordinator-allocated before the fork and coordinator-owned
thereafter: only the coordinator ever ``unlink``\\ s them — on shutdown
and on worker death — so a killed worker cannot leak a segment.
"""

from __future__ import annotations

import multiprocessing
import os
import pickle
import queue as _queue_mod
import signal
import uuid
from multiprocessing import connection as mp_connection
import time
import traceback
from typing import Optional

import numpy as np

from repro.core.stats import ClusterState, PairRates
from repro.engine import serde, shmx
from repro.engine.backpressure import CreditController
from repro.engine.config import ExecutionConfig
from repro.engine.executor import Engine, EngineMetrics, hot_key_summary
from repro.engine.faults import FaultPlan
from repro.engine.router import Router, concat_batches
from repro.engine.state import KeyedStore
from repro.engine.topology import Topology, make_batch

#: Seconds a coordinator/worker blocking wait may stall before the run is
#: declared wedged (overridable via the REPRO_CLUSTER_TIMEOUT env var).
DEFAULT_TIMEOUT = float(os.environ.get("REPRO_CLUSTER_TIMEOUT", "120"))

_METRIC_SUM_FIELDS = (
    "processed_tuples",
    "emitted_tuples",
    "cross_node_tuples",
    "intra_node_tuples",
    "sink_tuples",
    "seg_calls",
    "seg_tuples",
    "typed_batches",
)

#: Per-worker exchange counters, summed into ``ClusterEngine.exchange_stats``
#: at finalize (the benchmark's encode+decode and bytes-copied columns).
_EXCHANGE_STAT_FIELDS = (
    "enc_s",
    "dec_s",
    "shm_msgs",
    "queue_msgs",
    "shm_bytes_out",
    "shm_bytes_in",
)

#: Minimum seconds between worker heartbeats while the command queue is
#: busy.  An idle worker (empty command queue) always heartbeats after its
#: last command, so a quiescent worker's counters are exact and liveness
#: tracking never sees a silent-but-done worker as outstanding.
_HB_MIN_INTERVAL_S = 0.02


def contiguous_node_worker(num_nodes: int, num_workers: int) -> np.ndarray:
    """Node → worker map as contiguous ascending blocks.

    Contiguity in ascending worker order is what makes the exchange's
    worker-major merge equal the single-process node-major flush order —
    the determinism contract depends on this map staying monotone.
    """
    return (np.arange(num_nodes) * num_workers) // max(num_nodes, 1)


def worker_rng(seed: int, wid: int) -> np.random.Generator:
    """Per-worker RNG derived from the engine's single seed."""
    return np.random.default_rng([np.uint32(seed), np.uint32(wid)])


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------


class _ShardEngine(Engine):
    """One worker's engine shard: full topology, full routing table, but it
    drains only its own nodes and exchanges remote-destined outputs instead
    of enqueuing them."""

    def __init__(self, *args, wid: int, node_worker: np.ndarray, **kw):
        super().__init__(*args, **kw)
        self._wid = wid
        self._node_worker = node_worker
        # Per-tick exchange state: dop → [(batch, src_kgs, src_nodes)] for
        # my own nodes, and per-peer outboxes for everyone else's.
        self._xchg_local: dict[int, list] = {}
        self._xchg_out: dict[int, dict[int, list]] = {}
        self.rng = worker_rng(self.seed, wid)

    def _dispatch_batch(self, dop, batch, src_kgs, src_nodes) -> None:
        keys, values, ts = batch
        kgs, _ = self._partition(dop, keys, values)
        owners = self._node_worker[self.router.table[kgs]]
        for w in np.unique(owners):
            mask = owners == w
            if mask.all():
                sub, sk, sn = batch, src_kgs, src_nodes
            else:
                sub = (keys[mask], values[mask], ts[mask])
                sk = src_kgs[mask] if src_kgs is not None else None
                sn = src_nodes[mask] if src_nodes is not None else None
            w = int(w)
            if w == self._wid:
                self._xchg_local.setdefault(dop, []).append((sub, sk, sn))
            else:
                self._xchg_out.setdefault(w, {}).setdefault(dop, []).append(
                    (sub, sk, sn)
                )

    def take_exchange(self):
        local, self._xchg_local = self._xchg_local, {}
        out, self._xchg_out = self._xchg_out, {}
        return local, out

    def route_merged(self, per_dop: dict[int, list]) -> None:
        """Route each operator's worker-order-merged contribution once —
        the distributed half of ``_flush_outputs`` (same sorted-operator
        order, same single concatenated batch per operator)."""
        for dop in sorted(per_dop):
            items = per_dop[dop]
            if len(items) == 1:
                batch, sk, sn = items[0]
            else:
                batch = concat_batches([it[0] for it in items])
                sk = np.concatenate([it[1] for it in items])
                sn = np.concatenate([it[2] for it in items])
            Engine._route_batch(self, dop, batch, src_kgs=sk, src_nodes=sn)

    def worst_cost(self) -> float:
        my = self._node_worker == self._wid
        costs = [q.cost for n, q in enumerate(self._queues) if my[n]]
        return max(costs, default=0.0)

    def owned_keygroups(self) -> np.ndarray:
        return np.flatnonzero(self._node_worker[self.router.table] == self._wid)


def _encode_items(items):
    return [
        (dop, serde.encode_batch(batch), sk, sn)
        for dop, batch, sk, sn in items
    ]


def _worker_main(wid, spec):
    """Worker process body (fork-inherited arguments, nothing pickled)."""
    eng = _ShardEngine(
        spec["topology"],
        spec["num_nodes"],
        config=spec["config"],
        initial_alloc=spec["initial_alloc"],
        capacity=spec["capacity"],
        service_rate=spec["service_rate"],
        ser_cost=spec["ser_cost"],
        seed=spec["seed"],
        collect_sinks=spec["collect_sinks"],
        wid=wid,
        node_worker=spec["node_worker"].copy(),
    )
    cmd_q = spec["cmd_queues"][wid]
    rep_q = spec["report_queues"][wid]
    inboxes = spec["inboxes"]  # inboxes[receiver][sender]
    rings = spec["rings"]  # rings[receiver][sender] (ShmRing or None)
    dead_events = spec["dead_events"]
    num_workers = spec["num_workers"]
    timeout = spec["timeout"]
    # A replacement worker forks into a cluster with history: peers already
    # dead, nodes already failed (the respawn path fills these in).
    dead: set[int] = set(spec.get("dead_peers", ()))
    for node in spec.get("start_dead_nodes", ()):
        eng.fail_node(int(node))
    # Lane codecs over the fork-inherited rings: senders[peer] writes my
    # (wid → peer) ring, receivers[peer] reads the (peer → wid) ring.
    senders = [
        shmx.LaneSender(rings[w][wid]) if rings[w][wid] is not None else None
        for w in range(num_workers)
    ]
    receivers = [
        shmx.LaneReceiver(rings[wid][w]) if rings[wid][w] is not None else None
        for w in range(num_workers)
    ]
    xchg = dict.fromkeys(_EXCHANGE_STAT_FIELDS, 0)
    # stash[sender][tick] → ("s", decoded items) | ("q", encoded items)
    # (per-sender lanes deliver in tick order, but a fast peer can run
    # ahead in pipelined mode, and one sender's ticks may alternate between
    # the shm ring and the queue fallback).
    stash: dict[int, dict[int, tuple]] = {}
    sink_cursor = 0
    cmds_done = 0
    last_hb = [0.0]

    def maybe_hb():
        """Heartbeat over the report queue: liveness + the worker's current
        cumulative counters (the coordinator folds a dead worker's *last*
        heartbeat exactly once, so counters survive a respawn).

        Throttled while the command queue is busy; always emitted once the
        queue drains, so an idle worker's last heartbeat is exact.
        """
        now = time.monotonic()
        try:
            busy = not cmd_q.empty()
        except (NotImplementedError, OSError):  # pragma: no cover
            busy = False
        if busy and now - last_hb[0] < _HB_MIN_INTERVAL_S:
            return
        last_hb[0] = now
        xstats = dict(xchg)
        xstats["shm_bytes_out"] = sum(
            s.bytes_copied for s in senders if s is not None
        )
        xstats["shm_bytes_in"] = sum(
            r.bytes_copied for r in receivers if r is not None
        )
        rep_q.put(
            (
                "hb",
                wid,
                cmds_done,
                {
                    "metrics": {
                        f: getattr(eng.metrics, f) for f in _METRIC_SUM_FIELDS
                    },
                    "exchange": xstats,
                },
            )
        )

    def drain_lanes(sender):
        """Move every delivered (sender → me) message into the stash."""
        per = stash.setdefault(sender, {})
        rx = receivers[sender]
        if rx is not None:
            while True:
                t0 = time.perf_counter()
                got = rx.poll()
                if got is None:
                    break
                xchg["dec_s"] += time.perf_counter() - t0
                per[got[0]] = ("s", got[1])
        lane = inboxes[wid][sender]
        while True:
            # Timed around the successful get too: the queue path pays a
            # pipe read plus wrapper unpickle per message — real decode
            # cost of that transport, attributed where it is paid.
            t0 = time.perf_counter()
            try:
                blob = lane.get_nowait()
            except _queue_mod.Empty:
                break
            mt, enc = pickle.loads(blob)
            xchg["dec_s"] += time.perf_counter() - t0
            per[mt] = ("q", enc)

    def recv_exchange(t, sender):
        per = stash.setdefault(sender, {})
        now = time.monotonic()
        deadline = now + timeout
        next_wait_hb = now + _HB_MIN_INTERVAL_S
        while t not in per:
            drain_lanes(sender)
            if t in per:
                break
            if dead_events[sender].is_set():
                # Final sweep: a contribution published between our poll
                # and the peer's death still counts (the ring mapping
                # outlives the coordinator's unlink).
                drain_lanes(sender)
                if t in per:
                    break
                # Peer died before contributing this tick: its tuples
                # are lost (fail_node semantics) — drain with nothing.
                dead.add(sender)
                return None
            now = time.monotonic()
            if now >= next_wait_hb:
                # Blocked on a peer is waiting, not wedged: advertise
                # liveness so the supervisor's escalation targets the
                # silent peer, never the worker stuck waiting on it.
                # Deliberately NOT a full heartbeat — counters only ride
                # command-boundary heartbeats, so a mid-tick death never
                # folds a partially-executed tick into the lost totals.
                rep_q.put(("hb_wait", wid))
                next_wait_hb = now + _HB_MIN_INTERVAL_S
            if now > deadline:
                raise RuntimeError(
                    f"worker {wid}: exchange wait for peer {sender} "
                    f"tick {t} timed out"
                )
            time.sleep(0.0005)
        kind, payload = per.pop(t)
        if kind == "s":
            return payload
        t0 = time.perf_counter()
        items = [
            (dop, serde.decode_batch(enc), sk, sn)
            for dop, enc, sk, sn in payload
        ]
        xchg["dec_s"] += time.perf_counter() - t0
        return items

    def send_exchange(t, w, items):
        """Ship one tick's contribution to peer ``w``: shm ring when it
        fits and every batch is native, else the pickled queue lane.

        The fallback pickles to bytes *inline* (not via the queue's feeder
        thread) so the exchange counters attribute the serialization cost
        where it is actually paid.
        """
        tx = senders[w]
        if tx is not None:
            t0 = time.perf_counter()
            sent = tx.try_send(t, items)
            xchg["enc_s"] += time.perf_counter() - t0
            if sent:
                xchg["shm_msgs"] += 1
                return
        t0 = time.perf_counter()
        blob = pickle.dumps(
            (t, _encode_items(items)), protocol=pickle.HIGHEST_PROTOCOL
        )
        xchg["enc_s"] += time.perf_counter() - t0
        inboxes[w][wid].put(blob)
        xchg["queue_msgs"] += 1

    def do_tick(t):
        nonlocal sink_cursor
        eng.tick()  # drain + flush → exchange stashes
        local, out = eng.take_exchange()
        peers = [w for w in range(num_workers) if w != wid and w not in dead]
        for w in peers:
            send_exchange(t, w, [
                (dop, batch, sk, sn)
                for dop, items in sorted(out.get(w, {}).items())
                for batch, sk, sn in items
            ])
        contribs: dict[int, list] = {wid: [
            (dop, batch, sk, sn)
            for dop, items in sorted(local.items())
            for batch, sk, sn in items
        ]}
        for w in peers:
            contribs[w] = recv_exchange(t, w) or []
        per_dop: dict[int, list] = {}
        for w in sorted(contribs):
            for dop, batch, sk, sn in contribs[w]:
                per_dop.setdefault(dop, []).append((batch, sk, sn))
        eng.route_merged(per_dop)
        sinks = None
        if eng.collect_sinks:
            outs = eng.metrics.sink_outputs
            sinks = outs[sink_cursor:]
            sink_cursor = len(outs)
        rep_q.put(("tick", t, wid, eng.worst_cost(), sinks))

    try:
        while True:
            cmd = cmd_q.get()
            op = cmd[0]
            if op == "push":
                _, oid, keys, values, ts = cmd
                eng._route_batch(oid, (keys, values, ts), src_kgs=None,
                                 src_nodes=None)
            elif op == "tick":
                do_tick(cmd[1])
            elif op == "costs":
                rep_q.put(("ack", wid, "costs", eng.worst_cost()))
            elif op == "redirect":
                _, kg, dst = cmd
                eng.redirect(kg, dst)
                rep_q.put(("ack", wid, "redirect", None))
            elif op == "serialize":
                env = eng.export_keygroup(cmd[1])
                rep_q.put(("ack", wid, "serialize", env.blob))
            elif op == "install":
                _, kg, dst, blob = cmd
                eng.import_keygroup(serde.Envelope(kg, blob), dst)
                rep_q.put(("ack", wid, "install", None))
            elif op == "complete":
                eng.router.complete(cmd[1])  # never buffered here: discard
                rep_q.put(("ack", wid, "complete", None))
            elif op == "set_alloc":
                _, kgs, dst = cmd
                eng.router.table[np.asarray(kgs, dtype=np.int64)] = dst
                eng.router.version += 1
                rep_q.put(("ack", wid, "set_alloc", None))
            elif op == "export":
                rep_q.put(("ack", wid, "export", eng.export_keygroup(cmd[1]).blob))
            elif op == "node_down":
                for node in cmd[1]:
                    if eng.alive[node]:
                        eng.fail_node(node)
                rep_q.put(("ack", wid, "node_down", None))
            elif op == "peer_dead":
                dead.add(cmd[1])
            elif op == "add_nodes":
                _, count, capacity, owner = cmd
                eng.add_nodes(count, capacity)
                eng._node_worker = np.concatenate(
                    [eng._node_worker, np.full(count, owner, dtype=np.int64)]
                )
                rep_q.put(("ack", wid, "add_nodes", None))
            elif op == "end_period":
                win = eng.window
                pairs = win.pair_counts()
                payload = {
                    "usage": {r: u.copy() for r, u in win.kg_usage.items()},
                    "arrivals": win.kg_arrivals.copy(),
                    "pairs": (pairs.src, pairs.dst, pairs.rate),
                    "state_bytes": eng.store.state_bytes(refresh=True),
                    "ticks": eng._ticks_this_period,
                }
                win.reset()
                eng._ticks_this_period = 0
                rep_q.put(("ack", wid, "end_period", payload))
            elif op == "gather":
                owned_kgs = eng.owned_keygroups()
                my_nodes = np.flatnonzero(eng._node_worker == wid)
                xchg["shm_bytes_out"] = sum(
                    s.bytes_copied for s in senders if s is not None
                )
                xchg["shm_bytes_in"] = sum(
                    r.bytes_copied for r in receivers if r is not None
                )
                payload = {
                    "metrics": {
                        f: getattr(eng.metrics, f) for f in _METRIC_SUM_FIELDS
                    },
                    "states": {
                        int(kg): eng.store.get(int(kg)) for kg in owned_kgs
                    },
                    "queue_costs": {
                        int(n): eng._queues[n].cost for n in my_nodes
                    },
                    "exchange": dict(xchg),
                }
                rep_q.put(("ack", wid, "gather", payload))
            elif op == "export_all":
                # Checkpoint export: σ + *parked* backlog per key group,
                # never popping the backlog (unlike serialize — checkpoints
                # must not mutate the engine).
                blobs = {
                    int(kg): serde.encode_migration(
                        eng.store.serialize(int(kg)),
                        list(eng._backlog.get(int(kg), [])),
                    )
                    for kg in cmd[1]
                }
                rep_q.put(("ack", wid, "export_all", blobs))
            elif op == "window_peek":
                win = eng.window
                pairs = win.pair_counts()
                payload = {
                    "usage": {r: u.copy() for r, u in win.kg_usage.items()},
                    "arrivals": win.kg_arrivals.copy(),
                    "pairs": (
                        pairs.src.copy(),
                        pairs.dst.copy(),
                        pairs.rate.copy(),
                    ),
                    "samples": int(win.samples),
                    "ticks": eng._ticks_this_period,
                    "state_bytes": eng.store.state_bytes(refresh=True),
                }
                rep_q.put(("ack", wid, "window_peek", payload))
            elif op == "restore":
                # Global rewind to a checkpoint: adopt the table, drop every
                # transient, wipe σ (install_bulk follows with the
                # checkpointed envelopes for this worker's key groups).
                _, table = cmd
                for q in eng._queues:
                    q.clear()
                eng._backlog.clear()
                eng._out_pending.clear()
                eng.router.reset(table)
                for kg in range(len(eng.router.table)):
                    eng.store.put(kg, {})
                eng.window.reset()
                eng._ticks_this_period = 0
                stash.clear()
                rep_q.put(("ack", wid, "restore", None))
            elif op == "install_bulk":
                for kg in sorted(cmd[1]):
                    eng.install(
                        int(kg), int(eng.router.table[kg]), cmd[1][kg]
                    )
                rep_q.put(("ack", wid, "install_bulk", None))
            elif op == "peer_up":
                # A respawned peer: fresh exchange lanes (attach the
                # replacement segments by name — they were created after
                # our fork), cleared stash, nodes back alive.  Byte
                # counters carry over so gather/heartbeat totals stay
                # cumulative across the peer's incarnations.
                _, peer, nodes, in_ring, out_ring = cmd
                dead.discard(peer)
                stash.pop(peer, None)
                while True:  # drop the dead incarnation's stale fallbacks
                    try:
                        inboxes[wid][peer].get_nowait()
                    except _queue_mod.Empty:
                        break
                old_tx, old_rx = senders[peer], receivers[peer]
                senders[peer] = (
                    shmx.LaneSender(shmx.ShmRing.open(out_ring))
                    if out_ring
                    else None
                )
                receivers[peer] = (
                    shmx.LaneReceiver(shmx.ShmRing.open(in_ring))
                    if in_ring
                    else None
                )
                if old_tx is not None:
                    if senders[peer] is not None:
                        senders[peer].bytes_copied += old_tx.bytes_copied
                    old_tx.ring.close()
                if old_rx is not None:
                    if receivers[peer] is not None:
                        receivers[peer].bytes_copied += old_rx.bytes_copied
                    old_rx.ring.close()
                for node in nodes:
                    eng.alive[node] = True
                rep_q.put(("ack", wid, "peer_up", None))
            elif op == "fault":
                # Injected wedge: hang (optionally SIGTERM-deaf — the
                # shutdown-escalation worst case) or a bounded delay.  No
                # ack — from outside this is indistinguishable from a
                # worker stuck mid-command, which is the point.
                _, kind, seconds, ignore_term = cmd
                if kind == "hang":
                    if ignore_term:
                        signal.signal(signal.SIGTERM, signal.SIG_IGN)
                    end = time.monotonic() + seconds
                    while time.monotonic() < end:
                        time.sleep(0.01)
                elif kind == "delay":
                    time.sleep(seconds)
            elif op == "stop":
                # Drop this process's lane mappings explicitly: rings opened
                # after a peer respawn are reachable only from these locals,
                # and GC'ing a ShmRing tears down its SharedMemory before the
                # numpy/memoryview exports — close() releases the views first.
                for lane in (*senders, *receivers):
                    if lane is not None:
                        lane.ring.close()
                rep_q.put(("ack", wid, "stop", None))
                break
            else:  # pragma: no cover - protocol error
                raise RuntimeError(f"worker {wid}: unknown command {op!r}")
            cmds_done += 1
            maybe_hb()
    except BaseException:  # pragma: no cover - surfaced coordinator-side
        rep_q.put(("error", wid, traceback.format_exc()))
        raise


# ---------------------------------------------------------------------------
# Coordinator side
# ---------------------------------------------------------------------------


class WorkerPool:
    """Owns the worker processes and their channels (fork context).

    Every channel has exactly ONE writer — per-worker command queues
    (written by the coordinator), per-worker report queues (written by that
    worker), and per-``(sender → receiver)`` exchange lanes: one shm ring
    (:class:`repro.engine.shmx.ShmRing`, single-producer/single-consumer
    by construction) plus one fallback queue each.  The
    discipline is what makes ``kill()`` safe: a SIGKILLed process can die
    holding only locks no survivor ever takes (an ``mp.Queue`` shared by
    two writers serializes them on one pipe lock, and a process killed
    between its pipe write and the lock release — a wide window on a
    loaded single-CPU host — wedges every other writer forever).  Worker
    death is signalled to peers through per-worker Events (set by the
    coordinator only), never by injecting messages into another writer's
    channel.
    """

    #: Seconds to wait for a worker to exit after SIGTERM/SIGKILL before
    #: escalating / declaring it leaked (tests shrink this).
    _GRACE_S = 5.0

    def __init__(
        self,
        num_workers: int,
        spec: dict,
        timeout: float,
        *,
        shm_lane_bytes: int = 0,
    ):
        ctx = multiprocessing.get_context("fork")
        self._ctx = ctx
        self.num_workers = num_workers
        self.timeout = timeout
        self._shm_lane_bytes = shm_lane_bytes
        #: Commands sent per worker since its (re)spawn — the liveness
        #: tracker's "outstanding work" side of the heartbeat equation.
        self.sent_counts = [0] * num_workers
        self.cmd_queues = [ctx.Queue() for _ in range(num_workers)]
        self.report_queues = [ctx.Queue() for _ in range(num_workers)]
        # inboxes[receiver][sender]: the (sender → receiver) exchange lane's
        # fallback queue (ring-full overflow, object-dtype batches).
        self.inboxes = [
            [ctx.Queue() if s != r else None for s in range(num_workers)]
            for r in range(num_workers)
        ]
        # rings[receiver][sender]: the lane's shm ring — allocated here,
        # BEFORE the fork, so workers inherit the mappings; unlinked only
        # by the coordinator (shutdown / worker death).
        self.rings: list[list] = [
            [None] * num_workers for _ in range(num_workers)
        ]
        if shm_lane_bytes:
            uid = uuid.uuid4().hex[:8]
            try:
                for r in range(num_workers):
                    for s in range(num_workers):
                        if s != r:
                            self.rings[r][s] = shmx.ShmRing.create(
                                f"{shmx.SEGMENT_PREFIX}_{os.getpid()}"
                                f"_{uid}_{s}to{r}",
                                shm_lane_bytes,
                            )
            except OSError:
                # No usable /dev/shm on this host: run on the queue path.
                self._destroy_rings()
        self.dead_events = [ctx.Event() for _ in range(num_workers)]
        spec = dict(
            spec,
            cmd_queues=self.cmd_queues,
            report_queues=self.report_queues,
            inboxes=self.inboxes,
            rings=self.rings,
            dead_events=self.dead_events,
            num_workers=num_workers,
            timeout=timeout,
        )
        self.spec = spec
        self.processes = [
            ctx.Process(target=_worker_main, args=(w, spec), daemon=True)
            for w in range(num_workers)
        ]
        for p in self.processes:
            p.start()

    def _destroy_rings(self) -> None:
        for row in self.rings:
            for s, ring in enumerate(row):
                if ring is not None:
                    ring.unlink()
                    ring.close()
                    row[s] = None

    def release_worker_lanes(self, wid: int) -> None:
        """Unlink every segment a dead worker touches (coordinator-owned
        cleanup).  Survivors' inherited mappings stay valid, so a peer can
        still drain the dead sender's ring during its final sweep — only
        the *name* goes away, which is what prevents the leak."""
        for r in range(self.num_workers):
            for s in range(self.num_workers):
                if wid in (r, s) and self.rings[r][s] is not None:
                    self.rings[r][s].unlink()

    def send(self, wid: int, msg) -> None:
        self.sent_counts[wid] += 1
        self.cmd_queues[wid].put(msg)

    def alive(self, wid: int) -> bool:
        return self.processes[wid].is_alive()

    def kill(self, wid: int) -> None:
        p = self.processes[wid]
        if p.is_alive():
            p.kill()
            p.join(timeout=self._GRACE_S)
            if p.is_alive():  # pragma: no cover - SIGKILL cannot be ignored
                raise RuntimeError(
                    f"worker {wid} (pid {p.pid}) survived SIGKILL"
                )

    @staticmethod
    def _drain(q) -> None:
        while True:
            try:
                q.get_nowait()
            except (_queue_mod.Empty, OSError):
                return

    def respawn(self, wid: int) -> tuple[Optional[list], Optional[list]]:
        """Fork a replacement for a dead worker over fresh exchange lanes.

        Drains the dead incarnation's channels (its queues have exactly one
        other writer — the coordinator — so draining here cannot race a
        worker), replaces every (wid ↔ peer) shm ring *in the rings matrix
        before forking* (the replacement inherits the new mappings; the old
        segments were unlinked at death), clears the death Event survivors
        watch, and forks.  Returns ``(in_ring_names, out_ring_names)`` —
        per-peer segment names survivors attach via ``peer_up`` (None when
        lanes are disabled).

        The caller updates ``spec`` beforehand (current table, node map,
        dead peers) via :attr:`spec`; channel objects are reused — the fork
        start method hands the replacement the same queues and Events.
        """
        p = self.processes[wid]
        if p.is_alive():  # pragma: no cover - protocol error
            raise RuntimeError(f"worker {wid} is still alive")
        # Fresh command/report queues: a worker SIGKILLed while blocked in
        # ``cmd_q.get()`` — where an idle worker always sits — dies holding
        # the queue's reader lock, poisoning it for any future reader.
        # Both queues touch only the coordinator and the dead incarnation,
        # so they are safely replaceable (the spec holds these same lists;
        # the replacement inherits the new objects at fork).  Peer-written
        # inbox lanes cannot be swapped — live survivors hold fork-inherited
        # references — but their locks are only held inside non-blocking
        # ``get_nowait`` windows, never across a wait.
        for old in (self.cmd_queues[wid], self.report_queues[wid]):
            old.close()
            old.cancel_join_thread()
        self.cmd_queues[wid] = self._ctx.Queue()
        self.report_queues[wid] = self._ctx.Queue()
        for w in range(self.num_workers):
            if w != wid:
                self._drain(self.inboxes[wid][w])
                self._drain(self.inboxes[w][wid])
        in_names: Optional[list] = None
        out_names: Optional[list] = None
        if self._shm_lane_bytes and any(
            r is not None for row in self.rings for r in row
        ):
            uid = uuid.uuid4().hex[:8]
            try:
                for w in range(self.num_workers):
                    if w == wid:
                        continue
                    for r, s in ((wid, w), (w, wid)):
                        old = self.rings[r][s]
                        if old is not None:
                            old.close()
                        self.rings[r][s] = shmx.ShmRing.create(
                            f"{shmx.SEGMENT_PREFIX}_{os.getpid()}"
                            f"_{uid}_{s}to{r}",
                            self._shm_lane_bytes,
                        )
            except OSError:  # pragma: no cover - /dev/shm exhausted
                for w in range(self.num_workers):
                    for r, s in ((wid, w), (w, wid)):
                        ring = self.rings[r][s]
                        if ring is not None:
                            ring.unlink()
                            ring.close()
                            self.rings[r][s] = None
            else:
                in_names = [
                    self.rings[w][wid].shm.name if w != wid else None
                    for w in range(self.num_workers)
                ]
                out_names = [
                    self.rings[wid][w].shm.name if w != wid else None
                    for w in range(self.num_workers)
                ]
        # Same Event object (survivors hold fork-inherited references):
        # clear, don't replace.  Safe because the caller quiesced the pool —
        # every survivor finished its final sweep of the dead incarnation.
        self.dead_events[wid].clear()
        self.sent_counts[wid] = 0
        proc = self._ctx.Process(
            target=_worker_main, args=(wid, self.spec), daemon=True
        )
        proc.start()
        self.processes[wid] = proc
        return in_names, out_names

    def shutdown(self) -> None:
        # Graceful first (SIGTERM lets queue feeder threads flush), then
        # escalate to SIGKILL on timeout, then *check* — the join result
        # used to be ignored, so an ignore-everything worker leaked.
        for p in self.processes:
            if p.is_alive():
                p.terminate()
        deadline = time.monotonic() + self._GRACE_S
        for p in self.processes:
            p.join(timeout=max(0.0, deadline - time.monotonic()))
        leaked = [p for p in self.processes if p.is_alive()]
        for p in leaked:
            p.kill()
        for p in leaked:
            p.join(timeout=self._GRACE_S)
        still = [p.pid for p in self.processes if p.is_alive()]
        for q in (
            *self.cmd_queues,
            *self.report_queues,
            *(q for row in self.inboxes for q in row if q is not None),
        ):
            q.close()
            q.cancel_join_thread()
        self._destroy_rings()
        if still:  # pragma: no cover - SIGKILL cannot be ignored
            raise RuntimeError(f"leaked worker processes after SIGKILL: {still}")


class ClusterEngine:
    """Coordinator for the multi-worker runtime; Engine-compatible surface.

    Drives a :class:`WorkerPool` in lockstep (``push_source`` / ``tick`` —
    the conformance shape, bit-identical to single-process) or pipelined
    (:meth:`run_stream` — the throughput shape, no per-tick coordinator
    barrier).  Implements the ``StateMover`` protocol, so
    ``repro.core.migration.execute_plan`` migrates key groups *between live
    worker processes* exactly as it does between logical nodes.
    """

    def __init__(
        self,
        topology: Topology,
        num_nodes: int,
        *,
        config: Optional[ExecutionConfig] = None,
        initial_alloc: Optional[np.ndarray] = None,
        capacity: Optional[np.ndarray] = None,
        service_rate: float = 1_000.0,
        ser_cost: float = 0.25,
        seed: int = 0,
        collect_sinks: bool = True,
        timeout: float = DEFAULT_TIMEOUT,
        faults: Optional[FaultPlan] = None,
    ) -> None:
        if config is None:
            config = ExecutionConfig.workers(2)
        if config.num_workers < 2:
            raise ValueError("ClusterEngine needs ExecutionConfig.workers(n >= 2)")
        if "fork" not in multiprocessing.get_all_start_methods():
            raise RuntimeError(
                "the multi-worker runtime requires the 'fork' start method "
                "(operator closures are inherited, not pickled)"
            )
        topology.validate()
        self.topology = topology
        self.num_nodes = num_nodes
        self.config = config
        self.num_workers = config.num_workers
        self.service_rate = service_rate
        self.ser_cost = ser_cost
        self.seed = seed
        self.collect_sinks = collect_sinks
        self.capacity = (
            np.ones(num_nodes) if capacity is None else np.asarray(capacity)
        )
        g = topology.num_keygroups
        rng = np.random.default_rng(seed)  # Engine's exact alloc draw
        if initial_alloc is None:
            initial_alloc = rng.integers(0, num_nodes, size=g)
        self._initial_alloc = np.asarray(initial_alloc, dtype=np.int64).copy()
        self.router = Router(g, self._initial_alloc)
        self.node_worker = contiguous_node_worker(num_nodes, self.num_workers)
        self.alive = np.ones(num_nodes, dtype=bool)
        self.metrics = EngineMetrics()
        self.store = KeyedStore(g)  # populated at finalize()
        self.backpressure = CreditController(
            num_nodes, high_wm=50 * service_rate
        )
        self.ingest_rng = np.random.default_rng(
            [np.uint32(seed), np.uint32(0xC1)]
        )
        self._kg_op = topology.kg_operator()
        self._downstream = topology.downstream()
        self._op_schema = [
            o.schema if config.use_schema else None for o in topology.operators
        ]
        self._worker_config = config.replace(
            num_workers=1, checkpoint=None, supervision=None
        )
        self._timeout = timeout
        worker_cfg = self._worker_config
        self.pool = WorkerPool(
            self.num_workers,
            dict(
                topology=topology,
                num_nodes=num_nodes,
                config=worker_cfg,
                initial_alloc=self._initial_alloc,
                capacity=self.capacity,
                service_rate=service_rate,
                ser_cost=ser_cost,
                seed=seed,
                collect_sinks=collect_sinks,
                node_worker=self.node_worker,
            ),
            timeout,
            shm_lane_bytes=config.shm_lane_bytes,
        )
        #: Folded per-worker exchange counters (populated at finalize):
        #: encode/decode seconds, shm vs queue message counts, bytes copied.
        self.exchange_stats: dict[str, float] = dict.fromkeys(
            _EXCHANGE_STAT_FIELDS, 0
        )
        self._dead_workers: set[int] = set()
        self._worst = np.zeros(self.num_workers)
        self._tick_no = 0
        self._ticks_this_period = 0
        self._mig_src: dict[int, int] = {}
        # Pipelined-mode report reassembly: (tick → {wid: (worst, sinks)}).
        self._tick_reports: dict[int, dict[int, tuple]] = {}
        self._merged_through = -1
        self._pending_ticks: list[int] = []
        self._stashed_acks: dict[tuple[int, str], object] = {}
        self._queue_costs: Optional[list[float]] = None
        self._closed = False
        self._finalized = False
        # ---- self-healing state (heartbeats, checkpoints, recovery) ----
        self.faults = faults
        #: Source admissions since start — the checkpoint cut point and the
        #: replay buffer's ordering key.
        self.ingest_cursor = 0
        self._period_no = 0
        # Post-checkpoint admissions buffered coordinator-side, as
        # (cursor, oid, converted batch): after a global rewind to the last
        # checkpoint they are re-shipped in admission order.  Only kept when
        # both checkpoints and respawn are configured; pruned at each commit.
        self._buffer_replay = (
            config.checkpoint is not None
            and config.supervision is not None
            and config.supervision.respawn
        )
        self._replay: list[tuple[int, int, tuple]] = []
        #: Latest heartbeat per worker: (commands done, cumulative counters).
        #: A dead worker's entry is folded into the lost-counter accumulators
        #: exactly once (its gather payload is gone; the replacement counts
        #: from zero), so finalize stays conservation-exact across respawns.
        self._last_hb: dict[int, tuple[int, dict]] = {}
        self._lost_metrics = dict.fromkeys(_METRIC_SUM_FIELDS, 0)
        self._lost_exchange = dict.fromkeys(_EXCHANGE_STAT_FIELDS, 0.0)
        self._death_ts: dict[int, float] = {}
        self._needs_recovery: list[int] = []
        self._in_recovery = False
        #: One RecoveryReport per recovery attempt (see engine/supervisor.py).
        self.recoveries: list = []
        # Window statistics restored from a checkpoint, folded into the next
        # end_period exactly once (the periodic fold must see the partial
        # window the original run had at the cut).
        self._window_base: Optional[dict] = None
        self._window_resources: tuple = ("cpu", "network", "memory")
        self.supervisor = None
        if config.supervision is not None or config.checkpoint is not None:
            # Lazy import: the supervisor pulls in the checkpoint stack,
            # which plain cluster runs never need.
            from repro.engine.supervisor import Supervisor

            self.supervisor = Supervisor(self)

    # ------------------------------------------------------------- plumbing
    def _alive_workers(self) -> list[int]:
        return [
            w for w in range(self.num_workers) if w not in self._dead_workers
        ]

    def worker_of_node(self, node: int) -> int:
        return int(self.node_worker[node])

    def _recv(self):
        """One report message (any worker), with death detection and deadline.

        Polls every worker's report queue — including a dead worker's, whose
        already-flushed reports are still deliverable — in worker-id order.
        """
        deadline = time.monotonic() + self._timeout
        readers = [q._reader for q in self.pool.report_queues]
        while True:
            for w in range(self.num_workers):
                try:
                    msg = self.pool.report_queues[w].get_nowait()
                except _queue_mod.Empty:
                    continue
                if msg[0] == "error":
                    raise RuntimeError(
                        f"worker {msg[1]} crashed:\n{msg[2]}"
                    )
                if msg[0] == "hb":
                    # Liveness + counters only — never surfaced to callers.
                    self._note_hb(msg)
                    continue
                if msg[0] == "hb_wait":
                    # Worker blocked in the exchange on a peer: pure
                    # liveness, no counters (see recv_exchange).
                    if self.supervisor is not None:
                        self.supervisor.note_activity(msg[1])
                    continue
                if self.supervisor is not None:
                    self.supervisor.note_activity(
                        msg[2] if msg[0] == "tick" else msg[1]
                    )
                return msg
            for w in self._alive_workers():
                if not self.pool.alive(w):
                    self._on_worker_death(w)
                    return None
            if self.supervisor is not None and self.supervisor.escalate_wedged():
                continue  # SIGKILLed a wedged worker; re-run death detection
            if time.monotonic() > deadline:
                raise RuntimeError(
                    "cluster coordinator: wait on worker reports timed "
                    "out (wedged pool?)"
                )
            mp_connection.wait(readers, timeout=0.05)

    def _handle_tick_report(self, msg) -> None:
        _, t, wid, worst, sinks = msg
        self._worst[wid] = worst
        self._tick_reports.setdefault(t, {})[wid] = (worst, sinks)
        self._merge_ready_ticks()

    def _merge_ready_ticks(self) -> None:
        """Fold completed ticks' sink deltas in (tick, worker) order."""
        while self._pending_ticks:
            t = self._pending_ticks[0]
            reports = self._tick_reports.get(t, {})
            expected = [
                w for w in range(self.num_workers)
                if w not in self._dead_workers or w in reports
            ]
            if not all(w in reports for w in expected):
                return
            for w in sorted(reports):
                _, sinks = reports[w]
                if sinks:
                    self.metrics.sink_outputs.extend(sinks)
            # pop, not del: with every expected reporter dead the tick
            # merges empty and may have no reports entry at all.
            self._tick_reports.pop(t, None)
            self._pending_ticks.pop(0)
            self._merged_through = t

    def _await_acks(self, wids: list[int], tag: str):
        """Collect one tagged ack per worker; returns {wid: payload}.

        The stash is re-checked every iteration, not just on entry: a
        worker-death detour (``_on_worker_death`` → ``node_down`` ack wait)
        nested inside this wait consumes the report stream and stashes this
        tag's acks — entry-only checking would then wait forever for a
        message already consumed.
        """
        out = {}
        while True:
            for w in wids:
                key = (w, tag)
                if w not in out and key in self._stashed_acks:
                    out[w] = self._stashed_acks.pop(key)
            if len(out) >= len(
                [w for w in wids if w not in self._dead_workers]
            ):
                return out
            msg = self._recv()
            if msg is None:  # a worker died; re-evaluate expectations
                continue
            if msg[0] == "tick":
                self._handle_tick_report(msg)
                continue
            _, wid, mtag, payload = msg
            if mtag == tag and wid in wids:
                out[wid] = payload
            else:
                self._stashed_acks[(wid, mtag)] = payload

    def _command_all(self, msg, tag: str):
        wids = self._alive_workers()
        for w in wids:
            self.pool.send(w, msg)
        return self._await_acks(wids, tag)

    def _command_one(self, wid: int, msg, tag: str):
        if wid in self._dead_workers:
            raise RuntimeError(f"worker {wid} is dead")
        self.pool.send(wid, msg)
        return self._await_acks([wid], tag)[wid]

    def _on_worker_death(self, wid: int) -> None:
        """A worker vanished: unwedge peers, mark its nodes failed.

        Survivors stuck in the current tick's exchange see the dead
        worker's Event and drain with an empty contribution; future ticks
        skip it via ``peer_dead``.  The dead worker's queued work and
        un-reported tick output are lost — exactly a node crash
        (``fail_node`` semantics); recovery reinstalls its key groups from
        checkpoint envelopes via :meth:`import_keygroup` (see
        tests/test_cluster_faults.py).
        """
        if wid in self._dead_workers:
            return
        self._dead_workers.add(wid)
        self._death_ts[wid] = time.monotonic()
        # Drain reports the dead worker already flushed — the final
        # heartbeat rides the same pipe as the last ack and may not have
        # been polled yet — then fold its counters exactly once.
        while True:
            try:
                msg = self.pool.report_queues[wid].get_nowait()
            except (_queue_mod.Empty, OSError):
                break
            if msg[0] == "hb":
                self._note_hb(msg)
            elif msg[0] == "tick":
                self._handle_tick_report(msg)
            elif msg[0] == "ack":
                self._stashed_acks[(msg[1], msg[2])] = msg[3]
        last = self._last_hb.pop(wid, None)
        if last is not None:
            _, counters = last
            for f in _METRIC_SUM_FIELDS:
                self._lost_metrics[f] += counters["metrics"].get(f, 0)
            for f in _EXCHANGE_STAT_FIELDS:
                self._lost_exchange[f] += counters["exchange"].get(f, 0)
        dead_nodes = np.flatnonzero(self.node_worker == wid)
        self.alive[dead_nodes] = False
        # Coordinator-owned shm cleanup: a SIGKILLed worker can't unlink
        # its own lanes, so its segments are released here (names only —
        # survivors' mappings stay valid for the final drain).
        self.pool.release_worker_lanes(wid)
        # Unblock survivors stuck on the dead worker's exchange: the Event
        # is coordinator-owned, so no channel the dead process might have
        # wedged is involved (see WorkerPool).
        self.pool.dead_events[wid].set()
        survivors = self._alive_workers()
        for w in survivors:
            self.pool.send(w, ("peer_dead", wid))
        self._command_all(("node_down", dead_nodes.tolist()), "node_down")
        self._merge_ready_ticks()
        if (
            self.config.supervision is not None
            and self.config.supervision.respawn
        ):
            # Recovery runs at the next safe point (between supersteps),
            # not here: death is detected deep inside report waits.
            self._needs_recovery.append(wid)

    # ------------------------------------------------------------ self-healing
    def _note_hb(self, msg) -> None:
        _, wid, done, counters = msg
        self._last_hb[wid] = (done, counters)
        if self.supervisor is not None:
            self.supervisor.note_hb(wid, done)

    def _maybe_recover(self) -> None:
        """Run pending recoveries at a safe point (no tick in flight)."""
        if self._in_recovery or not self._needs_recovery:
            return
        self._in_recovery = True
        try:
            while self._needs_recovery:
                self.supervisor.recover(self._needs_recovery.pop(0))
        finally:
            self._in_recovery = False

    def _apply_faults(self, *, tick=None, period=None) -> None:
        """Apply scheduled FaultPlan events at this deterministic point."""
        if self.faults is None:
            return
        events = (
            self.faults.at_tick(tick)
            if tick is not None
            else self.faults.at_period(period)
        )
        for ev in events:
            w = ev.worker
            if w >= self.num_workers or w in self._dead_workers:
                continue
            if self.supervisor is not None:
                self.supervisor.note_fault(w, ev)
            if ev.kind == "kill":
                self.fail_worker(w)
            else:
                # No ack: from outside, a hang/delay is a worker stuck
                # mid-command — which is exactly what it should look like.
                self.pool.send(
                    w, ("fault", ev.kind, ev.seconds, ev.ignore_term)
                )

    # ------------------------------------------------------------------ feed
    def source_credits(self, *, refresh: bool = True) -> int:
        """Global credits from the worst per-worker queue depth.

        ``refresh=True`` (the lockstep default) round-trips to the workers
        for the exact instantaneous depths; ``refresh=False`` uses the
        latest tick reports (the pipelined mode's credit loop).
        """
        return self.backpressure.credits_from_worst(
            self.worst_queue_cost(refresh=refresh)
        )

    def worst_queue_cost(self, *, refresh: bool = True) -> float:
        """Deepest queue across alive workers (drives credits; drain loops
        poll it to detect quiescence without a full gather)."""
        if refresh:
            for w, worst in self._command_all(("costs",), "costs").items():
                self._worst[w] = worst
        return max(
            (float(self._worst[w]) for w in self._alive_workers()), default=0.0
        )

    def push_source(self, op, keys, values, ts, *, refresh: bool = True) -> int:
        self._maybe_recover()
        oid = self.topology._resolve(op)
        spec = self.topology.operators[oid]
        if not spec.is_source:
            raise ValueError(f"{spec.name!r} is not a source")
        credits = self.source_credits(refresh=refresh)
        n = min(len(keys), credits)
        if n < len(keys):
            self.metrics.dropped_credits += len(keys) - n
        if n == 0:
            return 0
        self._split_and_push(oid, keys, values, ts, n)
        return n

    def _split_and_push(self, oid, keys, values, ts, n: int) -> None:
        """Schema-convert the admitted slice and ship per-worker splits."""
        schema = self._op_schema[oid]
        if schema is not None:
            tv = schema.typed_values(values[:n] if len(values) != n else values)
            if isinstance(values, np.ndarray) and np.shares_memory(tv, values):
                tv = tv.copy()
            batch = (
                np.array(keys[:n], dtype=schema.key),
                tv,
                np.asarray(ts[:n], dtype=np.float64),
            )
        else:
            batch = make_batch(keys[:n], values[:n], ts[:n])
        self.ingest_cursor += 1
        if self._buffer_replay:
            self._replay.append((self.ingest_cursor, oid, batch))
        self._ship_batch(oid, batch)

    def _ship_batch(self, oid: int, batch) -> None:
        """Partition one admitted batch by owning worker and ship the slices
        (the replay path re-enters here, bypassing admission)."""
        bk, bv, bt = batch
        kgs = self.topology.keygroups_of(oid, bk, bv)
        owners = self.node_worker[self.router.table[kgs]]
        for w in np.unique(owners):
            w = int(w)
            if w in self._dead_workers:
                continue  # tuples to dead nodes are lost, as on fail_node
            mask = owners == w
            if mask.all():
                sub = batch
            else:
                sub = (bk[mask], bv[mask], bt[mask])
            self.pool.send(w, ("push", oid, *sub))

    # ------------------------------------------------------------------ tick
    def tick(self) -> None:
        """Lockstep BSP tick: command all workers, await all reports."""
        self._apply_faults(tick=self._tick_no)
        t = self._tick_no
        self._tick_no += 1
        self._pending_ticks.append(t)
        for w in self._alive_workers():
            self.pool.send(w, ("tick", t))
        self._wait_tick(t)
        self.metrics.ticks += 1
        self._ticks_this_period += 1
        self._maybe_recover()

    def _wait_tick(self, t: int) -> None:
        while self._merged_through < t:
            msg = self._recv()
            if msg is None:
                continue
            if msg[0] == "tick":
                self._handle_tick_report(msg)
            else:
                _, wid, mtag, payload = msg
                self._stashed_acks[(wid, mtag)] = payload

    def run_stream(self, op, batches, *, window: int = 4,
                   shuffle: bool = False) -> int:
        """Pipelined throughput mode: stream (push, tick) pairs without a
        per-tick coordinator barrier.

        ``batches`` is an iterable of ``(keys, values, ts)`` source batches,
        one tick each; at most ``window`` ticks run ahead of the last
        merged report, and credits come from the latest reports (the
        asynchronous credit loop).  ``shuffle=True`` permutes batch order
        with the seed-derived ingestion RNG (reproducible from
        ``Engine(seed=...)`` alone).  Returns tuples accepted.
        """
        oid = self.topology._resolve(op)
        batches = list(batches)
        if shuffle:
            batches = [batches[i] for i in self.ingest_rng.permutation(len(batches))]
        accepted = 0
        for keys, values, ts in batches:
            self._maybe_recover()
            while self._tick_no - self._merged_through - 1 >= window:
                msg = self._recv()
                if msg is None:
                    continue
                if msg[0] == "tick":
                    self._handle_tick_report(msg)
            credits = self.source_credits(refresh=False)
            n = min(len(keys), credits)
            if n < len(keys):
                self.metrics.dropped_credits += len(keys) - n
            if n:
                self._split_and_push(oid, keys, values, ts, n)
                accepted += n
            self._apply_faults(tick=self._tick_no)
            t = self._tick_no
            self._tick_no += 1
            self._pending_ticks.append(t)
            for w in self._alive_workers():
                self.pool.send(w, ("tick", t))
        if self._tick_no:
            self._wait_tick(self._tick_no - 1)
        self.metrics.ticks += len(batches)
        self._ticks_this_period += len(batches)
        self._maybe_recover()
        return accepted

    # ------------------------------------------------------- SPL statistics
    def end_period(self) -> ClusterState:
        """Fold every worker's SPL window into one ClusterState snapshot."""
        self._maybe_recover()
        payloads = self._command_all(("end_period",), "end_period")
        g = self.topology.num_keygroups
        order = sorted(payloads)
        usage = {
            r: np.zeros(g)
            for r in (payloads[order[0]]["usage"] if order else {"cpu": None})
        }
        arrivals = np.zeros(g)
        psrc, pdst, prate = [], [], []
        state_bytes = np.full(g, 64.0)
        owner_of_kg = self.node_worker[self.router.table]
        for w in order:
            p = payloads[w]
            for r, u in p["usage"].items():
                usage[r] += u
            arrivals += p["arrivals"]
            s, d, r_ = p["pairs"]
            psrc.append(s)
            pdst.append(d)
            prate.append(r_)
            mine = owner_of_kg == w
            state_bytes[mine] = p["state_bytes"][mine]
        if self._window_base is not None:
            # Window statistics carried out of the checkpoint a recovery
            # restored from — the fold must see the partial window the
            # original run had accumulated at the cut.  Folded once.
            base, self._window_base = self._window_base, None
            for r, u in base["usage"].items():
                if r in usage:
                    usage[r] += u
            arrivals += base["arrivals"]
            s, d, r_ = base["pairs"]
            if len(s):
                psrc.append(s)
                pdst.append(d)
                prate.append(r_)
        self._window_resources = tuple(usage)
        totals = {r: float(u.sum()) for r, u in usage.items()}
        resource = max(totals, key=totals.get)
        ticks = max(self._ticks_this_period, 1)
        scale = 100.0 / (ticks * self.service_rate)
        if psrc and sum(len(s) for s in psrc):
            src = np.concatenate(psrc)
            dst = np.concatenate(pdst)
            rate = np.concatenate(prate)
            pairs = PairRates.from_codes(src * g + dst, rate, g)
        else:
            pairs = PairRates.empty(g)
        state = ClusterState.create(
            self.num_nodes,
            self._kg_op,
            usage[resource] * scale,
            self.router.table.copy(),
            kg_state_bytes=state_bytes,
            out_rates=pairs,
            downstream=self._downstream,
            capacity=self.capacity.copy(),
            kg_tuple_rate=arrivals / ticks,
        )
        state.alive = self.alive.copy()
        # Hot-key observability over the cross-worker fold: the gauge sees
        # the same totals a single-process run of the same traffic would,
        # because `arrivals` is the sum of every worker's partial counts.
        self.metrics.hot_keygroups, self.metrics.max_kg_share = hot_key_summary(
            arrivals
        )
        self._ticks_this_period = 0
        self._period_no += 1
        if self.supervisor is not None:
            self.supervisor.note_period(state)
        # Period faults land *after* the fold and any checkpoint — a kill
        # here is a crash between periods, the canonical recovery scenario.
        self._apply_faults(period=self._period_no)
        self._maybe_recover()
        return state

    # ------------------------------------------------- direct state migration
    # StateMover protocol — migrations now move state between live worker
    # processes, through the versioned serde envelopes.
    def redirect(self, keygroup: int, dst: int) -> None:
        src_worker = self.worker_of_node(self.router.node_of(keygroup))
        self.router.redirect(keygroup, dst)
        self._mig_src[keygroup] = src_worker
        self._command_all(("redirect", keygroup, dst), "redirect")

    def serialize(self, keygroup: int) -> bytes:
        w = self._mig_src.pop(
            keygroup, self.worker_of_node(self.router.node_of(keygroup))
        )
        return self._command_one(w, ("serialize", keygroup), "serialize")

    def install(self, keygroup: int, dst: int, blob: bytes) -> None:
        w_dst = self.worker_of_node(dst)
        if w_dst in self._dead_workers:
            raise RuntimeError(
                f"cannot install key group {keygroup}: node {dst}'s worker "
                f"{w_dst} is dead"
            )
        wids = self._alive_workers()
        for w in wids:
            if w == w_dst:
                self.pool.send(w, ("install", keygroup, dst, blob))
            else:
                self.pool.send(w, ("complete", keygroup))
        self._await_acks(
            [w for w in wids if w != w_dst], "complete"
        )
        if w_dst not in self._dead_workers:
            self._await_acks([w_dst], "install")
        self.router.complete(keygroup)

    def export_keygroup(self, keygroup: int) -> serde.Envelope:
        w = self.worker_of_node(self.router.node_of(keygroup))
        blob = self._command_one(w, ("export", keygroup), "export")
        return serde.Envelope(keygroup, blob)

    def import_keygroup(
        self, envelope: serde.Envelope, dst: Optional[int] = None
    ) -> None:
        if dst is None:
            dst = self.router.node_of(envelope.keygroup)
        if int(self.router.table[envelope.keygroup]) != dst:
            self.set_alloc([envelope.keygroup], dst)
        self.install(envelope.keygroup, dst, envelope.blob)

    def set_alloc(self, keygroups, dst: int) -> None:
        """Point key groups at ``dst`` on every replica table (no in-flight
        semantics — the recovery path's table rewrite)."""
        self.router.table[np.asarray(keygroups, dtype=np.int64)] = dst
        self.router.version += 1
        self._command_all(("set_alloc", list(keygroups), dst), "set_alloc")

    # --------------------------------------------------------------- elastic
    def add_nodes(self, count: int, capacity: float = 1.0) -> None:
        """Append nodes, owned by the last worker (keeps the node → worker
        map monotone, which the determinism contract requires)."""
        owner = max(self._alive_workers())
        self.num_nodes += count
        self.capacity = np.concatenate([self.capacity, np.full(count, capacity)])
        self.alive = np.concatenate([self.alive, np.ones(count, dtype=bool)])
        self.node_worker = np.concatenate(
            [self.node_worker, np.full(count, owner, dtype=np.int64)]
        )
        self.backpressure.num_nodes = self.num_nodes
        self._command_all(("add_nodes", count, capacity, owner), "add_nodes")

    def fail_worker(self, wid: int) -> np.ndarray:
        """Kill a worker process outright (fault injection).

        Returns the orphaned key groups; their queued work and state on the
        dead worker are gone — reinstall from checkpoints via
        :meth:`import_keygroup` (see tests/test_cluster_faults.py).
        """
        dead_nodes = np.flatnonzero(self.node_worker == wid)
        orphans = np.flatnonzero(np.isin(self.router.table, dead_nodes))
        self.pool.kill(wid)
        self._on_worker_death(wid)
        return orphans

    # ------------------------------------------------------------- inspection
    def queue_costs(self) -> list[float]:
        if self._queue_costs is not None:
            return self._queue_costs
        costs = [0.0] * self.num_nodes
        for w, payload in self._command_all(("gather",), "gather").items():
            for node, c in payload["queue_costs"].items():
                costs[node] = c
        return costs

    def finalize(self) -> None:
        """Gather worker-side results onto the coordinator and stop the pool.

        After this, ``metrics`` (counters + merged sink outputs), ``store``
        (every key group's state, taken from its owning worker) and
        ``queue_costs()`` read exactly like a single-process engine's.
        """
        if self._finalized:
            return
        payloads = self._command_all(("gather",), "gather")
        costs = [0.0] * self.num_nodes
        for w in sorted(payloads):
            p = payloads[w]
            for f in _METRIC_SUM_FIELDS:
                setattr(
                    self.metrics, f, getattr(self.metrics, f) + p["metrics"][f]
                )
            for kg, state in p["states"].items():
                if state:
                    self.store.put(kg, state)
            for node, c in p["queue_costs"].items():
                costs[node] = c
            for f in _EXCHANGE_STAT_FIELDS:
                self.exchange_stats[f] += p.get("exchange", {}).get(f, 0)
        # Dead workers' final-heartbeat counters, folded exactly once: the
        # live gather above only sees the current incarnations (which count
        # from zero after a respawn).
        for f in _METRIC_SUM_FIELDS:
            setattr(
                self.metrics, f, getattr(self.metrics, f) + self._lost_metrics[f]
            )
        for f in _EXCHANGE_STAT_FIELDS:
            self.exchange_stats[f] += self._lost_exchange[f]
        self._queue_costs = costs
        self._finalized = True
        self.close()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            for w in self._alive_workers():
                self.pool.send(w, ("stop",))
            self._await_acks(self._alive_workers(), "stop")
        except Exception:
            pass
        self.pool.shutdown()

    def __enter__(self) -> "ClusterEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - belt and braces
        try:
            if not getattr(self, "_closed", True):
                self.pool.shutdown()
        except Exception:
            pass

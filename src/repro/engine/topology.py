"""Job topology: a DAG of operators connected by keyed streams (paper §3).

A job is ⟨O, E⟩ with src operators producing input and sink operators
producing none.  Each operator's input keys are hash-partitioned into a fixed
number of *key groups*; the processing of key groups is independent (the
paper's main execution-model assumption), which is what makes key groups the
unit of allocation and migration.

Operator logic is opaque to the system (paper §4.3.2: no pre-analysis of key
relations is possible) — the engine only sees tuples, keys and measured rates.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

# A tuple batch: parallel arrays ⟨key, value, ts⟩.  Values are object arrays so
# operators may carry arbitrary payloads (dicts, floats, small arrays).
Batch = tuple[np.ndarray, np.ndarray, np.ndarray]


def make_batch(keys: Sequence, values: Sequence, ts: Sequence) -> Batch:
    k = np.asarray(keys)
    v = np.empty(len(values), dtype=object)
    v[:] = list(values)
    return k, v, np.asarray(ts, dtype=np.float64)


def empty_batch() -> Batch:
    return np.empty(0, dtype=np.int64), np.empty(0, dtype=object), np.empty(0)


# Operator state-transition function:
#   fn(state: dict, keys, values, ts) -> (state', list[(out_key, out_value, out_ts)])
# It is called once per (key group, batch); `state` is that key group's σ_k.
OperatorFn = Callable[[dict, np.ndarray, np.ndarray, np.ndarray], tuple[dict, list]]


@dataclasses.dataclass
class OperatorSpec:
    """One operator O_i.

    Attributes:
      name: unique id.
      fn: keyed state transition (None for sources; sources are driven by the
        engine's input feeder).
      num_keygroups: how many key groups this operator's input is split into.
      cost_per_tuple: load points charged per processed tuple (the measured
        CPU cost in the paper's statistics; calibrated per operator).
      key_fn: maps an input tuple key to the partitioning key (defaults to
        identity).  The engine hashes the result into a key group.
      key_by_value: optional — partition by a function of the tuple *value*
        instead (e.g. RouteDelay partitions extract's airplane-keyed tuples
        by (origin, dest)).  Takes precedence over key_fn.
      is_source / is_sink: role flags.
    """

    name: str
    fn: Optional[OperatorFn]
    num_keygroups: int = 8
    cost_per_tuple: float = 1.0
    key_fn: Callable[[object], object] = staticmethod(lambda k: k)
    key_by_value: Optional[Callable[[object], object]] = None
    is_source: bool = False
    is_sink: bool = False


class Topology:
    """DAG of :class:`OperatorSpec` plus the global key-group index space.

    Key groups are numbered globally and contiguously per operator, so a
    single allocation vector covers the whole job (matching
    :class:`repro.core.stats.ClusterState`).
    """

    def __init__(self) -> None:
        self.operators: list[OperatorSpec] = []
        self.edges: list[tuple[int, int]] = []
        self._name_to_id: dict[str, int] = {}

    # -- construction --------------------------------------------------------
    def add_operator(self, spec: OperatorSpec) -> int:
        if spec.name in self._name_to_id:
            raise ValueError(f"duplicate operator {spec.name!r}")
        oid = len(self.operators)
        self.operators.append(spec)
        self._name_to_id[spec.name] = oid
        return oid

    def connect(self, src: str | int, dst: str | int) -> None:
        s = self._resolve(src)
        d = self._resolve(dst)
        self.edges.append((s, d))

    def _resolve(self, ref: str | int) -> int:
        return ref if isinstance(ref, int) else self._name_to_id[ref]

    # -- derived -------------------------------------------------------------
    @property
    def num_operators(self) -> int:
        return len(self.operators)

    @property
    def num_keygroups(self) -> int:
        return sum(o.num_keygroups for o in self.operators)

    def kg_base(self, op: int) -> int:
        return sum(o.num_keygroups for o in self.operators[:op])

    def kg_operator(self) -> np.ndarray:
        return np.concatenate(
            [np.full(o.num_keygroups, i, dtype=np.int64) for i, o in enumerate(self.operators)]
        )

    def downstream(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {i: [] for i in range(self.num_operators)}
        for s, d in self.edges:
            out[s].append(d)
        return out

    def upstream(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {i: [] for i in range(self.num_operators)}
        for s, d in self.edges:
            out[d].append(s)
        return out

    def topo_order(self) -> list[int]:
        indeg = [0] * self.num_operators
        for _, d in self.edges:
            indeg[d] += 1
        order, stack = [], [i for i, v in enumerate(indeg) if v == 0]
        while stack:
            u = stack.pop()
            order.append(u)
            for s, d in self.edges:
                if s == u:
                    indeg[d] -= 1
                    if indeg[d] == 0:
                        stack.append(d)
        if len(order) != self.num_operators:
            raise ValueError("topology has a cycle")
        return order

    def keygroup_of(self, op: int, key: object, value: object = None) -> int:
        """Hash-partition a tuple into one of the operator's key groups."""
        spec = self.operators[op]
        part_key = (
            spec.key_by_value(value)
            if (spec.key_by_value is not None and value is not None)
            else spec.key_fn(key)
        )
        h = hash(part_key) & 0x7FFFFFFF
        return self.kg_base(op) + (h % spec.num_keygroups)

    def validate(self) -> None:
        self.topo_order()  # raises on cycles
        downs = self.downstream()
        for i, o in enumerate(self.operators):
            if o.is_sink and downs[i]:
                raise ValueError(f"sink {o.name!r} has downstream edges")
            if not o.is_source and o.fn is None:
                raise ValueError(f"non-source {o.name!r} lacks fn")

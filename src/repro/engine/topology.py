"""Job topology: a DAG of operators connected by keyed streams (paper §3).

A job is ⟨O, E⟩ with src operators producing input and sink operators
producing none.  Each operator's input keys are hash-partitioned into a fixed
number of *key groups*; the processing of key groups is independent (the
paper's main execution-model assumption), which is what makes key groups the
unit of allocation and migration.

Operator logic is opaque to the system (paper §4.3.2: no pre-analysis of key
relations is possible) — the engine only sees tuples, keys and measured rates.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

# A tuple batch: parallel arrays ⟨key, value, ts⟩.  Values are object arrays so
# operators may carry arbitrary payloads (dicts, floats, small arrays).
Batch = tuple[np.ndarray, np.ndarray, np.ndarray]


def make_batch(keys: Sequence, values: Sequence, ts: Sequence) -> Batch:
    k = np.asarray(keys)
    if isinstance(values, np.ndarray):
        # Preserve the native dtype: a numeric values array flows through
        # slicing/gather/concat unboxed (object arrays pay per-element
        # refcounting on every gather).  Copied, not aliased — queued
        # batches must survive a caller refilling its buffer.
        v = values.copy()
    else:
        v = np.empty(len(values), dtype=object)
        v[:] = values if isinstance(values, list) else list(values)
    return k, v, np.asarray(ts, dtype=np.float64)


def empty_batch() -> Batch:
    return np.empty(0, dtype=np.int64), np.empty(0, dtype=object), np.empty(0)


# Operator state-transition function:
#   fn(state: dict, keys, values, ts) -> (state', outputs)
# where outputs is either a list of (out_key, out_value, out_ts) tuples or —
# the fast, array-native protocol — a Batch of three parallel arrays.
# It is called once per (key group, batch); `state` is that key group's σ_k.
OperatorFn = Callable[[dict, np.ndarray, np.ndarray, np.ndarray], tuple[dict, list]]

# Segment-level state transition (optional, the vectorized protocol):
#   fn_seg(store, kgs, starts, ends, keys, values, ts) -> (outputs, out_counts)
# One call covers every key group a node drains for this operator in a tick:
# `store` is the engine's state list (index by global key-group id), `kgs` the
# run key groups, and `starts`/`ends` slice bounds into the contiguous
# key/value/ts arrays.  `outputs` is a Batch (or None) concatenated over the
# runs in run order; `out_counts` gives per-run output lengths (None means
# each run emitted exactly its input length).  Must be semantically identical
# to calling `fn` run by run — the engine falls back to `fn` whenever the
# segment is not contiguous (in-flight migrations, partial budgets), and the
# routing-equivalence tests pin the two protocols against each other.
SegmentFn = Callable[
    [list, list, list, list, np.ndarray, np.ndarray, np.ndarray],
    tuple[Optional[Batch], Optional[list]],
]


@dataclasses.dataclass(frozen=True)
class Schema:
    """Declared record layout of a typed edge: value dtype + key dtype.

    ``value`` is a numpy dtype for the tuple *values* flowing over an edge —
    usually a structured record dtype (``Schema.record``), but any native
    scalar dtype works (e.g. plain ``float64`` payloads).  ``key`` types the
    partition keys.  Neither may be ``object``: a Schema is exactly the claim
    that the edge needs no object boxing, which is what lets the engine keep
    the routing permutation, the SoA work queues, sink buffers and migration
    codecs on native-dtype operations end to end.

    Two schemas are equal iff both dtypes are equal — topology validation
    compares them structurally, so declaring the same field layout twice
    (e.g. in the producer's ``out_schema`` and the consumer's ``schema``)
    compares equal even through distinct ``np.dtype`` instances.
    """

    value: np.dtype
    key: np.dtype = np.dtype(np.int64)

    def __post_init__(self) -> None:
        object.__setattr__(self, "value", np.dtype(self.value))
        object.__setattr__(self, "key", np.dtype(self.key))
        if self.value.kind == "O" or self.key.kind == "O":
            raise ValueError(
                "Schema dtypes must be native (object is the untyped path)"
            )

    @staticmethod
    def record(
        fields: Sequence[tuple[str, object]], key: object = np.int64
    ) -> "Schema":
        """Build a schema whose value layout is a structured record dtype."""
        return Schema(value=np.dtype(list(fields)), key=np.dtype(key))

    @property
    def names(self) -> Optional[tuple[str, ...]]:
        return self.value.names

    def typed_values(self, values) -> np.ndarray:
        """Coerce a value sequence/array to this schema's native layout.

        Lists of per-tuple records (python tuples) convert in one C-level
        ``np.array(..., dtype)``; object arrays go through ``tolist`` first
        (numpy cannot cast object arrays to structured dtypes directly); a
        native array of the right dtype passes through unchanged.
        """
        if isinstance(values, np.ndarray):
            if values.dtype == self.value:
                return values
            if values.dtype.kind == "O":
                return np.array(values.tolist(), dtype=self.value)
            return values.astype(self.value, copy=False)
        return np.array(
            values if isinstance(values, list) else list(values), dtype=self.value
        )

    def typed_keys(self, keys) -> np.ndarray:
        return np.asarray(keys, dtype=self.key)


# Compiled segment-level state transition (optional, the jit tier):
#   fn_jit(state_cols, kgs, starts, ends, keys, values, ts)
#       -> (state_cols', outputs, out_counts)
# A *pure JAX* function over column arrays, compiled once per (operator,
# padding bucket) by :mod:`repro.engine.jitexec` and executed as one
# ``jax.jit`` call per (node, operator) contiguous segment.  ``state_cols``
# is the operator's declared :class:`StateSchema` layout (per-key-group
# device columns — scalar vectors and keyed tables — instead of the python
# ``store`` dicts); ``kgs`` holds *local* key-group ids padded with the
# operator's key-group count, ``starts``/``ends`` are padded with the real
# tuple count (padding runs are empty), and ``values`` is a dict of native
# column arrays on record schemas (a plain array on scalar schemas).  Tuple
# validity is derived from the run bounds (``jitexec.tuple_valid``), never
# from array lengths, so the same body runs under padding and under
# ``shard_map`` run-sharding unchanged.  ``outputs`` is ``None`` or
# ``(out_keys, out_values, out_ts)`` with ``out_values`` a column dict /
# array in the operator's output layout; ``out_counts`` follows the fn_seg
# contract (None = one output per input tuple).  Must be semantically
# identical to ``fn_seg`` — bit-exact on integers and single float ops, with
# XLA reduction-order divergence allowed *only* for multi-term float
# reductions (running sums), see docs/operator_authoring.md.
JitFn = Callable[..., tuple]


@dataclasses.dataclass(frozen=True)
class StateField:
    """One declared per-key-group state column of a jit-tier operator.

    ``kind="scalar"``: one ``dtype`` cell per key group (counters,
    watermarks), materialized into the oracle state dict as
    ``{name: py(cell)}``.

    ``kind="table"``: a keyed accumulator — per key group a bounded table of
    ``(int64 code, dtype value)`` entries plus insertion sequence numbers,
    materialized as ``{name: {key_decode(code): float(value), ...}}`` in
    insertion order (the order the per-run oracle would have inserted them).
    ``key_encode``/``key_decode`` convert between the oracle's dict keys and
    the int64 codes the device table stores; codes must be unique per dict
    key and — because a table row belongs to one key group — equal codes
    must always hash to the same key group (keying the table by the
    operator's partition key guarantees this).  Capacity is managed by the
    runtime (power-of-two growth; a growth step is a recompile bucket).

    ``kind="vector"``: a bounded per-key-group ring of ``length`` ``dtype``
    cells plus an occupancy count (sliding windows), materialized as
    ``{name: [py(x) for x in cells[:count]]}`` oldest-first — exactly the
    list the per-run oracle keeps.
    """

    name: str
    kind: str = "scalar"
    dtype: object = np.int64
    init: object = 0
    py: Callable = int  # python scalar constructor used by to_dict
    key_encode: Optional[Callable[[object], int]] = None
    key_decode: Optional[Callable[[int], object]] = None
    length: int = 0  # vector kind: bounded window capacity

    def __post_init__(self) -> None:
        if self.kind not in ("scalar", "table", "vector"):
            raise ValueError(f"unknown StateField kind {self.kind!r}")
        if self.kind == "table" and (
            self.key_encode is None or self.key_decode is None
        ):
            raise ValueError(f"table field {self.name!r} needs key_encode/decode")
        if self.kind == "vector" and self.length <= 0:
            raise ValueError(f"vector field {self.name!r} needs length > 0")


@dataclasses.dataclass(frozen=True)
class StateSchema:
    """Declared array layout of a jit-tier operator's per-key-group state.

    Field order is the contract: it must match the order the per-run ``fn``
    first inserts the corresponding keys into its state dict, and every
    field must be written by ``fn`` for every processed run (the standard
    ``setdefault`` + update pattern satisfies both) — that is what lets the
    runtime materialize device columns back into dicts that are equal to the
    oracle's, including insertion order.
    """

    fields: tuple[StateField, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "fields", tuple(self.fields))
        names = [f.name for f in self.fields]
        if len(set(names)) != len(names):
            raise ValueError("duplicate StateSchema field names")


def _identity_key(k: object) -> object:
    return k


def _is_int_key(x: object) -> bool:
    """Keys eligible for the vectorized integer mix (bool excluded: its hash
    semantics follow Python's, and streams never key by bool)."""
    return type(x) is int or isinstance(x, np.integer)


# splitmix/murmur3-style 32-bit finisher over the 64→32 folded key.  Chosen
# 32-bit so the same mix runs on the TPU path (Pallas int32 lanes, see
# repro.kernels.keygroup_partition) and in numpy; the scalar and vectorized
# forms below are bit-identical by construction.
_MIX_C1 = 0x85EBCA6B
_MIX_C2 = 0xC2B2AE35
_MASK31 = 0x7FFFFFFF


def mix32_scalar(x: int) -> int:
    u = int(x) & 0xFFFFFFFFFFFFFFFF
    h = (u ^ (u >> 32)) & 0xFFFFFFFF
    h ^= h >> 16
    h = (h * _MIX_C1) & 0xFFFFFFFF
    h ^= h >> 13
    h = (h * _MIX_C2) & 0xFFFFFFFF
    h ^= h >> 16
    return h


import sys as _sys

_LITTLE_ENDIAN = _sys.byteorder == "little"


def mix32(x: np.ndarray) -> np.ndarray:
    """Vectorized :func:`mix32_scalar` over an integer array → uint32."""
    with np.errstate(over="ignore"):
        if (
            _LITTLE_ENDIAN
            and x.dtype in (np.dtype(np.int64), np.dtype(np.uint64))
            and x.flags.c_contiguous
        ):
            # (u ^ (u >> 32)) & 0xFFFFFFFF == lo ^ hi on uint32 lanes —
            # stays on 32-bit ops instead of widening to uint64.
            pair = x.view(np.uint32).reshape(-1, 2)
            h = pair[:, 0] ^ pair[:, 1]
        else:
            u = x.astype(np.uint64)
            h = ((u ^ (u >> np.uint64(32))) & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        h = h ^ (h >> np.uint32(16))
        h = h * np.uint32(_MIX_C1)
        h ^= h >> np.uint32(13)
        h = h * np.uint32(_MIX_C2)
        h ^= h >> np.uint32(16)
    return h


def hash_key(x: object) -> int:
    """31-bit partition hash of one key: integer mix for ints, `hash` else."""
    if _is_int_key(x):
        return mix32_scalar(x) & _MASK31
    return hash(x) & _MASK31


def _mixed_keygroups(h: np.ndarray, base: int, nkg: int) -> np.ndarray:
    """(mix32 output → global key-group ids), staying on uint32 lanes.

    Bit-identical to ``base + ((h & MASK31) % nkg)`` on int64: the masked
    value is non-negative, so the uint32 modulo (and the bitwise-and
    shortcut when nkg is a power of two) gives the same residues.
    """
    h = h & np.uint32(_MASK31)
    if nkg & (nkg - 1) == 0:  # power of two: mod is a mask
        loc = h & np.uint32(nkg - 1)
    else:
        loc = h % np.uint32(nkg)
    return loc.astype(np.int64) + base


@dataclasses.dataclass
class OperatorSpec:
    """One operator O_i.

    Attributes:
      name: unique id.
      fn: keyed state transition (None for sources; sources are driven by the
        engine's input feeder).
      num_keygroups: how many key groups this operator's input is split into.
      cost_per_tuple: load points charged per processed tuple (the measured
        CPU cost in the paper's statistics; calibrated per operator).
      key_fn: maps an input tuple key to the partitioning key (defaults to
        identity).  The engine hashes the result into a key group.
      key_by_value: optional — partition by a function of the tuple *value*
        instead (e.g. RouteDelay partitions extract's airplane-keyed tuples
        by (origin, dest)).  Takes precedence over key_fn.
      key_by_value_col: optional columnar form of ``key_by_value`` — applied
        to a whole schema-typed values array at once (field expressions like
        ``v["origin"] * na + v["dest"]`` vectorize over structured columns).
        Must return one partition key per tuple, elementwise identical to
        ``key_by_value``; ignored for untyped (object) batches.
      is_source / is_sink: role flags.
      schema: optional :class:`Schema` declaring the operator's *input* edge
        layout.  Schema-typed operators receive native structured value
        arrays (column views in ``fn_seg``); undeclared operators keep the
        object-array path behind the same API.
      out_schema: optional :class:`Schema` for the operator's *output* edge
        (sources forward their input, so their out schema is ``schema``).
        Validated against every downstream operator's declared input schema
        at construction time.
      jit_fusible: the author's claim that ``fn_jit`` is eligible for the
        fused device superstep: strictly 1:1 (``out_counts is None`` and one
        output per input tuple), state updates are pure per-run scatters
        (insensitive to run order and to empty runs), and — for
        non-terminal operators — ``out_schema`` is declared so the device
        can route outputs without a host conform step.  The superstep
        runtime additionally checks the structural conditions (linear
        chain, identity key_fn, integer keys, scalar-only state) and falls
        back to the per-operator jit tick when any fail.
      merge_state: optional — the author's declaration that the operator is
        **split-mergeable**: its per-key-group state transition is a
        commutative monoid over disjoint tuple subsets (processing a key
        group's tuples as several partial states, then folding them with
        ``merge_state(a, b) -> merged``, yields the same aggregate values
        the unsplit run would have produced), and its emitted tuples are
        *deltas* a downstream operator re-aggregates (so the merged
        downstream totals are identical no matter how the upstream tuples
        were partitioned).  Declaring it is what makes the operator
        eligible for hot-key splitting (``Engine.split_keygroup`` — a hot
        key group fans its tuples across replica key groups, partial-key-
        grouping style); the engine calls it at unsplit time to fold the
        replicas' σ back into the parent.  Exact-arithmetic payloads
        (ints) stay bit-exact under splitting; float running sums are
        reordered by construction — see docs/workloads.md.
      jit_key_map: optional host-evaluable key transform: the author's claim
        that ``fn_jit`` emits keys equal to ``jit_key_map(input_keys)``
        element-wise, in input order (pass ``lambda keys: keys`` for
        pass-through operators).  When every non-terminal fused operator
        declares one, the superstep scheduler can evaluate the whole routing
        schedule (hashes, stable radix permutations, per-edge count
        matrices) on the host ahead of the K-tick scan, leaving the scan
        body sort-free; chains with an undeclared map still fuse but sort
        on-device.  Must be wrap-consistent with the device body (numpy and
        jax integer arithmetic overflow identically, so plain column math
        qualifies).
    """

    name: str
    fn: Optional[OperatorFn]
    num_keygroups: int = 8
    cost_per_tuple: float = 1.0
    key_fn: Callable[[object], object] = _identity_key
    key_by_value: Optional[Callable[[object], object]] = None
    is_source: bool = False
    is_sink: bool = False
    fn_seg: Optional[SegmentFn] = None  # vectorized protocol (see SegmentFn)
    schema: Optional[Schema] = None
    out_schema: Optional[Schema] = None
    key_by_value_col: Optional[Callable[[np.ndarray], np.ndarray]] = None
    fn_jit: Optional[JitFn] = None  # compiled tier (see JitFn / jitexec)
    state_schema: Optional[StateSchema] = None
    jit_fusible: bool = False  # superstep-fusible fn_jit (see above)
    jit_key_map: Optional[Callable[[np.ndarray], np.ndarray]] = None
    merge_state: Optional[Callable[[dict, dict], dict]] = None  # split-mergeable


class Topology:
    """DAG of :class:`OperatorSpec` plus the global key-group index space.

    Key groups are numbered globally and contiguously per operator, so a
    single allocation vector covers the whole job (matching
    :class:`repro.core.stats.ClusterState`).
    """

    def __init__(self) -> None:
        self.operators: list[OperatorSpec] = []
        self.edges: list[tuple[int, int]] = []
        self._name_to_id: dict[str, int] = {}
        self._kg_base: Optional[np.ndarray] = None  # cached prefix sums

    # -- construction --------------------------------------------------------
    def add_operator(self, spec: OperatorSpec) -> int:
        if spec.name in self._name_to_id:
            raise ValueError(f"duplicate operator {spec.name!r}")
        oid = len(self.operators)
        self.operators.append(spec)
        self._name_to_id[spec.name] = oid
        self._kg_base = None
        return oid

    def connect(self, src: str | int, dst: str | int) -> None:
        s = self._resolve(src)
        d = self._resolve(dst)
        self.edges.append((s, d))

    def _resolve(self, ref: str | int) -> int:
        return ref if isinstance(ref, int) else self._name_to_id[ref]

    # -- derived -------------------------------------------------------------
    @property
    def num_operators(self) -> int:
        return len(self.operators)

    @property
    def num_keygroups(self) -> int:
        return sum(o.num_keygroups for o in self.operators)

    def kg_base_table(self) -> np.ndarray:
        """(num_operators + 1,) prefix sums: kg id space start per operator."""
        if self._kg_base is None or len(self._kg_base) != self.num_operators + 1:
            sizes = np.fromiter(
                (o.num_keygroups for o in self.operators),
                dtype=np.int64,
                count=self.num_operators,
            )
            self._kg_base = np.concatenate(
                [np.zeros(1, dtype=np.int64), np.cumsum(sizes)]
            )
        return self._kg_base

    def kg_base(self, op: int) -> int:
        return int(self.kg_base_table()[op])

    def kg_operator(self) -> np.ndarray:
        return np.concatenate(
            [
                np.full(o.num_keygroups, i, dtype=np.int64)
                for i, o in enumerate(self.operators)
            ]
        )

    def downstream(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {i: [] for i in range(self.num_operators)}
        for s, d in self.edges:
            out[s].append(d)
        return out

    def upstream(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {i: [] for i in range(self.num_operators)}
        for s, d in self.edges:
            out[d].append(s)
        return out

    def topo_order(self) -> list[int]:
        indeg = [0] * self.num_operators
        for _, d in self.edges:
            indeg[d] += 1
        order, stack = [], [i for i, v in enumerate(indeg) if v == 0]
        while stack:
            u = stack.pop()
            order.append(u)
            for s, d in self.edges:
                if s == u:
                    indeg[d] -= 1
                    if indeg[d] == 0:
                        stack.append(d)
        if len(order) != self.num_operators:
            raise ValueError("topology has a cycle")
        return order

    def keygroup_of(self, op: int, key: object, value: object = None) -> int:
        """Hash-partition a tuple into one of the operator's key groups."""
        spec = self.operators[op]
        part_key = (
            spec.key_by_value(value)
            if (spec.key_by_value is not None and value is not None)
            else spec.key_fn(key)
        )
        return self.kg_base(op) + (hash_key(part_key) % spec.num_keygroups)

    def keygroups_of(self, op: int, keys: np.ndarray, values: np.ndarray) -> np.ndarray:
        """Batched :meth:`keygroup_of`: key-group id per tuple, as int64.

        Integer partition keys take a fully vectorized path (the same 32-bit
        mix the TPU kernel uses); object keys (strings, tuples) fall back to
        per-object :func:`hash_key`.  Bit-identical to the scalar method.

        Integer-ness of extracted partition keys is probed with one C-level
        ``np.asarray`` instead of a per-element python scan: a list that
        coerces to an integer dtype is all-int (an all-bool list coerces to
        bool and falls through to the hash path, matching the scalar method;
        partition keys must not *mix* bools with ints — no job does, bools
        are not keys).
        """
        spec = self.operators[op]
        n = len(keys)
        base = self.kg_base(op)
        nkg = spec.num_keygroups
        if (
            spec.key_by_value_col is not None
            and isinstance(values, np.ndarray)
            and values.dtype.names is not None
        ):
            # Schema-typed batch with a columnar key expression: the whole
            # partition-key vector is field arithmetic — no per-tuple python,
            # no object array, straight into the vectorized mix.
            part = spec.key_by_value_col(values)
            if (
                isinstance(part, np.ndarray)
                and part.shape == (n,)
                and part.dtype.kind in "iu"
            ):
                return _mixed_keygroups(mix32(part), base, nkg)
            raise TypeError(
                f"key_by_value_col of operator {spec.name!r} must return an "
                f"integer array of length {n}, got {type(part).__name__}"
            )
        if spec.key_by_value is not None:
            # Match the scalar path: a None value falls back to key_fn(key).
            # Object arrays iterate faster as lists (no per-element boxing).
            kbv, kfn = spec.key_by_value, spec.key_fn
            vlist = values.tolist() if isinstance(values, np.ndarray) else values
            part = [kbv(v) if v is not None else kfn(k) for k, v in zip(keys, vlist)]
        elif spec.key_fn is not _identity_key:
            kfn = spec.key_fn
            part = [kfn(k) for k in keys]
        else:
            part = keys
        if isinstance(part, np.ndarray) and part.dtype.kind in "iu":
            return _mixed_keygroups(mix32(part), base, nkg)
        if isinstance(part, list):
            try:
                arr = np.asarray(part)
            except (OverflowError, ValueError, TypeError):
                arr = None  # out-of-int64 or ragged entries → hash path
            # ndim check: tuple keys coerce to a 2-D array — those hash.
            if arr is not None and arr.ndim == 1 and arr.dtype.kind in "iu":
                # mix32 folds int64 two's complement exactly like the scalar
                # ``int(x) & 0xFFFFFFFFFFFFFFFF``.
                return _mixed_keygroups(mix32(arr), base, nkg)
        h = np.fromiter((hash_key(x) for x in part), dtype=np.int64, count=n)
        return base + h % nkg

    def out_schema_of(self, op: int) -> Optional[Schema]:
        """Effective output schema of an operator (sources forward input)."""
        spec = self.operators[op]
        return spec.schema if spec.fn is None else spec.out_schema

    def validate(self) -> None:
        self.topo_order()  # raises on cycles
        downs = self.downstream()
        for i, o in enumerate(self.operators):
            if o.is_sink and downs[i]:
                raise ValueError(f"sink {o.name!r} has downstream edges")
            if not o.is_source and o.fn is None:
                # This also guarantees every fn_seg operator has the per-run
                # fn the engine falls back to on non-contiguous segments.
                raise ValueError(f"non-source {o.name!r} lacks fn")
            if o.fn_seg is not None and o.is_source:
                raise ValueError(
                    f"source {o.name!r} cannot have fn_seg — sources are "
                    "pass-through; the engine forwards their batches directly"
                )
            if o.key_by_value_col is not None and o.key_by_value is None:
                raise ValueError(
                    f"{o.name!r} declares key_by_value_col without the scalar "
                    "key_by_value it must be elementwise identical to"
                )
            if o.fn_jit is not None:
                if o.is_source:
                    raise ValueError(f"source {o.name!r} cannot have fn_jit")
                if o.schema is None:
                    raise ValueError(
                        f"{o.name!r} declares fn_jit without a Schema — the "
                        "jit tier operates on native column arrays only"
                    )
            if o.state_schema is not None and o.fn_jit is None:
                raise ValueError(
                    f"{o.name!r} declares a StateSchema without fn_jit"
                )
            if o.merge_state is not None and o.fn is None:
                raise ValueError(
                    f"source {o.name!r} cannot declare merge_state — sources "
                    "hold no per-key-group state to split"
                )
        # Schema mismatch across an edge is a construction-time error, not a
        # runtime surprise.  A declared consumer accepts either (a) producers
        # declaring the *same* schema (the fully typed edge) or (b) undeclared
        # producers — the gradual-typing boundary, where the engine coerces
        # object batches into the declared layout at routing time.  A typed
        # producer feeding an undeclared consumer decays to the object path.
        for s, d in self.edges:
            want = self.operators[d].schema
            have = self.out_schema_of(s)
            if want is not None and have is not None and have != want:
                raise ValueError(
                    f"schema mismatch on edge {self.operators[s].name!r} -> "
                    f"{self.operators[d].name!r}: producer emits {have.value} "
                    f"(key {have.key}), consumer declares {want.value} "
                    f"(key {want.key})"
                )

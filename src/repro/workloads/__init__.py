"""Skew workload subsystem: deterministic scenario streams (see scenarios.py
for the composition model and the determinism contract, docs/workloads.md
for the authoring guide)."""

from repro.workloads.scenarios import (
    GRID_SCENARIOS,
    SCENARIO_DTYPE,
    Churn,
    Diurnal,
    FlashCrowd,
    ScenarioSpec,
    drive_scenario,
    make_scenario,
    scenario_batches,
    scenario_schema,
    scenario_stream,
)

__all__ = [
    "GRID_SCENARIOS",
    "SCENARIO_DTYPE",
    "Churn",
    "Diurnal",
    "FlashCrowd",
    "ScenarioSpec",
    "drive_scenario",
    "make_scenario",
    "scenario_batches",
    "scenario_schema",
    "scenario_stream",
]

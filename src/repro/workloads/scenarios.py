"""Skew workload scenarios: deterministic, seed-threaded stream generation.

The paper's comparative claims (ALBIC/MILP vs COLA/Flux/PoTC) only
differentiate on *skewed, drifting* workloads — power-law key popularity,
flash crowds, diurnal traffic, key churn ("Parallel Stream Processing
Against Workload Skewness and Variance", AutoFlow).  This module generates
exactly those shapes as batched (keys, values, ts) streams, composable per
scenario through one small :class:`ScenarioSpec` value.

The composition model is a per-tick **weight vector** over a fixed key
space: a base Zipf/power-law popularity pmf, multiplied elementwise by the
flash-crowd boost (step or ramp on the top-ranked hot-key set), the diurnal
cohort multipliers (phase-shifted sinusoids over key cohorts, so the hot
cohort *rotates* instead of the whole stream merely breathing), and the
churn liveness mask (each key alive for ``lifetime_ticks`` out of every
``2·lifetime_ticks``, phases randomized once per stream).  The tick's
arrival count is Poisson with mean ``rate × Σw(t)`` — a flash crowd adds
traffic, it does not just reshape it — and keys are drawn from the
normalized weights.

Determinism contract: a scenario stream is a pure function of its spec.
All randomness flows from one ``np.random.default_rng(spec.seed)`` created
at stream start and consumed in a fixed order, so two streams built from
equal specs are **byte-identical** tick by tick (pinned by the hypothesis
property test in ``tests/test_workloads.py``), and any seed change reshapes
the whole stream.  Nothing here reads global RNG state or wall-clock time.

Batches are schema-typed: values are native :data:`SCENARIO_DTYPE`
structured arrays (the :mod:`repro.data.synthetic` idiom), so a source
declaring :func:`scenario_schema` ingests them without boxing; untyped
sources receive the identical record tuples via the object path.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.engine.topology import Batch, Schema

# Record layout of scenario tuples: the partition-key entity plus a float
# payload operators can aggregate (weights are part of the determinism
# contract — they are drawn from the stream's rng like everything else).
SCENARIO_DTYPE = np.dtype([("entity", "i8"), ("weight", "f8")])


def scenario_schema() -> Schema:
    """The ingestion :class:`~repro.engine.topology.Schema` for scenario
    streams (declare it on the source operator for boxing-free ingestion)."""
    return Schema(value=SCENARIO_DTYPE, key=np.dtype(np.int64))


@dataclasses.dataclass(frozen=True)
class FlashCrowd:
    """A surge of the ``hot_keys`` most popular keys.

    The hot set's popularity mass is multiplied by ``1 + (boost−1)·f(t)``
    where ``f`` rises from 0 to 1 starting at ``at_tick`` — as a step when
    ``ramp_ticks == 0``, linearly over ``ramp_ticks`` otherwise — holds 1
    for ``duration`` ticks (forever when None), then steps back to 0.
    Because weights are unnormalized, the surge raises the total arrival
    rate too, like a real crowd.
    """

    at_tick: int = 0
    hot_keys: int = 2
    boost: float = 16.0
    ramp_ticks: int = 0
    duration: Optional[int] = None

    def factor(self, tick: int) -> float:
        dt = tick - self.at_tick
        if dt < 0:
            return 0.0
        if self.duration is not None and dt >= max(self.ramp_ticks, 0) + self.duration:
            return 0.0
        if self.ramp_ticks > 0 and dt < self.ramp_ticks:
            return dt / self.ramp_ticks
        return 1.0


@dataclasses.dataclass(frozen=True)
class Diurnal:
    """Sinusoidal rate modulation with phase-shifted key cohorts.

    Key rank ``r`` belongs to cohort ``r % cohorts``; cohort ``c``'s weight
    is multiplied by ``1 + amplitude·sin(2π·t/period + 2π·c/cohorts)``
    (clipped at 0).  With one cohort the stream merely breathes; with
    several, popularity *drifts* — the hot cohort rotates once per period,
    the workload shape migration has to chase.
    """

    period_ticks: float = 200.0
    amplitude: float = 0.6
    cohorts: int = 4

    def multipliers(self, tick: int) -> np.ndarray:
        phase = 2.0 * np.pi * np.arange(self.cohorts) / self.cohorts
        wave = np.sin(2.0 * np.pi * tick / self.period_ticks + phase)
        return np.maximum(1.0 + self.amplitude * wave, 0.0)


@dataclasses.dataclass(frozen=True)
class Churn:
    """Birth/death of keys: each key alive ``lifetime_ticks`` out of every
    ``2·lifetime_ticks``, with per-key phases drawn once at stream start —
    so roughly half the key space is alive at any tick and the alive set
    turns over completely every lifetime."""

    lifetime_ticks: int = 64


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One composable skew scenario (see the module docstring).

    Attributes:
      name: label (benchmark row names, registry key).
      rate: mean tuples per tick at weight-sum 1 (Poisson).
      key_space: number of distinct keys.
      zipf_a: power-law exponent of the base popularity pmf
        (``p(rank) ∝ (rank+1)^-zipf_a``); 0 → uniform.
      flash / diurnal / churn: optional modulation components.
      seed: the single root seed every draw derives from.
    """

    name: str = "zipf"
    rate: float = 512.0
    key_space: int = 4096
    zipf_a: float = 1.2
    flash: Optional[FlashCrowd] = None
    diurnal: Optional[Diurnal] = None
    churn: Optional[Churn] = None
    seed: int = 0

    def __post_init__(self) -> None:
        if self.key_space < 1:
            raise ValueError("key_space must be >= 1")
        if self.rate < 0:
            raise ValueError("rate must be >= 0")
        if self.zipf_a < 0:
            raise ValueError("zipf_a must be >= 0 (0 = uniform)")


def _base_pmf(spec: ScenarioSpec) -> np.ndarray:
    ranks = np.arange(1, spec.key_space + 1, dtype=np.float64)
    p = ranks ** (-spec.zipf_a) if spec.zipf_a > 0 else np.ones_like(ranks)
    return p / p.sum()


def scenario_stream(spec: ScenarioSpec) -> Iterator[Batch]:
    """Infinite per-tick batch iterator for one scenario.

    Yields ``(keys, values, ts)`` with int64 keys, :data:`SCENARIO_DTYPE`
    values and a constant-per-tick float64 ``ts``; ticks may be empty
    (zero-length arrays) when the Poisson draw is 0.
    """
    rng = np.random.default_rng(spec.seed)
    p = _base_pmf(spec)
    # Rank → key id: a seeded shuffle so popularity rank and key identity
    # (hence key-group placement) are decoupled.
    perm = rng.permutation(spec.key_space).astype(np.int64)
    cohort = (
        np.arange(spec.key_space) % spec.diurnal.cohorts
        if spec.diurnal is not None
        else None
    )
    churn_phase = (
        rng.integers(0, 2 * spec.churn.lifetime_ticks, size=spec.key_space)
        if spec.churn is not None
        else None
    )
    tick = 0
    while True:
        w = p
        if spec.flash is not None:
            f = spec.flash.factor(tick)
            if f > 0.0:
                w = w.copy()
                w[: spec.flash.hot_keys] *= 1.0 + (spec.flash.boost - 1.0) * f
        if spec.diurnal is not None:
            w = w * spec.diurnal.multipliers(tick)[cohort]
        if spec.churn is not None:
            L = spec.churn.lifetime_ticks
            alive = (tick + churn_phase) % (2 * L) < L
            if not alive.any():  # never emit from an all-dead key space
                alive = np.ones(spec.key_space, dtype=bool)
            w = np.where(alive, w, 0.0)
        total = float(w.sum())
        n = int(rng.poisson(spec.rate * total))
        ranks = rng.choice(spec.key_space, size=n, p=w / total)
        keys = perm[ranks]
        values = np.empty(n, dtype=SCENARIO_DTYPE)
        values["entity"] = keys
        values["weight"] = rng.exponential(1.0, size=n)
        yield keys, values, np.full(n, float(tick))
        tick += 1


def scenario_batches(spec: ScenarioSpec, ticks: int) -> list[Batch]:
    """The first ``ticks`` batches of :func:`scenario_stream`, materialized
    (the shape ``Engine.run_supersteps`` / ``ClusterEngine.run_stream``
    consume)."""
    stream = scenario_stream(spec)
    return [next(stream) for _ in range(ticks)]


def drive_scenario(engine, source, spec: ScenarioSpec, ticks: int) -> int:
    """Feed a scenario into a live engine: one ``push_source`` + ``tick``
    per generated batch (works on every execution tier — the engine's
    ingestion edge handles typed and untyped sources alike).  Returns the
    number of tuples accepted past the backpressure gate."""
    accepted = 0
    for keys, values, ts in scenario_batches(spec, ticks):
        if len(keys):
            accepted += engine.push_source(source, keys, values, ts)
        engine.tick()
    return accepted


# -- named scenario grid -------------------------------------------------------
def make_scenario(
    name: str,
    *,
    rate: float = 512.0,
    key_space: int = 4096,
    seed: int = 0,
) -> ScenarioSpec:
    """The four canonical grid scenarios (``benchmarks/skew_grid.py``).

    ``zipf``: stationary power-law popularity (a = 1.2).
    ``flash_crowd``: mild zipf plus a 16× step surge of the top 2 keys.
    ``diurnal``: four phase-shifted cohorts, ±60% sinusoidal swing.
    ``churn``: zipf popularity over a key space turning over every 64 ticks.
    """
    if name == "zipf":
        return ScenarioSpec(
            name=name, rate=rate, key_space=key_space, zipf_a=1.2, seed=seed
        )
    if name == "flash_crowd":
        return ScenarioSpec(
            name=name,
            rate=rate,
            key_space=key_space,
            zipf_a=0.8,
            flash=FlashCrowd(at_tick=16, hot_keys=2, boost=16.0, ramp_ticks=0),
            seed=seed,
        )
    if name == "flash_ramp":
        return ScenarioSpec(
            name=name,
            rate=rate,
            key_space=key_space,
            zipf_a=0.8,
            flash=FlashCrowd(at_tick=16, hot_keys=2, boost=16.0, ramp_ticks=24),
            seed=seed,
        )
    if name == "diurnal":
        return ScenarioSpec(
            name=name,
            rate=rate,
            key_space=key_space,
            zipf_a=1.0,
            diurnal=Diurnal(period_ticks=48.0, amplitude=0.6, cohorts=4),
            seed=seed,
        )
    if name == "churn":
        return ScenarioSpec(
            name=name,
            rate=rate,
            key_space=key_space,
            zipf_a=1.2,
            churn=Churn(lifetime_ticks=64),
            seed=seed,
        )
    raise ValueError(f"unknown scenario {name!r} (see make_scenario docstring)")


#: The canonical grid, in benchmark row order.
GRID_SCENARIOS = ("zipf", "flash_crowd", "diurnal", "churn")

"""Optimizer substrate: AdamW with ZeRO-shardable state, LR schedules, and
gradient compression utilities for slow (cross-pod) links."""

from repro.optim.adamw import AdamW, AdamWState
from repro.optim.schedule import cosine_schedule, linear_warmup
from repro.optim.compress import compress_int8, decompress_int8

__all__ = [
    "AdamW",
    "AdamWState",
    "cosine_schedule",
    "linear_warmup",
    "compress_int8",
    "decompress_int8",
]

"""Gradient compression for slow links (the cross-pod axis).

Per-tensor symmetric int8 quantization with an fp32 scale: 4× fewer bytes on
the wire for the pod-axis gradient all-reduce.  Used by launch/train.py via a
``shard_map`` wrapper: reduce-scatter in int8 over ``pod``, dequantize,
finish the reduction in fp32 locally (error stays bounded because the pod
axis is only 2–8 wide; the data-axis reduction stays full precision).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce over ``axis_name`` with int8 payload (inside shard_map).

    Quantize → psum int32 (exact for int8 summands across ≤ 2^23 shards) →
    rescale by the max scale psum'd alongside.  The scale max makes the
    quantization grid shared, bounding the error to one grid step.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale

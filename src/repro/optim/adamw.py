"""AdamW with fp32 state over (possibly bf16) params.

State layout mirrors the parameter pytree, so the same logical-axis sharding
rules apply — under the production mesh the m/v moments are FSDP-sharded over
the ``data`` axis exactly like the parameters (ZeRO).  The update is pure
jnp; XLA fuses it into the backward pass.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params: Any) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def _lr(self, step: jax.Array) -> jax.Array:
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def global_norm(self, grads: Any) -> jax.Array:
        sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads)
        return jnp.sqrt(sum(jax.tree.leaves(sq)))

    def update(self, grads: Any, state: AdamWState, params: Any) -> tuple[
        Any,
        AdamWState,
    ]:
        step = state.step + 1
        lr = self._lr(step)
        gnorm = self.global_norm(grads)
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m_new = self.b1 * m + (1 - self.b1) * g
            v_new = self.b2 * v + (1 - self.b2) * jnp.square(g)
            m_hat = m_new / (1 - self.b1 ** step.astype(jnp.float32))
            v_hat = v_new / (1 - self.b2 ** step.astype(jnp.float32))
            delta = m_hat / (jnp.sqrt(v_hat) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (-lr * delta).astype(jnp.float32), m_new, v_new

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return updates, AdamWState(step=step, m=new_m, v=new_v)

"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.

RoPE + GQA.  [hf:THUDM/glm-4-9b; hf]
"""

from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    pattern=(ATTN,),
    cycles=40,
    mlp_kind="swiglu",
    rope_kind="rope",
)

SMOKE = ModelConfig(
    name="glm4-9b-smoke",
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=352,
    vocab_size=512,
    pattern=(ATTN,),
    cycles=2,
    mlp_kind="swiglu",
    rope_kind="rope",
    max_seq_len=512,
)

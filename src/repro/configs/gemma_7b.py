"""gemma-7b [dense] — 28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000.

GeGLU, head_dim=256 (MQA on the 2b sibling).  [arXiv:2403.08295; hf]
"""

from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    pattern=(ATTN,),
    cycles=28,
    head_dim=256,
    mlp_kind="geglu",
    rope_kind="rope",
    tie_embeddings=True,
    logits_softcap=30.0,
)

SMOKE = ModelConfig(
    name="gemma-7b-smoke",
    d_model=96,
    num_heads=4,
    num_kv_heads=4,
    d_ff=384,
    vocab_size=512,
    pattern=(ATTN,),
    cycles=2,
    head_dim=32,
    mlp_kind="geglu",
    rope_kind="rope",
    tie_embeddings=True,
    logits_softcap=30.0,
    max_seq_len=512,
)

"""llama3.2-3b [dense] — 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.

Small llama3.  [hf:meta-llama/Llama-3.2-1B; unverified]
"""

from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    pattern=(ATTN,),
    cycles=28,
    mlp_kind="swiglu",
    rope_kind="rope",
    rope_theta=500_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-3b-smoke",
    d_model=96,
    num_heads=6,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    pattern=(ATTN,),
    cycles=2,
    mlp_kind="swiglu",
    rope_kind="rope",
    rope_theta=500_000.0,
    tie_embeddings=True,
    max_seq_len=512,
)

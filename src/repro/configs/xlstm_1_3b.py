"""xlstm-1.3b [ssm] — 48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304.

sLSTM + mLSTM blocks at the xLSTM[7:1] ratio: 48 = 6 × (7 mLSTM + 1 sLSTM).
d_ff=0: blocks carry their own up/down projections (no separate FFN).
Constant-size matrix memory → long_500k runs.  [arXiv:2405.04517; unverified]
"""

from repro.configs.base import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=(MLSTM,) * 7 + (SLSTM,),
    cycles=6,
    mlp_kind="gelu",
    rope_kind="none",
    norm_kind="layernorm",
)

SMOKE = ModelConfig(
    name="xlstm-1.3b-smoke",
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    pattern=(MLSTM, MLSTM, MLSTM, SLSTM),
    cycles=1,
    mlp_kind="gelu",
    rope_kind="none",
    norm_kind="layernorm",
    max_seq_len=512,
)

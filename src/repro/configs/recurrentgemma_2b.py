"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000.  RG-LRU + local attention, 1 attention per 3 blocks (2:1).
[arXiv:2402.19427; hf]

26 layers = 8 × (rglru, rglru, local_attn) + (rglru, rglru) remainder.
Sub-quadratic: local window 2048 + O(1) recurrent state → long_500k runs.
"""

from repro.configs.base import LOCAL_ATTN, RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    cycles=8,
    remainder=(RGLRU, RGLRU),
    head_dim=256,
    mlp_kind="geglu",
    rope_kind="rope",
    local_window=2048,
    lru_width=2560,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke",
    d_model=96,
    num_heads=2,
    num_kv_heads=1,
    d_ff=256,
    vocab_size=512,
    pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    cycles=1,
    remainder=(RGLRU, RGLRU),
    head_dim=48,
    mlp_kind="geglu",
    rope_kind="rope",
    local_window=64,
    lru_width=96,
    tie_embeddings=True,
    max_seq_len=512,
)

"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64 experts top-6 (kimi/moonlight).
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""

from repro.configs.base import ATTN_MOE, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    pattern=(ATTN_MOE,),
    cycles=48,
    mlp_kind="swiglu",
    rope_kind="rope",
    moe=MoEConfig(num_experts=64, top_k=6),
)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke",
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=96,
    vocab_size=512,
    pattern=(ATTN_MOE,),
    cycles=2,
    mlp_kind="swiglu",
    rope_kind="rope",
    moe=MoEConfig(num_experts=8, top_k=2),
    max_seq_len=512,
)

"""whisper-small [audio] — 12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865.

Encoder–decoder; the conv frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings.
[arXiv:2212.04356; unverified]
"""

from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    pattern=(ATTN,),
    cycles=12,  # decoder layers
    encoder_layers=12,
    encoder_is_input_embeds=True,
    mlp_kind="gelu",
    rope_kind="learned",
    norm_kind="layernorm",
    max_seq_len=448,
)

SMOKE = ModelConfig(
    name="whisper-small-smoke",
    d_model=96,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    pattern=(ATTN,),
    cycles=2,
    encoder_layers=2,
    encoder_is_input_embeds=True,
    mlp_kind="gelu",
    rope_kind="learned",
    norm_kind="layernorm",
    max_seq_len=448,
)

"""Architecture registry: one module per assigned architecture."""

from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeSpec,
    all_configs,
    canon,
    get_config,
    input_specs,
    shape_applicable,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "MoEConfig",
    "ShapeSpec",
    "all_configs",
    "canon",
    "get_config",
    "input_specs",
    "shape_applicable",
]

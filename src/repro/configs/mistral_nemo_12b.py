"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k ctx, head_dim=128.  [hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""

from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    pattern=(ATTN,),
    cycles=40,
    head_dim=128,
    mlp_kind="swiglu",
    rope_kind="rope",
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
)

SMOKE = ModelConfig(
    name="mistral-nemo-12b-smoke",
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=384,
    vocab_size=512,
    pattern=(ATTN,),
    cycles=2,
    head_dim=32,
    mlp_kind="swiglu",
    rope_kind="rope",
    rope_theta=1_000_000.0,
    max_seq_len=512,
)

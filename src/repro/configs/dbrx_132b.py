"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained.  [hf:databricks/dbrx-base; unverified]
"""

from repro.configs.base import ATTN_MOE, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    pattern=(ATTN_MOE,),
    cycles=40,
    mlp_kind="swiglu",
    rope_kind="rope",
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=4),
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke",
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    pattern=(ATTN_MOE,),
    cycles=2,
    mlp_kind="swiglu",
    rope_kind="rope",
    moe=MoEConfig(num_experts=4, top_k=2),
    max_seq_len=512,
)

"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE + dynamic resolution; the vision frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed patch embeddings.
[arXiv:2409.12191; hf]
"""

from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    pattern=(ATTN,),
    cycles=28,
    mlp_kind="swiglu",
    rope_kind="mrope",
    rope_theta=1_000_000.0,
    decoder_only_inputs_embeds=True,
)

SMOKE = ModelConfig(
    name="qwen2-vl-7b-smoke",
    d_model=112,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    pattern=(ATTN,),
    cycles=2,
    mlp_kind="swiglu",
    rope_kind="mrope",
    decoder_only_inputs_embeds=True,
    max_seq_len=512,
)

"""Model/shape configuration and the architecture registry.

Every assigned architecture provides ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (the exact published config) and ``SMOKE`` (a reduced same-family
config for CPU smoke tests).  ``input_specs`` builds ShapeDtypeStruct
stand-ins for the four assigned input shapes — weak-type-correct, shardable,
and allocation-free, exactly what the multi-pod dry-run lowers against.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax.numpy as jnp
from jax import ShapeDtypeStruct

# ---------------------------------------------------------------------------
# Block kinds assembled by repro.models.transformer
# ---------------------------------------------------------------------------
ATTN = "attn"  # GQA attention + MLP
ATTN_MOE = "attn_moe"  # GQA attention + MoE FFN
RGLRU = "rglru"  # RecurrentGemma RG-LRU block (conv + gated linear recurrence)
LOCAL_ATTN = "local_attn"  # windowed attention + MLP
MLSTM = "mlstm"  # xLSTM matrix-memory block
SLSTM = "slstm"  # xLSTM scalar-memory block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  ``pattern`` × ``cycles`` (+ ``remainder``) = layers."""

    name: str
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: tuple[str, ...]  # block kinds in one repeating cycle
    cycles: int  # lax.scan length
    remainder: tuple[str, ...] = ()  # trailing blocks outside the scan
    head_dim: Optional[int] = None  # defaults to d_model // num_heads
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu
    rope_kind: str = "rope"  # rope | mrope | none | learned
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    local_window: int = 2048  # for LOCAL_ATTN blocks
    lru_width: Optional[int] = None  # RG-LRU recurrence width
    # Encoder–decoder (whisper): encoder layer count; 0 → decoder-only.
    encoder_layers: int = 0
    encoder_is_input_embeds: bool = False  # frontend stub feeds embeddings
    decoder_only_inputs_embeds: bool = False  # VLM stub: embeddings, not ids
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    logits_softcap: float = 0.0
    tie_embeddings: bool = False
    max_seq_len: int = 131_072
    dtype: str = "bfloat16"
    # Training-time policies (perf levers for §Perf iterations).
    remat: str = "full"  # full | none | dots
    scan_layers: bool = True
    full_attn_max_seq: int = 8192  # above this, chunked (flash-style) attention

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.cycles + len(self.remainder)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """True when no block attends to unbounded context (long_500k eligible)."""
        kinds = set(self.pattern) | set(self.remainder)
        return ATTN not in kinds and ATTN_MOE not in kinds

    def param_count(self) -> int:
        """Approximate parameter count (reported in EXPERIMENTS.md)."""
        d, hd = self.d_model, self.resolved_head_dim
        qo = d * self.num_heads * hd * 2
        kv = d * self.num_kv_heads * hd * 2
        n_mlp_mats = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        total = 0
        for kind in self.pattern * self.cycles + self.remainder:
            if kind in (ATTN, LOCAL_ATTN):
                total += qo + kv + n_mlp_mats * d * self.d_ff + 2 * d
            elif kind == ATTN_MOE:
                assert self.moe is not None
                total += qo + kv + d * self.moe.num_experts
                total += self.moe.num_experts * n_mlp_mats * d * self.d_ff + 2 * d
            elif kind == RGLRU:
                w = self.lru_width or d
                total += 2 * d * w + w * 4 + w * d + n_mlp_mats * d * self.d_ff + 2 * d
            elif kind == MLSTM:
                total += qo + kv + 2 * d * 2 * d + 3 * d + 2 * d
            elif kind == SLSTM:
                total += 4 * d * d + 4 * d + 2 * d
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.is_encdec:
            total += self.encoder_layers * (
                qo + kv + n_mlp_mats * d * self.d_ff + 2 * d
            )
            # decoder cross-attention
            total += self.num_layers * (qo + kv)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        n_mlp_mats = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        expert_mats = (
            self.num_layers
            * self.moe.num_experts
            * n_mlp_mats
            * self.d_model
            * self.d_ff
        )
        active_mats = (
            self.num_layers * self.moe.top_k * n_mlp_mats * self.d_model * self.d_ff
        )
        return full - expert_mats + active_mats


# ---------------------------------------------------------------------------
# Assigned input shapes (identical for all LM archs per the assignment)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this (arch, shape).

    No device allocation happens here; the dry-run lowers against these.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.is_encdec:
            # Audio frontend stub: precomputed frame embeddings (paper-assigned
            # modality stub), decoder tokens + labels.
            return {
                "encoder_embeds": ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": ShapeDtypeStruct((b, min(s, 448)), i32),
                "labels": ShapeDtypeStruct((b, min(s, 448)), i32),
            }
        if cfg.decoder_only_inputs_embeds:
            # VLM stub: patch embeddings prepended is folded into embeds input.
            return {
                "inputs_embeds": ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
                "labels": ShapeDtypeStruct((b, s), i32),
            }
        return {
            "tokens": ShapeDtypeStruct((b, s), i32),
            "labels": ShapeDtypeStruct((b, s), i32),
        }
    if shape.kind == "prefill":
        if cfg.is_encdec:
            return {
                "encoder_embeds": ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": ShapeDtypeStruct((b, min(s, 448)), i32),
            }
        if cfg.decoder_only_inputs_embeds:
            return {
                "inputs_embeds": ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
            }
        return {"tokens": ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a seq_len-deep cache (built by the caller
    # via kvcache.cache_specs); here only the step inputs.
    return {
        "tokens": ShapeDtypeStruct((b, 1), i32),
        "positions": ShapeDtypeStruct((b,), i32),
    }


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "glm4_9b",
    "llama3_2_3b",
    "mistral_nemo_12b",
    "gemma_7b",
    "dbrx_132b",
    "moonshot_v1_16b_a3b",
    "recurrentgemma_2b",
    "whisper_small",
    "qwen2_vl_7b",
    "xlstm_1_3b",
)

# CLI ids use dashes; module names use underscores.
def canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, smoke=smoke) for a in ARCH_IDS}

"""Data substrate: paper-dataset-shaped stream generators, the paper's real
jobs 1–4 as topologies, and the sharded token pipeline for the LM workloads."""

from repro.data.synthetic import (
    airline_stream,
    weather_stream,
    wiki_edit_stream,
)
from repro.data.jobs import real_job_1, real_job_2, real_job_3, real_job_4

__all__ = [
    "airline_stream",
    "weather_stream",
    "wiki_edit_stream",
    "real_job_1",
    "real_job_2",
    "real_job_3",
    "real_job_4",
]

"""Stream generators shaped like the paper's three datasets (§5 "Datasets").

The container is offline, so the Wikipedia edit history, Airline On-Time and
NOAA GSOD datasets are reproduced *distributionally*: heavy-tailed entity
popularity (Zipf — Wikipedia article edits famously follow one), diurnal rate
fluctuation, and the attribute schemas the paper's jobs consume.  Each
generator yields (keys, values, ts) batches suitable for
:meth:`repro.engine.Engine.push_source`.

Values are emitted as **native structured arrays** (the declared ingestion
schema's dtype), generated column-wise: the whole batch is one C-level
assembly, so ``push_source`` on a schema-typed source passes the buffer
straight through — no per-tuple record boxing anywhere on the ingestion
edge (the last boxed boundary the ROADMAP named).  Untyped consumers are
unaffected: a structured array ``tolist()``s to the identical record
tuples the old per-tuple generators produced.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class StreamSpec:
    rate: float = 200.0  # tuples per tick (paper: hundreds/s, scaled)
    fluctuation: float = 0.3  # relative amplitude of the rate wave
    period_ticks: float = 200.0
    seed: int = 0


def _rate_at(spec: StreamSpec, tick: int, rng: np.random.Generator) -> int:
    wave = 1.0 + spec.fluctuation * np.sin(2 * np.pi * tick / spec.period_ticks)
    lam = max(spec.rate * wave, 0.0)
    return int(rng.poisson(lam))


# Wikipedia revision record layout: tuples in the ``W_*`` positional order
# below, with the matching structured dtype for schema-typed ingestion.
W_ARTICLE, W_EDITOR, W_BYTES, W_MINOR = range(4)
WIKI_DTYPE = np.dtype(
    [("article", "i8"), ("editor", "i8"), ("bytes_changed", "i8"), ("minor", "?")]
)


def wiki_edit_stream(
    spec: StreamSpec | None = None, *, num_articles: int = 5_000, zipf_a: float = 1.3
) -> Iterator[tuple[np.ndarray, list, np.ndarray]]:
    """Parsed-Wikipedia-edit-history-shaped stream.

    Keys are article ids with Zipf popularity; values carry the ≥14-attribute
    revision record (truncated to what the jobs read) as record tuples in the
    ``W_*`` layout — ``WIKI_DTYPE`` is the corresponding declared schema.
    """
    spec = spec or StreamSpec()
    rng = np.random.default_rng(spec.seed)
    tick = 0
    while True:
        n = _rate_at(spec, tick, rng)
        arts = np.minimum(rng.zipf(zipf_a, size=n) - 1, num_articles - 1)
        values = np.empty(n, dtype=WIKI_DTYPE)
        values["article"] = arts
        values["editor"] = rng.integers(0, 100_000, size=n)
        values["bytes_changed"] = rng.integers(-500, 2_000, size=n)
        values["minor"] = rng.random(n) < 0.3
        ts = np.full(n, float(tick))
        yield arts.astype(np.int64), values, ts
        tick += 1


# Airline On-Time (RITA/DoT 2004–2013): airplane, origin, dest, delays, year.
_NUM_AIRPLANES = 4_000
_NUM_AIRPORTS = 300

# Airline record layout: tuples, not dicts — a typed ingestion schema whose
# columns segment-vectorized operators read as structured column views (or
# extract with one ``zip(*values)`` on the object path).
A_PLANE, A_ORIGIN, A_DEST, A_DEP_DELAY, A_ARR_DELAY, A_YEAR = range(6)
AIRLINE_DTYPE = np.dtype(
    [
        ("plane", "i8"),
        ("origin", "i8"),
        ("dest", "i8"),
        ("dep_delay", "f8"),
        ("arr_delay", "f8"),
        ("year", "i8"),
    ]
)


def airline_stream(
    spec: StreamSpec | None = None,
) -> Iterator[tuple[np.ndarray, list, np.ndarray]]:
    """Airline-On-Time-shaped stream keyed by airplane id (jobs 2–4).

    Values are record tuples in the ``A_*`` layout above.
    """
    spec = spec or StreamSpec()
    rng = np.random.default_rng(spec.seed + 1)
    tick = 0
    while True:
        n = _rate_at(spec, tick, rng)
        planes = np.minimum(rng.zipf(1.2, size=n) - 1, _NUM_AIRPLANES - 1)
        origins = rng.integers(0, _NUM_AIRPORTS, size=n)
        jump = 1 + rng.integers(0, _NUM_AIRPORTS - 1, size=n)
        values = np.empty(n, dtype=AIRLINE_DTYPE)
        values["plane"] = planes
        values["origin"] = origins
        values["dest"] = (origins + jump) % _NUM_AIRPORTS
        values["dep_delay"] = np.maximum(rng.normal(8.0, 20.0, size=n), -10.0)
        values["arr_delay"] = np.maximum(rng.normal(6.0, 25.0, size=n), -20.0)
        values["year"] = 2004 + (tick // 500) % 10
        ts = np.full(n, float(tick))
        yield planes.astype(np.int64), values, ts
        tick += 1


_NUM_STATIONS = 2_000
_MAX_PRECIP = 30.0

# GSOD observation layout: record tuples in the ``WX_*`` positional order.
WX_STATION, WX_PRECIP, WX_TEMP, WX_VIS, WX_AIRPORT = range(5)
WEATHER_DTYPE = np.dtype(
    [
        ("station", "i8"),
        ("precip", "f8"),
        ("mean_temp", "f8"),
        ("visibility", "f8"),
        ("airport", "i8"),
    ]
)


def weather_stream(
    spec: StreamSpec | None = None,
) -> Iterator[tuple[np.ndarray, list, np.ndarray]]:
    """NOAA GSOD-shaped stream keyed by station (job 4 rainscore input).

    Values are record tuples in the ``WX_*`` layout above; stations map onto
    airports for the job-4 join.
    """
    spec = spec or StreamSpec(rate=50.0)
    rng = np.random.default_rng(spec.seed + 2)
    tick = 0
    while True:
        n = _rate_at(spec, tick, rng)
        stations = rng.integers(0, _NUM_STATIONS, size=n)
        values = np.empty(n, dtype=WEATHER_DTYPE)
        values["station"] = stations
        values["precip"] = np.clip(rng.exponential(2.0, size=n), 0.0, _MAX_PRECIP)
        values["mean_temp"] = rng.normal(12.0, 10.0, size=n)
        values["visibility"] = np.clip(rng.normal(9.0, 3.0, size=n), 0.0, 20.0)
        values["airport"] = stations % _NUM_AIRPORTS
        ts = np.full(n, float(tick))
        yield stations.astype(np.int64), values, ts
        tick += 1


def max_precip() -> float:
    """Maximal historically measured precipitation (rainscore denominator)."""
    return _MAX_PRECIP


def num_airports() -> int:
    return _NUM_AIRPORTS

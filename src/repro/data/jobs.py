"""The paper's Real Jobs 1–4 (§5.2–§5.4) as engine topologies.

Operator logic is genuinely executed (geohashing, windowed TopK, keyed sums,
stream joins) — the engine measures the resulting loads and communication, it
does not assume them.

Job 1  wiki → GeoHash → windowed TopK → global TopK      (full partitioning —
       the "LP-solver-only" case; collocation maxes out ~5%)
Job 2  airline → ExtractDelay → SumDelay(airplane, year)  (same key both ops —
       perfect collocation possible)
Job 3  job 2 + RouteDelay(origin→dest)                    (different key — the
       RouteDelay operator cannot collocate with SumDelay)
Job 4  job 3 + weather → RainScore → join(route × rainscore) → courier
       efficiency → store (periodic DB writes modelled as a sink)
"""

from __future__ import annotations

import numpy as np

from repro.data import synthetic
from repro.engine.topology import OperatorSpec, Topology

# --------------------------------------------------------------------------
# Shared operator bodies (state dicts are σ_k — everything must live there).
# --------------------------------------------------------------------------


def _geohash(lat: float, lon: float, precision: int = 5) -> str:
    """Standard geohash (base32) — executed per tuple like the paper's job."""
    _b32 = "0123456789bcdefghjkmnpqrstuvwxyz"
    lat_r, lon_r = [-90.0, 90.0], [-180.0, 180.0]
    bits, ch, even, out = 0, 0, True, []
    while len(out) < precision:
        if even:
            mid = (lon_r[0] + lon_r[1]) / 2
            if lon > mid:
                ch = ch * 2 + 1
                lon_r[0] = mid
            else:
                ch *= 2
                lon_r[1] = mid
        else:
            mid = (lat_r[0] + lat_r[1]) / 2
            if lat > mid:
                ch = ch * 2 + 1
                lat_r[0] = mid
            else:
                ch *= 2
                lat_r[1] = mid
        even = not even
        bits += 1
        if bits == 5:
            out.append(_b32[ch])
            bits, ch = 0, 0
    return "".join(out)


# Denmark bounding box (paper: "completely even distribution of GeoHash
# values covering Denmark").
_DK = (54.5, 57.8, 8.0, 12.7)


def make_real_job_1(
    *, keygroups_per_op: int = 100, topk: int = 10, window_ticks: float = 60.0
) -> Topology:
    def geohash_op(state, keys, values, ts):
        out = []
        for k, v, t in zip(keys, values, ts):
            # Article id → deterministic pseudo-location inside Denmark.
            rng = (int(k) * 2654435761) & 0xFFFFFFFF
            lat = _DK[0] + (rng % 10_000) / 10_000 * (_DK[1] - _DK[0])
            lon = _DK[2] + ((rng // 10_000) % 10_000) / 10_000 * (_DK[3] - _DK[2])
            gh = _geohash(lat, lon)
            out.append((gh, {"article": int(k), "gh": gh}, float(t)))
        return state, out

    def topk_op(state, keys, values, ts):
        counts = state.setdefault("counts", {})
        w_start = state.setdefault("w_start", float(ts[0]) if len(ts) else 0.0)
        out = []
        for k, v, t in zip(keys, values, ts):
            art = v["article"]
            counts[art] = counts.get(art, 0) + 1
            if t - w_start >= window_ticks:
                top = sorted(counts.items(), key=lambda x: -x[1])[:topk]
                out.append((str(k), {"top": top, "gh": str(k)}, float(t)))
                counts.clear()
                state["w_start"] = float(t)
                w_start = float(t)
        return state, out

    def global_topk_op(state, keys, values, ts):
        counts = state.setdefault("counts", {})
        w_start = state.setdefault("w_start", float(ts[0]) if len(ts) else 0.0)
        out = []
        for k, v, t in zip(keys, values, ts):
            for art, c in v["top"]:
                counts[art] = counts.get(art, 0) + c
            if t - w_start >= window_ticks:
                top = sorted(counts.items(), key=lambda x: -x[1])[:topk]
                out.append(("global", {"top": top}, float(t)))
                counts.clear()
                state["w_start"] = float(t)
                w_start = float(t)
        return state, out

    t = Topology()
    t.add_operator(
        OperatorSpec("wiki", None, num_keygroups=keygroups_per_op, is_source=True)
    )
    t.add_operator(
        OperatorSpec("geohash", geohash_op, num_keygroups=keygroups_per_op, cost_per_tuple=1.2)
    )
    t.add_operator(OperatorSpec("topk", topk_op, num_keygroups=keygroups_per_op))
    t.add_operator(
        OperatorSpec(
            "global_topk",
            global_topk_op,
            num_keygroups=keygroups_per_op,
            is_sink=True,
            key_fn=lambda k: "global",
        )
    )
    t.connect("wiki", "geohash")
    t.connect("geohash", "topk")
    t.connect("topk", "global_topk")
    return t


def real_job_1(**kw) -> Topology:
    return make_real_job_1(**kw)


# --------------------------------------------------------------------------
# Jobs 2–4 (airline + weather)
# --------------------------------------------------------------------------


def _extract_delay(state, keys, values, ts):
    out = []
    for k, v, t in zip(keys, values, ts):
        delay = v["dep_delay"] + v["arr_delay"]
        out.append(
            (
                v["airplane"],  # keyed by airplane → 1:1 with SumDelay
                {
                    "airplane": v["airplane"],
                    "delay": delay,
                    "year": v["year"],
                    "origin": v["origin"],
                    "dest": v["dest"],
                },
                float(t),
            )
        )
    return state, out


def _sum_delay(state, keys, values, ts):
    sums = state.setdefault("sums", {})
    out = []
    for k, v, t in zip(keys, values, ts):
        key = (v["airplane"], v["year"])
        sums[key] = sums.get(key, 0.0) + v["delay"]
        out.append((v["airplane"], {"airplane": v["airplane"], "sum": sums[key]}, float(t)))
    return state, out


def _route_delay(state, keys, values, ts):
    sums = state.setdefault("route_sums", {})
    out = []
    for k, v, t in zip(keys, values, ts):
        route = (v["origin"], v["dest"])
        sums[route] = sums.get(route, 0.0) + v["delay"]
        out.append(
            (
                v["origin"] * synthetic.num_airports() + v["dest"],
                {"route": route, "origin": v["origin"], "sum": sums[route], "delay": v["delay"]},
                float(t),
            )
        )
    return state, out


def real_job_2(*, keygroups_per_op: int = 100) -> Topology:
    t = Topology()
    t.add_operator(
        OperatorSpec("airline", None, num_keygroups=keygroups_per_op, is_source=True)
    )
    # Both operators parallelized on the SAME attribute (airplane) — the
    # One-To-One pattern where perfect collocation is possible (paper §5.4).
    t.add_operator(
        OperatorSpec(
            "extract",
            _extract_delay,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: v["airplane"],
        )
    )
    t.add_operator(
        OperatorSpec(
            "sumdelay",
            _sum_delay,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: v["airplane"],
            is_sink=True,
        )
    )
    t.connect("airline", "extract")
    t.connect("extract", "sumdelay")
    return t


def real_job_3(*, keygroups_per_op: int = 100) -> Topology:
    t = real_job_2(keygroups_per_op=keygroups_per_op)
    t.operators[t._resolve("sumdelay")].is_sink = True
    # RouteDelay partitions by route — a different attribute, so it CANNOT be
    # collocated with SumDelay (paper: "collocation factor is only half").
    t.add_operator(
        OperatorSpec(
            "routedelay",
            _route_delay,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: (v["origin"], v["dest"]),
            is_sink=True,
        )
    )
    t.connect("extract", "routedelay")
    return t


def real_job_4(*, keygroups_per_op: int = 100) -> Topology:
    def rainscore(state, keys, values, ts):
        out = []
        for k, v, t in zip(keys, values, ts):
            score = 100.0 * v["precip"] / synthetic.max_precip()
            out.append((v["airport"], {"airport": v["airport"], "rainscore": score}, float(t)))
        return state, out

    def join_route_rain(state, keys, values, ts):
        rain = state.setdefault("rain", {})  # airport → latest rainscore
        out = []
        for k, v, t in zip(keys, values, ts):
            if "rainscore" in v:
                rain[v["airport"]] = v["rainscore"]
            else:  # a route-delay tuple; join on origin airport
                score = rain.get(v["origin"], 0.0)
                out.append(
                    (v["origin"], {"delay": v["delay"], "rainscore": score}, float(t))
                )
        return state, out

    def courier_efficiency(state, keys, values, ts):
        buckets = state.setdefault("buckets", {})  # rainscore decile → Σ delay
        out = []
        for k, v, t in zip(keys, values, ts):
            b = min(int(v["rainscore"] // 10), 9)
            buckets[b] = buckets.get(b, 0.0) + v["delay"]
            out.append((b, {"bucket": b, "sum_delay": buckets[b]}, float(t)))
        return state, out

    def store(state, keys, values, ts):
        rows = state.setdefault("rows", [])
        for k, v, t in zip(keys, values, ts):
            rows.append((int(k), v["sum_delay"], float(t)))
        if len(rows) > 1_000:  # periodic flush to the "local database"
            del rows[:-100]
        return state, []

    t = real_job_3(keygroups_per_op=keygroups_per_op)
    t.operators[t._resolve("routedelay")].is_sink = False
    t.add_operator(
        OperatorSpec("weather", None, num_keygroups=keygroups_per_op, is_source=True)
    )
    t.add_operator(
        OperatorSpec(
            "rainscore",
            rainscore,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: v["station"],
        )
    )
    t.add_operator(
        OperatorSpec(
            "join",
            join_route_rain,
            num_keygroups=keygroups_per_op,
            # Both sides partition by airport id: rain tuples carry "airport",
            # route tuples join on their origin airport.
            key_by_value=lambda v: v["airport"] if "airport" in v else v["origin"],
        )
    )
    t.add_operator(
        OperatorSpec(
            "efficiency",
            courier_efficiency,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: min(int(v["rainscore"] // 10), 9),
        )
    )
    t.add_operator(
        OperatorSpec("store", store, num_keygroups=keygroups_per_op, is_sink=True)
    )
    t.connect("weather", "rainscore")
    t.connect("rainscore", "join")
    t.connect("routedelay", "join")
    t.connect("join", "efficiency")
    t.connect("efficiency", "store")
    return t

"""The paper's Real Jobs 1–4 (§5.2–§5.4) as engine topologies.

Operator logic is genuinely executed (geohashing, windowed TopK, keyed sums,
stream joins) — the engine measures the resulting loads and communication, it
does not assume them.

Job 1  wiki → GeoHash → windowed TopK → global TopK      (full partitioning —
       the "LP-solver-only" case; collocation maxes out ~5%)
Job 2  airline → ExtractDelay → SumDelay(airplane, year)  (same key both ops —
       perfect collocation possible)
Job 3  job 2 + RouteDelay(origin→dest)                    (different key — the
       RouteDelay operator cannot collocate with SumDelay)
Job 4  job 3 + weather → RainScore → join(route × rainscore) → courier
       efficiency → store (periodic DB writes modelled as a sink)

Every operator implements *both* interpreted execution protocols:

* the per-run ``fn`` — the semantic oracle, executed per (key group, batch);
* the segment-vectorized ``fn_seg`` — one call per (node, operator) per tick
  covering every key group as whole-segment array operations (vectorized
  geohash bisection, segment-reduced running sums, masked join/rainscore);

and the flight-delay operators of jobs 2–3 (extract / sumdelay /
routedelay — pure integer/float column math) additionally implement the
compiled tier ``fn_jit`` with declared ``StateSchema`` keyed-accumulator
state (see :mod:`repro.engine.jitexec` and docs/operator_authoring.md).

``fn_seg`` is required to be bit-identical to running ``fn`` run by run:
same emitted tuples in the same order, same per-key-group state including
dict insertion order (it decides TopK tie-breaks and pickle bytes), same
float trajectories (running sums accumulate strictly left to right).  The
differential conformance harness (``tests/conformance.py``) pins every job's
fn_seg/fn and SoA/deque combinations against each other.

Typed edges (this PR's port): every record-carrying edge declares a
:class:`~repro.engine.topology.Schema`, so with ``use_schema=True`` (the
default) values flow as native structured arrays — ``fn_seg`` bodies branch
on ``values.dtype.names`` and read whole *column views* instead of
``zip(*values.tolist())`` column extraction, and ``key_by_value_col`` keys
typed batches with vectorized field arithmetic.  The per-run ``fn`` bodies
normalize with one ``values.tolist()`` (a structured array and an object
array of the same record tuples produce the *identical* list of python-
scalar tuples), which is what keeps typed and untyped execution
bit-identical — including dict insertion order and pickle bytes of σ_k.
Only the join keeps an undeclared (object) input edge: its two upstreams
carry different record layouts, so both decay at that boundary and the
operator discriminates sides by record arity.
"""

from __future__ import annotations

import numpy as np

from repro.data import synthetic
from repro.engine.topology import (
    OperatorSpec,
    Schema,
    StateField,
    StateSchema,
    Topology,
)

# --------------------------------------------------------------------------
# Shared operator bodies (state dicts are σ_k — everything must live there).
# --------------------------------------------------------------------------


def _geohash(lat: float, lon: float, precision: int = 5) -> str:
    """Standard geohash (base32) — executed per tuple like the paper's job."""
    _b32 = "0123456789bcdefghjkmnpqrstuvwxyz"
    lat_r, lon_r = [-90.0, 90.0], [-180.0, 180.0]
    bits, ch, even, out = 0, 0, True, []
    while len(out) < precision:
        if even:
            mid = (lon_r[0] + lon_r[1]) / 2
            if lon > mid:
                ch = ch * 2 + 1
                lon_r[0] = mid
            else:
                ch *= 2
                lon_r[1] = mid
        else:
            mid = (lat_r[0] + lat_r[1]) / 2
            if lat > mid:
                ch = ch * 2 + 1
                lat_r[0] = mid
            else:
                ch *= 2
                lat_r[1] = mid
        even = not even
        bits += 1
        if bits == 5:
            out.append(_b32[ch])
            bits, ch = 0, 0
    return "".join(out)


_B32_BYTES = np.frombuffer(b"0123456789bcdefghjkmnpqrstuvwxyz", dtype=np.uint8)


def _geohash_batch(lat: np.ndarray, lon: np.ndarray, precision: int = 5) -> list[str]:
    """Vectorized :func:`_geohash` — the same bisection, whole arrays at once.

    Each iteration performs exactly the scalar loop's float operations
    (``mid = (lo + hi) / 2``, compare, narrow), so the emitted characters are
    bit-identical to the per-tuple geohash for every input.
    """
    n = len(lat)
    lat_lo, lat_hi = np.full(n, -90.0), np.full(n, 90.0)
    lon_lo, lon_hi = np.full(n, -180.0), np.full(n, 180.0)
    codes = np.empty((n, precision), dtype=np.int64)
    ch = np.zeros(n, dtype=np.int64)
    bits, ci = 0, 0
    for i in range(precision * 5):
        if i % 2 == 0:
            mid = (lon_lo + lon_hi) / 2
            take = lon > mid
            ch = ch * 2 + take
            lon_lo = np.where(take, mid, lon_lo)
            lon_hi = np.where(take, lon_hi, mid)
        else:
            mid = (lat_lo + lat_hi) / 2
            take = lat > mid
            ch = ch * 2 + take
            lat_lo = np.where(take, mid, lat_lo)
            lat_hi = np.where(take, lat_hi, mid)
        bits += 1
        if bits == 5:
            codes[:, ci] = ch
            ch = np.zeros(n, dtype=np.int64)
            bits, ci = 0, ci + 1
    flat = _B32_BYTES[codes].tobytes().decode("ascii")
    return [flat[i * precision : (i + 1) * precision] for i in range(n)]


# Denmark bounding box (paper: "completely even distribution of GeoHash
# values covering Denmark").
_DK = (54.5, 57.8, 8.0, 12.7)


def _pseudo_locations(keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized article-id → deterministic location inside Denmark.

    Mirrors the scalar ``(int(k) * 2654435761) & 0xFFFFFFFF`` mix: uint64
    wraparound keeps the low 32 bits identical to Python's unbounded product
    for any int64 key, and the float expressions apply the same operations
    in the same order.
    """
    rng = (keys.astype(np.uint64) * np.uint64(2654435761)) & np.uint64(0xFFFFFFFF)
    lat = _DK[0] + (rng % np.uint64(10_000)) / 10_000 * (_DK[1] - _DK[0])
    lon = _DK[2] + ((rng // np.uint64(10_000)) % np.uint64(10_000)) / 10_000 * (
        _DK[3] - _DK[2]
    )
    return lat, lon


def _segment_groups(codes: np.ndarray, ends: list, *, max_group_fraction: float = 0.8):
    """Group segment tuples by an integer code.

    Returns an iterator of ``(first_index, run_slot, member_positions)`` per
    distinct code — groups in first-occurrence order (so state-dict keys are
    inserted exactly as the per-run loop would insert them), ``run_slot``
    indexing the run (hence key group) that owns the group's tuples, member
    positions ascending (original tuple order within the group),
    ``members=None`` for singletons.  Returns **None** when the codes are
    mostly unique (``> max_group_fraction`` of the tuples): per-group
    machinery cannot pay for itself there, and the caller's plain sequential
    loop is both faster and trivially order-exact.
    """
    n = len(codes)
    order = np.argsort(codes, kind="stable")
    sc = codes[order]
    group_starts = np.flatnonzero(np.concatenate(([True], sc[1:] != sc[:-1])))
    if len(group_starts) > max_group_fraction * n:
        return None
    return _iter_groups(order, group_starts, n, ends)


def _iter_groups(order: np.ndarray, group_starts: np.ndarray, n: int, ends: list):
    group_ends = np.append(group_starts[1:], n)
    # The stable sort keeps original order inside a group, so each block's
    # first element is the group's first occurrence.
    first = order[group_starts]
    # Runs tile the segment, so the run owning tuple i is the first whose
    # end exceeds i — one vectorized searchsorted for every group at once.
    slots = np.searchsorted(np.asarray(ends), first, side="right").tolist()
    starts_l, ends_l = group_starts.tolist(), group_ends.tolist()
    first_l = first.tolist()
    for gi in np.argsort(first, kind="stable").tolist():
        a, z = starts_l[gi], ends_l[gi]
        if z - a == 1:
            yield first_l[gi], slots[gi], None
        else:
            yield first_l[gi], slots[gi], order[a:z]


def _running_sum(base: float, addends: np.ndarray) -> np.ndarray:
    """Per-tuple running totals with the exact left-to-right float trajectory
    of ``s = base; for d in addends: s = s + d`` (np.cumsum is a sequential
    left fold, so ``cumsum([base, d0, d1, ...])[1:]`` reproduces it bit for
    bit)."""
    seq = np.empty(len(addends) + 1)
    seq[0] = base
    seq[1:] = addends
    return np.cumsum(seq)[1:]


# Below this group size a plain python accumulation beats the numpy cumsum's
# fixed cost; both produce the identical left-to-right float trajectory.
_CUMSUM_MIN = 16


def _scatter_running(out, sums, key, base, members, delays_l, delays):
    """Write the running totals of one multi-member group into ``out`` (a
    python list) and return the group's final total."""
    members_l = members.tolist()
    if len(members_l) < _CUMSUM_MIN:
        s = base
        for pos in members_l:
            s = s + delays_l[pos]
            out[pos] = s
        sums[key] = s
    else:
        run = _running_sum(base, delays[members]).tolist()
        for pos, s in zip(members_l, run):
            out[pos] = s
        sums[key] = run[-1]


def _object_array(items: list) -> np.ndarray:
    out = np.empty(len(items), dtype=object)
    out[:] = items
    return out


def _grouped_running_sums(
    store, kgs, starts, ends, codes, state_name, keys_l, delays_l, delays
):
    """Running-sum reduction of one segment, grouped by integer ``codes``.

    ``keys_l[i]`` is tuple i's state-dict key (each key lives in exactly one
    key group, so grouping the whole segment touches each ``store[kg]``
    dict exactly as the per-run loop would, in the same insertion order);
    ``state_name`` names the per-key-group dict holding the sums.  Returns
    the per-tuple running totals, python floats in tuple order, with the
    exact left-to-right float trajectory of the scalar loop.  Shared by
    SumDelay, RouteDelay and courier-efficiency.
    """
    n = len(codes)
    out_sums = [0.0] * n
    groups = _segment_groups(codes, ends)
    if groups is None:  # mostly-unique keys: plain per-run sequential loop
        for kg, a, z in zip(kgs, starts, ends):
            sums = store[kg].setdefault(state_name, {})
            for i in range(a, z):
                key = keys_l[i]
                s = sums.get(key, 0.0) + delays_l[i]
                sums[key] = s
                out_sums[i] = s
    else:
        run_sums: list = [None] * len(kgs)
        for i0, slot, members in groups:
            sums = run_sums[slot]
            if sums is None:
                sums = run_sums[slot] = store[kgs[slot]].setdefault(state_name, {})
            key = keys_l[i0]
            if members is None:
                s = sums.get(key, 0.0) + delays_l[i0]
                sums[key] = s
                out_sums[i0] = s
            else:
                base = sums.get(key, 0.0)
                _scatter_running(out_sums, sums, key, base, members, delays_l, delays)
    return out_sums


# geohash → topk record layout: (article, gh) tuples / the structured dtype.
G_ARTICLE, G_GH = range(2)
GEO_SCHEMA = Schema.record([("article", "i8"), ("gh", "U5")], key="U5")
WIKI_SCHEMA = Schema(synthetic.WIKI_DTYPE)


def make_real_job_1(
    *, keygroups_per_op: int = 100, topk: int = 10, window_ticks: float = 60.0
) -> Topology:
    def geohash_run(out, keys, values, ts):
        for k, t in zip(keys, ts):
            # Article id → deterministic pseudo-location inside Denmark.
            rng = (int(k) * 2654435761) & 0xFFFFFFFF
            lat = _DK[0] + (rng % 10_000) / 10_000 * (_DK[1] - _DK[0])
            lon = _DK[2] + ((rng // 10_000) % 10_000) / 10_000 * (_DK[3] - _DK[2])
            gh = _geohash(lat, lon)
            out.append((gh, (int(k), gh), float(t)))

    def geohash_op(state, keys, values, ts):
        out = []
        geohash_run(out, keys, values, ts)
        return state, out

    def geohash_seg(store, kgs, starts, ends, keys, values, ts):
        lat, lon = _pseudo_locations(keys)
        ghs = _geohash_batch(lat, lon)
        gh_keys = np.asarray(ghs)
        if values.dtype.names is not None:  # typed edge: build record columns
            out_vals = np.empty(len(keys), dtype=GEO_SCHEMA.value)
            out_vals["article"] = keys
            out_vals["gh"] = gh_keys
        else:
            out_vals = _object_array(list(zip(keys.tolist(), ghs)))
        return (gh_keys, out_vals, ts), None

    def topk_run(state, out, keys, values, ts):
        """Scalar TopK body shared by fn and the fn_seg window-closing path."""
        counts = state.setdefault("counts", {})
        w_start = state.setdefault("w_start", float(ts[0]) if len(ts) else 0.0)
        vals = values.tolist() if isinstance(values, np.ndarray) else values
        for k, v, t in zip(keys, vals, ts):
            art = v[G_ARTICLE]
            counts[art] = counts.get(art, 0) + 1
            if t - w_start >= window_ticks:
                top = sorted(counts.items(), key=lambda x: -x[1])[:topk]
                out.append((str(k), {"top": top, "gh": str(k)}, float(t)))
                counts.clear()
                state["w_start"] = float(t)
                w_start = float(t)

    def topk_op(state, keys, values, ts):
        out = []
        topk_run(state, out, keys, values, ts)
        return state, out

    def windowed_seg(scalar_run, accumulate):
        """Shared fn_seg wrapper for the windowed TopK operators.

        Runs where no window can close (every ts within ``window_ticks`` of
        the run's ``w_start``) take ``accumulate`` — the bulk counting path;
        runs that may close a window fall back to ``scalar_run``, the exact
        per-tuple body, so emissions stay bit-identical to the oracle.
        """

        def seg(store, kgs, starts, ends, keys, values, ts):
            out, lens = [], []
            for kg, a, z in zip(kgs, starts, ends):
                state = store[kg]
                t_run = ts[a:z]
                counts = state.setdefault("counts", {})
                w_start = state.setdefault(
                    "w_start", float(t_run[0]) if len(t_run) else 0.0
                )
                if len(t_run) and float(t_run.max()) - w_start < window_ticks:
                    accumulate(counts, keys[a:z], values[a:z])
                    lens.append(0)
                else:
                    run_out = []
                    scalar_run(state, run_out, keys[a:z], values[a:z], t_run)
                    out.extend(run_out)
                    lens.append(len(run_out))
            if not out:
                return None, None
            ok, ov, ot = zip(*out)
            return (np.asarray(ok), _object_array(list(ov)), np.asarray(ot)), lens

        return seg

    def topk_accumulate(counts, keys, values):
        # Segment-reduce the article counts.  First-occurrence order
        # preserves the dict insertion order the scalar loop produces (the
        # sort that ranks the TopK is stable, so ties break on it).
        n = len(values)
        if values.dtype.names is not None:  # typed edge: the column itself
            arts = values["article"]
        else:
            arts = np.fromiter((v[G_ARTICLE] for v in values), np.int64, count=n)
        uniq, first, cnt = np.unique(arts, return_index=True, return_counts=True)
        order = np.argsort(first, kind="stable")
        for art, c in zip(uniq[order].tolist(), cnt[order].tolist()):
            counts[art] = counts.get(art, 0) + c

    def global_topk_run(state, out, keys, values, ts):
        counts = state.setdefault("counts", {})
        w_start = state.setdefault("w_start", float(ts[0]) if len(ts) else 0.0)
        for k, v, t in zip(keys, values, ts):
            for art, c in v["top"]:
                counts[art] = counts.get(art, 0) + c
            if t - w_start >= window_ticks:
                top = sorted(counts.items(), key=lambda x: -x[1])[:topk]
                out.append(("global", {"top": top}, float(t)))
                counts.clear()
                state["w_start"] = float(t)
                w_start = float(t)

    def global_topk_op(state, keys, values, ts):
        out = []
        global_topk_run(state, out, keys, values, ts)
        return state, out

    def global_topk_accumulate(counts, keys, values):
        for v in values:
            for art, c in v["top"]:
                counts[art] = counts.get(art, 0) + c

    topk_seg = windowed_seg(topk_run, topk_accumulate)
    global_topk_seg = windowed_seg(global_topk_run, global_topk_accumulate)

    t = Topology()
    t.add_operator(
        OperatorSpec(
            "wiki",
            None,
            num_keygroups=keygroups_per_op,
            is_source=True,
            schema=WIKI_SCHEMA,
        )
    )
    t.add_operator(
        OperatorSpec(
            "geohash",
            geohash_op,
            num_keygroups=keygroups_per_op,
            cost_per_tuple=1.2,
            fn_seg=geohash_seg,
            schema=WIKI_SCHEMA,
            out_schema=GEO_SCHEMA,
        )
    )
    t.add_operator(
        OperatorSpec(
            "topk",
            topk_op,
            num_keygroups=keygroups_per_op,
            fn_seg=topk_seg,
            # TopK windows emit variable-length rankings (dict payloads):
            # the input edge is typed, the output edge stays object.
            schema=GEO_SCHEMA,
        )
    )
    t.add_operator(
        OperatorSpec(
            "global_topk",
            global_topk_op,
            num_keygroups=keygroups_per_op,
            is_sink=True,
            key_fn=lambda k: "global",
            fn_seg=global_topk_seg,
        )
    )
    t.connect("wiki", "geohash")
    t.connect("geohash", "topk")
    t.connect("topk", "global_topk")
    return t


def real_job_1(**kw) -> Topology:
    return make_real_job_1(**kw)


# --------------------------------------------------------------------------
# Jobs 2–4 (airline + weather)
#
# ExtractDelay is a projection: it reads the wide airline record once and
# emits a *compact record tuple* — the classic column-pruning pushdown.
# Downstream operators index the record positionally, so the segment-
# vectorized bodies extract whole columns — as structured column views on
# schema-typed edges, or with one C-level ``zip(*values)`` on the object
# path.  Record layouts (each with a declared Schema for the typed edge):
#
#   extract    → (airplane, delay, year, origin, dest)       _R_*
#   sumdelay   → (airplane, running_sum)                      sink record
#   routedelay → (origin, dest, running_sum, delay)          _RD_*
#   join       → (delay, rainscore)                          _J_*
#   efficiency → (bucket, running_sum_delay)
#
# ``join`` merges two *different* record layouts (rainscore's (airport,
# rainscore) and routedelay's _RD_*), so its input edge stays undeclared —
# both sides decay to object tuples there and the operator discriminates
# them by record arity (rain records have 2 fields, route records 4).  Both
# layouts carry the join key at position 0.
# --------------------------------------------------------------------------

_R_PLANE, _R_DELAY, _R_YEAR, _R_ORIGIN, _R_DEST = range(5)
_RD_ORIGIN, _RD_DEST, _RD_SUM, _RD_DELAY = range(4)
_RAIN_AIRPORT, _RAIN_SCORE = range(2)
_J_DELAY, _J_SCORE = range(2)

AIRLINE_SCHEMA = Schema(synthetic.AIRLINE_DTYPE)
WEATHER_SCHEMA = Schema(synthetic.WEATHER_DTYPE)
EXTRACT_SCHEMA = Schema.record(
    [
        ("plane", "i8"),
        ("delay", "f8"),
        ("year", "i8"),
        ("origin", "i8"),
        ("dest", "i8"),
    ]
)
SUM_OUT_SCHEMA = Schema.record([("plane", "i8"), ("sum", "f8")])
ROUTE_SCHEMA = Schema.record(
    [("origin", "i8"), ("dest", "i8"), ("sum", "f8"), ("delay", "f8")]
)
RAIN_SCHEMA = Schema.record([("airport", "i8"), ("rainscore", "f8")])
JOIN_SCHEMA = Schema.record([("delay", "f8"), ("rainscore", "f8")])
EFF_SCHEMA = Schema.record([("bucket", "i8"), ("sum_delay", "f8")])


def _extract_delay(state, keys, values, ts):
    out = []
    for v, t in zip(values.tolist(), ts):
        delay = v[synthetic.A_DEP_DELAY] + v[synthetic.A_ARR_DELAY]
        out.append(
            (
                v[synthetic.A_PLANE],  # keyed by airplane → 1:1 with SumDelay
                (
                    v[synthetic.A_PLANE],
                    delay,
                    v[synthetic.A_YEAR],
                    v[synthetic.A_ORIGIN],
                    v[synthetic.A_DEST],
                ),
                float(t),
            )
        )
    return state, out


def _extract_delay_seg(store, kgs, starts, ends, keys, values, ts):
    """Stateless projection over the whole segment.

    Typed edge: every column moves with one native assignment and the delay
    is one vector add — no python objects are materialized at all.  Object
    path: column extraction is one C-level ``zip(*values)`` and the records
    are zipped back together."""
    if values.dtype.names is not None:
        out_vals = np.empty(len(values), dtype=EXTRACT_SCHEMA.value)
        out_vals["plane"] = values["plane"]
        out_vals["delay"] = values["dep_delay"] + values["arr_delay"]
        out_vals["year"] = values["year"]
        out_vals["origin"] = values["origin"]
        out_vals["dest"] = values["dest"]
        return (values["plane"], out_vals, ts), None
    vals = values.tolist()
    planes, origins, dests, dep, arr, years = zip(*vals)
    delays = (np.asarray(dep) + np.asarray(arr)).tolist()
    out_keys = np.asarray(planes, dtype=np.int64)
    out_vals = _object_array(list(zip(planes, delays, years, origins, dests)))
    return (out_keys, out_vals, ts), None


def _sum_delay(state, keys, values, ts):
    sums = state.setdefault("sums", {})
    out = []
    for v, t in zip(values.tolist(), ts):
        key = (v[_R_PLANE], v[_R_YEAR])
        sums[key] = sums.get(key, 0.0) + v[_R_DELAY]
        out.append((v[_R_PLANE], (v[_R_PLANE], sums[key]), float(t)))
    return state, out


def _sum_delay_seg(store, kgs, starts, ends, keys, values, ts):
    """Segment-reduced keyed sums: one grouped pass over every key group.

    Every (airplane, year) pair lives in exactly one key group (the operator
    partitions by airplane), so grouping the whole segment by the pair code
    touches each state dict exactly as the per-run loop would.  Hot pairs
    (Zipf airplane popularity) reduce to one cumulative sum; tail singletons
    take a plain scalar add.
    """
    typed = values.dtype.names is not None
    if typed:
        planes = values["plane"]
        years = values["year"]
        delays = values["delay"]
        planes_l, years_l, delays_l = (
            planes.tolist(),
            years.tolist(),
            delays.tolist(),
        )
    else:
        vals = values.tolist()
        planes_l, delays_l, years_l, _, _ = zip(*vals)
        planes = np.asarray(planes_l, dtype=np.int64)
        years = np.asarray(years_l, dtype=np.int64)
        delays = np.asarray(delays_l)
    # Airplane ids and years are non-negative and < 2^31: the shifted code is
    # collision-free in int64.
    codes = (planes << np.int64(32)) | years
    out_sums = _grouped_running_sums(
        store,
        kgs,
        starts,
        ends,
        codes,
        "sums",
        list(zip(planes_l, years_l)),
        delays_l,
        delays,
    )
    if typed:
        out_vals = np.empty(len(values), dtype=SUM_OUT_SCHEMA.value)
        out_vals["plane"] = planes
        out_vals["sum"] = out_sums
        return (planes, out_vals, ts), None
    out_vals = _object_array(list(zip(planes_l, out_sums)))
    return (planes, out_vals, ts), None


def _route_delay(state, keys, values, ts):
    sums = state.setdefault("route_sums", {})
    out = []
    for v, t in zip(values.tolist(), ts):
        route = (v[_R_ORIGIN], v[_R_DEST])
        sums[route] = sums.get(route, 0.0) + v[_R_DELAY]
        out.append(
            (
                v[_R_ORIGIN] * synthetic.num_airports() + v[_R_DEST],
                (v[_R_ORIGIN], v[_R_DEST], sums[route], v[_R_DELAY]),
                float(t),
            )
        )
    return state, out


def _route_delay_seg(store, kgs, starts, ends, keys, values, ts):
    """Segment-reduced route sums; the group code doubles as the output key."""
    na = synthetic.num_airports()
    typed = values.dtype.names is not None
    if typed:
        origins, dests, delays = values["origin"], values["dest"], values["delay"]
        origins_l, dests_l, delays_l = (
            origins.tolist(),
            dests.tolist(),
            delays.tolist(),
        )
        # dest < num_airports() ⇒ collision-free group code == output key
        out_keys = origins * np.int64(na) + dests
    else:
        vals = values.tolist()
        _, delays_l, _, origins_l, dests_l = zip(*vals)
        origins = np.asarray(origins_l, dtype=np.int64)
        dests = np.asarray(dests_l, dtype=np.int64)
        delays = np.asarray(delays_l)
        out_keys = origins * na + dests
    out_sums = _grouped_running_sums(
        store,
        kgs,
        starts,
        ends,
        out_keys,
        "route_sums",
        list(zip(origins_l, dests_l)),
        delays_l,
        delays,
    )
    if typed:
        out_vals = np.empty(len(values), dtype=ROUTE_SCHEMA.value)
        out_vals["origin"] = origins
        out_vals["dest"] = dests
        out_vals["sum"] = out_sums
        out_vals["delay"] = delays
        return (out_keys, out_vals, ts), None
    out_vals = _object_array(list(zip(origins_l, dests_l, out_sums, delays_l)))
    return (out_keys, out_vals, ts), None


# --------------------------------------------------------------------------
# Compiled tier (OperatorSpec.fn_jit) for the flight-delay operators — pure
# integer/float column math, executed by repro.engine.jitexec as one jax.jit
# call per (node, operator) segment.  Bodies are module-level so every
# topology instance shares one compile cache; jax is imported lazily inside
# them (only engines with use_fn_jit=True ever trace these).
#
# State lives in declared StateSchema columns: the (airplane, year) and
# (origin, dest) running sums are keyed-accumulator tables whose int64
# codes refine the partition key (equal codes ⇒ equal key group), with
# key_encode/key_decode converting to the oracle dicts' tuple keys.
# --------------------------------------------------------------------------


def _extract_delay_jit(state, kgs, starts, ends, keys, values, ts):
    out = {
        "plane": values["plane"],
        "delay": values["dep_delay"] + values["arr_delay"],
        "year": values["year"],
        "origin": values["origin"],
        "dest": values["dest"],
    }
    return state, (values["plane"], out, ts), None


def _sum_delay_jit(state, kgs, starts, ends, keys, values, ts):
    import jax.numpy as jnp

    from repro.engine import jitexec as jx

    planes, years, delays = values["plane"], values["year"], values["delay"]
    nb = planes.shape[0]
    codes = (planes << jnp.int64(32)) | years
    kg = kgs[jx.run_of_tuples(ends, nb)]
    valid = jx.tuple_valid(starts, ends, nb)
    table, running = jx.keyed_running_sum(
        state["sums"], codes, kg, delays, valid
    )
    return {"sums": table}, (planes, {"plane": planes, "sum": running}, ts), None


def _route_delay_jit(state, kgs, starts, ends, keys, values, ts):
    import jax.numpy as jnp

    from repro.engine import jitexec as jx

    na = synthetic.num_airports()
    origins, dests, delays = values["origin"], values["dest"], values["delay"]
    nb = origins.shape[0]
    codes = origins * jnp.int64(na) + dests
    kg = kgs[jx.run_of_tuples(ends, nb)]
    valid = jx.tuple_valid(starts, ends, nb)
    table, running = jx.keyed_running_sum(
        state["route_sums"], codes, kg, delays, valid
    )
    out = {"origin": origins, "dest": dests, "sum": running, "delay": delays}
    return {"route_sums": table}, (codes, out, ts), None


def _plane_year_encode(key: tuple) -> int:
    return (int(key[0]) << 32) | int(key[1])


def _plane_year_decode(code: int) -> tuple:
    return (code >> 32, code & 0xFFFFFFFF)


def _route_encode(key: tuple) -> int:
    return int(key[0]) * synthetic.num_airports() + int(key[1])


def _route_decode(code: int) -> tuple:
    na = synthetic.num_airports()
    return (code // na, code % na)


SUM_STATE = StateSchema(
    (
        StateField(
            "sums",
            "table",
            dtype=np.float64,
            py=float,
            key_encode=_plane_year_encode,
            key_decode=_plane_year_decode,
        ),
    )
)
ROUTE_STATE = StateSchema(
    (
        StateField(
            "route_sums",
            "table",
            dtype=np.float64,
            py=float,
            key_encode=_route_encode,
            key_decode=_route_decode,
        ),
    )
)


def real_job_2(*, keygroups_per_op: int = 100) -> Topology:
    t = Topology()
    t.add_operator(
        OperatorSpec(
            "airline",
            None,
            num_keygroups=keygroups_per_op,
            is_source=True,
            schema=AIRLINE_SCHEMA,
        )
    )
    # Both operators parallelized on the SAME attribute (airplane) — the
    # One-To-One pattern where perfect collocation is possible (paper §5.4).
    # The airline stream keys tuples by airplane and extract re-keys by
    # airplane, so identity partitioning hashes exactly the attribute the
    # paper names — and integer keys route through the vectorized mix.
    t.add_operator(
        OperatorSpec(
            "extract",
            _extract_delay,
            num_keygroups=keygroups_per_op,
            fn_seg=_extract_delay_seg,
            fn_jit=_extract_delay_jit,
            schema=AIRLINE_SCHEMA,
            out_schema=EXTRACT_SCHEMA,
        )
    )
    t.add_operator(
        OperatorSpec(
            "sumdelay",
            _sum_delay,
            num_keygroups=keygroups_per_op,
            is_sink=True,
            fn_seg=_sum_delay_seg,
            fn_jit=_sum_delay_jit,
            state_schema=SUM_STATE,
            schema=EXTRACT_SCHEMA,
            # Sinks have no downstream edge to validate, but the jit tier
            # packs its output columns through the declared record layout.
            out_schema=SUM_OUT_SCHEMA,
        )
    )
    t.connect("airline", "extract")
    t.connect("extract", "sumdelay")
    return t


def real_job_3(*, keygroups_per_op: int = 100) -> Topology:
    t = real_job_2(keygroups_per_op=keygroups_per_op)
    t.operators[t._resolve("sumdelay")].is_sink = True
    # RouteDelay partitions by route — a different attribute, so it CANNOT be
    # collocated with SumDelay (paper: "collocation factor is only half").
    # The partition key is the integer route code (bijective with the
    # (origin, dest) pair, dest < num_airports): integer keys hash through
    # the vectorized mix — on typed batches as one whole-column expression
    # (key_by_value_col), never touching per-tuple python.
    na = synthetic.num_airports()
    t.add_operator(
        OperatorSpec(
            "routedelay",
            _route_delay,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: v[_R_ORIGIN] * na + v[_R_DEST],
            key_by_value_col=lambda v: v["origin"] * np.int64(na) + v["dest"],
            is_sink=True,
            fn_seg=_route_delay_seg,
            fn_jit=_route_delay_jit,
            state_schema=ROUTE_STATE,
            schema=EXTRACT_SCHEMA,
            out_schema=ROUTE_SCHEMA,
        )
    )
    t.connect("extract", "routedelay")
    return t


def real_job_4(*, keygroups_per_op: int = 100) -> Topology:
    def rainscore(state, keys, values, ts):
        out = []
        for v, t in zip(values.tolist(), ts):
            score = 100.0 * v[synthetic.WX_PRECIP] / synthetic.max_precip()
            airport = v[synthetic.WX_AIRPORT]
            out.append((airport, (airport, score), float(t)))
        return state, out

    def rainscore_seg(store, kgs, starts, ends, keys, values, ts):
        if values.dtype.names is not None:
            scores = 100.0 * values["precip"] / synthetic.max_precip()
            out_keys = values["airport"]
            out_vals = np.empty(len(values), dtype=RAIN_SCHEMA.value)
            out_vals["airport"] = out_keys
            out_vals["rainscore"] = scores
            return (out_keys, out_vals, ts), None
        vals = values.tolist()
        precip = np.asarray([v[synthetic.WX_PRECIP] for v in vals])
        scores = (100.0 * precip / synthetic.max_precip()).tolist()
        out_keys = np.asarray(
            [v[synthetic.WX_AIRPORT] for v in vals], dtype=np.int64
        )
        out_vals = _object_array(list(zip(out_keys.tolist(), scores)))
        return (out_keys, out_vals, ts), None

    def join_route_rain(state, keys, values, ts):
        rain = state.setdefault("rain", {})  # airport → latest rainscore
        out = []
        for v, t in zip(values.tolist(), ts):
            if len(v) == 2:  # a rainscore record: (airport, rainscore)
                rain[v[_RAIN_AIRPORT]] = v[_RAIN_SCORE]
            else:  # a route-delay record; join on origin airport
                score = rain.get(v[_RD_ORIGIN], 0.0)
                out.append((v[_RD_ORIGIN], (v[_RD_DELAY], score), float(t)))
        return state, out

    def join_seg(store, kgs, starts, ends, keys, values, ts):
        """Masked join: runs of a single side take the vectorized path (bulk
        dict update / bulk lookup); mixed runs keep the ordered scalar walk,
        because an update must be visible to every later lookup in the run."""
        vals = values.tolist()
        ts_list = ts.tolist()
        out_k, out_v, out_t, lens = [], [], [], []
        for kg, a, z in zip(kgs, starts, ends):
            rain = store[kg].setdefault("rain", {})
            run_vals = vals[a:z]
            is_rain = [len(v) == 2 for v in run_vals]
            emitted = 0
            if all(is_rain):  # pure weather run: last write per airport wins
                rain.update(run_vals)
            elif not any(is_rain):  # pure route run: lookups only
                for i, v in enumerate(run_vals):
                    o = v[_RD_ORIGIN]
                    out_k.append(o)
                    out_v.append((v[_RD_DELAY], rain.get(o, 0.0)))
                    out_t.append(ts_list[a + i])
                    emitted += 1
            else:
                for i, v in enumerate(run_vals):
                    if is_rain[i]:
                        rain[v[_RAIN_AIRPORT]] = v[_RAIN_SCORE]
                    else:
                        o = v[_RD_ORIGIN]
                        out_k.append(o)
                        out_v.append((v[_RD_DELAY], rain.get(o, 0.0)))
                        out_t.append(ts_list[a + i])
                        emitted += 1
            lens.append(emitted)
        if not out_k:
            return None, None
        return (
            (np.asarray(out_k), _object_array(out_v), np.asarray(out_t)),
            lens,
        )

    def courier_efficiency(state, keys, values, ts):
        buckets = state.setdefault("buckets", {})  # rainscore decile → Σ delay
        out = []
        for v, t in zip(values.tolist(), ts):
            b = min(int(v[_J_SCORE] // 10), 9)
            buckets[b] = buckets.get(b, 0.0) + v[_J_DELAY]
            out.append((b, (b, buckets[b]), float(t)))
        return state, out

    def efficiency_seg(store, kgs, starts, ends, keys, values, ts):
        if values.dtype.names is not None:
            delays = values["delay"]
            scores = values["rainscore"]
            delays_l = delays.tolist()
        else:
            vals = values.tolist()
            delays_l, scores_l = zip(*vals)
            delays = np.asarray(delays_l)
            scores = np.asarray(scores_l)
        # Rainscores are non-negative, so the float floor-division matches
        # the scalar ``min(int(score // 10), 9)`` bucket exactly.
        buckets_arr = np.minimum((scores // 10.0).astype(np.int64), 9)
        buckets_l = buckets_arr.tolist()
        out_sums = _grouped_running_sums(
            store,
            kgs,
            starts,
            ends,
            buckets_arr,
            "buckets",
            buckets_l,
            delays_l,
            delays,
        )
        if values.dtype.names is not None:
            out_vals = np.empty(len(values), dtype=EFF_SCHEMA.value)
            out_vals["bucket"] = buckets_arr
            out_vals["sum_delay"] = out_sums
            return (buckets_arr, out_vals, ts), None
        out_vals = _object_array(list(zip(buckets_l, out_sums)))
        return (buckets_arr, out_vals, ts), None

    def store(state, keys, values, ts):
        rows = state.setdefault("rows", [])
        vals = values.tolist()
        for k, v, t in zip(keys, vals, ts):
            rows.append((int(k), v[1], float(t)))  # v = (bucket, sum_delay)
        if len(rows) > 1_000:  # periodic flush to the "local database"
            del rows[:-100]
        return state, []

    def store_seg(kg_store, kgs, starts, ends, keys, values, ts):
        klist = keys.tolist()
        if values.dtype.names is not None:
            sums_l = values["sum_delay"].tolist()
        else:
            sums_l = [v[1] for v in values.tolist()]
        tlist = ts.tolist()
        for kg, a, z in zip(kgs, starts, ends):
            rows = kg_store[kg].setdefault("rows", [])
            rows.extend(zip(klist[a:z], sums_l[a:z], tlist[a:z]))
            if len(rows) > 1_000:  # the scalar body flushes once per run
                del rows[:-100]
        return None, None

    t = real_job_3(keygroups_per_op=keygroups_per_op)
    t.operators[t._resolve("routedelay")].is_sink = False
    t.add_operator(
        OperatorSpec(
            "weather",
            None,
            num_keygroups=keygroups_per_op,
            is_source=True,
            schema=WEATHER_SCHEMA,
        )
    )
    t.add_operator(
        OperatorSpec(
            "rainscore",
            rainscore,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: v[synthetic.WX_STATION],
            key_by_value_col=lambda v: v["station"],
            fn_seg=rainscore_seg,
            schema=WEATHER_SCHEMA,
            out_schema=RAIN_SCHEMA,
        )
    )
    t.add_operator(
        OperatorSpec(
            "join",
            join_route_rain,
            num_keygroups=keygroups_per_op,
            # Both sides partition by airport id, carried at position 0 of
            # either record layout (rain: airport; route: origin airport).
            # The input edge is undeclared — two different upstream layouts —
            # so both sides decay to object tuples here.
            key_by_value=lambda v: v[0],
            fn_seg=join_seg,
            out_schema=JOIN_SCHEMA,
        )
    )
    t.add_operator(
        OperatorSpec(
            "efficiency",
            courier_efficiency,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: min(int(v[_J_SCORE] // 10), 9),  # decile
            key_by_value_col=lambda v: np.minimum(
                (v["rainscore"] // 10.0).astype(np.int64), 9
            ),
            fn_seg=efficiency_seg,
            schema=JOIN_SCHEMA,
            out_schema=EFF_SCHEMA,
        )
    )
    t.add_operator(
        OperatorSpec(
            "store",
            store,
            num_keygroups=keygroups_per_op,
            is_sink=True,
            fn_seg=store_seg,
            schema=EFF_SCHEMA,
        )
    )
    t.connect("weather", "rainscore")
    t.connect("rainscore", "join")
    t.connect("routedelay", "join")
    t.connect("join", "efficiency")
    t.connect("efficiency", "store")
    return t

"""Sharded token pipeline for the LM workloads.

A deterministic, restartable synthetic-token stream (offline container):
each *data shard* owns a disjoint key range; the cursor (shard, step) is
checkpointed so restarts resume exactly.  Shards are the paper's key groups
on the training plane: per-shard throughput statistics feed the controller's
``gLoad_k`` and the MILP's heterogeneous-capacity rebalancing assigns shards
to (possibly unequal) workers — see launch/train.py.

Double-buffered host prefetch keeps the input pipeline off the step's
critical path.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_shards: int = 16
    seed: int = 0


class TokenPipeline:
    """Deterministic restartable synthetic LM batches."""

    def __init__(self, config: PipelineConfig, *, start_step: int = 0) -> None:
        self.config = config
        self.step = start_step
        if config.global_batch % config.num_shards != 0:
            raise ValueError("global_batch must divide into shards")
        self.per_shard = config.global_batch // config.num_shards
        # Shard→worker assignment: the controller's rebalancing lever.
        self.shard_assignment = np.arange(config.num_shards)

    def cursor(self) -> dict:
        return {"step": self.step, "assignment": self.shard_assignment.copy()}

    def restore(self, cursor: dict) -> None:
        self.step = int(cursor["step"])
        self.shard_assignment = np.asarray(cursor["assignment"])

    def _shard_batch(self, shard: int, step: int) -> np.ndarray:
        cfg = self.config
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + shard) * 1_000_003 + step
        )
        # Zipf-ish token distribution: realistic softmax pressure.
        toks = rng.zipf(1.2, size=(self.per_shard, cfg.seq_len + 1))
        return np.minimum(toks, cfg.vocab_size - 1).astype(np.int32)

    def next_batch(self) -> dict[str, np.ndarray]:
        cfg = self.config
        rows = [self._shard_batch(s, self.step) for s in range(cfg.num_shards)]
        data = np.concatenate(rows, axis=0)
        self.step += 1
        return {"tokens": data[:, :-1], "labels": data[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


class Prefetcher:
    """Double-buffered background prefetch over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2) -> None:
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = False
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self) -> None:
        try:
            for item in self._it:
                self._q.put(item)
                if self._done:
                    return
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self) -> None:
        self._done = True


@dataclasses.dataclass
class ShardStats:
    """Per-shard throughput statistics → ClusterState for the controller."""

    num_shards: int

    def __post_init__(self) -> None:
        self.tokens = np.zeros(self.num_shards)
        self.seconds = np.zeros(self.num_shards)

    def record(self, shard: int, tokens: int, seconds: float) -> None:
        self.tokens[shard] += tokens
        self.seconds[shard] += seconds

    def loads(self) -> np.ndarray:
        """Load per shard: time share, in percent of the period."""
        total = self.seconds.sum()
        if total <= 0:
            return np.zeros(self.num_shards)
        return 100.0 * self.seconds / total

    def reset(self) -> None:
        self.tokens[:] = 0
        self.seconds[:] = 0

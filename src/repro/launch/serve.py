"""Serving driver: continuous batching decode with integrative reconfiguration.

Sequences are the key groups: each active request owns KV-cache state on its
worker (decode replica).  The controller runs Algorithm 1 every SPL:

* per-sequence load = decode cost share over the period (real measured step
  times, scaled by worker capacity);
* the MILP rebalances sequences across workers under a migration budget where
  mc_k = the sequence's KV-cache bytes — migrating a sequence physically
  moves its cache rows between worker batches (direct state migration);
* horizontal scaling: the utilization scaler adds/retires decode workers with
  queue depth; retired workers drain via the MILP (Lemmas 1–2);
* worker failure orphans its sequences — they are re-admitted from their
  last prefill (checkpointed prompt) on surviving workers.

Real model decode (reduced config) runs per worker per tick via
``make_serve_step``; this driver is the single-host specialization of the
multi-host layout where workers are hosts.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --ticks 120
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import canon, get_config
from repro.core import (
    AdaptationFramework,
    ClusterState,
    UtilizationScaler,
)
from repro.models import init_params, make_serve_step
from repro.models.kvcache import init_cache


@dataclasses.dataclass
class Sequence:
    sid: int
    prompt_len: int
    target_len: int
    generated: int = 0
    worker: int = 0


class DecodeWorker:
    """One decode replica: a fixed-capacity batch of sequence slots."""

    def __init__(self, wid: int, cfg, params, slots: int, capacity: float = 1.0):
        self.wid = wid
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.capacity = capacity
        self.cache = init_cache(cfg, slots, cfg.max_seq_len)
        self.positions = np.zeros(slots, dtype=np.int32)
        self.tokens = np.zeros((slots, 1), dtype=np.int32)
        self.occupant: list[int | None] = [None] * slots
        self.alive = True
        self.step = jax.jit(make_serve_step(cfg))

    def free_slots(self) -> list[int]:
        return [i for i, o in enumerate(self.occupant) if o is None]

    def active(self) -> list[int]:
        return [i for i, o in enumerate(self.occupant) if o is not None]

    def decode_tick(self) -> tuple[int, float]:
        """Decode one token for every active slot.  Returns (tokens, secs)."""
        act = self.active()
        if not act:
            return 0, 0.0
        t0 = time.perf_counter()
        logits, self.cache = self.step(
            self.params,
            self.cache,
            jnp.asarray(self.tokens),
            jnp.asarray(self.positions),
        )
        tok = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), dtype=np.int32)
        dt = (time.perf_counter() - t0) / max(self.capacity, 1e-6)
        for i in act:
            self.tokens[i, 0] = tok[i]
            self.positions[i] += 1
        return len(act), dt

    # -- direct state migration of one slot's KV cache -----------------------
    def extract(self, slot: int):
        take = lambda a: np.asarray(a[slot]) if hasattr(a, "shape") else a
        cache_rows = jax.tree.map(lambda a: np.asarray(a[slot : slot + 1]), self.cache)
        return {
            "cache": cache_rows,
            "pos": int(self.positions[slot]),
            "tok": int(self.tokens[slot, 0]),
        }

    def install(self, slot: int, blob: dict, sid: int) -> None:
        def put(dst, src):
            return jnp.asarray(np.concatenate([
                np.asarray(dst[:slot]), np.asarray(src), np.asarray(dst[slot + 1:])
            ]))
        self.cache = jax.tree.map(put, self.cache, blob["cache"])
        self.positions[slot] = blob["pos"]
        self.tokens[slot, 0] = blob["tok"]
        self.occupant[slot] = sid

    def evict(self, slot: int) -> None:
        self.occupant[slot] = None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--ticks", type=int, default=120)
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--arrival-rate", type=float, default=1.2, help="req/tick")
    ap.add_argument("--spl-ticks", type=int, default=15)
    ap.add_argument("--max-migrations", type=int, default=2)
    ap.add_argument("--hetero", type=float, default=0.4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(canon(args.arch), smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    workers = [
        DecodeWorker(
            w, cfg, params, args.slots,
            capacity=float(1.0 + args.hetero * rng.uniform(-0.5, 1.0)),
        )
        for w in range(args.workers)
    ]
    framework = AdaptationFramework(
        scaler=UtilizationScaler(high_wm=85.0, low_wm=25.0, target=60.0, max_step=1),
        mode="milp",
        max_migrations=args.max_migrations,
        time_limit=2.0,
    )

    sequences: dict[int, Sequence] = {}
    queue: list[Sequence] = []
    next_sid = 0
    done = 0
    latencies: list[float] = []
    seq_seconds: dict[int, float] = {}
    tick_of_arrival: dict[int, int] = {}

    for tick in range(args.ticks):
        # Arrivals.
        for _ in range(rng.poisson(args.arrival_rate)):
            seq = Sequence(
                next_sid,
                prompt_len=int(rng.integers(8, 32)),
                target_len=int(rng.integers(16, 64)),
            )
            queue.append(seq)
            tick_of_arrival[seq.sid] = tick
            next_sid += 1

        # Admission: fill free slots (prefill modeled as cache init).
        for w in workers:
            if not w.alive:
                continue
            for slot in w.free_slots():
                if not queue:
                    break
                seq = queue.pop(0)
                seq.worker = w.wid
                w.occupant[slot] = seq.sid
                w.positions[slot] = seq.prompt_len
                w.tokens[slot, 0] = 1
                sequences[seq.sid] = seq
                seq_seconds[seq.sid] = 0.0

        # Decode one token everywhere (real model step).
        for w in workers:
            if not w.alive:
                continue
            n, dt = w.decode_tick()
            act = w.active()
            for slot in act:
                sid = w.occupant[slot]
                seq_seconds[sid] += dt / max(len(act), 1)
                sequences[sid].generated += 1
                if sequences[sid].generated >= sequences[sid].target_len:
                    latencies.append(tick - tick_of_arrival[sid])
                    w.evict(slot)
                    done += 1

        # Adaptation period.
        if (tick + 1) % args.spl_ticks == 0:
            active_sids = sorted(
                sid for w in workers for sid in w.occupant if sid is not None
            )
            if active_sids:
                idx = {sid: i for i, sid in enumerate(active_sids)}
                total = sum(seq_seconds.get(s, 0.0) for s in active_sids) or 1e-9
                g_load = np.array(
                    [100.0 * seq_seconds.get(s, 0.0) / total for s in active_sids]
                )
                alloc = np.array([sequences[s].worker for s in active_sids])
                kv_bytes = np.array(
                    [
                        float(sequences[s].prompt_len + sequences[s].generated)
                        for s in active_sids
                    ]
                )
                state = ClusterState.create(
                    num_nodes=len(workers),
                    kg_operator=np.zeros(len(active_sids), dtype=np.int64),
                    kg_load=g_load,
                    alloc=alloc,
                    kg_state_bytes=kv_bytes,
                    capacity=np.array([w.capacity for w in workers]),
                    downstream={0: []},
                )
                state.alive = np.array([w.alive for w in workers])
                result = framework.adapt(state)
                # Elastic scale-out: provision new decode workers.
                if result.scaling.add_nodes:
                    for _ in range(result.scaling.add_nodes):
                        workers.append(
                            DecodeWorker(len(workers), cfg, params, args.slots)
                        )
                # Apply migrations: physically move KV rows between workers.
                applied = 0
                for m in result.migration_plan.moves:
                    sid = active_sids[m.keygroup]
                    src, dst = workers[m.src], workers[m.dst]
                    if not dst.alive or not dst.free_slots():
                        continue
                    src_slot = src.occupant.index(sid)
                    blob = src.extract(src_slot)
                    src.evict(src_slot)
                    dst.install(dst.free_slots()[0], blob, sid)
                    sequences[sid].worker = m.dst
                    applied += 1
                util = [
                    100.0 * len(w.active()) / w.slots for w in workers if w.alive
                ]
                lat = np.percentile(latencies, 99) if latencies else 0.0
                print(
                    f"[serve] tick {tick+1:4d} active={len(active_sids):3d} "
                    f"queued={len(queue):3d} done={done:4d} "
                    f"LD={result.plan.load_distance:6.2f} migrated={applied} "
                    f"util={[f'{u:.0f}' for u in util]} p99_lat={lat:.1f} ticks"
                )
                seq_seconds = {k: 0.0 for k in seq_seconds}

    print(
        f"[serve] done: {done} completed, p50={np.percentile(latencies,50):.1f} "
        f"p99={np.percentile(latencies,99):.1f} ticks"
    )


if __name__ == "__main__":
    main()

"""Roofline-term extraction from the compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds (lower-bound estimates):

    compute    = HLO_FLOPs_total / (chips × peak_FLOP/s)
    memory     = HLO_bytes_total / (chips × HBM_bw)
    collective = wire_bytes_total / (chips × link_bw)

Methodology — all three terms are derived from the *optimized HLO text* of
the compiled SPMD module (one device's program), weighted by while-loop trip
counts.  ``compiled.cost_analysis()`` is NOT trusted for looped programs: the
XLA CPU cost model counts a while body ONCE regardless of its trip count
(verified experimentally), which under-counts scanned-layer models by ~the
layer count.  Instead:

* **FLOPs** — every ``dot`` op contributes 2 × prod(result dims) ×
  prod(contracting dims), times the product of enclosing while trip counts
  (trip counts recovered from the loop-condition constants).  Elementwise
  FLOPs are ignored: matmul-dominated workloads, stated lower bound.
* **bytes** — per top-level instruction (post-fusion!), result bytes +
  operand bytes, skipping bookkeeping ops (tuple/gte/bitcast/parameter/
  constant) and fusion-internal instructions — i.e. an HBM-traffic model of
  the fused module, trip-weighted.
* **collective wire bytes** — per collective op, ring-model wire bytes:
    all-gather          result × (N−1)/N
    all-reduce          operand × 2(N−1)/N
    reduce-scatter      operand × (N−1)/N
    all-to-all          operand × (N−1)/N
    collective-permute  operand
  with N the participating group size, trip-weighted.

``cost_analysis`` numbers are still recorded (``xla_*``) for reference.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (one link charged per chip: conservative).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional



PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (1 link charged per chip)

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 0.5,
    "u4": 0.5,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "s32": 4,
    "u32": 4,
    "s64": 8,
    "u64": 8,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "bf16": 2,
    "f16": 2,
    "f32": 4,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]  # static op counts (loop bodies counted once)
    wire_bytes: dict[str, float]  # trip-count-weighted wire bytes

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


_WHILE_RE = re.compile(
    r"\bwhile\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
)
_COMP_START_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*(?:/\*.*\*/)?$"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALLS_RE = re.compile(
    r"(?:calls|to_apply|condition|body|true_computation|false_computation)=%?([\w\.\-]+)"
)
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"\s([a-z][a-z0-9\-_\.]*)\(")
_LEAF_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BOOKKEEPING_OPS = {
    "parameter",
    "constant",
    "tuple",
    "get-tuple-element",
    "bitcast",
    "while",
    "conditional",
    "call",
    "after-all",
    "iota",
    "partition-id",
    "replica-id",
}


def _collective_bytes_of_line(line: str):
    """Ring-model wire bytes from the collective's RESULT type.

    Operand types are not printed inline at call sites in optimized HLO text,
    but every collective's wire traffic is derivable from its result:
    all-reduce/all-to-all/permute results equal their operands; a
    reduce-scatter's operand is result × N.
    """
    m = _COLLECTIVE_RE.search(line)
    if not m:
        return None
    op = m.group(1)
    n = 1
    g = _GROUPS_RE.search(line)
    if g:
        n = len([x for x in g.group(1).split(",") if x.strip() != ""])
    else:
        gi = _GROUPS_IOTA_RE.search(line)
        if gi:
            n = int(gi.group(2))
    n = max(n, 2)
    lhs, rhs = line.split("=", 1)
    result_part = rhs[: m.end() - len(lhs) - 1]
    result_bytes = _shape_bytes(result_part)
    ring = (n - 1) / n
    if op == "all-gather":
        b = result_bytes * ring
    elif op == "all-reduce":
        b = result_bytes * 2 * ring
    elif op == "reduce-scatter":
        b = result_bytes * (n - 1)  # operand = result × N
    elif op == "all-to-all":
        b = result_bytes * ring
    else:  # collective-permute
        b = result_bytes
    return op, b


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """Computation name → its lines (coarse brace-depth split)."""
    comps: dict[str, list[str]] = {}
    cur: Optional[str] = None
    depth = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and ("=" not in stripped.split("(")[0]):
                m = _COMP_START_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    depth = 1
            continue
        depth += stripped.count("{") - stripped.count("}")
        if depth <= 0:
            cur = None
            continue
        comps[cur].append(line)
    return comps


@dataclasses.dataclass
class HloAnalysis:
    flops: float  # trip-weighted dot FLOPs (per device)
    hbm_bytes: float  # trip-weighted post-fusion traffic (per device)
    collectives: CollectiveStats
    num_dots: int


def _parse_module(hlo_text: str):
    """Split into computations, build shape map, edges, trip multipliers."""
    comps = _split_computations(hlo_text)

    shapes: dict[str, list[tuple[str, list[int]]]] = {}
    parsed: dict[str, list[tuple[str, str, str]]] = {}  # comp → [(name, op, line)]
    while_edges: dict[str, list[tuple[str, str]]] = {}
    call_edges: dict[str, list[str]] = {}
    called_as_fusion: set[str] = set()

    for cname, lines in comps.items():
        instrs = []
        wh = []
        calls = []
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            opm = _OPNAME_RE.search(" " + rhs)
            if not opm:
                continue
            op = opm.group(1)
            type_str = rhs[: opm.start()]
            leaves = [
                (dt, [int(x) for x in dims.split(",") if x])
                for dt, dims in _LEAF_TYPE_RE.findall(type_str)
            ]
            shapes[name] = leaves
            instrs.append((name, op, line))
            w = _WHILE_RE.search(line)
            if w:
                wh.append((w.group(1), w.group(2)))
            for ref in _CALLS_RE.findall(line):
                if "condition=" not in line or ref not in (w.groups() if w else ()):
                    calls.append(ref)
                if f"calls={ref}" in line or f"calls=%{ref}" in line:
                    called_as_fusion.add(ref)
                if f"to_apply={ref}" in line or f"to_apply=%{ref}" in line:
                    called_as_fusion.add(ref)
        parsed[cname] = instrs
        while_edges[cname] = wh
        call_edges[cname] = calls

    def trip_count(cond_name: str) -> int:
        best = 1
        for line in comps.get(cond_name, []):
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
        return best

    # Multiplier propagation: roots = computations never referenced.
    referenced = set(called_as_fusion)
    for whs in while_edges.values():
        for cond, body in whs:
            referenced.add(cond)
            referenced.add(body)
    for cs in call_edges.values():
        referenced.update(cs)
    mult: dict[str, float] = {n: 1.0 for n in comps if n not in referenced}
    frontier = list(mult)
    while frontier:
        nxt = []
        for name in frontier:
            base = mult.get(name, 1.0)
            for cond, body in while_edges.get(name, []):
                m = base * trip_count(cond)
                for tgt in (body, cond):
                    if mult.get(tgt, 0.0) < m:
                        mult[tgt] = m
                        nxt.append(tgt)
            for ref in call_edges.get(name, []):
                if mult.get(ref, 0.0) < base:
                    mult[ref] = base
                    nxt.append(ref)
        frontier = nxt
    return comps, parsed, shapes, mult, called_as_fusion


def _bytes_of_leaves(leaves: list[tuple[str, list[int]]]) -> float:
    total = 0.0
    for dt, dims in leaves:
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def analyze_hlo(hlo_text: str) -> HloAnalysis:
    comps, parsed, shapes, mult, fusion_bodies = _parse_module(hlo_text)

    flops = 0.0
    hbm = 0.0
    ndots = 0
    counts: dict[str, int] = {}
    wire: dict[str, float] = {}

    # Def-op map: which op produced each value (loop-param detection below).
    def_op: dict[str, str] = {}
    for instrs in parsed.values():
        for name, op, _ in instrs:
            def_op[name] = op

    for cname, instrs in parsed.items():
        m = mult.get(cname, 1.0)
        in_fusion = cname in fusion_bodies
        for name, op, line in instrs:
            # -- FLOPs: dots everywhere (incl. fusion bodies) ---------------
            if op == "dot":
                operands = _OPERAND_RE.findall(
                    line.split(op + "(", 1)[1].split(")", 1)[0]
                )
                result = 1
                for _, dims in shapes.get(name, []):
                    for d in dims:
                        result *= d
                contract = 1
                cd = _CDIMS_RE.search(line)
                if cd and operands:
                    lhs_leaves = shapes.get(operands[0], [])
                    if lhs_leaves:
                        lhs_dims = lhs_leaves[0][1]
                        for idx in cd.group(1).split(","):
                            if idx and int(idx) < len(lhs_dims):
                                contract *= lhs_dims[int(idx)]
                flops += 2.0 * result * contract * m
                ndots += 1
            # -- collectives -------------------------------------------------
            got = _collective_bytes_of_line(line)
            if got:
                cop, b = got
                counts[cop] = counts.get(cop, 0) + 1
                wire[cop] = wire.get(cop, 0.0) + b * m
            # -- HBM traffic: top-level (non-fusion-body) instructions ------
            if not in_fusion and op not in _BOOKKEEPING_OPS:
                b = _bytes_of_leaves(shapes.get(name, []))
                if op == "fusion" and "dynamic-update-slice" in name:
                    # In-place scatter into a loop-carried buffer (scan ys
                    # stacking): physical traffic is the updated window, not
                    # the full buffer — count 2× the small operands only.
                    res = b
                    small = 0.0
                    arg_seg = line.split("(", 1)
                    if len(arg_seg) > 1:
                        end = arg_seg[1].find(")")
                        for oname in _OPERAND_RE.findall(
                            arg_seg[1][: end if end > 0 else None]
                        ):
                            ob = _bytes_of_leaves(shapes.get(oname, []))
                            if 0 < ob < res / 4:
                                small += ob
                    hbm += 2.0 * small * m
                    continue
                if op == "dynamic-slice":
                    # Reads only the slice (= result), not the operand array;
                    # counting the operand inflates scan-sliced xs by the trip
                    # count (measured 40× on per-timestep recurrences).
                    b *= 2.0  # read slice + write result
                elif op == "dynamic-update-slice":
                    # Reads + writes the updated window only (in-place alias).
                    upd = 0.0
                    arg_seg = line.split("(", 1)
                    if len(arg_seg) > 1:
                        end = arg_seg[1].find(")")
                        ops_ = _OPERAND_RE.findall(
                            arg_seg[1][: end if end > 0 else None]
                        )
                        if len(ops_) >= 2:
                            upd = _bytes_of_leaves(shapes.get(ops_[1], []))
                    b = 2.0 * upd if upd else b
                else:
                    arg_seg = line.split("(", 1)
                    if len(arg_seg) > 1:
                        end = arg_seg[1].find(")")
                        for oname in _OPERAND_RE.findall(
                            arg_seg[1][: end if end > 0 else None]
                        ):
                            ob = _bytes_of_leaves(shapes.get(oname, []))
                            if m > 1 and def_op.get(oname) in (
                                "parameter",
                                "get-tuple-element",
                            ):
                                # Loop-carried / xs buffer: each element is
                                # touched once across the loop, not in full
                                # per iteration (a stacked-weights or
                                # timestep-xs array would otherwise count
                                # trips× too much traffic).
                                ob = ob / m
                            b += ob
                hbm += b * m
    return HloAnalysis(
        flops=flops,
        hbm_bytes=hbm,
        collectives=CollectiveStats(counts=counts, wire_bytes=wire),
        num_dots=ndots,
    )


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Trip-count-aware collective accounting (see analyze_hlo)."""
    return analyze_hlo(hlo_text).collectives


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    wire_bytes_per_device: float
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float
    collectives: dict[str, int]
    bytes_per_device_hbm: Optional[float] = None  # from memory_analysis

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops_per_device * self.chips
        return self.model_flops / total if total > 0 else float("nan")

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_total": self.hlo_flops_per_device * self.chips,
            "useful_ratio": self.useful_flops_ratio,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "hbm_bytes_per_device": self.bytes_per_device_hbm,
            "collectives": self.collectives,
        }


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def build_report(
    *,
    arch: str,
    shape,
    cfg,
    mesh_name: str,
    chips: int,
    compiled,
    hlo_text: Optional[str] = None,
) -> RooflineReport:
    text = hlo_text if hlo_text is not None else compiled.as_text()
    analysis = analyze_hlo(text)
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
        )
    except Exception:
        pass
    return RooflineReport(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops_per_device=analysis.flops,
        hlo_bytes_per_device=analysis.hbm_bytes,
        wire_bytes_per_device=analysis.collectives.total_wire_bytes,
        model_flops=model_flops_estimate(cfg, shape),
        compute_s=analysis.flops / PEAK_FLOPS,
        memory_s=analysis.hbm_bytes / HBM_BW,
        collective_s=analysis.collectives.total_wire_bytes / ICI_BW,
        collectives=analysis.collectives.counts,
        bytes_per_device_hbm=mem,
    )

"""End-to-end training driver with integrative reconfiguration.

Trains a real model (reduced config on CPU; the production mesh path reuses
launch/sharding.py) while the paper's controller manages the *data plane*:

* the global batch is split into **shards** (= key groups, repro.core);
* **workers** process shards; per-shard step times are measured (real
  compute) and scaled by per-worker capacity factors (heterogeneity /
  degradation injection for testing — on real clusters this is just the
  measured time);
* every SPL the controller folds shard loads into a ClusterState and runs
  Algorithm 1: the MILP reassigns shards to workers under a migration budget
  (shard reassignment = repartitioning the input stream; cost = data-cursor
  handoff, small) — straggler mitigation as load balancing;
* checkpoints carry params, optimizer state, data cursor AND the shard
  assignment, so a restart resumes the balanced configuration;
* worker failure ⇒ its shards are orphaned and the next adaptation
  reallocates them (scale-in with kill=1 semantics).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch llama3_2_3b \
        --steps 200 --d-model 512 --layers 8 [--restore]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import canon, get_config
from repro.core import AdaptationFramework, ClusterState, NullScaler
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models import init_params, make_train_step
from repro.optim import AdamW, cosine_schedule


def reduced_config(arch: str, d_model: int, layers: int, vocab: int):
    """~100M-class config of the same family as `arch`."""
    cfg = get_config(arch, smoke=True)
    heads = max(cfg.num_heads, 4)
    kv = max(cfg.num_kv_heads, 2)
    pattern_cycles = max(layers // max(len(cfg.pattern), 1), 1)
    return dataclasses.replace(
        cfg,
        name=f"{cfg.name}-train",
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=0 if cfg.d_ff == 0 else d_model * 4,
        vocab_size=vocab,
        cycles=pattern_cycles,
        lru_width=d_model if cfg.lru_width else None,
        max_seq_len=4096,
    )


@dataclasses.dataclass
class Worker:
    wid: int
    capacity: float = 1.0
    alive: bool = True


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=32_768)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--num-shards", type=int, default=16)
    ap.add_argument("--num-workers", type=int, default=4)
    ap.add_argument(
        "--spl-steps",
        type=int,
        default=10,
        help="steps per adaptation period",
    )
    ap.add_argument(
        "--hetero",
        type=float,
        default=0.5,
        help="capacity spread (0=homog)",
    )
    ap.add_argument(
        "--fail-worker",
        type=int,
        default=-1,
        help="worker to kill mid-run",
    )
    ap.add_argument("--fail-at", type=int, default=-1, help="step to kill it at")
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(canon(args.arch), args.d_model, args.layers, args.vocab)
    print(f"[train] {cfg.name}: ~{cfg.param_count()/1e6:.1f}M params")

    opt = AdamW(learning_rate=cosine_schedule(args.lr, 20, args.steps))
    step_fn = jax.jit(make_train_step(cfg, opt))

    pipe = TokenPipeline(
        PipelineConfig(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq_len,
            global_batch=args.batch,
            num_shards=args.num_shards,
            seed=args.seed,
        )
    )
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    rng = np.random.default_rng(args.seed)
    workers = [
        Worker(w, capacity=float(1.0 + args.hetero * rng.uniform(-0.6, 1.0)))
        for w in range(args.num_workers)
    ]
    # Initial shard→worker assignment: round robin.
    assignment = np.arange(args.num_shards) % args.num_workers

    start = 0
    params = opt_state = None
    if args.restore and ckpt.latest_step() is not None:
        (params, opt_state), meta = ckpt.restore()
        pipe.restore(meta["cursor"])
        assignment = np.asarray(meta["assignment"])
        start = meta["step"] + 1
        print(f"[train] restored from step {meta['step']}")
    if params is None:
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        opt_state = opt.init(params)

    framework = AdaptationFramework(
        scaler=NullScaler(), mode="milp", max_migrations=4, time_limit=2.0
    )
    shard_seconds = np.zeros(args.num_shards)
    period_losses: list[float] = []
    t_run = time.perf_counter()

    for step in range(start, args.steps):
        batch_np = pipe.next_batch()
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}

        # Real compute, measured per shard (shards are batch slices).
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        step_time = time.perf_counter() - t0
        period_losses.append(loss)

        # Attribute time to shards ∝ tokens; worker wall time = Σ its shards
        # scaled by 1/capacity (heterogeneity model).
        per_shard_t = step_time / args.num_shards
        for s in range(args.num_shards):
            w = workers[int(assignment[s])]
            shard_seconds[s] += per_shard_t / max(w.capacity, 1e-6)

        # Failure injection.
        if step == args.fail_at and 0 <= args.fail_worker < len(workers):
            workers[args.fail_worker].alive = False
            print(f"[train] step {step}: worker {args.fail_worker} FAILED")

        # Adaptation period: rebalance shards with the MILP.
        if (step + 1) % args.spl_steps == 0:
            total = shard_seconds.sum()
            g_load = 100.0 * shard_seconds / max(total, 1e-9)
            state = ClusterState.create(
                num_nodes=len(workers),
                kg_operator=np.zeros(args.num_shards, dtype=np.int64),
                kg_load=g_load,
                alloc=assignment.copy(),
                kg_state_bytes=np.full(args.num_shards, 1.0),
                capacity=np.array([w.capacity for w in workers]),
                downstream={0: []},
            )
            state.alive = np.array([w.alive for w in workers])
            state.kill = ~state.alive  # dead workers drain immediately
            result = framework.adapt(state)
            moved = result.migration_plan.num_migrations
            assignment = result.state.alloc.copy()
            # Makespan = the busiest worker's period time.
            per_worker = np.zeros(len(workers))
            np.add.at(per_worker, assignment, shard_seconds)
            makespan = per_worker.max()
            print(
                f"[train] step {step+1:4d} loss={np.mean(period_losses):.4f} "
                f"LD={result.plan.load_distance:6.2f} moved={moved} "
                f"makespan={makespan:.2f}s tok/s={args.batch*args.seq_len*args.spl_steps/ (time.perf_counter()-t_run):,.0f}"
            )
            shard_seconds[:] = 0
            period_losses.clear()
            t_run = time.perf_counter()

        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            ckpt.save_async(
                step,
                (params, opt_state),
                metadata={
                    "cursor": {k: np.asarray(v).tolist() if hasattr(v, "tolist") else v
                               for k, v in pipe.cursor().items()},
                    "assignment": assignment.tolist(),
                    "step": step,
                },
            )
    ckpt.wait()
    print("[train] done")


if __name__ == "__main__":
    main()

"""§Perf hillclimb driver: measure one (arch × shape × mesh) cell under a
named variant and append the result to experiments/perf_iterations.json.

    PYTHONPATH=src python -m repro.launch.perf_iter --arch dbrx_132b \
        --shape train_4k --variant chunked_attn
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time

from repro.configs.base import SHAPES, canon, get_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import build_report

OUT = "experiments/perf_iterations.json"


def apply_variant(cfg, variant: str):
    if variant == "baseline":
        return cfg
    if variant == "chunked_attn":
        return dataclasses.replace(cfg, full_attn_max_seq=2048)
    if variant == "remat_dots":
        return dataclasses.replace(cfg, remat="dots")
    if variant == "remat_none":
        return dataclasses.replace(cfg, remat="none")
    if variant == "chunked_attn+remat_dots":
        return dataclasses.replace(cfg, full_attn_max_seq=2048, remat="dots")
    if variant == "seq_parallel":  # handled via rules_for wrapper in main()
        return cfg
    if variant == "seq_parallel+chunked_attn":
        return dataclasses.replace(cfg, full_attn_max_seq=2048)
    raise ValueError(variant)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--note", default="")
    ap.add_argument("--dump-collectives", action="store_true")
    args = ap.parse_args()

    from repro.launch import dryrun  # imports after XLA_FLAGS

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    arch = canon(args.arch)
    base_cfg = get_config(arch)
    cfg = apply_variant(base_cfg, args.variant)

    # Patch the registry lookup so dryrun.lower_cell sees the variant config.
    import repro.configs.base as cfgbase

    orig_get = cfgbase.get_config
    cfgbase.get_config = lambda a, smoke=False: cfg if canon(a) == arch else orig_get(
        a,
        smoke=smoke,
    )
    dryrun.get_config = cfgbase.get_config
    if "seq_parallel" in args.variant:
        # Megatron-SP: hidden stream sequence-sharded over the model axis —
        # the per-layer TP all-reduce becomes reduce-scatter + all-gather.
        orig_rules = shd.rules_for

        def sp_rules(cfg_, shape_, mesh_):
            r = orig_rules(cfg_, shape_, mesh_)
            if shape_.seq_len % mesh_.shape["model"] == 0:
                # vocab must leave the model axis: the (B, S, V) logits would
                # otherwise need 'model' on two dims.
                r = dict(r, seq="model", vocab=None)
            return r

        shd.rules_for = sp_rules
        dryrun.shd.rules_for = sp_rules
    try:
        t0 = time.perf_counter()
        with mesh:
            _cfg, shape, lowered, chips = dryrun.lower_cell(
                arch, args.shape, mesh, args.mesh
            )
            compiled = lowered.compile()
        dt = time.perf_counter() - t0
        report = build_report(
            arch=arch,
            shape=shape,
            cfg=cfg,
            mesh_name=args.mesh,
            chips=chips,
            compiled=compiled,
        )
        mem = compiled.memory_analysis()
    finally:
        cfgbase.get_config = orig_get
        dryrun.get_config = orig_get
        if "seq_parallel" in args.variant:
            shd.rules_for = orig_rules
            dryrun.shd.rules_for = orig_rules

    row = report.row()
    row.update(
        {
            "variant": args.variant,
            "note": args.note,
            "compile_s": round(dt, 1),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    )
    rows = []
    if os.path.exists(OUT):
        rows = json.load(open(OUT))
    rows.append(row)
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    json.dump(rows, open(OUT, "w"), indent=1, default=str)
    print(
        f"[perf] {arch}×{args.shape}×{args.mesh} variant={args.variant}: "
        f"compute={report.compute_s:.3f}s memory={report.memory_s:.3f}s "
        f"collective={report.collective_s:.3f}s dominant={report.dominant} "
        f"useful={report.useful_flops_ratio:.2f} peak={row['peak_bytes']}"
    )
    if args.dump_collectives:
        from repro.launch import roofline as rl

        text = compiled.as_text()
        comps, parsed, shapes, mult, fusion_bodies = rl._parse_module(text)
        items = []
        for cname, instrs in parsed.items():
            m = mult.get(cname, 1.0)
            for name, op, line in instrs:
                got = rl._collective_bytes_of_line(line)
                if got:
                    items.append((got[1] * m / 1e9, got[0], m, line.strip()[:120]))
        items.sort(reverse=True)
        for it in items[:10]:
            print(f"  {it[0]:9.1f}GB x{it[2]:5.0f} {it[1]:18s} {it[3]}")


if __name__ == "__main__":
    main()

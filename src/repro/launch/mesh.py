"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before the first jax call).
"""

from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (see launch/dryrun.py)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the real local devices (tests, examples)."""
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh((data, model), ("data", "model"), devices=devices[:n])

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set XLA_FLAGS before any other import — jax locks the device count on
first init.  The 512 placeholder host devices exist ONLY here; smoke tests
and benchmarks see the real single device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4_9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # 2×16×16 only
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the two lines above must precede every other import)
import argparse
import json
import time
import traceback

import jax

from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    canon,
    get_config,
    input_specs,
    shape_applicable,
)
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import build_report
from repro.models import make_prefill_step, make_serve_step, make_train_step
from repro.models.common import activation_rules
from repro.optim import AdamW

RESULTS_PATH = os.environ.get("DRYRUN_RESULTS", "experiments/dryrun_results.json")


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str, *, remat=None):
    cfg = get_config(arch)
    if remat is not None:
        import dataclasses

        cfg = dataclasses.replace(cfg, remat=remat)
    shape = SHAPES[shape_name]
    rules = shd.rules_for(cfg, shape, mesh)
    chips = 1
    for v in mesh.shape.values():
        chips *= v

    p_shapes = shd.param_shapes(cfg)
    p_shard = shd.param_shardings(cfg, mesh, rules)
    batch_specs = input_specs(cfg, shape)
    b_shard = shd.batch_shardings(cfg, shape, mesh, rules)
    rep = NamedSharding(mesh, P())

    with activation_rules(rules, mesh=mesh):
        if shape.kind == "train":
            opt = AdamW(learning_rate=1e-4)
            o_shapes = shd.opt_shapes(cfg, opt)
            o_shard = shd.opt_shardings(cfg, mesh, rules)
            step = make_train_step(cfg, opt)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, {"loss": rep, "grad_norm": rep}),
            )
            lowered = jitted.lower(p_shapes, o_shapes, batch_specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            c_shard = shd.cache_shardings(cfg, shape, mesh, rules)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, b_shard),
                out_shardings=(shd.logits_sharding(cfg, mesh, rules), c_shard),
            )
            lowered = jitted.lower(p_shapes, batch_specs)
        else:  # decode
            step = make_serve_step(cfg)
            c_shapes = shd.cache_shapes(cfg, shape)
            c_shard = shd.cache_shardings(cfg, shape, mesh, rules)
            tok_shard = b_shard["tokens"]
            pos_shard = b_shard["positions"]
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
                out_shardings=(shd.logits_sharding(cfg, mesh, rules), c_shard),
            )
            lowered = jitted.lower(
                p_shapes, c_shapes, batch_specs["tokens"], batch_specs["positions"]
            )
    return cfg, shape, lowered, chips


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str, *, remat=None) -> dict:
    t0 = time.perf_counter()
    cfg, shape, lowered, chips = lower_cell(
        arch, shape_name, mesh, mesh_name, remat=remat
    )
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    report = build_report(
        arch=arch,
        shape=shape,
        cfg=cfg,
        mesh_name=mesh_name,
        chips=chips,
        compiled=compiled,
    )
    row = report.row()
    row.update(
        {
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory_analysis": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
        }
    )
    print(
        f"[dryrun] {arch:>22s} × {shape_name:<12s} × {mesh_name:<6s} OK  "
        f"compute={report.compute_s:.4f}s memory={report.memory_s:.4f}s "
        f"collective={report.collective_s:.4f}s dominant={report.dominant} "
        f"useful={report.useful_flops_ratio:.2f} "
        f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)",
        flush=True,
    )
    print(f"  memory_analysis: {row['memory_analysis']}", flush=True)
    print(f"  cost: flops/dev={report.hlo_flops_per_device:.3e} "
          f"bytes/dev={report.hlo_bytes_per_device:.3e} "
          f"wire/dev={report.wire_bytes_per_device:.3e} "
          f"collectives={report.collectives}", flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (canon or dashed)")
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--remat", default=None, choices=["full", "none", "dots"])
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()

    archs = [canon(args.arch)] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: list[dict] = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {
        (r["arch"], r["shape"], r["mesh"]) for r in results if r.get("status") == "ok"
    }

    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            ok, reason = shape_applicable(cfg, SHAPES[shape_name])
            if not ok:
                print(f"[dryrun] {arch} × {shape_name}: SKIP ({reason})", flush=True)
                results = [
                    r
                    for r in results
                    if not (r["arch"] == arch and r["shape"] == shape_name)
                ] + [
                    {
                        "arch": arch,
                        "shape": shape_name,
                        "mesh": "-",
                        "status": "skip",
                        "reason": reason,
                    }
                ]
                continue
            for mesh_name, mesh in meshes:
                if (arch, shape_name, mesh_name) in done:
                    continue
                try:
                    with mesh:
                        row = run_cell(
                            arch, shape_name, mesh, mesh_name, remat=args.remat
                        )
                    results.append(row)
                except Exception as e:  # a failure here is a bug in our sharding
                    failures += 1
                    traceback.print_exc()
                    results.append(
                        {
                            "arch": arch,
                            "shape": shape_name,
                            "mesh": mesh_name,
                            "status": "fail",
                            "error": f"{type(e).__name__}: {e}"[:500],
                        }
                    )
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, default=str)
    print(f"[dryrun] wrote {args.out}; failures={failures}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

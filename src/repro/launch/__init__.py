"""Launch layer: production mesh, sharding resolution, multi-pod dry-run,
roofline extraction, and the train/serve drivers."""

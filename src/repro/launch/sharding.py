"""Sharding resolution: logical axes → NamedSharding trees per (arch, shape).

The production policy (DESIGN.md §5):

* batch over ``("pod","data")`` (pure DP on the pod axis),
* TP over ``model`` (heads / ff columns / experts / lru width / vocab),
* FSDP over ``data`` (params + optimizer state; XLA all-gathers per layer),
* decode caches head-sharded when kv_heads divides the model axis, else
  sequence-sharded (flash-decode style partial softmax, handled by GSPMD
  reductions over the sharded seq dim),
* degenerate batches (long_500k: batch 1) replicate the batch axis.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec, input_specs
from repro.models import kvcache
from repro.models.common import DEFAULT_RULES, ParamSpec, logical_spec
from repro.models.transformer import param_specs


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def rules_for(cfg: ModelConfig, shape: ShapeSpec, mesh) -> dict[str, Any]:
    """Resolve the logical→mesh rules for one (arch, shape, mesh) cell.

    Explicit arg shardings require exact divisibility under GSPMD, so each
    logical axis falls back to replication when its size does not divide the
    mesh axis (e.g. llama3.2's 24 heads on a 16-wide model axis → attention
    params FSDP-only; the MLP keeps TP via d_ff).  These fallbacks are
    baseline policy — §Perf iterates on the ones that dominate the roofline.
    """
    rules = dict(DEFAULT_RULES)
    model = mesh_axis_size(mesh, "model")
    data = mesh_axis_size(mesh, "data")

    div = lambda n, m: (n > 0) and (n % m == 0)
    rules["heads"] = "model" if div(cfg.num_heads, model) else None
    rules["kv_heads"] = None  # replicated by default (GQA kv heads are few)
    rules["ff"] = "model" if div(cfg.d_ff, model) else None
    rules["vocab"] = "model" if div(cfg.vocab_size, model) else None
    rules["embed"] = "data" if div(cfg.d_model, data) else None
    if cfg.moe is not None:
        rules["expert"] = "model" if div(cfg.moe.num_experts, model) else None
    lru = cfg.lru_width or cfg.d_model
    rules["lru"] = "model" if div(lru, model) else None

    has_pod = "pod" in mesh.shape
    batch_axes = ("pod", "data") if has_pod else ("data",)
    dp = 1
    for a in batch_axes:
        dp *= mesh_axis_size(mesh, a)
    if shape.global_batch % dp != 0 or shape.global_batch < dp:
        # Degenerate batch (long_500k): replicate batch, keep TP.
        rules["batch"] = None
        rules["cache_batch"] = None
    else:
        rules["batch"] = batch_axes
        rules["cache_batch"] = batch_axes

    if shape.kind in ("decode", "prefill"):  # both produce/carry caches
        cap = min(shape.seq_len, cfg.max_seq_len)
        if div(cfg.num_kv_heads, model):
            rules["cache_heads"], rules["cache_seq"] = "model", None
        elif div(cap, model):
            # Sequence-sharded cache (flash-decode): kv heads replicated.
            rules["cache_heads"], rules["cache_seq"] = None, "model"
        else:
            rules["cache_heads"], rules["cache_seq"] = None, None
        if cfg.local_window and min(cfg.local_window, shape.seq_len) % model != 0:
            # Ring-buffer caches with non-dividing windows stay replicated.
            if rules["cache_heads"] is None:
                rules["cache_seq"] = None
    return rules


# ---------------------------------------------------------------------------
# Spec/shape trees
# ---------------------------------------------------------------------------


def param_shapes(cfg: ModelConfig) -> Any:
    dtype = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda s: ShapeDtypeStruct(s.shape, dtype),
        param_specs(cfg),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_shardings(cfg: ModelConfig, mesh, rules) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, logical_spec(s.logical, rules)),
        param_specs(cfg),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def opt_shapes(cfg: ModelConfig, optimizer) -> Any:
    from repro.optim.adamw import AdamWState

    ps = param_shapes(cfg)
    f32 = lambda sd: ShapeDtypeStruct(sd.shape, jnp.float32)
    return AdamWState(
        step=ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(f32, ps),
        v=jax.tree.map(f32, ps),
    )


def opt_shardings(cfg: ModelConfig, mesh, rules) -> Any:
    from repro.optim.adamw import AdamWState

    psh = param_shardings(cfg, mesh, rules)
    return AdamWState(
        step=NamedSharding(mesh, P()),
        m=psh,
        v=psh,
    )


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh, rules) -> dict:
    specs = input_specs(cfg, shape)
    out = {}
    for name, sd in specs.items():
        if sd.ndim == 3:  # (B, S, D) embeds
            spec = P(rules["batch"], rules["seq"], None)
        elif sd.ndim == 2:  # (B, S) tokens/labels
            spec = P(rules["batch"], rules["seq"])
        else:  # (B,) positions
            spec = P(rules["batch"])
        out[name] = NamedSharding(mesh, spec)
    return out


def cache_shapes(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    enc_len = shape.seq_len if cfg.is_encdec else 0
    return kvcache.cache_specs(cfg, shape.global_batch, shape.seq_len, enc_len=enc_len)


def cache_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh, rules) -> dict:
    logical = kvcache.cache_logical(cfg)
    shapes = cache_shapes(cfg, shape)
    return jax.tree.map(
        lambda ax, sd: NamedSharding(mesh, logical_spec(ax, rules)),
        logical,
        shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x
        ),
    )


def logits_sharding(cfg: ModelConfig, mesh, rules) -> NamedSharding:
    return NamedSharding(mesh, P(rules["batch"], None, rules["vocab"]))

"""The integrative adaptation framework (paper §4.1, Algorithm 1).

    1  for each node marked for removal in previous periods:
    2      if it holds no key groups:
    3          terminate it
    4  plan ← keyGroupAlloc()                    # balancing (+ collocation)
    5  if Scaling(plan):                         # decide USING the plan
    6      wait until new nodes are allocated
    7      plan ← keyGroupAlloc()                # re-plan integratively
    8  apply(plan)

The three sub-problems stay coupled through two levers: (i) the scaler sees
the *potential* plan, so balancing/collocation that would absorb an overload
suppresses scale-out, and un-balanceable scale-in is vetoed by the re-plan;
(ii) the allocator sees ``kill`` marks and the migration budget together, so
draining B competes with urgent rebalancing for the same budget (the paper's
Fig. 5 behaviour, guaranteed by Lemmas 1–2 to still converge to a full drain).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.albic import AlbicParams, albic
from repro.core.migration import MigrationPlan, plan_from_allocations
from repro.core.milp import AllocationPlan, solve_allocation
from repro.core.scaling import NullScaler, Scaler, ScalingDecision, apply_scaling
from repro.core.splitting import HotKeySplitter, SplitDecision
from repro.core.stats import ClusterState

Allocator = Callable[[ClusterState], AllocationPlan]


@dataclasses.dataclass
class AdaptationResult:
    state: ClusterState  # post-adaptation snapshot (alloc updated)
    plan: AllocationPlan
    migration_plan: MigrationPlan
    scaling: ScalingDecision
    terminated: list[int]
    # Advisory hot-key split/unsplit picks (None when no splitter is
    # configured); the controller applies them after the migrations run.
    split: Optional[SplitDecision] = None


@dataclasses.dataclass
class AdaptationFramework:
    """Periodic controller implementing Algorithm 1.

    ``mode`` selects the allocator: "milp" (pure §4.3.1) or "albic"
    (§4.3.2).  Budgets mirror the paper: exactly one of max_migr_cost /
    max_migrations (the latter for Flux-comparable experiments).
    """

    scaler: Scaler = dataclasses.field(default_factory=NullScaler)
    mode: str = "albic"
    # Optional hot-key splitting policy: when set, adapt() also emits an
    # advisory SplitDecision from the same snapshot (and the same
    # kg_tuple_rate leading signal) the allocation plan was computed from.
    splitter: Optional[HotKeySplitter] = None
    max_migr_cost: Optional[float] = None
    max_migrations: Optional[int] = None
    albic_params: AlbicParams = dataclasses.field(default_factory=AlbicParams)
    time_limit: float = 10.0
    alpha: float = 1.0
    # Previous period's kg_tuple_rate — the leading-load signal: ALBIC's
    # step-3 node scoring AND the MILP balance objective's gLoad vector
    # project with it (mirrors the scalers' rate projection; see
    # repro.core.scaling.rate_growth).
    _prev_rate: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False, compare=False
    )

    def _allocate(self, state: ClusterState) -> AllocationPlan:
        if self.mode == "albic":
            return albic(
                state,
                max_migr_cost=self.max_migr_cost,
                max_migrations=self.max_migrations,
                params=self.albic_params,
                prev_rate=self._prev_rate,
            ).plan
        return solve_allocation(
            state,
            max_migr_cost=self.max_migr_cost,
            max_migrations=self.max_migrations,
            alpha=self.alpha,
            time_limit=self.time_limit,
            prev_rate=self._prev_rate,
        )

    def adapt(
        self,
        state: ClusterState,
        *,
        split_families: Optional[dict] = None,
        split_eligible: Optional[np.ndarray] = None,
    ) -> AdaptationResult:
        """One adaptation period.  Returns the updated snapshot + artifacts.

        ``split_families`` / ``split_eligible`` carry the engine's live
        split map and mergeability mask to the splitter policy (ignored
        when no :attr:`splitter` is configured).
        """
        state = state.copy()

        # Lines 1–3: terminate drained nodes marked in previous periods.
        terminated: list[int] = []
        kg_per_node = np.bincount(state.alloc, minlength=state.num_nodes)
        for i in np.where(state.kill & state.alive)[0]:
            if kg_per_node[i] == 0:
                state.alive[i] = False
                terminated.append(int(i))

        # Line 4: potential allocation plan (balancing + collocation).
        plan = self._allocate(state)

        # Lines 5–7: scaling decision *on the plan*, then integrative re-plan.
        decision = self.scaler.decide(state, plan)
        if decision.scaled:
            state = apply_scaling(state, decision)
            plan = self._allocate(state)
            # Veto scale-in that the re-plan cannot balance: unmark nodes whose
            # removal leaves the survivors outside maxLD.
            if decision.mark_for_removal and self.mode == "albic":
                if plan.load_distance > self.albic_params.max_ld:
                    for i in decision.mark_for_removal:
                        state.kill[i] = False
                    decision = ScalingDecision()
                    plan = self._allocate(state)

        # Line 8: apply(plan) — emit the migration plan and commit the alloc.
        migration_plan = plan_from_allocations(state, plan.alloc, alpha=self.alpha)
        state.alloc = plan.alloc.copy()
        # Hot-key splitting rides the same snapshot: the splitter projects
        # with its own copy of the rate signal, so a surge that grows the
        # migration plan also surfaces the key group that migration cannot
        # fix.  The decision is advisory — the controller applies it against
        # the engine after the migrations execute.
        split = None
        if self.splitter is not None:
            split = self.splitter.decide(
                state, split_families or {}, eligible=split_eligible
            )
        # Remember this period's arrival rates for next period's projection.
        self._prev_rate = (
            None if state.kg_tuple_rate is None else state.kg_tuple_rate.copy()
        )
        return AdaptationResult(
            state=state,
            plan=plan,
            migration_plan=migration_plan,
            scaling=decision,
            terminated=terminated,
            split=split,
        )

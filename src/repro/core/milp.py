"""The paper's Mixed-Integer Linear Program (§4.3.1, Table 2).

    min  w1·d − w2·(d_u + d_l)
    s.t. (1) ∀ g_k:  Σ_i x[i,k] = 1
         (2) Σ_{i,k} (1 − q[i,k]) · x[i,k] · mc_k  ≤  maxMigrCost
         (3) ∀ n_i ∈ N:            Σ_k x[i,k]·gLoad_k ≤ mean + (d − d_u)
         (4) ∀ n_i ∈ N, kill_i=0:  Σ_k x[i,k]·gLoad_k ≥ mean − (d − d_l)
         (5) mean − d ≥ 0

with w1 ≫ w2 so d is minimized first and d_u + d_l maximized second.

Generalizations carried from the paper text:

* **Migration units** — ALBIC migrates collocated partitions as indivisible
  units, so the program is built over *units* (sets of key groups); the pure
  MILP is the special case of singleton units.
* **Heterogeneity** — gLoad coefficients are divided by the node capacity
  (paper §3 / "Extending to Heterogeneous Nodes").
* **Pin constraints** — ALBIC step 3 pins a unit to a node; implemented by
  fixing the corresponding binary's bounds.
* **maxMigrations mode** — for the Flux comparison (§5.2.1) the budget counts
  migrated key groups instead of migration cost.
* **Multi-dimensional load** — optional extra per-resource capacity rows
  ("Extending to Multi-Dimensional Load").
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.core.stats import ClusterState
from repro.solver.lp import MilpBuilder, solve_milp

# w1 >> w2 per the paper's objective discussion.
W1_DEFAULT = 1000.0
W2_DEFAULT = 1.0


@dataclasses.dataclass
class AllocationPlan:
    """Result of one key-group-allocation solve."""

    alloc: np.ndarray  # (G,) node per key group
    d: float
    d_u: float
    d_l: float
    objective: float
    status: str
    solve_seconds: float
    load_distance: float
    migrations: list[tuple[int, int, int]]  # (kg, src_node, dst_node)
    migration_cost: float

    @property
    def num_migrations(self) -> int:
        return len(self.migrations)


def _pad_units(
    unit_list: list[np.ndarray],
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad ragged units to a (nu, max_size) member matrix.

    Returns (members, valid, sizes): ``members`` holds key-group ids (0-padded),
    ``valid`` masks real entries, ``sizes`` is the per-unit member count.  Lets
    per-unit reductions (loads, migration costs) run as one masked sum.
    """
    nu = len(unit_list)
    sizes = np.fromiter((len(m) for m in unit_list), dtype=np.int64, count=nu)
    maxm = int(sizes.max()) if nu else 1
    members = np.zeros((nu, maxm), dtype=np.int64)
    valid = np.arange(maxm)[None, :] < sizes[:, None]
    members[valid] = np.concatenate(unit_list) if nu else []
    return members, valid, sizes


def _units_or_singletons(
    num_keygroups: int, units: Optional[Sequence[Sequence[int]]]
) -> list[np.ndarray]:
    if units is None:
        return [np.array([k]) for k in range(num_keygroups)]
    covered = np.zeros(num_keygroups, dtype=bool)
    out: list[np.ndarray] = []
    for u in units:
        arr = np.asarray(list(u), dtype=np.int64)
        if covered[arr].any():
            raise ValueError("units overlap")
        covered[arr] = True
        out.append(arr)
    for k in np.where(~covered)[0]:
        out.append(np.array([k]))
    return out


def solve_allocation(
    state: ClusterState,
    *,
    max_migr_cost: Optional[float] = None,
    max_migrations: Optional[int] = None,
    units: Optional[Sequence[Sequence[int]]] = None,
    pins: Optional[dict[int, int]] = None,
    alpha: float = 1.0,
    w1: float = W1_DEFAULT,
    w2: float = W2_DEFAULT,
    time_limit: float = 10.0,
    extra_resources: Optional[dict[str, tuple[np.ndarray, np.ndarray]]] = None,
    candidate_limit: Optional[int] = None,
    prev_rate: Optional[np.ndarray] = None,
) -> AllocationPlan:
    """Build and solve the Table-2 MILP; return the new allocation plan.

    Args:
      state: current cluster snapshot (q, gLoad, kill, capacities).
      prev_rate: previous period's per-key-group arrival rates.  When given
        (and the snapshot carries ``kg_tuple_rate``), the gLoad vector the
        balance objective optimizes is *projected one period ahead* by the
        clipped rate-growth ratios (``repro.core.scaling.rate_growth``) —
        a key group whose arrivals are surging weighs as the load it is
        about to impose, so the solver rebalances one period before the
        measured loads would force it.  The reported ``load_distance`` stays
        measured (it scores the plan against today's loads).
      max_migr_cost: budget on Σ mc_k of migrated key groups (paper default).
      max_migrations: alternative budget on the *count* of migrated key
        groups (used for the Flux comparison, §5.2.1).  Exactly one of the two
        budgets may be set; with neither, rebalancing is unrestricted (§5.2.2).
      units: indivisible sets of key groups (ALBIC partitions).  Key groups
        not covered become singleton units.
      pins: {unit_index_in_`units`: node} collocation constraints (ALBIC
        step 3).  Indexes into the *expanded* unit list returned by
        `_units_or_singletons`, i.e. the order of `units` first.
      alpha: state-size → migration-cost constant (mc_k = α·|σ_k|).
      extra_resources: optional {name: (kg_usage (G,), node_cap (N,))} rows.
      candidate_limit: beyond-paper scalability lever — restrict each unit's
        binaries to {current node} ∪ pins ∪ the k least-loaded A-nodes.  The
        paper's CPLEX solved the dense 72k-binary instances; HiGHS needs the
        pruning to hit the same few-second solve times at 60×1200 scale.
        Auto-enabled above 20k binaries.
    """
    if max_migr_cost is not None and max_migrations is not None:
        raise ValueError("set at most one of max_migr_cost / max_migrations")

    n, g = state.num_nodes, state.num_keygroups
    unit_list = _units_or_singletons(g, units)
    nu = len(unit_list)
    mc = state.migration_costs(alpha)
    # Effective gLoad: measured, or rate-projected when the leading signal
    # is available — the projection only ever raises loads, so it can move
    # a surge early but never hides one.
    kg_load = state.kg_load
    if prev_rate is not None:
        from repro.core.scaling import rate_growth

        growth = rate_growth(state, prev_rate)
        if growth is not None:
            kg_load = kg_load * growth
    node_loads = (
        np.bincount(state.alloc, weights=kg_load, minlength=n) / state.capacity
    )
    a_live = np.where(state.alive & ~state.kill)[0]
    mean = (
        math.ceil(float(node_loads[state.alive].sum()) / len(a_live))
        if len(a_live)
        else 0.0
    )
    live = state.alive  # dead nodes take no variables at all
    pins = pins or {}

    if candidate_limit is None and nu * int(live.sum()) > 20_000:
        candidate_limit = 8

    b = MilpBuilder()
    # Continuous deviation variables.  d ≤ mean encodes constraint (5).
    vd = b.add_var("d", obj=w1, lb=0.0, ub=max(mean, 0.0))
    vdu = b.add_var("d_u", obj=-w2, lb=0.0)
    vdl = b.add_var("d_l", obj=-w2, lb=0.0)

    members, valid, sizes = _pad_units(unit_list)
    mem_alloc = state.alloc[members]  # (nu, maxm); garbage where ~valid

    # Candidate mask (nu, n): which node each unit may be assigned to.  With
    # pruning: the k least-loaded A-nodes ∪ the unit's current homes ∪ pins.
    cand = np.zeros((nu, n), dtype=bool)
    live_nodes = np.where(live)[0]
    if candidate_limit is None:
        cand[:, live_nodes] = True
    else:
        loads = node_loads
        a_sorted = [i for i in np.argsort(loads) if live[i] and not state.kill[i]]
        cand[:, a_sorted[: max(candidate_limit, 1)]] = True
        home_ok = valid & live[mem_alloc]
        cand[np.nonzero(home_ok)[0], mem_alloc[home_ok]] = True
        for u, node in pins.items():
            cand[u, int(node)] = True

    # Assignment binaries x[u, i] for every candidate pair, allocated as one
    # contiguous block and scattered into the (nu, n) variable map.
    u_idx, i_idx = np.nonzero(cand)
    nbin = len(u_idx)
    xstart = b.add_binaries(nbin)
    bin_ids = xstart + np.arange(nbin, dtype=np.int64)
    xvar = np.full((nu, n), -1, dtype=np.int64)
    xvar[u_idx, i_idx] = bin_ids

    for u, node in pins.items():
        if not live[node]:
            raise ValueError(f"pin to dead node {node}")
        for i in live_nodes:
            idx = int(xvar[u, i])
            if idx < 0:
                continue
            # Fix bounds: 1 on the pinned node, 0 elsewhere.
            fixed = 1.0 if i == node else 0.0
            b.set_var_bounds(idx, fixed, fixed)

    # (1) each unit on exactly one node — one block row per unit.
    b.add_rows(u_idx, bin_ids, np.ones(nbin), num_rows=nu, lb=1.0, ub=1.0)

    # (2) migration budget.  Coefficient of x[u,i] is the cost of the members
    # of u that are not already on node i ((1−q)·mc summed over the unit).
    if max_migr_cost is not None or max_migrations is not None:
        moved = (mem_alloc[u_idx] != i_idx[:, None]) & valid[u_idx]
        if max_migrations is not None:
            cost = moved.sum(axis=1).astype(np.float64)
        else:
            cost = (mc[members][u_idx] * moved).sum(axis=1)
        budget = float(max_migrations if max_migrations is not None else max_migr_cost)
        nz = cost > 0
        if nz.any():
            b.add_row(bin_ids[nz], cost[nz], ub=budget)

    # (3)/(4) load bounds per node, assembled node-major from the candidate
    # mask transpose.  Heterogeneity: divide by capacity.  Nodes without any
    # candidate binary (pruned) cannot receive anything and need no bound.
    unit_load = (kg_load[members] * valid).sum(axis=1)
    iT, uT = np.nonzero(cand.T)
    colsT = xvar[uT, iT]
    loadT = unit_load[uT] / state.capacity[iT]
    nodes3 = np.unique(iT)
    m3 = len(nodes3)
    # (3): Σ load·x − d + d_u ≤ mean   (all live nodes, incl. B)
    b.add_rows(
        np.concatenate([np.searchsorted(nodes3, iT), np.arange(m3), np.arange(m3)]),
        np.concatenate([colsT, np.full(m3, vd), np.full(m3, vdu)]),
        np.concatenate([loadT, -np.ones(m3), np.ones(m3)]),
        num_rows=m3,
        ub=float(mean),
    )
    # (4): Σ load·x + d − d_l ≥ mean   (only nodes not marked for removal)
    keep = ~state.kill[iT]
    nodes4 = nodes3[~state.kill[nodes3]]
    m4 = len(nodes4)
    if m4:
        b.add_rows(
            np.concatenate(
                [np.searchsorted(nodes4, iT[keep]), np.arange(m4), np.arange(m4)]
            ),
            np.concatenate([colsT[keep], np.full(m4, vd), np.full(m4, vdl)]),
            np.concatenate([loadT[keep], np.ones(m4), -np.ones(m4)]),
            num_rows=m4,
            lb=float(mean),
        )

    # Multi-dimensional load extension: cap each extra resource per node.
    for _name, (usage, caps) in (extra_resources or {}).items():
        res_unit = (np.asarray(usage)[members] * valid).sum(axis=1)
        b.add_rows(
            np.searchsorted(nodes3, iT),
            colsT,
            res_unit[uT],
            num_rows=m3,
            ub=np.asarray(caps, dtype=np.float64)[nodes3],
        )

    problem = b.build()
    # Warm start: keep every unit where its (first member) currently lives.
    warm = np.zeros(problem.num_vars)
    warm[0] = mean
    homes = mem_alloc[:, 0]
    home_x = xvar[np.arange(nu), homes]
    keep_home = live[homes] & (home_x >= 0)
    warm[home_x[keep_home]] = 1.0
    result = solve_milp(problem, time_limit=time_limit, warm_start=warm)

    if not result.ok:
        # Infeasible (e.g. budget too tight for pins): fall back to identity.
        return AllocationPlan(
            alloc=state.alloc.copy(),
            d=float("nan"),
            d_u=0.0,
            d_l=0.0,
            objective=float("inf"),
            status=result.status,
            solve_seconds=result.solve_seconds,
            load_distance=state.load_distance(),
            migrations=[],
            migration_cost=0.0,
        )

    x = result.x
    alloc = state.alloc.copy()
    scores = np.full((nu, n), -1.0)
    scores[u_idx, i_idx] = x[bin_ids]
    best = np.argmax(scores, axis=1)
    alloc[members[valid]] = np.repeat(best, sizes)

    moved = np.where(alloc != state.alloc)[0]
    migrations = [(int(k), int(state.alloc[k]), int(alloc[k])) for k in moved]
    return AllocationPlan(
        alloc=alloc,
        d=float(x[vd]),
        d_u=float(x[vdu]),
        d_l=float(x[vdl]),
        objective=result.objective,
        status=result.status,
        solve_seconds=result.solve_seconds,
        load_distance=state.load_distance(alloc),
        migrations=migrations,
        migration_cost=float(mc[moved].sum()),
    )

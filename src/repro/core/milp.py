"""The paper's Mixed-Integer Linear Program (§4.3.1, Table 2).

    min  w1·d − w2·(d_u + d_l)
    s.t. (1) ∀ g_k:  Σ_i x[i,k] = 1
         (2) Σ_{i,k} (1 − q[i,k]) · x[i,k] · mc_k  ≤  maxMigrCost
         (3) ∀ n_i ∈ N:            Σ_k x[i,k]·gLoad_k ≤ mean + (d − d_u)
         (4) ∀ n_i ∈ N, kill_i=0:  Σ_k x[i,k]·gLoad_k ≥ mean − (d − d_l)
         (5) mean − d ≥ 0

with w1 ≫ w2 so d is minimized first and d_u + d_l maximized second.

Generalizations carried from the paper text:

* **Migration units** — ALBIC migrates collocated partitions as indivisible
  units, so the program is built over *units* (sets of key groups); the pure
  MILP is the special case of singleton units.
* **Heterogeneity** — gLoad coefficients are divided by the node capacity
  (paper §3 / "Extending to Heterogeneous Nodes").
* **Pin constraints** — ALBIC step 3 pins a unit to a node; implemented by
  fixing the corresponding binary's bounds.
* **maxMigrations mode** — for the Flux comparison (§5.2.1) the budget counts
  migrated key groups instead of migration cost.
* **Multi-dimensional load** — optional extra per-resource capacity rows
  ("Extending to Multi-Dimensional Load").
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.stats import ClusterState
from repro.solver.lp import MilpBuilder, solve_milp

# w1 >> w2 per the paper's objective discussion.
W1_DEFAULT = 1000.0
W2_DEFAULT = 1.0


@dataclasses.dataclass
class AllocationPlan:
    """Result of one key-group-allocation solve."""

    alloc: np.ndarray  # (G,) node per key group
    d: float
    d_u: float
    d_l: float
    objective: float
    status: str
    solve_seconds: float
    load_distance: float
    migrations: list[tuple[int, int, int]]  # (kg, src_node, dst_node)
    migration_cost: float

    @property
    def num_migrations(self) -> int:
        return len(self.migrations)


def _units_or_singletons(
    num_keygroups: int, units: Optional[Sequence[Sequence[int]]]
) -> list[np.ndarray]:
    if units is None:
        return [np.array([k]) for k in range(num_keygroups)]
    covered = np.zeros(num_keygroups, dtype=bool)
    out: list[np.ndarray] = []
    for u in units:
        arr = np.asarray(list(u), dtype=np.int64)
        if covered[arr].any():
            raise ValueError("units overlap")
        covered[arr] = True
        out.append(arr)
    for k in np.where(~covered)[0]:
        out.append(np.array([k]))
    return out


def solve_allocation(
    state: ClusterState,
    *,
    max_migr_cost: Optional[float] = None,
    max_migrations: Optional[int] = None,
    units: Optional[Sequence[Sequence[int]]] = None,
    pins: Optional[dict[int, int]] = None,
    alpha: float = 1.0,
    w1: float = W1_DEFAULT,
    w2: float = W2_DEFAULT,
    time_limit: float = 10.0,
    extra_resources: Optional[dict[str, tuple[np.ndarray, np.ndarray]]] = None,
    candidate_limit: Optional[int] = None,
) -> AllocationPlan:
    """Build and solve the Table-2 MILP; return the new allocation plan.

    Args:
      state: current cluster snapshot (q, gLoad, kill, capacities).
      max_migr_cost: budget on Σ mc_k of migrated key groups (paper default).
      max_migrations: alternative budget on the *count* of migrated key
        groups (used for the Flux comparison, §5.2.1).  Exactly one of the two
        budgets may be set; with neither, rebalancing is unrestricted (§5.2.2).
      units: indivisible sets of key groups (ALBIC partitions).  Key groups
        not covered become singleton units.
      pins: {unit_index_in_`units`: node} collocation constraints (ALBIC
        step 3).  Indexes into the *expanded* unit list returned by
        `_units_or_singletons`, i.e. the order of `units` first.
      alpha: state-size → migration-cost constant (mc_k = α·|σ_k|).
      extra_resources: optional {name: (kg_usage (G,), node_cap (N,))} rows.
      candidate_limit: beyond-paper scalability lever — restrict each unit's
        binaries to {current node} ∪ pins ∪ the k least-loaded A-nodes.  The
        paper's CPLEX solved the dense 72k-binary instances; HiGHS needs the
        pruning to hit the same few-second solve times at 60×1200 scale.
        Auto-enabled above 20k binaries.
    """
    if max_migr_cost is not None and max_migrations is not None:
        raise ValueError("set at most one of max_migr_cost / max_migrations")

    n, g = state.num_nodes, state.num_keygroups
    unit_list = _units_or_singletons(g, units)
    nu = len(unit_list)
    mc = state.migration_costs(alpha)
    mean = state.mean_load()
    live = state.alive  # dead nodes take no variables at all
    pins = pins or {}

    if candidate_limit is None and nu * int(live.sum()) > 20_000:
        candidate_limit = 8

    b = MilpBuilder()
    # Continuous deviation variables.  d ≤ mean encodes constraint (5).
    vd = b.add_var("d", obj=w1, lb=0.0, ub=max(mean, 0.0))
    vdu = b.add_var("d_u", obj=-w2, lb=0.0)
    vdl = b.add_var("d_l", obj=-w2, lb=0.0)

    # Assignment binaries x[u, i], only for live nodes (optionally pruned to
    # per-unit candidate sets).
    live_nodes = np.where(live)[0]
    if candidate_limit is not None:
        loads = state.node_loads()
        a_sorted = [i for i in np.argsort(loads) if live[i] and not state.kill[i]]
        base_cands = a_sorted[: max(candidate_limit, 1)]
    xvar = -np.ones((nu, n), dtype=np.int64)
    for u in range(nu):
        if candidate_limit is None:
            cands = live_nodes
        else:
            cset = set(base_cands)
            for k in unit_list[u]:
                home = int(state.alloc[k])
                if live[home]:
                    cset.add(home)
            if u in pins:
                cset.add(int(pins[u]))
            cands = sorted(cset)
        for i in cands:
            xvar[u, i] = b.add_binary(f"x[{u},{int(i)}]")

    for u, node in pins.items():
        if not live[node]:
            raise ValueError(f"pin to dead node {node}")
        for i in live_nodes:
            idx = xvar[u, i]
            if idx < 0:
                continue
            # Fix bounds: 1 on the pinned node, 0 elsewhere.
            b._lb[idx] = 1.0 if i == node else 0.0  # noqa: SLF001 - builder-internal fastpath
            b._ub[idx] = 1.0 if i == node else 0.0  # noqa: SLF001

    # (1) each unit on exactly one node.
    for u in range(nu):
        cols = [xvar[u, i] for i in live_nodes if xvar[u, i] >= 0]
        b.add_row(cols, [1.0] * len(cols), lb=1.0, ub=1.0)

    # (2) migration budget.  Coefficient of x[u,i] is the cost of the members
    # of u that are not already on node i ((1−q)·mc summed over the unit).
    if max_migr_cost is not None or max_migrations is not None:
        cols, vals = [], []
        for u, members in enumerate(unit_list):
            cur = state.alloc[members]
            for i in live_nodes:
                if xvar[u, i] < 0:
                    continue
                moved = cur != i
                cost = (
                    float(moved.sum())
                    if max_migrations is not None
                    else float(mc[members][moved].sum())
                )
                if cost > 0:
                    cols.append(xvar[u, i])
                    vals.append(cost)
        budget = float(max_migrations if max_migrations is not None else max_migr_cost)
        if cols:
            b.add_row(cols, vals, ub=budget)

    # (3)/(4) load bounds per node.  Heterogeneity: divide by capacity.
    unit_load = np.array([state.kg_load[m].sum() for m in unit_list])
    for i in live_nodes:
        us = [u for u in range(nu) if xvar[u, i] >= 0]
        if not us:
            continue  # pruned node: cannot receive anything, no bound needed
        cols = [xvar[u, i] for u in us]
        vals = list(unit_load[us] / state.capacity[i])
        # (3): Σ load·x − d + d_u ≤ mean   (all live nodes, incl. B)
        b.add_row(cols + [vd, vdu], vals + [-1.0, 1.0], ub=float(mean))
        # (4): Σ load·x + d − d_l ≥ mean   (only nodes not marked for removal)
        if not state.kill[i]:
            b.add_row(cols + [vd, vdl], vals + [1.0, -1.0], lb=float(mean))

    # Multi-dimensional load extension: cap each extra resource per node.
    for _name, (usage, caps) in (extra_resources or {}).items():
        res_unit = np.array([usage[m].sum() for m in unit_list])
        for i in live_nodes:
            us = [u for u in range(nu) if xvar[u, i] >= 0]
            if not us:
                continue
            cols = [xvar[u, i] for u in us]
            b.add_row(cols, list(res_unit[us]), ub=float(caps[i]))

    problem = b.build()
    # Warm start: keep every unit where its (first member) currently lives.
    warm = np.zeros(problem.num_vars)
    warm[0] = mean
    for u, members in enumerate(unit_list):
        home = int(state.alloc[members[0]])
        if live[home] and xvar[u, home] >= 0:
            warm[xvar[u, home]] = 1.0
    result = solve_milp(problem, time_limit=time_limit, warm_start=warm)

    if not result.ok:
        # Infeasible (e.g. budget too tight for pins): fall back to identity.
        return AllocationPlan(
            alloc=state.alloc.copy(),
            d=float("nan"),
            d_u=0.0,
            d_l=0.0,
            objective=float("inf"),
            status=result.status,
            solve_seconds=result.solve_seconds,
            load_distance=state.load_distance(),
            migrations=[],
            migration_cost=0.0,
        )

    x = result.x
    alloc = state.alloc.copy()
    for u, members in enumerate(unit_list):
        scores = np.array([x[xvar[u, i]] if xvar[u, i] >= 0 else -1.0 for i in range(n)])
        alloc[members] = int(np.argmax(scores))

    moved = np.where(alloc != state.alloc)[0]
    migrations = [(int(k), int(state.alloc[k]), int(alloc[k])) for k in moved]
    return AllocationPlan(
        alloc=alloc,
        d=float(x[vd]),
        d_u=float(x[vdu]),
        d_l=float(x[vdl]),
        objective=result.objective,
        status=result.status,
        solve_seconds=result.solve_seconds,
        load_distance=state.load_distance(alloc),
        migrations=migrations,
        migration_cost=float(mc[moved].sum()),
    )

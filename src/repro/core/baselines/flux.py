"""Flux [36] — adaptive pairwise partition movement (paper §2.2, §5.2.1).

At the end of each period, nodes are sorted by load descending.  The most
loaded node is paired with the least loaded, the 2nd with the 2nd-last, and so
on; within each pair Flux moves the *largest suitable* partition (key group)
from donor to receiver — "suitable" meaning the move reduces the pair's load
imbalance (it must not overshoot past the mean of the pair).  The number of
migrations per period is capped (maxMigrations), which is exactly the knob the
paper matches its MILP against in §5.2.1.
"""

from __future__ import annotations

import numpy as np

from repro.core.milp import AllocationPlan
from repro.core.stats import ClusterState


def flux_rebalance(state: ClusterState, *, max_migrations: int = 13) -> AllocationPlan:
    alloc = state.alloc.copy()
    budget = max_migrations
    loads = state.node_loads(alloc).copy()
    live = np.where(state.alive)[0]
    migrations: list[tuple[int, int, int]] = []

    order = live[np.argsort(-loads[live])]
    i, j = 0, len(order) - 1
    while i < j and budget > 0:
        donor, receiver = int(order[i]), int(order[j])
        moved_any = False
        # Keep moving the biggest suitable key group donor→receiver while the
        # pair's imbalance shrinks and budget remains.
        while budget > 0:
            gap = loads[donor] - loads[receiver]
            if gap <= 0:
                break
            kgs = np.where(alloc == donor)[0]
            if len(kgs) == 0:
                break
            # Largest key group that still fits in half the gap (no overshoot).
            g_loads = state.kg_load[kgs] / state.capacity[receiver]
            suitable = kgs[g_loads <= gap / 2.0 + 1e-12]
            if len(suitable) == 0:
                break
            pick = int(suitable[np.argmax(state.kg_load[suitable])])
            alloc[pick] = receiver
            delta = state.kg_load[pick]
            loads[donor] -= delta / state.capacity[donor]
            loads[receiver] += delta / state.capacity[receiver]
            migrations.append((pick, donor, receiver))
            budget -= 1
            moved_any = True
        i += 1
        j -= 1
        if not moved_any and budget <= 0:
            break

    mc = state.migration_costs()
    moved = [m[0] for m in migrations]
    return AllocationPlan(
        alloc=alloc,
        d=float("nan"),
        d_u=0.0,
        d_l=0.0,
        objective=float("nan"),
        status="heuristic",
        solve_seconds=0.0,
        load_distance=state.load_distance(alloc),
        migrations=migrations,
        migration_cost=float(mc[moved].sum()) if moved else 0.0,
    )

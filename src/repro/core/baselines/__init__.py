"""Baselines the paper compares against: Flux [36], PoTC [29], COLA [21]."""

from repro.core.baselines.cola import cola_allocate
from repro.core.baselines.flux import flux_rebalance
from repro.core.baselines.potc import PotcSimulator

__all__ = ["flux_rebalance", "PotcSimulator", "cola_allocate"]

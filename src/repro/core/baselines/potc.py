"""The Power of Two Choices (PoTC) [29] (paper §2.2, §5.2.1).

Each key x has two candidate downstream instances h1(x), h2(x); every sender
routes x's tuples to whichever candidate is currently less loaded.  State for
a key is therefore *split* across two instances and must be merged (each
window) before the final computation — a continuous overhead that exists even
when no balancing is needed, and whose cost varies with the split state sizes,
skewing load in a way PoTC itself does not see (the effect the paper
demonstrates in Fig. 6).

This simulator reproduces those dynamics at key-group granularity: each key
group k has two candidate nodes (hash-derived); per period its input rate is
routed greedily to the lighter candidate; merge load proportional to the
*smaller* split fraction's accumulated state is charged to the candidate
hosting the merge (the first hash choice — the merge "cannot be balanced").
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.stats import ClusterState


@dataclasses.dataclass
class PotcSimulator:
    state: ClusterState
    merge_cost_factor: float = 0.25  # load points of merge per split-state unit
    seed: int = 0

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        g, n = self.state.num_keygroups, self.state.num_nodes
        self.h1 = rng.integers(0, n, size=g)
        self.h2 = (self.h1 + 1 + rng.integers(0, n - 1, size=g)) % n
        # Fraction of each key group's state accumulated at its h2 replica.
        self.split_frac = np.zeros(g)

    def step(self, kg_load: np.ndarray) -> tuple[np.ndarray, float]:
        """One SPL: greedy two-choice routing; returns (node_loads, load_distance)."""
        n = self.state.num_nodes
        loads = np.zeros(n)
        # Route in descending-load order (heavy hitters first, as senders do).
        order = np.argsort(-kg_load)
        for k in order:
            a, b = int(self.h1[k]), int(self.h2[k])
            if loads[a] <= loads[b]:
                loads[a] += kg_load[k]
                self.split_frac[k] = 0.9 * self.split_frac[k]  # decays toward h1
            else:
                loads[b] += kg_load[k]
                self.split_frac[k] = 0.9 * self.split_frac[k] + 0.1
        # Merge overhead: charged at h1, proportional to split state moved.
        for k in range(len(kg_load)):
            split = min(self.split_frac[k], 1.0 - self.split_frac[k]) * 2.0
            loads[self.h1[k]] += (
                self.merge_cost_factor * split * self.state.kg_state_bytes[k] * 0.01
            )
        loads = loads / self.state.capacity
        live = self.state.nodes_a
        mean = loads[live].mean() if len(live) else 0.0
        ld = float(np.max(np.abs(loads[live] - mean))) if len(live) else 0.0
        return loads, ld

    @property
    def continuous_overhead(self) -> float:
        """Total merge load charged last period even with perfect balance."""
        split = np.minimum(self.split_frac, 1.0 - self.split_frac) * 2.0
        return float(
            (self.merge_cost_factor * split * self.state.kg_state_bytes * 0.01).sum()
        )

"""COLA [21] — balanced graph partitioning scheduler (paper §2.1, §5.3–5.4).

COLA optimizes load balance *and* cross-node communication by partitioning the
operator (here: key-group) graph into |A| balanced parts with minimum weighted
edge cut: it starts from one partition and keeps splitting until the load
balance constraint is met.  It is a *static* optimizer: invoked at runtime it
re-partitions from scratch, so the resulting allocation is near-optimal in
collocation but pays massive migrations (paper Fig. 12: ~200 key groups per
period vs ALBIC's 10) — which is precisely the behaviour the comparison needs.

Part→node mapping greedily maximizes overlap with the current allocation (the
most charitable choice for COLA; anything else would inflate its migration
count further).
"""

from __future__ import annotations

import numpy as np

from repro.core.milp import AllocationPlan
from repro.core.stats import ClusterState
from repro.solver.graphpart import Graph, partition_graph


def cola_allocate(
    state: ClusterState,
    *,
    balance_tol: float = 0.10,
    seed: int = 0,
) -> AllocationPlan:
    live = state.nodes_a
    nparts = len(live)
    g = state.num_keygroups

    eu, ev, ew = state.out_pairs.symmetric_edges()
    graph = Graph(
        num_vertices=g,
        edge_u=eu,
        edge_v=ev,
        edge_w=ew,
        vertex_w=np.maximum(state.kg_load, 1e-9),
    )
    labels = partition_graph(graph, nparts, balance_tol=balance_tol, seed=seed)

    # Greedy max-overlap part→node mapping (minimizes COLA's migrations).
    overlap = np.zeros((nparts, nparts))  # parts × live nodes
    node_pos = {int(nd): j for j, nd in enumerate(live)}
    for k in range(g):
        cur = int(state.alloc[k])
        if cur in node_pos:
            overlap[labels[k], node_pos[cur]] += state.kg_load[k]
    part_to_node = -np.ones(nparts, dtype=np.int64)
    taken = np.zeros(nparts, dtype=bool)
    flat_order = np.argsort(-overlap, axis=None)
    order = np.dstack(np.unravel_index(flat_order, overlap.shape))[0]
    for p, j in order:
        if part_to_node[p] < 0 and not taken[j]:
            part_to_node[p] = live[j]
            taken[j] = True
    for p in range(nparts):  # any leftovers
        if part_to_node[p] < 0:
            part_to_node[p] = live[int(np.argmin(taken))]
            taken[int(np.argmin(taken))] = True

    alloc = part_to_node[labels]
    moved = np.where(alloc != state.alloc)[0]
    mc = state.migration_costs()
    return AllocationPlan(
        alloc=alloc,
        d=float("nan"),
        d_u=0.0,
        d_l=0.0,
        objective=float("nan"),
        status="heuristic",
        solve_seconds=0.0,
        load_distance=state.load_distance(alloc),
        migrations=[(int(k), int(state.alloc[k]), int(alloc[k])) for k in moved],
        migration_cost=float(mc[moved].sum()),
    )

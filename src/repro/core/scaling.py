"""Horizontal scaling policies (paper §4.2).

The paper deliberately plugs in *existing* scaling estimators ([10, 12]) —
"developing a novel scaling optimizer is outside the scope".  We do the same:
:class:`UtilizationScaler` is the standard watermark policy used by Gedik et
al. [12]; :class:`LatencyProxyScaler` approximates DRS [10] with an M/M/1-style
latency proxy.  What the paper *does* contribute is the integration contract
(Algorithm 1): the scaler decides **on the basis of the potential allocation
plan**, so load that mere re-balancing or collocation would absorb never
triggers a scale-out, and scale-in is refused when the survivors could not be
balanced.  That contract is enforced in :mod:`repro.core.framework`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Protocol

import numpy as np

from repro.core.milp import AllocationPlan
from repro.core.stats import ClusterState


@dataclasses.dataclass
class ScalingDecision:
    add_nodes: int = 0
    mark_for_removal: list[int] = dataclasses.field(default_factory=list)

    @property
    def scaled(self) -> bool:
        return self.add_nodes > 0 or bool(self.mark_for_removal)


class Scaler(Protocol):
    def decide(self, state: ClusterState, plan: AllocationPlan) -> ScalingDecision: ...


@dataclasses.dataclass
class UtilizationScaler:
    """Watermark policy over the *planned* (not current) node loads.

    Scale out when the planned average load exceeds ``high_wm`` (enough nodes
    to bring it to ``target``); scale in when it sits below ``low_wm`` and the
    survivors stay under ``target`` — Algorithm 1 re-plans afterwards and will
    veto the removal if balance under ``maxLD`` is unattainable.
    """

    high_wm: float = 80.0
    low_wm: float = 40.0
    target: float = 60.0
    max_step: int = 8  # nodes added/removed per adaptation round

    def decide(self, state: ClusterState, plan: AllocationPlan) -> ScalingDecision:
        a = state.nodes_a
        if len(a) == 0:
            return ScalingDecision(add_nodes=1)
        loads = state.node_loads(plan.alloc)
        avg = float(loads[a].mean())
        total = float((loads[a] * state.capacity[a]).sum())
        if avg > self.high_wm:
            want = math.ceil(total / self.target)
            return ScalingDecision(add_nodes=min(max(want - len(a), 1), self.max_step))
        if avg < self.low_wm and len(a) > 1:
            keep = max(math.ceil(total / self.target), 1)
            drop = min(len(a) - keep, self.max_step)
            if drop <= 0:
                return ScalingDecision()
            # Prefer removing the least-loaded nodes: cheapest to drain.
            order = a[np.argsort(loads[a])]
            return ScalingDecision(mark_for_removal=[int(i) for i in order[:drop]])
        return ScalingDecision()


@dataclasses.dataclass
class LatencyProxyScaler:
    """DRS-style [10] latency-constrained sizing with an M/M/1 proxy.

    Expected queueing delay on a node with utilization ρ scales as ρ/(1−ρ);
    size the cluster so the *maximum planned* utilization keeps the proxy
    under ``latency_budget`` (expressed in the same arbitrary units).
    """

    latency_budget: float = 4.0  # ρ/(1−ρ) ≤ budget  ⇒  ρ ≤ b/(1+b)
    max_step: int = 8

    def decide(self, state: ClusterState, plan: AllocationPlan) -> ScalingDecision:
        a = state.nodes_a
        if len(a) == 0:
            return ScalingDecision(add_nodes=1)
        rho_cap = 100.0 * self.latency_budget / (1.0 + self.latency_budget)
        loads = state.node_loads(plan.alloc)
        peak = float(loads[a].max())
        total = float((loads[a] * state.capacity[a]).sum())
        if peak > rho_cap:
            want = math.ceil(total / rho_cap)
            return ScalingDecision(add_nodes=min(max(want - len(a), 1), self.max_step))
        # Scale in when even after consolidation the cap holds with slack.
        if len(a) > 1:
            keep = max(math.ceil(total / (0.8 * rho_cap)), 1)
            drop = min(len(a) - keep, self.max_step)
            if drop > 0:
                order = a[np.argsort(loads[a])]
                return ScalingDecision(mark_for_removal=[int(i) for i in order[:drop]])
        return ScalingDecision()


@dataclasses.dataclass
class NullScaler:
    """Never scales — pure load-balancing mode (used by several benchmarks)."""

    def decide(self, state: ClusterState, plan: AllocationPlan) -> ScalingDecision:  # noqa: ARG002
        return ScalingDecision()


def apply_scaling(
    state: ClusterState,
    decision: ScalingDecision,
    *,
    new_node_capacity: float = 1.0,
) -> ClusterState:
    """Materialize a scaling decision on the cluster snapshot.

    Adding nodes grows every per-node array (simulating instant provisioning;
    Algorithm 1's "wait until new nodes are allocated").  Marking nodes only
    flips ``kill`` — draining and termination are the MILP's and the
    framework's job respectively (Lemmas 1–2).
    """
    out = state.copy()
    if decision.add_nodes > 0:
        n_new = decision.add_nodes
        out.num_nodes += n_new
        out.capacity = np.concatenate([out.capacity, np.full(n_new, new_node_capacity)])
        out.kill = np.concatenate([out.kill, np.zeros(n_new, dtype=bool)])
        out.alive = np.concatenate([out.alive, np.ones(n_new, dtype=bool)])
    for i in decision.mark_for_removal:
        out.kill[i] = True
    return out

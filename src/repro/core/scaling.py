"""Horizontal scaling policies (paper §4.2).

The paper deliberately plugs in *existing* scaling estimators ([10, 12]) —
"developing a novel scaling optimizer is outside the scope".  We do the same:
:class:`UtilizationScaler` is the standard watermark policy used by Gedik et
al. [12]; :class:`LatencyProxyScaler` approximates DRS [10] with an M/M/1-style
latency proxy.  What the paper *does* contribute is the integration contract
(Algorithm 1): the scaler decides **on the basis of the potential allocation
plan**, so load that mere re-balancing or collocation would absorb never
triggers a scale-out, and scale-in is refused when the survivors could not be
balanced.  That contract is enforced in :mod:`repro.core.framework`.

Both scalers additionally consume ``ClusterState.kg_tuple_rate`` — the
per-key-group arrival rates measured from the partition histograms — as a
*leading* load signal: CPU load lags arrivals by up to one statistics period
(tuples admitted late in the period are still queued), so a key group whose
arrival rate is surging will overload its node one period before the
utilization watermark sees it.  The scalers remember the previous period's
rates, project each key group's load forward by its (clipped) rate-growth
ratio, and scale out as soon as the *projected* planned loads breach the
watermark.  The projection only ever raises loads (growth is clipped to
``[1, max growth]``), so it can trigger a scale-out early but never masks
one; scale-in additionally requires the projection to agree, so surging
arrivals also veto premature consolidation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Protocol

import numpy as np

from repro.core.milp import AllocationPlan
from repro.core.stats import ClusterState


@dataclasses.dataclass
class ScalingDecision:
    add_nodes: int = 0
    mark_for_removal: list[int] = dataclasses.field(default_factory=list)

    @property
    def scaled(self) -> bool:
        return self.add_nodes > 0 or bool(self.mark_for_removal)


class Scaler(Protocol):
    def decide(self, state: ClusterState, plan: AllocationPlan) -> ScalingDecision: ...


# Per-key-group rate growth is clipped to this factor before projecting load
# forward: small-sample Poisson noise easily produces 2× single-kg ratios,
# but a genuine hotspot sustains them across most of its tuples, so the cap
# bounds the damage of noise while keeping real surges visible.
MAX_RATE_GROWTH = 4.0


def rate_growth(
    state: ClusterState,
    prev_rate: Optional[np.ndarray],
    *,
    max_growth: float = MAX_RATE_GROWTH,
    min_rate: float = 0.5,
) -> Optional[np.ndarray]:
    """Per-key-group arrival-rate growth ratios versus the previous period.

    Clipped to ``[1, max_growth]``; key groups below ``min_rate``
    tuples/tick previously stay at 1 — their ratios are noise.  Returns
    None when rates are unavailable for either period.  This is the shared
    leading-load signal: the scalers and ALBIC's step-3 scoring project
    node loads with it, and :func:`repro.core.milp.solve_allocation` scales
    its gLoad vector by it so the balance objective itself anticipates the
    surge.
    """
    cur = state.kg_tuple_rate
    if cur is None or prev_rate is None or len(prev_rate) != len(cur):
        return None
    growth = np.ones_like(cur)
    meaningful = prev_rate >= min_rate
    growth[meaningful] = cur[meaningful] / prev_rate[meaningful]
    np.clip(growth, 1.0, max_growth, out=growth)
    return growth


def projected_loads(
    state: ClusterState,
    alloc: np.ndarray,
    prev_rate: Optional[np.ndarray],
    *,
    max_growth: float = MAX_RATE_GROWTH,
    min_rate: float = 0.5,
) -> Optional[np.ndarray]:
    """Planned node loads one period ahead, using arrival-rate growth.

    Each key group's measured ``gLoad`` is scaled by its
    :func:`rate_growth` ratio.  Returns None when rates are unavailable for
    either period, so callers fall back to utilization-only behaviour.
    """
    growth = rate_growth(
        state, prev_rate, max_growth=max_growth, min_rate=min_rate
    )
    if growth is None:
        return None
    raw = np.bincount(alloc, weights=state.kg_load * growth, minlength=state.num_nodes)
    return raw / state.capacity


def _take_rate_projection(scaler, state: ClusterState, alloc: np.ndarray):
    """One period's leading-load bookkeeping, shared by both scalers: compute
    the projected planned loads from the previous period's rates (None when
    disabled or unavailable), then remember this period's rates."""
    proj = (
        projected_loads(state, alloc, scaler._prev_rate)
        if scaler.use_rate_signal
        else None
    )
    scaler._prev_rate = (
        None if state.kg_tuple_rate is None else state.kg_tuple_rate.copy()
    )
    return proj


@dataclasses.dataclass
class UtilizationScaler:
    """Watermark policy over the *planned* (not current) node loads.

    Scale out when the planned average load exceeds ``high_wm`` (enough nodes
    to bring it to ``target``); scale in when it sits below ``low_wm`` and the
    survivors stay under ``target`` — Algorithm 1 re-plans afterwards and will
    veto the removal if balance under ``maxLD`` is unattainable.

    With ``use_rate_signal`` (default) the per-key-group arrival rates lead
    the decision: loads projected by rate growth can breach ``high_wm`` a
    period before the measured loads do, and surging rates veto scale-in.
    """

    high_wm: float = 80.0
    low_wm: float = 40.0
    target: float = 60.0
    max_step: int = 8  # nodes added/removed per adaptation round
    use_rate_signal: bool = True
    _prev_rate: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False, compare=False
    )

    def decide(self, state: ClusterState, plan: AllocationPlan) -> ScalingDecision:
        a = state.nodes_a
        if len(a) == 0:
            return ScalingDecision(add_nodes=1)
        proj = _take_rate_projection(self, state, plan.alloc)
        loads = state.node_loads(plan.alloc)
        avg = float(loads[a].mean())
        total = float((loads[a] * state.capacity[a]).sum())
        if avg > self.high_wm:
            want = math.ceil(total / self.target)
            return ScalingDecision(add_nodes=min(max(want - len(a), 1), self.max_step))
        if proj is not None and float(proj[a].mean()) > self.high_wm:
            # Leading signal: arrivals are surging into key groups whose load
            # will breach the watermark next period — provision now.
            total_p = float((proj[a] * state.capacity[a]).sum())
            want = math.ceil(total_p / self.target)
            return ScalingDecision(add_nodes=min(max(want - len(a), 1), self.max_step))
        if (
            avg < self.low_wm
            and len(a) > 1
            and (proj is None or float(proj[a].mean()) < self.low_wm)
        ):
            keep = max(math.ceil(total / self.target), 1)
            drop = min(len(a) - keep, self.max_step)
            if drop <= 0:
                return ScalingDecision()
            # Prefer removing the least-loaded nodes: cheapest to drain.
            order = a[np.argsort(loads[a])]
            return ScalingDecision(mark_for_removal=[int(i) for i in order[:drop]])
        return ScalingDecision()


@dataclasses.dataclass
class LatencyProxyScaler:
    """DRS-style [10] latency-constrained sizing with an M/M/1 proxy.

    Expected queueing delay on a node with utilization ρ scales as ρ/(1−ρ);
    size the cluster so the *maximum planned* utilization keeps the proxy
    under ``latency_budget`` (expressed in the same arbitrary units).

    Like :class:`UtilizationScaler`, the per-key-group arrival rates lead
    the decision: a hotspot key group whose rate is surging breaches the
    *projected* peak utilization one period before the measured one.
    """

    latency_budget: float = 4.0  # ρ/(1−ρ) ≤ budget  ⇒  ρ ≤ b/(1+b)
    max_step: int = 8
    use_rate_signal: bool = True
    _prev_rate: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False, compare=False
    )

    def decide(self, state: ClusterState, plan: AllocationPlan) -> ScalingDecision:
        a = state.nodes_a
        if len(a) == 0:
            return ScalingDecision(add_nodes=1)
        proj = _take_rate_projection(self, state, plan.alloc)
        rho_cap = 100.0 * self.latency_budget / (1.0 + self.latency_budget)
        loads = state.node_loads(plan.alloc)
        peak = float(loads[a].max())
        total = float((loads[a] * state.capacity[a]).sum())
        if peak > rho_cap:
            want = math.ceil(total / rho_cap)
            return ScalingDecision(add_nodes=min(max(want - len(a), 1), self.max_step))
        if proj is not None and float(proj[a].max()) > rho_cap:
            total_p = float((proj[a] * state.capacity[a]).sum())
            want = math.ceil(total_p / rho_cap)
            return ScalingDecision(add_nodes=min(max(want - len(a), 1), self.max_step))
        # Scale in when even after consolidation the cap holds with slack —
        # unless the projection says the slack is about to vanish.
        if len(a) > 1 and (proj is None or float(proj[a].max()) <= rho_cap):
            keep = max(math.ceil(total / (0.8 * rho_cap)), 1)
            drop = min(len(a) - keep, self.max_step)
            if drop > 0:
                order = a[np.argsort(loads[a])]
                return ScalingDecision(mark_for_removal=[int(i) for i in order[:drop]])
        return ScalingDecision()


@dataclasses.dataclass
class NullScaler:
    """Never scales — pure load-balancing mode (used by several benchmarks)."""

    def decide(  # noqa: ARG002
        self, state: ClusterState, plan: AllocationPlan
    ) -> ScalingDecision:
        return ScalingDecision()


def apply_scaling(
    state: ClusterState,
    decision: ScalingDecision,
    *,
    new_node_capacity: float = 1.0,
) -> ClusterState:
    """Materialize a scaling decision on the cluster snapshot.

    Adding nodes grows every per-node array (simulating instant provisioning;
    Algorithm 1's "wait until new nodes are allocated").  Marking nodes only
    flips ``kill`` — draining and termination are the MILP's and the
    framework's job respectively (Lemmas 1–2).
    """
    out = state.copy()
    if decision.add_nodes > 0:
        n_new = decision.add_nodes
        out.num_nodes += n_new
        out.capacity = np.concatenate([out.capacity, np.full(n_new, new_node_capacity)])
        out.kill = np.concatenate([out.kill, np.zeros(n_new, dtype=bool)])
        out.alive = np.concatenate([out.alive, np.ones(n_new, dtype=bool)])
    for i in decision.mark_for_removal:
        out.kill[i] = True
    return out

"""ALBIC — Autonomic Load Balancing with Integrated Collocation (§4.3.2, Alg. 2).

ALBIC layers collocation on top of the MILP without making the program
quadratic:

  Step 1  score every communicating key-group pair: a pair (g_i, g_j)
          *contributes* when out(g_i, g_j) > avg(g_i) · sF.  Pairs already on
          the same node go to ``colGrps``; the rest to ``toBeColGrps``.
  Step 2  union existing collocated pairs into sets; split each set with
          balanced graph partitioning into migration *units* bounded by
          maxMigrCost (p1) and maxPL (p2).  Vertex weight is mc_i when the
          migration-cost ratio dominates, else gLoad_i; ties random.
  Step 3  pick one pair from toBeColGrps with maximal out(g_i, g_j) (random
          among ties) and pin it — and the partitions it touches — to a node
          per the three cases of the paper.  Node scoring for the target
          choice uses *rate-projected* loads when the caller supplies the
          previous period's ``kg_tuple_rate`` (mirroring the scalers'
          leading-load signal): a node whose key groups' arrivals are
          surging scores as already loaded, so migration targeting
          anticipates next period's load instead of only balancing the
          measured one.
  Step 4  solve the constrained MILP; if the achieved load distance exceeds
          maxLD, retry with maxPL reduced by stepPL (more, smaller units).
          At maxPL == 0 this degenerates to the pure MILP.

Defaults follow the paper: maxLD = 10, maxPL = 25, stepPL = 5, sF = 1.5.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core.milp import AllocationPlan, solve_allocation
from repro.core.scaling import projected_loads
from repro.core.stats import ClusterState
from repro.solver.graphpart import Graph, partition_graph


@dataclasses.dataclass
class AlbicParams:
    max_ld: float = 10.0  # maxLD — user-defined max load distance
    max_pl: float = 25.0  # maxPL — max partition load (initial)
    step_pl: float = 5.0  # stepPL
    score_factor: float = 1.5  # sF
    alpha: float = 1.0  # migration cost constant
    time_limit: float = 10.0
    seed: int = 0
    # Score step-3 target nodes on rate-projected loads (leading signal)
    # whenever the previous period's kg_tuple_rate is available.
    use_rate_signal: bool = True


@dataclasses.dataclass
class AlbicResult:
    plan: AllocationPlan
    units: list[list[int]]  # migration units (collocation partitions)
    pinned_pair: Optional[tuple[int, int]]
    retries: int  # number of maxPL back-offs taken
    col_grps: list[tuple[int, int]]  # realized collocated pairs (diagnostics)
    to_be_col: list[tuple[int, int]]  # candidate pairs not yet collocated


def _score_pairs(
    state: ClusterState, score_factor: float
) -> tuple[list[tuple[int, int]], list[tuple[int, int, float]]]:
    """Algorithm 2 lines 2–12: (colGrps, toBeColGrps-with-rates).

    Walks the sparse pair triples (CSR rows) instead of dense (G, G) rows:
    a key group's candidate downstream partners are exactly its nonzero
    pairs, and the per-source average still divides by the *full* downstream
    key-group count (zero-rate partners dilute the average but can never be
    hot themselves).
    """
    col: list[tuple[int, int]] = []
    tobe: list[tuple[int, int, float]] = []
    indptr, dsts, rates = state.out_pairs.rows_csr()
    kg_op = state.kg_operator
    op_sizes = np.bincount(kg_op, minlength=int(kg_op.max()) + 1 if len(kg_op) else 0)
    for op, downs in state.downstream.items():
        if not downs:
            continue
        op_kgs = np.where(kg_op == op)[0]
        n_down = int(op_sizes[downs].sum())
        if n_down == 0:
            continue
        downs_arr = np.asarray(downs)
        for gk in op_kgs:
            row = slice(indptr[gk], indptr[gk + 1])
            d, r = dsts[row], rates[row]
            m = np.isin(kg_op[d], downs_arr)
            rm = r[m]
            total = float(rm.sum())
            if total <= 0:
                continue
            avg = total / n_down
            sel = rm > avg * score_factor
            hot = d[m][sel]
            hot_rates = rm[sel]
            for gj, rate in zip(hot, hot_rates):
                pair = (int(gk), int(gj))
                if state.alloc[gk] == state.alloc[gj]:
                    col.append(pair)
                else:
                    tobe.append((*pair, float(rate)))
    return col, tobe


def _union_sets(pairs: list[tuple[int, int]]) -> list[list[int]]:
    """Merge pairs into disjoint sets (union–find)."""
    parent: dict[int, int] = {}

    def find(x: int) -> int:
        parent.setdefault(x, x)
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in pairs:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
    groups: dict[int, list[int]] = {}
    for x in parent:
        groups.setdefault(find(x), []).append(x)
    return [sorted(v) for v in groups.values() if len(v) > 1]


def _split_set(
    state: ClusterState,
    members: list[int],
    *,
    max_migr_cost: float,
    max_pl: float,
    alpha: float,
    rng: np.random.Generator,
) -> list[list[int]]:
    """Algorithm 2 lines 15–20: split one collocation set into partitions."""
    mc = state.migration_costs(alpha)
    set_mc = float(mc[members].sum())
    set_load = float(state.kg_load[members].sum())
    p1 = math.ceil(set_mc / max_migr_cost) if max_migr_cost > 0 else 1
    p2 = math.ceil(set_load / max_pl) if max_pl > 0 else len(members)
    nparts = max(p1, p2, 1)
    if nparts <= 1 or len(members) <= 1:
        return [list(members)]
    nparts = min(nparts, len(members))

    # Vertex weight: mc if the migration-cost ratio dominates, else gLoad.
    ratio_mc = set_mc / max_migr_cost if max_migr_cost > 0 else 0.0
    ratio_pl = set_load / max_pl if max_pl > 0 else float("inf")
    if ratio_mc > ratio_pl:
        vweights = mc[members]
    elif ratio_mc < ratio_pl:
        vweights = state.kg_load[members]
    else:  # tie broken randomly (paper)
        vweights = mc[members] if rng.random() < 0.5 else state.kg_load[members]

    idx = {g: i for i, g in enumerate(members)}
    index_map = np.full(state.num_keygroups, -1, dtype=np.int64)
    index_map[members] = np.arange(len(members))
    eu, ev, ew = state.out_pairs.symmetric_edges(index_map)
    graph = Graph(
        num_vertices=len(members),
        edge_u=eu,
        edge_v=ev,
        edge_w=ew,
        vertex_w=np.maximum(vweights, 1e-9),
    )
    labels = partition_graph(graph, nparts, seed=int(rng.integers(2**31)))

    parts: list[list[int]] = [[] for _ in range(nparts)]
    for g in members:
        parts[int(labels[idx[g]])].append(g)
    parts = [p for p in parts if p]

    # Re-split any partition still violating a constraint (paper: "may need
    # to be applied again").
    final: list[list[int]] = []
    for p in parts:
        pmc = float(mc[p].sum())
        pl = float(state.kg_load[p].sum())
        if len(p) > 1 and (
            (max_migr_cost > 0 and pmc > max_migr_cost) or (max_pl > 0 and pl > max_pl)
        ):
            final.extend(
                _split_set(
                    state,
                    p,
                    max_migr_cost=max_migr_cost,
                    max_pl=max_pl,
                    alpha=alpha,
                    rng=rng,
                )
            )
        else:
            final.append(p)
    return final


def albic(
    state: ClusterState,
    *,
    max_migr_cost: Optional[float] = None,
    max_migrations: Optional[int] = None,
    params: AlbicParams | None = None,
    prev_rate: Optional[np.ndarray] = None,
) -> AlbicResult:
    """One ALBIC invocation (Algorithm 2).

    ``prev_rate`` is the previous period's per-key-group arrival rates; when
    given (and ``params.use_rate_signal``), step 3 scores candidate target
    nodes on loads projected forward by rate growth, steering new
    collocations away from nodes that are merely *currently* balanced but
    about to absorb a surge.
    """
    params = params or AlbicParams()
    rng = np.random.default_rng(params.seed)
    budget = max_migr_cost if max_migr_cost is not None else float("inf")

    # Step 1 — calculate scores.
    col_pairs, tobe = _score_pairs(state, params.score_factor)

    # Leading-load node scores for step 3 (None → fall back to measured).
    proj_loads = (
        projected_loads(state, state.alloc, prev_rate)
        if params.use_rate_signal
        else None
    )

    max_pl = params.max_pl
    retries = 0
    while True:
        # Step 2 — maintain collocation.
        units: list[list[int]] = []
        if max_pl > 0:
            for s in _union_sets(col_pairs):
                units.extend(
                    _split_set(
                        state,
                        s,
                        max_migr_cost=budget if np.isfinite(budget) else 0.0,
                        max_pl=max_pl,
                        alpha=params.alpha,
                        rng=rng,
                    )
                )

        # Step 3 — improve collocation: one new pair, max out(), ties random.
        pins: dict[int, int] = {}
        pinned_pair: Optional[tuple[int, int]] = None
        if tobe and max_pl > 0:
            rates = np.array([r for _, _, r in tobe])
            best = np.where(rates == rates.max())[0]
            gi, gj, _ = tobe[int(rng.choice(best))]
            pinned_pair = (gi, gj)
            n1, n2 = int(state.alloc[gi]), int(state.alloc[gj])
            loads = proj_loads if proj_loads is not None else state.node_loads()
            member_of = {g: u for u, p in enumerate(units) for g in p}
            ui, uj = member_of.get(gi), member_of.get(gj)
            if ui is None and uj is None:
                # Case 1: pin both key groups to the less-loaded node.
                target = n1 if loads[n1] <= loads[n2] else n2
                units.append([gi])
                units.append([gj])
                pins[len(units) - 2] = target
                pins[len(units) - 1] = target
            elif ui is not None and uj is None:
                # Case 2a: g_j joins g_i's node.
                units.append([gj])
                pins[ui] = n1
                pins[len(units) - 1] = n1
            elif ui is None and uj is not None:
                # Case 2b: g_i joins g_j's node.
                units.append([gi])
                pins[uj] = n2
                pins[len(units) - 1] = n2
            else:
                # Case 3: both partitions move to the less-loaded node.
                target = n1 if loads[n1] <= loads[n2] else n2
                pins[ui] = target
                if uj != ui:
                    pins[uj] = target

        # Step 4 — solve the constrained MILP.  The rate projection feeds
        # the balance objective itself here, not just step 3's target
        # scoring: a surging key group weighs as next period's load.
        plan = solve_allocation(
            state,
            max_migr_cost=max_migr_cost,
            max_migrations=max_migrations,
            units=units if units else None,
            pins=pins if pins else None,
            alpha=params.alpha,
            time_limit=params.time_limit,
            prev_rate=prev_rate if params.use_rate_signal else None,
        )
        ld_ok = plan.status != "infeasible" and plan.load_distance <= params.max_ld
        if ld_ok or max_pl <= 0:
            return AlbicResult(
                plan=plan,
                units=units,
                pinned_pair=pinned_pair,
                retries=retries,
                col_grps=col_pairs,
                to_be_col=[(a, b) for a, b, _ in tobe],
            )
        max_pl = max(max_pl - params.step_pl, 0.0)
        retries += 1

"""Direct state migration (paper §3, "State Migration"; Madsen & Zhou [27]).

Moving key group g_k from n1 to n2:

  1. upstream instances are told to *redirect* new tuples for g_k to n2;
  2. n2 buffers the redirected tuples;
  3. n1 serializes σ_k — plus g_k's queued backlog, extracted at redirect —
     and ships the envelope to n2 (schema-typed engines encode the backlog
     as raw buffer slices; see repro.engine.serde);
  4. n2 deserializes, reconstructs g_k, replays backlog + buffer, resumes.

The cost model is mc_k = α·|σ_k| — the serialization time on an average-loaded
node.  The adaptation algorithms are independent of the mechanism (paper:
alternative techniques [9, 27, 40] can be swapped in), so this module exposes
a :class:`MigrationPlan` plus an executor protocol; the streaming engine and
the LM serving/training planes each implement the executor against their own
state (keyed pytrees / KV pages / expert weights).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Protocol

import numpy as np

from repro.core.stats import ClusterState


@dataclasses.dataclass(frozen=True)
class Migration:
    keygroup: int
    src: int
    dst: int
    cost: float  # mc_k


@dataclasses.dataclass
class MigrationPlan:
    moves: list[Migration]

    @property
    def total_cost(self) -> float:
        return sum(m.cost for m in self.moves)

    @property
    def num_migrations(self) -> int:
        return len(self.moves)

    def by_source(self) -> dict[int, list[Migration]]:
        out: dict[int, list[Migration]] = {}
        for m in self.moves:
            out.setdefault(m.src, []).append(m)
        return out


def plan_from_allocations(
    state: ClusterState,
    new_alloc: np.ndarray,
    *,
    alpha: float = 1.0,
) -> MigrationPlan:
    mc = state.migration_costs(alpha)
    moves = [
        Migration(int(k), int(state.alloc[k]), int(new_alloc[k]), float(mc[k]))
        for k in np.where(new_alloc != state.alloc)[0]
    ]
    return MigrationPlan(moves=moves)


class StateMover(Protocol):
    """What an execution plane must provide for direct state migration."""

    def redirect(self, keygroup: int, dst: int) -> None:
        """Point upstream routing for `keygroup` at `dst` (starts buffering)."""

    def serialize(self, keygroup: int) -> bytes:
        """Extract σ_k from its current owner."""

    def install(self, keygroup: int, dst: int, blob: bytes) -> None:
        """Reconstruct σ_k at `dst` and replay the buffered tuples."""


@dataclasses.dataclass
class MigrationReport:
    applied: int
    total_cost: float
    pause_seconds: float  # summed per-key-group pause (paper Fig. 9 metric)
    # Total serialized envelope bytes shipped (σ_k state + queued segments;
    # schema-typed engines encode the segments as raw buffer slices, so this
    # is the real wire cost the α·|σ_k| model approximates).
    bytes_moved: int = 0


def execute_plan(
    plan: MigrationPlan,
    mover: StateMover,
    *,
    measure: bool = True,
) -> MigrationReport:
    """Run direct state migration for every move in the plan.

    The pause of one key group spans serialize→install (steps 3–4); redirect
    and buffering keep upstream flowing meanwhile — this is what keeps the
    paper's per-key-group latency at ~2.5 s rather than a full-job stall.
    """
    pause = 0.0
    bytes_moved = 0
    for m in plan.moves:
        mover.redirect(m.keygroup, m.dst)
        t0 = time.perf_counter() if measure else 0.0
        blob = mover.serialize(m.keygroup)
        mover.install(m.keygroup, m.dst, blob)
        if measure:
            pause += time.perf_counter() - t0
        bytes_moved += len(blob)
    return MigrationReport(
        applied=len(plan.moves),
        total_cost=plan.total_cost,
        pause_seconds=pause,
        bytes_moved=bytes_moved,
    )


def apply_to_state(state: ClusterState, moves: Iterable[Migration]) -> None:
    """Bookkeeping-only application (simulation paths, benchmarks)."""
    for m in moves:
        state.alloc[m.keygroup] = m.dst

"""The paper's primary contribution: integrative dynamic reconfiguration.

Public surface:

* :class:`repro.core.stats.ClusterState` — the shared allocation/statistics
  snapshot (gLoad, load_i, out(g_i,g_j), kill marks, capacities).
* :func:`repro.core.milp.solve_allocation` — the Table-2 MILP (load balancing
  + integrated scale-in) over migration units.
* :func:`repro.core.albic.albic` — Algorithm 2 (collocation on top of MILP).
* :class:`repro.core.framework.AdaptationFramework` — Algorithm 1.
* :mod:`repro.core.baselines` — Flux, PoTC, COLA comparison points.
"""

from repro.core.albic import AlbicParams, AlbicResult, albic
from repro.core.framework import AdaptationFramework, AdaptationResult
from repro.core.migration import (
    Migration,
    MigrationPlan,
    execute_plan,
    plan_from_allocations,
)
from repro.core.milp import AllocationPlan, solve_allocation
from repro.core.scaling import (
    LatencyProxyScaler,
    NullScaler,
    ScalingDecision,
    UtilizationScaler,
    apply_scaling,
)
from repro.core.stats import ClusterState, PairRates, SPLWindow

__all__ = [
    "AdaptationFramework",
    "AdaptationResult",
    "AlbicParams",
    "AlbicResult",
    "albic",
    "AllocationPlan",
    "ClusterState",
    "LatencyProxyScaler",
    "Migration",
    "MigrationPlan",
    "NullScaler",
    "PairRates",
    "ScalingDecision",
    "SPLWindow",
    "UtilizationScaler",
    "apply_scaling",
    "execute_plan",
    "plan_from_allocations",
    "solve_allocation",
]

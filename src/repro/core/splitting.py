"""Hot-key splitting policy: when migration alone cannot balance, split.

Key-group migration — the framework's whole repertoire — moves *whole* key
groups.  A single key group hotter than a node's fair share of the arrival
rate is therefore unbalanceable by any allocator: wherever it lands, that
node overloads (the partial-key-grouping observation; see PAPERS.md).  The
:class:`HotKeySplitter` watches the same ``kg_tuple_rate`` leading signal
the scalers and allocators project with, and when a key group's projected
rate crosses ``hot_frac`` of the per-node fair share it decides to split it
across replicas (``Engine.split_keygroup``).  Cooled families fold back
(``Engine.unsplit_keygroup``) under a hysteresis band so a rate hovering at
the threshold does not thrash.

The decision is *advisory*: :class:`~repro.core.framework.AdaptationFramework`
computes it alongside the allocation plan (same snapshot, same projection)
and the controller applies it against the live engine after the period's
migrations execute — replicas then show up as ordinary key groups in the
next snapshot, so balancing, collocation scoring and migration budgeting
compose with splitting for free.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.scaling import MAX_RATE_GROWTH
from repro.core.stats import ClusterState


@dataclasses.dataclass(frozen=True)
class SplitDecision:
    """Advisory outcome of one period's splitting policy."""

    split: tuple[int, ...] = ()  # parents to split, hottest first
    unsplit: tuple[int, ...] = ()  # cooled families to fold back

    @property
    def acted(self) -> bool:
        return bool(self.split or self.unsplit)


@dataclasses.dataclass
class HotKeySplitter:
    """Threshold policy over the projected per-key-group arrival rate.

    A key group is *hot* when its projected rate exceeds
    ``hot_frac × (total rate / alive nodes)`` — hotter than that, no
    placement balances it, so it splits.  A split family folds back when
    its combined projected rate drops below ``cool_frac`` of the same
    threshold (any ``cool_frac < 1`` leaves a hysteresis band between the
    two, so a rate hovering at the boundary does not thrash).

    Projection mirrors :func:`repro.core.scaling.rate_growth`: each key
    group's rate is scaled by its clipped growth ratio versus the previous
    period, so a flash crowd's ramp triggers the split one period early —
    the same leading-signal treatment the scalers and allocators get.
    """

    hot_frac: float = 0.5
    cool_frac: float = 0.25
    max_splits_per_period: int = 2
    min_rate: float = 0.5
    _prev_rate: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False, compare=False
    )

    def decide(
        self,
        state: ClusterState,
        families: dict[int, list[int]],
        eligible: Optional[np.ndarray] = None,
    ) -> SplitDecision:
        """One period's split/unsplit picks.

        ``families`` is the engine's live ``split_families()`` map;
        ``eligible`` (bool mask over key groups, or None = all) excludes
        key groups whose operator is not split-mergeable, so the decision
        never asks the engine for an impossible split.
        """
        rate = state.kg_tuple_rate
        if rate is None:
            return SplitDecision()
        proj = rate.astype(np.float64, copy=True)
        prev = self._prev_rate
        if prev is not None and len(prev) == len(rate):
            meaningful = prev >= self.min_rate
            growth = np.ones_like(proj)
            growth[meaningful] = rate[meaningful] / prev[meaningful]
            np.clip(growth, 1.0, MAX_RATE_GROWTH, out=growth)
            proj *= growth
        self._prev_rate = rate.copy()

        alive = int(state.alive.sum())
        total = float(proj.sum())
        if alive == 0 or total <= 0.0:
            return SplitDecision()
        threshold = self.hot_frac * total / alive

        replica_of = {s: p for p, slots in families.items() for s in slots}
        split: list[int] = []
        for kg in np.argsort(-proj, kind="stable").tolist():
            if proj[kg] <= threshold or len(split) >= self.max_splits_per_period:
                break
            if kg in families or kg in replica_of:
                continue  # already spread across a family
            if eligible is not None and not eligible[kg]:
                continue
            split.append(int(kg))

        unsplit: list[int] = []
        for parent in sorted(families):
            fam = [parent] + list(families[parent])
            if float(proj[fam].sum()) < self.cool_frac * threshold:
                unsplit.append(parent)
        return SplitDecision(tuple(split), tuple(unsplit))

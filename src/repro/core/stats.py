"""Cluster model and SPL statistics (paper §3).

The controller maintains, per *statistics period* (SPL), the load of every key
group (``gLoad_k``), the load of every node (``load_i``), and the pairwise
communication rates ``out(g_i, g_j)``.  All of the paper's algorithms consume
exactly this state, so it is factored into one dataclass,
:class:`ClusterState`, shared by the MILP, ALBIC, the baselines and the
engine's controller.

Loads are percentage points of the bottleneck resource in ``[0, 100]`` as in
the paper.  Heterogeneity (paper §3) is carried as a per-node ``capacity``
weight: a node with capacity 2.0 exhibits half the load for the same work.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass
class ClusterState:
    """Allocation + statistics snapshot consumed by the optimizers.

    Attributes:
      num_nodes: |N|.
      capacity: (num_nodes,) relative node capacities (1.0 == reference node).
      kill: (num_nodes,) bool — marked for removal by the scaling algorithm
        (the paper's set ``B``; ``A`` is the complement).
      alive: (num_nodes,) bool — False once a node failed or was terminated.
      kg_operator: (G,) int — operator that owns each key group.
      kg_load: (G,) float — ``gLoad_k`` over the last SPL.
      kg_state_bytes: (G,) float — |σ_k|, the serialized state size.
      alloc: (G,) int — current node of each key group (``q_{i,k}``).
      out_rates: (G, G) float — ``out(g_i, g_j)`` tuple rates over the SPL.
        Kept dense; benchmark-scale is ≤ a few thousand key groups.
      downstream: operator adjacency — downstream[o] = list of operator ids.
    """

    num_nodes: int
    capacity: np.ndarray
    kill: np.ndarray
    alive: np.ndarray
    kg_operator: np.ndarray
    kg_load: np.ndarray
    kg_state_bytes: np.ndarray
    alloc: np.ndarray
    out_rates: np.ndarray
    downstream: dict[int, list[int]]

    # -- constructors --------------------------------------------------------
    @staticmethod
    def create(
        num_nodes: int,
        kg_operator: np.ndarray,
        kg_load: np.ndarray,
        alloc: np.ndarray,
        *,
        kg_state_bytes: np.ndarray | None = None,
        out_rates: np.ndarray | None = None,
        downstream: dict[int, list[int]] | None = None,
        capacity: np.ndarray | None = None,
    ) -> "ClusterState":
        g = len(kg_load)
        return ClusterState(
            num_nodes=num_nodes,
            capacity=(
                np.ones(num_nodes) if capacity is None else np.asarray(capacity, dtype=np.float64)
            ),
            kill=np.zeros(num_nodes, dtype=bool),
            alive=np.ones(num_nodes, dtype=bool),
            kg_operator=np.asarray(kg_operator, dtype=np.int64),
            kg_load=np.asarray(kg_load, dtype=np.float64),
            kg_state_bytes=(
                np.full(g, 1.0)
                if kg_state_bytes is None
                else np.asarray(kg_state_bytes, dtype=np.float64)
            ),
            alloc=np.asarray(alloc, dtype=np.int64),
            out_rates=(np.zeros((g, g)) if out_rates is None else np.asarray(out_rates)),
            downstream=dict(downstream or {}),
        )

    # -- derived quantities (paper Table 1 / §4.3.1) --------------------------
    @property
    def num_keygroups(self) -> int:
        return int(self.kg_load.shape[0])

    @property
    def nodes_a(self) -> np.ndarray:
        """A = nodes not marked for removal (and alive)."""
        return np.where(~self.kill & self.alive)[0]

    @property
    def nodes_b(self) -> np.ndarray:
        """B = nodes marked for removal (still alive, draining)."""
        return np.where(self.kill & self.alive)[0]

    def node_loads(self, alloc: np.ndarray | None = None) -> np.ndarray:
        """load_i: capacity-normalized sum of gLoad over key groups on i."""
        alloc = self.alloc if alloc is None else alloc
        raw = np.bincount(alloc, weights=self.kg_load, minlength=self.num_nodes)
        return raw / self.capacity

    def mean_load(self) -> float:
        """Paper: mean = ceil( (1/|A|) · Σ_{n_i ∈ N} load_i )."""
        a = self.nodes_a
        if len(a) == 0:
            return 0.0
        total = float(self.node_loads()[self.alive].sum())
        return math.ceil(total / len(a))

    def load_distance(self, alloc: np.ndarray | None = None) -> float:
        """max_{n_i ∈ A} |load_i − mean| for the given (or current) alloc."""
        loads = self.node_loads(alloc)
        a = self.nodes_a
        if len(a) == 0:
            return 0.0
        return float(np.max(np.abs(loads[a] - self.mean_load())))

    def migration_costs(self, alpha: float = 1.0) -> np.ndarray:
        """mc_k = α · |σ_k| (paper §4.3.1 cost model)."""
        return alpha * self.kg_state_bytes

    # -- communication metrics (ALBIC §4.3.2, experiments §5) -----------------
    def collocation_factor(self, alloc: np.ndarray | None = None) -> float:
        """Fraction of inter-key-group traffic that stays intra-node, in %.

        Real Job 2's "perfect collocation" (all communicating pairs on one
        node) measures 100; a worst-case allocation measures ~0.
        """
        alloc = self.alloc if alloc is None else alloc
        total = float(self.out_rates.sum())
        if total <= 0:
            return 0.0
        same = alloc[:, None] == alloc[None, :]
        return 100.0 * float(self.out_rates[same].sum()) / total

    def cross_node_rate(self, alloc: np.ndarray | None = None) -> float:
        """Total tuple rate crossing node boundaries (drives the load index)."""
        alloc = self.alloc if alloc is None else alloc
        diff = alloc[:, None] != alloc[None, :]
        return float(self.out_rates[diff].sum())

    def system_load(self, alloc: np.ndarray | None = None, ser_cost: float = 0.0) -> float:
        """Average node load including serialization cost of cross-node sends.

        ``ser_cost`` is load points charged per unit of cross-node rate (it
        models CPU serialization + deserialization in the paper; ICI/bytes on
        TPU).  The *load index* metric divides this by its value at t0.
        """
        alloc = self.alloc if alloc is None else alloc
        base = float(self.kg_load.sum())
        comm = ser_cost * self.cross_node_rate(alloc)
        a = self.nodes_a
        return (base + comm) / max(len(a), 1)

    def copy(self) -> "ClusterState":
        return ClusterState(
            num_nodes=self.num_nodes,
            capacity=self.capacity.copy(),
            kill=self.kill.copy(),
            alive=self.alive.copy(),
            kg_operator=self.kg_operator.copy(),
            kg_load=self.kg_load.copy(),
            kg_state_bytes=self.kg_state_bytes.copy(),
            alloc=self.alloc.copy(),
            out_rates=self.out_rates.copy(),
            downstream={k: list(v) for k, v in self.downstream.items()},
        )


@dataclasses.dataclass
class SPLWindow:
    """Accumulates raw statistics over one statistics period (SPL).

    The engine's controller feeds tuple counts / resource samples in; at the
    end of the window it folds them into a :class:`ClusterState` snapshot.
    Resources are tracked separately so the *bottleneck resource* (the one
    with greatest total usage — paper §3) can be selected per window.
    """

    num_keygroups: int
    resources: tuple[str, ...] = ("cpu", "network", "memory")

    def __post_init__(self) -> None:
        g = self.num_keygroups
        self.kg_usage = {r: np.zeros(g) for r in self.resources}
        self.out_counts = np.zeros((g, g))
        self.samples = 0

    def record_processing(self, resource: str, kg: int, usage: float) -> None:
        self.kg_usage[resource][kg] += usage

    def record_send(self, src_kg: int, dst_kg: int, tuples: float) -> None:
        self.out_counts[src_kg, dst_kg] += tuples

    def record_processing_many(
        self, resource: str, kgs: np.ndarray, usage: np.ndarray
    ) -> None:
        """Batched :meth:`record_processing` (kgs need not be unique)."""
        np.add.at(self.kg_usage[resource], kgs, usage)

    def record_send_pairs(self, src_kgs: np.ndarray, dst_kgs: np.ndarray) -> None:
        """Batched :meth:`record_send`: one tuple per (src, dst) pair entry."""
        np.add.at(self.out_counts, (src_kgs, dst_kgs), 1.0)

    def bottleneck_resource(self) -> str:
        totals = {r: float(u.sum()) for r, u in self.kg_usage.items()}
        return max(totals, key=totals.get)  # type: ignore[arg-type]

    def fold(self, scale_to_percent: float = 1.0) -> tuple[np.ndarray, np.ndarray, str]:
        """Return (gLoad vector on bottleneck resource, out_rates, resource)."""
        r = self.bottleneck_resource()
        return self.kg_usage[r] * scale_to_percent, self.out_counts.copy(), r

    def reset(self) -> None:
        for r in self.resources:
            self.kg_usage[r][:] = 0
        self.out_counts[:] = 0
        self.samples = 0

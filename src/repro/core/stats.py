"""Cluster model and SPL statistics (paper §3).

The controller maintains, per *statistics period* (SPL), the load of every key
group (``gLoad_k``), the load of every node (``load_i``), and the pairwise
communication rates ``out(g_i, g_j)``.  All of the paper's algorithms consume
exactly this state, so it is factored into one dataclass,
:class:`ClusterState`, shared by the MILP, ALBIC, the baselines and the
engine's controller.

Pairwise rates are stored *sparse* (:class:`PairRates` — COO triples over the
(G, G) pair space): a stream job's communication graph has O(G) hot pairs,
not G², and the dense matrix is 11 MB at the paper's 1200 key groups and
quadratically worse beyond.  ``ClusterState.out_rates`` still materializes
the dense matrix on demand (cached) so existing dense consumers keep working,
while ALBIC / COLA / the collocation metrics walk the sparse triples.

Loads are percentage points of the bottleneck resource in ``[0, 100]`` as in
the paper.  Heterogeneity (paper §3) is carried as a per-node ``capacity``
weight: a node with capacity 2.0 exhibits half the load for the same work.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


class PairRates:
    """Sparse ``out(g_i, g_j)``: COO triples, sorted by (src, dst).

    Immutable once built; row access (``rows_csr``) and symmetric edge
    extraction (``symmetric_edges``) are the two shapes the optimizers need.
    """

    __slots__ = ("src", "dst", "rate", "num_keygroups", "_indptr")

    def __init__(
        self, src: np.ndarray, dst: np.ndarray, rate: np.ndarray, num_keygroups: int
    ) -> None:
        self.src = np.asarray(src, dtype=np.int64)
        self.dst = np.asarray(dst, dtype=np.int64)
        self.rate = np.asarray(rate, dtype=np.float64)
        self.num_keygroups = int(num_keygroups)
        self._indptr: np.ndarray | None = None

    # -- constructors --------------------------------------------------------
    @classmethod
    def empty(cls, num_keygroups: int) -> "PairRates":
        z = np.empty(0, dtype=np.int64)
        return cls(z, z, np.empty(0), num_keygroups)

    @classmethod
    def from_dense(cls, dense: np.ndarray) -> "PairRates":
        dense = np.asarray(dense)
        g = dense.shape[0]
        src, dst = np.nonzero(dense)
        return cls(src, dst, dense[src, dst], g)

    @classmethod
    def from_codes(
        cls, codes: np.ndarray, weights: np.ndarray, num_keygroups: int
    ) -> "PairRates":
        """Build from ``src * G + dst`` pair codes with per-entry weights.

        Codes need not be unique; duplicate pairs are summed.  ``np.unique``
        returns sorted codes, which is exactly the (src, dst)-lexicographic
        order the class guarantees.
        """
        if len(codes) == 0:
            return cls.empty(num_keygroups)
        uniq, inv = np.unique(codes, return_inverse=True)
        rate = np.bincount(inv, weights=weights, minlength=len(uniq))
        return cls(uniq // num_keygroups, uniq % num_keygroups, rate, num_keygroups)

    # -- views ----------------------------------------------------------------
    @property
    def nnz(self) -> int:
        return len(self.rate)

    def total(self) -> float:
        return float(self.rate.sum())

    def to_dense(self) -> np.ndarray:
        g = self.num_keygroups
        dense = np.zeros((g, g))
        dense[self.src, self.dst] = self.rate
        return dense

    def rows_csr(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR view: (indptr, dst, rate) with rows sorted by src (invariant)."""
        if self._indptr is None:
            counts = np.bincount(self.src, minlength=self.num_keygroups)
            self._indptr = np.concatenate([[0], np.cumsum(counts)])
        return self._indptr, self.dst, self.rate

    def intra_rate(self, alloc: np.ndarray) -> float:
        """Total rate of pairs whose endpoints share a node under ``alloc``."""
        if self.nnz == 0:
            return 0.0
        same = alloc[self.src] == alloc[self.dst]
        return float(self.rate[same].sum())

    def symmetric_edges(
        self, index_map: np.ndarray | None = None
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Undirected positive-weight edges (u < v, lexicographic order).

        Edge weight is ``out[u, v] + out[v, u]`` — the symmetrized rate the
        graph partitioners cut.  ``index_map`` (len G, −1 = excluded)
        restricts to a vertex subset and relabels into its local index space;
        self-loops are dropped either way.
        """
        if index_map is None:
            u, v, m = self.src, self.dst, self.num_keygroups
        else:
            u = index_map[self.src]
            v = index_map[self.dst]
            keep = (u >= 0) & (v >= 0)
            u, v = u[keep], v[keep]
            m = int(index_map.max()) + 1 if len(index_map) else 0
        if len(u) == 0:
            z = np.empty(0, dtype=np.int64)
            return z, z, np.empty(0)
        rate = self.rate if index_map is None else self.rate[keep]
        off = (u != v)
        lo = np.minimum(u[off], v[off])
        hi = np.maximum(u[off], v[off])
        codes = lo * m + hi
        uniq, inv = np.unique(codes, return_inverse=True)
        w = np.bincount(inv, weights=rate[off], minlength=len(uniq))
        return uniq // m, uniq % m, w

    def copy(self) -> "PairRates":
        return PairRates(
            self.src.copy(), self.dst.copy(), self.rate.copy(), self.num_keygroups
        )


def _as_pairs(out_rates, g: int) -> PairRates:
    if out_rates is None:
        return PairRates.empty(g)
    if isinstance(out_rates, PairRates):
        return out_rates
    return PairRates.from_dense(np.asarray(out_rates))


@dataclasses.dataclass
class ClusterState:
    """Allocation + statistics snapshot consumed by the optimizers.

    Attributes:
      num_nodes: |N|.
      capacity: (num_nodes,) relative node capacities (1.0 == reference node).
      kill: (num_nodes,) bool — marked for removal by the scaling algorithm
        (the paper's set ``B``; ``A`` is the complement).
      alive: (num_nodes,) bool — False once a node failed or was terminated.
      kg_operator: (G,) int — operator that owns each key group.
      kg_load: (G,) float — ``gLoad_k`` over the last SPL.
      kg_state_bytes: (G,) float — |σ_k|, the serialized state size.
      alloc: (G,) int — current node of each key group (``q_{i,k}``).
      out_pairs: sparse ``out(g_i, g_j)`` tuple rates over the SPL
        (:class:`PairRates`); the dense (G, G) matrix is available on demand
        through the :attr:`out_rates` property.
      kg_tuple_rate: (G,) float — per-key-group arrival rate (tuples/tick)
        over the SPL, or None when not measured.
      downstream: operator adjacency — downstream[o] = list of operator ids.
    """

    num_nodes: int
    capacity: np.ndarray
    kill: np.ndarray
    alive: np.ndarray
    kg_operator: np.ndarray
    kg_load: np.ndarray
    kg_state_bytes: np.ndarray
    alloc: np.ndarray
    out_pairs: PairRates
    downstream: dict[int, list[int]]
    kg_tuple_rate: np.ndarray | None = None

    @property
    def out_rates(self) -> np.ndarray:
        """Dense (G, G) ``out(g_i, g_j)`` view, materialized lazily (cached)."""
        cached = getattr(self, "_out_dense", None)
        if cached is None:
            cached = self.out_pairs.to_dense()
            object.__setattr__(self, "_out_dense", cached)
        return cached

    # -- constructors --------------------------------------------------------
    @staticmethod
    def create(
        num_nodes: int,
        kg_operator: np.ndarray,
        kg_load: np.ndarray,
        alloc: np.ndarray,
        *,
        kg_state_bytes: np.ndarray | None = None,
        out_rates=None,
        downstream: dict[int, list[int]] | None = None,
        capacity: np.ndarray | None = None,
        kg_tuple_rate: np.ndarray | None = None,
    ) -> "ClusterState":
        g = len(kg_load)
        return ClusterState(
            num_nodes=num_nodes,
            capacity=(
                np.ones(num_nodes) if capacity is None else np.asarray(
                    capacity,
                    dtype=np.float64,
                )
            ),
            kill=np.zeros(num_nodes, dtype=bool),
            alive=np.ones(num_nodes, dtype=bool),
            kg_operator=np.asarray(kg_operator, dtype=np.int64),
            kg_load=np.asarray(kg_load, dtype=np.float64),
            kg_state_bytes=(
                np.full(g, 1.0)
                if kg_state_bytes is None
                else np.asarray(kg_state_bytes, dtype=np.float64)
            ),
            alloc=np.asarray(alloc, dtype=np.int64),
            out_pairs=_as_pairs(out_rates, g),
            downstream=dict(downstream or {}),
            kg_tuple_rate=kg_tuple_rate,
        )

    # -- derived quantities (paper Table 1 / §4.3.1) --------------------------
    @property
    def num_keygroups(self) -> int:
        return int(self.kg_load.shape[0])

    @property
    def nodes_a(self) -> np.ndarray:
        """A = nodes not marked for removal (and alive)."""
        return np.where(~self.kill & self.alive)[0]

    @property
    def nodes_b(self) -> np.ndarray:
        """B = nodes marked for removal (still alive, draining)."""
        return np.where(self.kill & self.alive)[0]

    def node_loads(self, alloc: np.ndarray | None = None) -> np.ndarray:
        """load_i: capacity-normalized sum of gLoad over key groups on i."""
        alloc = self.alloc if alloc is None else alloc
        raw = np.bincount(alloc, weights=self.kg_load, minlength=self.num_nodes)
        return raw / self.capacity

    def mean_load(self) -> float:
        """Paper: mean = ceil( (1/|A|) · Σ_{n_i ∈ N} load_i )."""
        a = self.nodes_a
        if len(a) == 0:
            return 0.0
        total = float(self.node_loads()[self.alive].sum())
        return math.ceil(total / len(a))

    def load_distance(self, alloc: np.ndarray | None = None) -> float:
        """max_{n_i ∈ A} |load_i − mean| for the given (or current) alloc."""
        loads = self.node_loads(alloc)
        a = self.nodes_a
        if len(a) == 0:
            return 0.0
        return float(np.max(np.abs(loads[a] - self.mean_load())))

    def migration_costs(self, alpha: float = 1.0) -> np.ndarray:
        """mc_k = α · |σ_k| (paper §4.3.1 cost model)."""
        return alpha * self.kg_state_bytes

    # -- communication metrics (ALBIC §4.3.2, experiments §5) -----------------
    def collocation_factor(self, alloc: np.ndarray | None = None) -> float:
        """Fraction of inter-key-group traffic that stays intra-node, in %.

        Real Job 2's "perfect collocation" (all communicating pairs on one
        node) measures 100; a worst-case allocation measures ~0.
        """
        alloc = self.alloc if alloc is None else alloc
        total = self.out_pairs.total()
        if total <= 0:
            return 0.0
        return 100.0 * self.out_pairs.intra_rate(alloc) / total

    def cross_node_rate(self, alloc: np.ndarray | None = None) -> float:
        """Total tuple rate crossing node boundaries (drives the load index)."""
        alloc = self.alloc if alloc is None else alloc
        return self.out_pairs.total() - self.out_pairs.intra_rate(alloc)

    def system_load(
        self, alloc: np.ndarray | None = None, ser_cost: float = 0.0
    ) -> float:
        """Average node load including serialization cost of cross-node sends.

        ``ser_cost`` is load points charged per unit of cross-node rate (it
        models CPU serialization + deserialization in the paper; ICI/bytes on
        TPU).  The *load index* metric divides this by its value at t0.
        """
        alloc = self.alloc if alloc is None else alloc
        base = float(self.kg_load.sum())
        comm = ser_cost * self.cross_node_rate(alloc)
        a = self.nodes_a
        return (base + comm) / max(len(a), 1)

    def copy(self) -> "ClusterState":
        return ClusterState(
            num_nodes=self.num_nodes,
            capacity=self.capacity.copy(),
            kill=self.kill.copy(),
            alive=self.alive.copy(),
            kg_operator=self.kg_operator.copy(),
            kg_load=self.kg_load.copy(),
            kg_state_bytes=self.kg_state_bytes.copy(),
            alloc=self.alloc.copy(),
            out_pairs=self.out_pairs.copy(),
            downstream={k: list(v) for k, v in self.downstream.items()},
            kg_tuple_rate=(
                None if self.kg_tuple_rate is None else self.kg_tuple_rate.copy()
            ),
        )


@dataclasses.dataclass
class SPLWindow:
    """Accumulates raw statistics over one statistics period (SPL).

    The engine's controller feeds tuple counts / resource samples in; at the
    end of the window it folds them into a :class:`ClusterState` snapshot.
    Resources are tracked separately so the *bottleneck resource* (the one
    with greatest total usage — paper §3) can be selected per window.

    Pair rates accumulate sparsely: each recorded batch appends its
    ``src * G + dst`` codes, and :meth:`fold` reduces them to unique
    (src, dst, count) triples — O(recorded tuples) memory with periodic
    compaction, never a (G, G) matrix.  Per-key-group arrival histograms
    (``kg_arrivals``) come either from ``np.bincount`` on the CPU path or
    straight from the Pallas ``keygroup_partition`` kernel's histogram
    output on TPU — the two are validated bit-identical.
    """

    num_keygroups: int
    resources: tuple[str, ...] = ("cpu", "network", "memory")
    compact_threshold: int = 1 << 21  # pending pair entries before compaction

    def __post_init__(self) -> None:
        g = self.num_keygroups
        self.kg_usage = {r: np.zeros(g) for r in self.resources}
        self.kg_arrivals = np.zeros(g)
        # Pair sends accumulate as raw (src, dst[, weight]) array refs — the
        # record path is two list appends; codes are computed at compaction.
        self._pair_src: list[np.ndarray] = []
        self._pair_dst: list[np.ndarray] = []
        self._pair_weights: list[np.ndarray | None] = []  # None → all-ones
        self._compacted: tuple[np.ndarray, np.ndarray] | None = None
        self._pair_entries = 0
        self.samples = 0

    def record_processing(self, resource: str, kg: int, usage: float) -> None:
        self.kg_usage[resource][kg] += usage

    def record_send(self, src_kg: int, dst_kg: int, tuples: float) -> None:
        self._pair_src.append(np.array([src_kg], dtype=np.int64))
        self._pair_dst.append(np.array([dst_kg], dtype=np.int64))
        self._pair_weights.append(np.array([tuples]))
        self._pair_entries += 1
        if self._pair_entries > self.compact_threshold:
            self._compact_pairs()

    def record_processing_many(
        self, resource: str, kgs: np.ndarray, usage: np.ndarray
    ) -> None:
        """Batched :meth:`record_processing` (kgs need not be unique)."""
        np.add.at(self.kg_usage[resource], kgs, usage)

    def record_send_pairs(self, src_kgs: np.ndarray, dst_kgs: np.ndarray) -> None:
        """Batched :meth:`record_send`: one tuple per (src, dst) pair entry.

        Holds references to the arrays (callers pass freshly built
        attribution arrays, never mutated afterwards).
        """
        self._pair_src.append(src_kgs)
        self._pair_dst.append(dst_kgs)
        self._pair_weights.append(None)
        self._pair_entries += len(src_kgs)
        if self._pair_entries > self.compact_threshold:
            self._compact_pairs()

    def record_send_counts(
        self, src_kgs: np.ndarray, dst_kgs: np.ndarray, counts: np.ndarray
    ) -> None:
        """Batched :meth:`record_send` with explicit per-pair tuple counts.

        Equivalent to :meth:`record_send_pairs` over ``counts[j]`` repeats of
        each ``(src_kgs[j], dst_kgs[j])`` pair — the compaction sums weights,
        and integer counts sum exactly in float64 — without materializing the
        per-tuple attribution arrays (the fused superstep path only ever
        knows per-edge counts).
        """
        self._pair_src.append(np.asarray(src_kgs, dtype=np.int64))
        self._pair_dst.append(np.asarray(dst_kgs, dtype=np.int64))
        self._pair_weights.append(np.asarray(counts, dtype=np.float64))
        self._pair_entries += len(src_kgs)
        if self._pair_entries > self.compact_threshold:
            self._compact_pairs()

    def record_arrivals(self, base: int, hist: np.ndarray) -> None:
        """Add one operator's per-key-group tuple histogram (kernel output)."""
        self.kg_arrivals[base : base + len(hist)] += hist

    def pair_counts(self) -> "PairRates":
        """Reduce the accumulated pair sends into sparse rates."""
        self._compact_pairs()
        if self._compacted is None:
            return PairRates.empty(self.num_keygroups)
        codes, weights = self._compacted
        g = self.num_keygroups
        return PairRates(codes // g, codes % g, weights, g)

    def _compact_pairs(self) -> None:
        if not self._pair_src and self._compacted is None:
            return
        g = self.num_keygroups
        parts_c = [] if self._compacted is None else [self._compacted[0]]
        parts_w = [] if self._compacted is None else [self._compacted[1]]
        if self._pair_src:
            src = np.concatenate(self._pair_src)
            dst = np.concatenate(self._pair_dst)
            parts_c.append(src * g + dst)
            parts_w.append(
                np.concatenate(
                    [
                        np.ones(len(s)) if w is None else w
                        for s, w in zip(self._pair_src, self._pair_weights)
                    ]
                )
            )
        codes = np.concatenate(parts_c) if len(parts_c) > 1 else parts_c[0]
        weights = np.concatenate(parts_w) if len(parts_w) > 1 else parts_w[0]
        uniq, inv = np.unique(codes, return_inverse=True)
        summed = np.bincount(inv, weights=weights, minlength=len(uniq))
        self._compacted = (uniq, summed)
        self._pair_src = []
        self._pair_dst = []
        self._pair_weights = []
        self._pair_entries = len(uniq)

    def bottleneck_resource(self) -> str:
        totals = {r: float(u.sum()) for r, u in self.kg_usage.items()}
        return max(totals, key=totals.get)  # type: ignore[arg-type]

    def fold(self, scale_to_percent: float = 1.0) -> tuple[
        np.ndarray,
        "PairRates",
        str,
    ]:
        """Return (gLoad vector on bottleneck resource, pair rates, resource)."""
        r = self.bottleneck_resource()
        return self.kg_usage[r] * scale_to_percent, self.pair_counts(), r

    def reset(self) -> None:
        for r in self.resources:
            self.kg_usage[r][:] = 0
        self.kg_arrivals[:] = 0
        self._pair_src = []
        self._pair_dst = []
        self._pair_weights = []
        self._compacted = None
        self._pair_entries = 0
        self.samples = 0

"""Regenerate the EXPERIMENTS.md §Dry-run/§Roofline tables from the JSONs."""

import json
import sys

ROOF = "experiments/dryrun_results.json"
PERF = "experiments/perf_iterations.json"


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.1f}"


def roofline_table() -> str:
    rows = json.load(open(ROOF))
    ok = sorted(
        (r for r in rows if r.get("status") == "ok"),
        key=lambda r: (r["arch"], r["shape"], r["mesh"]),
    )
    skip = [r for r in rows if r.get("status") == "skip"]
    out = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) | dominant | MODEL/HLO | peak GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in ok:
        peak = (r.get("memory_analysis") or {}).get("peak_bytes")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {fmt_bytes(peak)} |"
        )
    out.append("")
    out.append(f"Skipped cells ({len(skip)}):")
    for r in skip:
        out.append(f"- `{r['arch']} × {r['shape']}` — {r['reason']}")
    return "\n".join(out)


def perf_table() -> str:
    rows = json.load(open(PERF))
    out = [
        "| cell | variant | compute (s) | memory (s) | collective (s) | dominant | useful | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']}×{r['shape']}×{r['mesh']} | {r['variant']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} | {r.get('note','')[:70]} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    print(roofline_table() if which == "roofline" else perf_table())

"""Schema-typed operator API: declaration, validation, columnar routing and
raw-buffer migration codecs.

Covers the construction-time contract (schema mismatch across an edge is an
error, not a runtime surprise), the no-object-fallback guarantee on fully
typed paths (the small-fix satellite: neither ``keygroups_of`` nor
``_route_batch`` may box when every edge into the batch is schema-typed),
and bit-exact serialize→install round-trips of schema-typed state and
queued segments across both queue implementations.
"""

import pickle

import numpy as np
import pytest

from conformance import make_pipeline_topo
from repro.data.jobs import real_job_3
from repro.data.synthetic import StreamSpec, airline_stream
from repro.engine import (
    Engine,
    ExecutionConfig,
    OperatorSpec,
    Schema,
    Topology,
)
from repro.engine import serde
from repro.engine.topology import make_batch


def _noop(state, keys, values, ts):
    return state, []


REC = Schema.record([("a", "i8"), ("b", "f8")])


# ---------------------------------------------------------------------------
# Declaration and validation
# ---------------------------------------------------------------------------


def test_schema_rejects_object_dtypes():
    with pytest.raises(ValueError, match="native"):
        Schema(np.dtype(object))
    with pytest.raises(ValueError, match="native"):
        Schema(np.dtype(np.float64), key=np.dtype(object))


def test_schema_structural_equality():
    assert REC == Schema.record([("a", "i8"), ("b", "f8")])
    assert REC != Schema.record([("a", "i8"), ("b", "f4")])
    assert Schema(np.float64) == Schema(np.dtype("f8"))


def test_edge_schema_mismatch_is_construction_error():
    t = Topology()
    t.add_operator(OperatorSpec("src", None, is_source=True, schema=REC))
    t.add_operator(
        OperatorSpec("dst", _noop, schema=Schema.record([("a", "i8"), ("b", "f4")]))
    )
    t.connect("src", "dst")
    with pytest.raises(ValueError, match="schema mismatch"):
        t.validate()


def test_gradual_edges_validate():
    """Typed→untyped (decay) and untyped→typed (promote) are both legal."""
    t = Topology()
    t.add_operator(OperatorSpec("src", None, is_source=True, schema=REC))
    t.add_operator(OperatorSpec("untyped", _noop, out_schema=None))
    t.add_operator(OperatorSpec("typed", _noop, schema=REC, is_sink=True))
    t.connect("src", "untyped")
    t.connect("untyped", "typed")
    t.validate()


def test_key_by_value_col_requires_scalar_form():
    t = Topology()
    t.add_operator(OperatorSpec("src", None, is_source=True))
    t.add_operator(
        OperatorSpec("op", _noop, key_by_value_col=lambda v: v["a"], is_sink=True)
    )
    t.connect("src", "op")
    with pytest.raises(ValueError, match="key_by_value_col"):
        t.validate()


# ---------------------------------------------------------------------------
# Columnar keying skips the object-dtype fallback entirely
# ---------------------------------------------------------------------------


def _typed_byval_topo():
    t = Topology()
    t.add_operator(OperatorSpec("src", None, is_source=True, schema=REC))
    t.add_operator(
        OperatorSpec(
            "op",
            _noop,
            num_keygroups=16,
            key_by_value=lambda v: v[0] * 3 + 1,
            key_by_value_col=lambda v: v["a"] * np.int64(3) + np.int64(1),
            schema=REC,
            is_sink=True,
        )
    )
    t.connect("src", "op")
    return t


def test_columnar_key_by_value_matches_scalar_oracle():
    t = _typed_byval_topo()
    values = np.array(
        [(i, float(i) / 3) for i in range(257)], dtype=REC.value
    )
    keys = np.arange(257, dtype=np.int64)
    batched = t.keygroups_of(1, keys, values)
    scalar = np.array(
        [t.keygroup_of(1, k, v) for k, v in zip(keys, values)], dtype=np.int64
    )
    assert np.array_equal(batched, scalar)


def test_typed_batch_keying_never_boxes(monkeypatch):
    """On a fully schema-typed batch the per-object hash fallback is dead
    code: poison it and the batched path must not notice."""
    import repro.engine.topology as topo_mod

    t = _typed_byval_topo()
    values = np.array([(i, 0.0) for i in range(64)], dtype=REC.value)
    keys = np.arange(64, dtype=np.int64)

    def boom(x):
        raise AssertionError("object-dtype fallback reached on a typed batch")

    monkeypatch.setattr(topo_mod, "hash_key", boom)
    t.keygroups_of(1, keys, values)  # does not raise


def test_typed_job_routes_no_object_arrays(monkeypatch):
    """Job 3 typed end to end: every routed/queued value array is native
    (the airline jobs' edges are all declared), and the per-object hash
    never fires."""
    import repro.engine.topology as topo_mod

    real_hash = topo_mod.hash_key

    def boom(x):
        raise AssertionError(f"hash_key({x!r}) on the typed airline job")

    monkeypatch.setattr(topo_mod, "hash_key", boom)
    eng = Engine(real_job_3(keygroups_per_op=12), 4, service_rate=1e9, seed=0)
    stream = airline_stream(StreamSpec(rate=150.0, seed=3))
    for _ in range(6):
        k, v, ts = next(stream)
        eng.push_source("airline", k, v, ts)
        for q in eng._queues:  # queued segments are native-dtype slices
            for seg in getattr(q, "_segs", ()):
                assert seg[1].dtype.kind != "O"
                assert seg[0].dtype.kind != "O"
        eng.tick()
    monkeypatch.setattr(topo_mod, "hash_key", real_hash)
    assert eng.metrics.typed_batches > 0
    assert eng.metrics.processed_tuples > 0


def test_untyped_engine_routes_zero_typed_batches():
    eng = Engine(
        real_job_3(keygroups_per_op=12), 4, service_rate=1e9, seed=0,
        config=ExecutionConfig.seg()
    )
    stream = airline_stream(StreamSpec(rate=150.0, seed=3))
    for _ in range(4):
        k, v, ts = next(stream)
        eng.push_source("airline", k, v, ts)
        eng.tick()
    assert eng.metrics.typed_batches == 0
    assert eng.metrics.processed_tuples > 0


# ---------------------------------------------------------------------------
# serde: raw-buffer batch codec and the migration envelope
# ---------------------------------------------------------------------------


def test_typed_batch_roundtrip_is_byte_exact():
    values = np.array([(i, i * 0.25) for i in range(500)], dtype=REC.value)
    keys = np.arange(500, dtype=np.int32)
    ts = np.linspace(0.0, 1.0, 500)
    out = serde.decode_batch(serde.encode_batch((keys, values, ts)))
    for orig, dec in zip((keys, values, ts), out):
        assert dec.dtype == orig.dtype
        assert dec.tobytes() == orig.tobytes()
        assert dec.flags.writeable


def test_empty_typed_batch_roundtrip_is_byte_exact():
    keys = np.empty(0, dtype=np.int64)
    values = np.empty(0, dtype=REC.value)
    ts = np.empty(0, dtype=np.float64)
    blob = serde.encode_batch((keys, values, ts))
    out = serde.decode_batch(blob)
    for orig, dec in zip((keys, values, ts), out):
        assert dec.dtype == orig.dtype
        assert dec.shape == (0,)
    # Re-encoding the decode reproduces the exact bytes (stable layout).
    assert serde.encode_batch(out) == blob


def test_padded_structured_dtype_roundtrip_is_byte_exact():
    """A structured dtype with alignment padding must survive the raw-buffer
    path byte-exactly — itemsize includes the pad, so raw slices do."""
    padded = np.dtype([("a", "i1"), ("b", "f8")], align=True)
    assert padded.itemsize == 16  # 7 pad bytes between the fields
    values = np.zeros(64, dtype=padded)
    values["a"] = np.arange(64) % 100
    values["b"] = np.linspace(-1.0, 1.0, 64)
    keys = np.arange(64, dtype=np.int64)
    ts = np.zeros(64)
    blob = serde.encode_batch((keys, values, ts))
    out = serde.decode_batch(blob)
    for orig, dec in zip((keys, values, ts), out):
        assert dec.dtype == orig.dtype
        assert dec.tobytes() == orig.tobytes()
    assert serde.encode_batch(out) == blob


def test_typed_headers_are_interned():
    """Same schema ⇒ the exact same header bytes (and the same object), so
    two batches of one schema differ only in their length+column bytes."""
    h1 = serde.typed_header(
        np.dtype(np.int64), np.dtype(REC.value), np.dtype(np.float64)
    )
    h2 = serde.typed_header(
        np.dtype(np.int64), np.dtype(REC.value), np.dtype(np.float64)
    )
    assert h1 is h2
    a = serde.encode_batch(
        (np.arange(3, dtype=np.int64), np.zeros(3, REC.value), np.zeros(3))
    )
    b = serde.encode_batch(
        (np.arange(9, dtype=np.int64), np.zeros(9, REC.value), np.zeros(9))
    )
    hlen = int.from_bytes(a[:4], "little")
    assert a[: 4 + hlen] == b[: 4 + hlen]  # shared interned prefix


def test_object_field_inside_structured_dtype_takes_pickle_path():
    """kind == "V" but hasobject: raw buffers would ship pointers, so the
    codec must fall back to pickle (and still round-trip values)."""
    tricky = np.dtype([("n", "i8"), ("o", "O")])
    values = np.empty(3, dtype=tricky)
    values["n"] = [1, 2, 3]
    values["o"] = [{"x": 1}, None, "s"]
    batch = (np.arange(3, dtype=np.int64), values, np.zeros(3))
    assert not serde.is_typed_batch(batch)
    out = serde.decode_batch(serde.encode_batch(batch))
    assert out[1]["n"].tolist() == [1, 2, 3]
    assert out[1]["o"].tolist() == [{"x": 1}, None, "s"]


def test_legacy_five_tuple_header_still_decodes():
    """Blobs written before header interning carried the batch length inside
    the pickled header; decode_batch must keep reading them."""
    keys = np.arange(7, dtype=np.int64)
    values = np.linspace(0.0, 1.0, 7)
    ts = np.zeros(7)
    head = pickle.dumps((0, keys.dtype, values.dtype, ts.dtype, 7))
    legacy = (
        len(head).to_bytes(4, "little")
        + head
        + keys.tobytes()
        + values.tobytes()
        + ts.tobytes()
    )
    out = serde.decode_batch(legacy)
    for orig, dec in zip((keys, values, ts), out):
        assert dec.dtype == orig.dtype
        assert dec.tobytes() == orig.tobytes()


def test_object_batch_roundtrip_preserves_values():
    batch = make_batch(
        [1, 2, 3], [(1, "x"), {"d": 2}, None], [0.0, 1.0, 2.0]
    )
    out = serde.decode_batch(serde.encode_batch(batch))
    assert out[0].tolist() == [1, 2, 3]
    assert out[1].tolist() == [(1, "x"), {"d": 2}, None]
    assert out[2].tolist() == [0.0, 1.0, 2.0]


def test_typed_encoding_beats_pickled_tuples():
    """The raw-buffer encoding of a typed batch is smaller than what the
    object path ships for the same tuples (a pickled object array of boxed
    record tuples)."""
    n = 4_000
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2**40, size=n)
    b = rng.random(n)
    keys = rng.integers(0, 2**40, size=n)
    ts = rng.random(n)
    values = np.empty(n, dtype=REC.value)
    values["a"] = a
    values["b"] = b
    boxed_vals = np.empty(n, dtype=object)
    boxed_vals[:] = list(zip(a.tolist(), b.tolist()))
    typed = serde.encode_batch((keys, values, ts))
    boxed = serde.encode_batch((keys, boxed_vals, ts))
    assert len(typed) < len(boxed)
    # Raw-slice encoding: header + the exact column bytes, nothing else.
    payload = n * (8 + values.dtype.itemsize + 8)
    assert len(typed) < payload + 256


def test_migration_envelope_roundtrip_and_legacy_blobs():
    state_blob = pickle.dumps({"n": 7})
    batch = (
        np.arange(8, dtype=np.int64),
        np.arange(8, dtype=np.float64),
        np.zeros(8),
    )
    blob = serde.encode_migration(state_blob, [batch, batch])
    state_out, backlog = serde.decode_migration(blob)
    assert state_out == state_blob
    assert len(backlog) == 2
    assert np.array_equal(backlog[0][0], batch[0])
    # Pre-envelope blobs (failure recovery from checkpoints) pass through.
    assert serde.decode_migration(state_blob) == (state_blob, [])


def test_envelope_version_reading_and_rejection():
    blob = serde.encode_migration(pickle.dumps({"n": 1}), [])
    assert blob[:4] == serde.MAGIC
    assert serde.envelope_version(blob) == serde.ENVELOPE_VERSION == 1
    # Bare pickles are versionless, not an error.
    assert serde.envelope_version(pickle.dumps({"n": 1})) is None
    # A future layout must be rejected loudly, never misparsed.
    future = b"RSE2" + blob[4:]
    assert serde.envelope_version(future) == 2
    with pytest.raises(ValueError, match="unsupported migration envelope"):
        serde.decode_migration(future)
    with pytest.raises(ValueError, match="malformed envelope version"):
        serde.envelope_version(b"RSEx-junk")
    # This build only writes the current version.
    with pytest.raises(ValueError, match="cannot encode"):
        serde.encode_migration(b"", [], version=2)


def test_envelope_dataclass_exposes_version_and_size():
    env = serde.Envelope(keygroup=3, blob=serde.encode_migration(b"s", []))
    assert env.version == 1 and env.keygroup == 3
    assert env.nbytes == len(env.blob)


def test_export_import_keygroup_roundtrip():
    topo = make_pipeline_topo(8)
    a = Engine(topo, 3, service_rate=1e9, seed=0)
    b = Engine(topo, 3, service_rate=1e9, seed=0)
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 4_000, size=300).astype(np.int64)
    for eng in (a, b):
        eng.push_source("src", keys, rng.random(300), np.zeros(300))
        eng.tick()
    kg = int(topo.kg_base(1))
    env = a.export_keygroup(kg)
    assert env.version == 1
    # Export is non-destructive: the same call reproduces the same bytes.
    assert a.export_keygroup(kg).blob == env.blob
    # Install onto another node of an identically-driven engine and finish
    # the job there; no tuples may be lost.
    dst = (b.router.node_of(kg) + 1) % 3
    b.router.table[kg] = dst
    b.router.version += 1
    b.import_keygroup(env, dst)
    assert b.router.node_of(kg) == dst


# ---------------------------------------------------------------------------
# Engine serialize→install: schema-typed state and queued segments
# ---------------------------------------------------------------------------


def test_schema_roundtrip_identical_across_queue_impls():
    """Mid-migration serialize blobs — σ_k plus queued segments — are
    byte-identical on SoA and deque queues under backpressure, and both
    engines finish the migration with identical results."""
    engines, blobs = [], []
    for impl in ("soa", "deque"):
        eng = Engine(
            make_pipeline_topo(8), 3, service_rate=90.0, seed=0,
            config=ExecutionConfig(queue_impl=impl)
        )
        rng = np.random.default_rng(11)
        for t in range(4):  # binding budget: work stays queued
            keys = rng.integers(0, 5_000, size=300).astype(np.int64)
            eng.push_source("src", keys, rng.random(300), np.full(300, float(t)))
            eng.tick()
        kg = eng.topology.kg_base(1) + 2
        dst = (eng.router.node_of(kg) + 1) % eng.num_nodes
        eng.redirect(kg, dst)
        eng.push_source(
            "src",
            rng.integers(0, 5_000, size=200).astype(np.int64),
            rng.random(200),
            np.full(200, 9.0),
        )
        eng.tick()
        blob = eng.serialize(kg)
        blobs.append(blob)
        eng.install(kg, dst, blob)
        for _ in range(60):
            if not any(eng._queues):
                break
            eng.tick()
        engines.append(eng)
    assert blobs[0] == blobs[1]
    # The envelope really carried queued segments as raw typed buffers.
    _state, backlog = serde.decode_migration(blobs[0])
    assert backlog, "migration moved no queued segments — vacuous round-trip"
    assert all(b[1].dtype.kind != "O" for b in backlog)
    a, b = engines
    assert a.metrics.processed_tuples == b.metrics.processed_tuples
    assert a.metrics.sink_outputs == b.metrics.sink_outputs
    assert [s for _, s in a.store.items()] == [s for _, s in b.store.items()]


def test_bare_blob_install_does_not_strand_backlog():
    """Installing a checkpoint-style bare state pickle (no envelope) after a
    redirect must still replay the queued tuples redirect extracted — the
    engine-side backlog drains on install regardless of blob provenance."""
    eng = Engine(make_pipeline_topo(8), 3, service_rate=1e9, seed=0)
    rng = np.random.default_rng(5)
    keys = rng.integers(0, 5_000, size=400).astype(np.int64)
    accepted = eng.push_source("src", keys, rng.random(400), np.zeros(400))
    eng.tick()  # src → mid queued
    kg = eng.topology.kg_base(1) + 1
    dst = (eng.router.node_of(kg) + 1) % eng.num_nodes
    eng.redirect(kg, dst)
    assert eng._backlog.get(kg), "redirect extracted no queued work — vacuous"
    # Failure-recovery style: state restored from a raw store pickle, the
    # serialize() envelope never built.
    eng.install(kg, dst, eng.store.serialize(kg))
    assert kg not in eng._backlog
    for _ in range(40):
        if not any(eng._queues):
            break
        eng.tick()
    mid_base = eng.topology.kg_base(1)
    mid_total = sum(
        eng.store.get(k).get("n", 0) for k in range(mid_base, mid_base + 8)
    )
    assert mid_total == accepted  # every accepted tuple processed exactly once


def test_schema_roundtrip_matches_untyped_path():
    """The same migration schedule driven typed and untyped lands on the
    identical state, sinks and statistics (raw-buffer vs pickle envelopes
    are an encoding choice, not a semantic one)."""
    results = []
    for use_schema in (True, False):
        eng = Engine(
            make_pipeline_topo(8), 3, service_rate=120.0, seed=0,
            config=ExecutionConfig(use_schema=use_schema)
        )
        rng = np.random.default_rng(13)
        pending = []
        for t in range(8):
            keys = rng.integers(0, 5_000, size=250).astype(np.int64)
            eng.push_source("src", keys, rng.random(250), np.full(250, float(t)))
            if t in (2, 5):
                kg = int(rng.integers(0, eng.topology.num_keygroups))
                dstn = int(rng.integers(0, eng.num_nodes))
                if not eng.router.is_in_flight(kg):
                    eng.redirect(kg, dstn)
                    pending.append(kg)
            eng.tick()
            if t in (4, 7):
                for kg in pending:
                    eng.install(kg, eng.router.node_of(kg), eng.serialize(kg))
                pending = []
        for _ in range(80):
            if not any(eng._queues):
                break
            eng.tick()
        snap = eng.end_period()
        results.append(
            (
                eng.metrics.processed_tuples,
                eng.metrics.sink_outputs,
                [s for _, s in eng.store.items()],
                snap.kg_load.tolist(),
                snap.kg_state_bytes.tolist(),
            )
        )
    assert results[0] == results[1]

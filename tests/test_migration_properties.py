"""Property test: migration round-trips preserve tuples and state.

Random interleavings of ``redirect`` / ``serialize`` / ``install`` across
random key groups — with pushes and ticks in between — must preserve the
total tuple counts and the per-key-group state, identically on both queue
implementations and on the schema-typed data path (whose serialize/install
envelope ships queued segments as raw buffer slices rather than pickled
lists).  This generalizes the hand-written round-trip cases in
tests/test_routing_equivalence.py to arbitrary schedules.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from conformance import make_pipeline_topo, normalize
from repro.engine import Engine, ExecutionConfig

KGS = 8
NODES = 3

# An action is one of:
#   ("push", seed)      feed a batch of source tuples
#   ("tick", n)         run n engine ticks
#   ("redirect", kg, dst)  start migrating key group kg to node dst
#   ("install",)        complete the oldest in-flight migration
actions = st.lists(
    st.one_of(
        st.tuples(st.just("push"), st.integers(0, 7)),
        st.tuples(st.just("tick"), st.integers(1, 3)),
        st.tuples(
            st.just("redirect"), st.integers(0, 3 * KGS - 1), st.integers(0, NODES - 1)
        ),
        st.tuples(st.just("install")),
    ),
    min_size=1,
    max_size=24,
)


def _apply(eng, schedule):
    """Run the schedule; returns tuples accepted.  Deterministic given the
    schedule, so both engines see byte-identical inputs."""
    rng = np.random.default_rng(1234)
    accepted = 0
    pending: list[int] = []  # redirected, not yet installed (FIFO)
    for action in schedule:
        kind = action[0]
        if kind == "push":
            n = 40 + 8 * action[1]
            keys = rng.integers(0, 5_000, size=n).astype(np.int64)
            accepted += eng.push_source("src", keys, rng.random(n), np.zeros(n))
        elif kind == "tick":
            for _ in range(action[1]):
                eng.tick()
        elif kind == "redirect":
            kg, dst = action[1], action[2]
            if not eng.router.is_in_flight(kg):
                eng.redirect(kg, dst)
                pending.append(kg)
        else:  # install
            if pending:
                kg = pending.pop(0)
                dst = eng.router.node_of(kg)  # redirect already flipped it
                eng.install(kg, dst, eng.serialize(kg))
    # Quiesce: complete stragglers, then drain until every queue is empty.
    while pending:
        kg = pending.pop(0)
        eng.install(kg, eng.router.node_of(kg), eng.serialize(kg))
    for _ in range(200):
        if not any(eng._queues):
            break
        eng.tick()
    assert not any(eng._queues), "engine failed to quiesce"
    assert not eng.router.in_flight
    return accepted


@settings(max_examples=30, deadline=None)
@given(schedule=actions)
def test_migration_interleavings_preserve_tuples_and_state(schedule):
    results = []
    for impl, use_schema in (("soa", True), ("soa", False), ("deque", False)):
        eng = Engine(
            make_pipeline_topo(KGS),
            NODES,
            service_rate=120.0,
            seed=0,
            config=ExecutionConfig(
                queue_impl=impl,
                use_schema=use_schema,
                use_fn_seg=impl == "soa",
            ),
        )
        accepted = _apply(eng, schedule)
        mid_base = eng.topology.kg_base(1)
        mid_counts = [
            eng.store.get(kg).get("n", 0) for kg in range(mid_base, mid_base + KGS)
        ]
        sink_base = eng.topology.kg_base(2)
        sink_counts = [
            eng.store.get(kg).get("n", 0) for kg in range(sink_base, sink_base + KGS)
        ]
        # Conservation: every accepted tuple was processed exactly once by
        # the mid operator and its output exactly once by the sink.
        assert sum(mid_counts) == accepted
        assert sum(sink_counts) == accepted
        results.append(
            (
                accepted,
                eng.metrics.processed_tuples,
                eng.metrics.emitted_tuples,
                mid_counts,
                sink_counts,
                normalize(eng.metrics.sink_outputs),
                eng.router.table.tolist(),
            )
        )
    # Every configuration agrees field for field.
    assert results[0] == results[1] == results[2]

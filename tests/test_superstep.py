"""The device-resident superstep (engine/superstep.py): fused ticks and the
K-tick scan must be observationally identical to the classic engine.

The conformance matrix (tests/test_real_jobs_conformance.py,
tests/test_conformance_fuzz.py) already pins the ``+superstep``
configuration against every oracle; this module pins the *mechanics*:

* static eligibility (``plan_chain``) accepts exactly the documented shape;
* a fused run really crosses the host boundary once per tick
  (``metrics.jit_host_syncs``), and ``run_supersteps(K)`` once per K ticks;
* migration at a superstep boundary produces byte-identical serialize
  envelopes (``flush_to_host`` materializes device pendings first);
* binding budgets / dead nodes force the classic fallback without any
  divergence;
* ``Engine(use_fn_jit=True, superstep=True)`` over a topology with zero
  ``fn_jit`` operators never imports jax (no x64 flip) — the flag degrades
  to the plain engine.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conformance import (
    METRIC_FIELDS,
    Scenario,
    _int_batches,
    assert_equivalent,
    fuzz_feeders,
    make_fuzz_topology,
    make_pipeline_topo,
    normalize,
    run_configs,
)
from repro.engine import Engine, ExecutionConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _engine(superstep, *, service_rate=1e9, num_nodes=4):
    config = ExecutionConfig.superstep() if superstep else ExecutionConfig.jit()
    return Engine(
        make_pipeline_topo(),
        num_nodes,
        service_rate=service_rate,
        seed=0,
        config=config,
    )


def _result(eng):
    snap = eng.end_period()
    return {
        "metrics": {m: getattr(eng.metrics, m) for m in METRIC_FIELDS},
        "sink_outputs": normalize(eng.metrics.sink_outputs),
        "states": [normalize(s) for _, s in eng.store.items()],
        "pair_src": snap.out_pairs.src.tolist(),
        "pair_dst": snap.out_pairs.dst.tolist(),
        "pair_rate": snap.out_pairs.rate.tolist(),
        "arrivals": eng._arrivals.tolist(),
        "usage": eng._cpu_usage.tolist(),
        "queue_costs": [q.cost for q in eng._queues],
        "alloc": eng.router.table.tolist(),
    }


def _drive(eng, *, ticks=12, migrate_at=(), fail_at=None, collect_blobs=False):
    feed = _int_batches()
    rng = np.random.default_rng(1)
    in_flight = []
    blobs = []
    for t in range(ticks):
        if t in migrate_at:
            kg = int(rng.integers(0, eng.topology.num_keygroups))
            dst = int(rng.integers(0, eng.num_nodes))
            if not eng.router.is_in_flight(kg):
                eng.redirect(kg, dst)
                in_flight.append((t, kg, dst))
        if fail_at is not None and t == fail_at:
            eng.fail_node(2)
        keys, values, ts = next(feed)
        eng.push_source("src", keys, values, ts)
        eng.tick()
        for item in list(in_flight):
            t0, kg, dst = item
            if t >= t0 + 1:
                blob = eng.serialize(kg)
                if collect_blobs:
                    blobs.append(blob)
                eng.install(kg, dst, blob)
                in_flight.remove(item)
    for _ in range(8):
        eng.tick()
    return blobs


# ---------------------------------------------------------------------------
# static eligibility
# ---------------------------------------------------------------------------


def test_plan_accepts_the_pipeline_chain():
    from repro.engine.superstep import plan_chain

    eng = _engine(True)
    plan = plan_chain(eng)
    assert plan is not None
    assert [eng.topology.operators[o].name for o in plan.fops] == [
        "mid",
        "sink",
    ]


def test_plan_rejects_non_fusible_shapes():
    from repro.engine.superstep import plan_chain

    # Not marked jit_fusible → never fuses (the contract is an opt-in).
    topo = make_pipeline_topo()
    topo.operators[1].jit_fusible = False
    eng = Engine(topo, 4, service_rate=1e9, seed=0,
                 config=ExecutionConfig.superstep())
    assert plan_chain(eng) is None
    # Non-identity partition key breaks the device-routing replay.
    topo = make_pipeline_topo()
    topo.operators[2].key_fn = lambda k: k % 3
    eng = Engine(topo, 4, service_rate=1e9, seed=0,
                 config=ExecutionConfig.superstep())
    assert plan_chain(eng) is None
    # The interpreted tiers must not build a plan at all.
    eng = Engine(make_pipeline_topo(), 4, service_rate=1e9, seed=0)
    assert plan_chain(eng) is None


# ---------------------------------------------------------------------------
# fused tick: equivalence + O(1) crossings per tick
# ---------------------------------------------------------------------------


def test_fused_tick_is_bit_identical_and_syncs_once_per_tick():
    ea = _engine(False)
    _drive(ea)
    eb = _engine(True)
    _drive(eb)
    assert _result(ea) == _result(eb)
    # Classic: one crossing per fn_jit operator per non-empty tick.  Fused:
    # one per non-empty tick, regardless of chain depth.
    assert 0 < eb.metrics.jit_host_syncs < ea.metrics.jit_host_syncs
    assert eb.metrics.jit_host_syncs <= eb.metrics.ticks


def test_migration_blobs_byte_identical_at_superstep_boundary():
    ea = _engine(False)
    blobs_a = _drive(ea, migrate_at=(3, 7), collect_blobs=True)
    eb = _engine(True)
    blobs_b = _drive(eb, migrate_at=(3, 7), collect_blobs=True)
    assert _result(ea) == _result(eb)
    assert blobs_a and blobs_a == blobs_b  # byte-identical envelopes


def test_binding_budget_forces_classic_fallback():
    # service_rate 60 → partial drains every tick: _collect must bail and
    # flush_to_host must leave the classic drain bit-exact.
    ea = _engine(False, service_rate=60.0)
    _drive(ea)
    eb = _engine(True, service_rate=60.0)
    _drive(eb)
    assert _result(ea) == _result(eb)


def test_dead_node_forces_classic_fallback():
    ea = _engine(False)
    _drive(ea, fail_at=5)
    eb = _engine(True)
    _drive(eb, fail_at=5)
    assert _result(ea) == _result(eb)


# ---------------------------------------------------------------------------
# fixed fuzz specs (the hypothesis suite generalizes; these always run)
# ---------------------------------------------------------------------------

_FUZZ_SPECS = {
    "scalar-chain": {
        "family": "scalar",
        "key_dtype": "i8",
        "source_schema": True,
        "ops": [
            {"kind": "rekey", "kgs": 8, "schema": True, "out_schema": True,
             "key": "id"},
            {"kind": "vshift", "kgs": 8, "schema": True, "out_schema": True,
             "key": "id"},
        ],
        "edges": [[-1], [0]],
    },
    "record-window-filter": {
        "family": "record",
        "key_dtype": "i4",
        "source_schema": True,
        "ops": [
            {"kind": "project", "kgs": 6, "schema": True, "out_schema": True,
             "key": "id"},
            {"kind": "window", "kgs": 5, "schema": True, "out_schema": True,
             "key": "mod"},
            {"kind": "filter", "kgs": 7, "schema": True, "out_schema": False,
             "key": "id"},
        ],
        "edges": [[-1], [0], [1]],
    },
    "fanout-mixed-tiers": {
        "family": "scalar",
        "key_dtype": "i8",
        "source_schema": True,
        "ops": [
            {"kind": "window", "kgs": 8, "schema": True, "out_schema": True,
             "key": "id"},
            {"kind": "filter", "kgs": 6, "schema": True, "out_schema": True,
             "key": "mod"},
            {"kind": "accum", "kgs": 5, "schema": False, "out_schema": False,
             "key": "id"},
        ],
        "edges": [[-1], [-1, 0], [1, 0]],
    },
}


@pytest.mark.parametrize("name", list(_FUZZ_SPECS), ids=str)
def test_fuzz_jit_ports_conform(name):
    spec = _FUZZ_SPECS[name]
    scenario = Scenario("fuzz", ticks=10, drain_ticks=6, migrate_at=(4,))
    results = run_configs(
        lambda: make_fuzz_topology(spec), fuzz_feeders(spec), scenario
    )
    assert_equivalent(results)
    # The ported operators really ran on the compiled tier.
    assert results["soa+seg+schema+jit"]["jit_calls"] > 0


# ---------------------------------------------------------------------------
# run_supersteps: the K-tick scan
# ---------------------------------------------------------------------------


def _batches(K, seed=5):
    feed = _int_batches(seed=seed)
    return [next(feed) for _ in range(K)]


def test_run_supersteps_matches_classic_full_drain():
    K = 14
    batches = _batches(K)
    ea = _engine(False)
    for k, v, t in batches:
        ea.push_source("src", k, v, t)
        ea.tick()
    while any(bool(q) for q in ea._queues):
        ea.tick()

    eb = _engine(True)
    syncs0 = eb.metrics.jit_host_syncs
    assert eb.run_supersteps(batches) == K
    # One host crossing for all K supersteps — the tentpole invariant.
    assert eb.metrics.jit_host_syncs - syncs0 == 1
    while any(bool(q) for q in eb._queues):
        eb.tick()

    ra, rb = _result(ea), _result(eb)
    # The scan records no per-admission latency and needs fewer drain
    # ticks, but every pinned aggregate must match exactly.
    assert ra == rb
    assert ea.metrics.sink_outputs == eb.metrics.sink_outputs


def test_run_supersteps_static_route_matches_classic():
    """With jit_key_map declared on every non-terminal fused operator the
    scan routes from a host-precomputed schedule (no device sorts); the
    result must stay bit-identical to the classic engine and to one host
    crossing per scan."""
    from repro.engine.superstep import plan_chain

    def static_engine():
        topo = make_pipeline_topo()
        topo.operators[1].jit_key_map = lambda k: k + 17  # mid re-keys by +17
        return Engine(topo, 4, service_rate=1e9, seed=0,
                      config=ExecutionConfig.superstep())

    # The undeclared chain must keep using the on-device routing path.
    assert not plan_chain(_engine(True)).static_route

    K = 14
    batches = _batches(K)
    ea = _engine(False)
    for k, v, t in batches:
        ea.push_source("src", k, v, t)
        ea.tick()
    while any(bool(q) for q in ea._queues):
        ea.tick()

    eb = static_engine()
    assert plan_chain(eb).static_route
    syncs0 = eb.metrics.jit_host_syncs
    assert eb.run_supersteps(batches) == K
    assert eb.metrics.jit_host_syncs - syncs0 == 1
    while any(bool(q) for q in eb._queues):
        eb.tick()
    assert _result(ea) == _result(eb)
    assert ea.metrics.sink_outputs == eb.metrics.sink_outputs

    # A migration right after the scan still extracts/replays the
    # materialized pendings byte-exactly.
    batches = _batches(8)
    ea = _engine(False)
    for k, v, t in batches:
        ea.push_source("src", k, v, t)
        ea.tick()
    eb = static_engine()
    eb.run_supersteps(batches)
    for eng in (ea, eb):
        eng.redirect(5, 2)
        eng.tick()
        eng.install(5, 2, eng.serialize(5))
        while any(bool(q) for q in eng._queues):
            eng.tick()
    assert _result(ea) == _result(eb)


def test_run_supersteps_guards():
    eng = _engine(False)
    with pytest.raises(RuntimeError, match="superstep=True"):
        eng.run_supersteps(_batches(2))
    eng = _engine(True)
    k, v, t = _batches(1)[0]
    eng.push_source("src", k, v, t)
    with pytest.raises(RuntimeError, match="empty queues"):
        eng.run_supersteps(_batches(2))
    eng = _engine(True, service_rate=100.0)  # a superstep cannot fit
    with pytest.raises(RuntimeError, match="backpressure"):
        eng.run_supersteps(_batches(2))


def test_run_supersteps_then_migration_round_trip():
    """The scan's leftover pendings are real segments: a migration right
    after run_supersteps extracts/replays them like any queued work."""
    batches = _batches(8)
    ea = _engine(False)
    for k, v, t in batches:
        ea.push_source("src", k, v, t)
        ea.tick()
    eb = _engine(True)
    eb.run_supersteps(batches)
    for eng in (ea, eb):
        eng.redirect(5, 2)
        eng.tick()
        eng.install(5, 2, eng.serialize(5))
        while any(bool(q) for q in eng._queues):
            eng.tick()
    assert _result(ea) == _result(eb)


# ---------------------------------------------------------------------------
# zero-fn_jit regression: superstep must not drag jax in
# ---------------------------------------------------------------------------

ZERO_FN_JIT = textwrap.dedent(
    """
    import sys
    import numpy as np
    from repro.engine import Engine, ExecutionConfig
    from repro.engine.topology import OperatorSpec, Schema, Topology

    t = Topology()
    scalar = Schema(np.dtype(np.float64))
    t.add_operator(OperatorSpec("src", None, num_keygroups=4,
                                is_source=True, schema=scalar))

    def fn(state, keys, values, ts):
        state["n"] = state.get("n", 0) + len(keys)
        return state, (keys, values, ts)

    t.add_operator(OperatorSpec("snk", fn, num_keygroups=4, is_sink=True,
                                schema=scalar))
    t.connect("src", "snk")
    eng = Engine(t, 2, service_rate=1e9, seed=0,
                 config=ExecutionConfig.superstep())
    assert eng.superstep is False  # degraded: nothing to fuse
    eng.push_source("src", np.arange(8, dtype=np.int64), np.ones(8),
                    np.zeros(8))
    eng.tick()
    eng.tick()
    assert eng.metrics.sink_tuples == 8
    assert "repro.engine.jitexec" not in sys.modules
    assert "repro.engine.superstep" not in sys.modules
    assert "jax" not in sys.modules
    assert np.asarray([1.5]).dtype == np.float64  # x64 never flipped
    print("ZERO-FN-JIT-OK")
    """
)


def test_superstep_with_zero_fn_jit_ops_skips_jit_setup():
    """use_fn_jit=True + superstep=True over a topology with no fn_jit
    operators must not import jitexec/superstep/jax (the x64 flip is the
    observable side effect guarded here).  Subprocess: module-import state
    is process-global."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", ZERO_FN_JIT],
        capture_output=True,
        text=True,
        timeout=300,
        env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ZERO-FN-JIT-OK" in proc.stdout

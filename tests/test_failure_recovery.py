"""Engine.fail_node + add_nodes recovery paths.

Covers the engine-level elastic/failure API directly (the controller-driven
crash path has its own test in test_engine.py): a failed node's key groups
are orphaned and reassignable without losing post-recovery tuples, queue
accounting survives the crash, and freshly added nodes are fully wired into
capacity, backpressure and SPL statistics.
"""

import numpy as np

from conformance import make_pipeline_topo
from repro.engine import Engine

KGS = 8


def _engine(num_nodes=3, service_rate=1e9, **kw):
    return Engine(
        make_pipeline_topo(KGS),
        num_nodes,
        service_rate=service_rate,
        seed=0,
        **kw,
    )


def _push(eng, n, seed, key_space=5_000):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_space, size=n).astype(np.int64)
    return eng.push_source("src", keys, rng.random(n), np.zeros(n))


def _drain(eng, max_ticks=200):
    for _ in range(max_ticks):
        if not any(eng._queues):
            return
        eng.tick()
    raise AssertionError("engine failed to quiesce")


def _mid_total(eng):
    base = eng.topology.kg_base(1)
    return sum(eng.store.get(kg).get("n", 0) for kg in range(base, base + KGS))


def test_fail_node_orphans_and_recovery_loses_no_new_tuples():
    eng = _engine()
    accepted = _push(eng, 300, seed=1)
    _drain(eng)
    assert _mid_total(eng) == accepted

    victim = 1
    expected_orphans = eng.router.keygroups_on(victim)
    orphans = eng.fail_node(victim)
    assert np.array_equal(orphans, expected_orphans)
    assert not eng.alive[victim]
    assert not eng._queues[victim] and eng._queues[victim].cost == 0.0

    # Reassign every orphan (state survives in-process; the real system
    # restores it from the checkpoint — see repro.checkpoint).
    for kg in orphans.tolist():
        dst = (victim + 1) % eng.num_nodes
        eng.redirect(kg, dst)
        eng.install(kg, dst, eng.serialize(kg))
    assert (eng.router.table != victim).all()

    # Post-recovery traffic flows completely: nothing routes to the dead
    # node, and conservation holds for the new epoch.
    accepted2 = _push(eng, 300, seed=2)
    _drain(eng)
    assert _mid_total(eng) == accepted + accepted2
    assert eng.metrics.sink_tuples == accepted + accepted2
    assert eng._queues[victim].cost == 0.0


def test_fail_node_with_queued_work_keeps_accounting_consistent():
    eng = _engine(service_rate=50.0)  # tight budget: work stays queued
    accepted = _push(eng, 400, seed=3)
    eng.tick()
    victim = int(np.argmax([q.cost for q in eng._queues]))
    lost_cost = eng._queues[victim].cost
    assert lost_cost > 0.0, "scenario must crash a node with queued work"

    orphans = eng.fail_node(victim)
    assert eng._queues[victim].cost == 0.0
    for kg in orphans.tolist():
        dst = (victim + 1) % eng.num_nodes
        eng.redirect(kg, dst)
        eng.install(kg, dst, eng.serialize(kg))
    _drain(eng)

    # Tuples queued on the crashed node are gone (recovered via checkpoint
    # replay in the full system), but everything else drains exactly once
    # and the books stay consistent.
    assert _mid_total(eng) < accepted
    assert _mid_total(eng) == eng.metrics.sink_tuples
    assert all(q.cost == 0.0 for q in eng._queues)

    # SPL statistics still fold into a well-formed snapshot.
    snap = eng.end_period()
    assert np.isfinite(snap.kg_load).all() and (snap.kg_load >= 0).all()
    assert not snap.alive[victim]
    assert len(snap.alloc) == eng.topology.num_keygroups


def test_add_nodes_wires_capacity_queues_and_backpressure():
    eng = _engine(num_nodes=2)
    eng.add_nodes(2, capacity=2.0)
    assert eng.num_nodes == 4
    assert len(eng._queues) == 4
    assert eng.capacity.tolist() == [1.0, 1.0, 2.0, 2.0]
    assert eng._capacity_list == [1.0, 1.0, 2.0, 2.0]
    assert eng.alive.tolist() == [True] * 4
    assert eng.backpressure.num_nodes == 4

    # Migrate a key group onto a new node; it processes there.
    kg = int(eng.topology.kg_base(1)) + 2
    eng.redirect(kg, 3)
    eng.install(kg, 3, eng.serialize(kg))
    accepted = _push(eng, 400, seed=4)
    _drain(eng)
    assert _mid_total(eng) == accepted
    assert eng.store.get(kg).get("n", 0) > 0, "migrated key group never ran"

    # The folded snapshot reflects the grown cluster.
    snap = eng.end_period()
    assert snap.num_nodes == 4
    assert snap.capacity.tolist() == [1.0, 1.0, 2.0, 2.0]
    assert snap.alive.tolist() == [True] * 4


def test_failed_node_budget_is_skipped_until_recovered():
    """Ticks never drain a dead node's queue.  Work routed there after the
    crash piles up untouched until the key groups are reassigned — and then
    ``redirect`` pulls the queued runs along, so none of it is lost."""
    eng = _engine(service_rate=1e9)
    _push(eng, 200, seed=5)
    victim = 0
    eng.fail_node(victim)
    eng.tick()  # survivors drain; their outputs may route to the dead node
    stranded = eng._queues[victim].cost
    assert stranded > 0.0, "scenario must strand work on the dead node"
    eng.tick()
    # The dead node's queue only ever accumulates — it is never drained.
    assert eng._queues[victim].cost >= stranded, "dead node's queue was drained"

    orphans = eng.router.keygroups_on(victim)
    for kg in orphans.tolist():
        eng.redirect(kg, 1)  # extracts the stranded runs into the buffer...
        eng.install(kg, 1, eng.serialize(kg))  # ...and replays them at node 1
    assert eng._queues[victim].cost == 0.0
    accepted2 = _push(eng, 100, seed=6)
    assert accepted2 > 0
    _drain(eng)
    # Everything that survived the crash itself drained exactly once.
    assert _mid_total(eng) == eng.metrics.sink_tuples
    assert all(q.cost == 0.0 for q in eng._queues)

"""Crash-during-checkpoint atomicity (satellite of the self-healing PR).

A checkpoint writer SIGKILLed mid-save must never corrupt the latest
restorable checkpoint: the stage-then-rename protocol guarantees that a
directory named ``step_N`` (no ``.tmp``) is complete by construction, and
``CheckpointManager.__init__`` prunes any stage a killed writer left
behind.  Each test forks a real child process, wedges it at a chosen
point inside the write path, SIGKILLs it, and then restores from the
surviving parent-side manager.
"""

from __future__ import annotations

import multiprocessing as mp
import os
import signal
import time

import numpy as np
import pytest

from repro.checkpoint.checkpoint import MANIFEST, CheckpointManager

_ctx = mp.get_context("fork")


def _tree(step: int) -> dict:
    return {"w": np.full(8, float(step)), "bias": np.arange(3) + step}


def _wedged_writer(directory: str, staged, wedge: str, api: str) -> None:
    """Child body: start writing step 2, signal, then hang until SIGKILL.

    ``wedge`` picks the crash point: ``"rename"`` wedges at the commit
    (stage complete, manifest written, rename never happens); ``"treedef"``
    wedges mid-stage, before the manifest — which is written last — even
    starts (stage partial, no manifest file at all).
    """
    import repro.checkpoint.checkpoint as ck

    def hang(*a, **k):
        staged.set()
        time.sleep(600)

    if wedge == "rename":
        ck.os.rename = hang
    else:
        ck.pickle.dump = hang
    mgr = CheckpointManager(directory, keep=3)
    if api == "save":
        mgr.save(2, _tree(2), metadata={"ingest_cursor": 2})
    else:
        mgr.save_async(2, _tree(2), metadata={"ingest_cursor": 2})
        mgr.wait()


def _kill_mid_save(directory: str, wedge: str, api: str) -> None:
    staged = _ctx.Event()
    child = _ctx.Process(
        target=_wedged_writer, args=(directory, staged, wedge, api)
    )
    child.start()
    try:
        assert staged.wait(timeout=30.0), "writer never reached the wedge"
        os.kill(child.pid, signal.SIGKILL)
    finally:
        child.join(timeout=30.0)
    assert child.exitcode == -signal.SIGKILL


@pytest.mark.parametrize("api", ["save", "save_async"])
def test_kill_before_commit_restores_previous_checkpoint(tmp_path, api):
    """SIGKILL between a complete stage and the rename commit: the stage —
    manifest and all — is garbage, and ``restore()`` returns the previous
    committed checkpoint byte-for-byte."""
    directory = str(tmp_path / "ck")
    mgr = CheckpointManager(directory, keep=3)
    mgr.save(1, _tree(1), metadata={"ingest_cursor": 1})

    _kill_mid_save(directory, "rename", api)

    # The crash window is real: a fully-written stage (manifest included)
    # is sitting on disk, uncommitted.
    stages = [n for n in os.listdir(directory) if n.endswith(".tmp")]
    assert len(stages) == 1
    assert os.path.exists(os.path.join(directory, stages[0], MANIFEST))

    # A stage is never a checkpoint, even before anyone prunes it.
    assert mgr.steps() == [1]

    # A fresh manager (the respawned coordinator) prunes the orphan stage
    # and restores the previous complete checkpoint.
    healed = CheckpointManager(directory, keep=3)
    assert not [n for n in os.listdir(directory) if n.endswith(".tmp")]
    assert healed.steps() == [1]
    tree, meta = healed.restore()
    assert meta["step"] == 1
    assert meta["ingest_cursor"] == 1
    np.testing.assert_array_equal(tree["w"], _tree(1)["w"])
    np.testing.assert_array_equal(tree["bias"], _tree(1)["bias"])


def test_kill_mid_stage_leaves_no_manifest_and_restores_previous(tmp_path):
    """SIGKILL while the stage is still being written (before the manifest,
    which goes last): the partial stage has no manifest, is invisible to
    ``steps()``, and is pruned on the next manager construction."""
    directory = str(tmp_path / "ck")
    mgr = CheckpointManager(directory, keep=3)
    mgr.save(1, _tree(1), metadata={"ingest_cursor": 1})

    _kill_mid_save(directory, "treedef", "save")

    stages = [n for n in os.listdir(directory) if n.endswith(".tmp")]
    assert len(stages) == 1
    assert not os.path.exists(os.path.join(directory, stages[0], MANIFEST))

    healed = CheckpointManager(directory, keep=3)
    assert not [n for n in os.listdir(directory) if n.endswith(".tmp")]
    assert healed.steps() == [1]
    tree, meta = healed.restore()
    assert meta["step"] == 1
    np.testing.assert_array_equal(tree["w"], _tree(1)["w"])


def test_kill_with_no_prior_checkpoint_restores_nothing(tmp_path):
    """First-ever checkpoint killed mid-commit: the directory holds only
    garbage, ``latest_step()`` is None, and ``restore()`` raises — the
    engine's recovery path treats this as a rewind to T0."""
    directory = str(tmp_path / "ck")
    CheckpointManager(directory, keep=3)

    _kill_mid_save(directory, "rename", "save")

    healed = CheckpointManager(directory, keep=3)
    assert healed.steps() == []
    assert healed.latest_step() is None
    with pytest.raises(FileNotFoundError):
        healed.restore()

"""Fuzzing mode for the conformance harness: randomized topologies.

Hypothesis draws random fan-out DAGs — value family, key dtype,
schema/no-schema mix per operator, partitioning flavor, random mid-run
migrations — and every drawn topology must be bit-identical across the full
execution-configuration matrix (soa+seg+schema / soa+seg / soa+fn /
deque+fn), exactly like the hand-written jobs.  This generalizes the fixed
JOBS registry the same way tests/test_migration_properties.py generalizes
the hand-written migration round-trips.
"""

import pytest

pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from conformance import (
    FUZZ_CONFIGS,
    FUZZ_KINDS,
    Scenario,
    assert_equivalent,
    fuzz_feeders,
    make_fuzz_topology,
    run_configs,
)


@st.composite
def fuzz_specs(draw):
    family = draw(st.sampled_from(["scalar", "record"]))
    keys = ["id", "mod", "byval"] if family == "record" else ["id", "mod"]
    n_ops = draw(st.integers(1, 4))
    ops = [
        {
            "kind": draw(st.sampled_from(FUZZ_KINDS[family])),
            "kgs": draw(st.sampled_from([4, 8, 12])),
            "schema": draw(st.booleans()),
            "out_schema": draw(st.booleans()),
            "key": draw(st.sampled_from(keys)),
        }
        for _ in range(n_ops)
    ]
    edges = [
        draw(
            st.lists(
                st.integers(-1, i - 1),
                min_size=1,
                max_size=min(i + 1, 3),
                unique=True,
            )
        )
        for i in range(n_ops)
    ]
    return {
        "family": family,
        "key_dtype": draw(st.sampled_from(["i8", "i4"])),
        "source_schema": draw(st.booleans()),
        "ops": ops,
        "edges": edges,
        "migrate_at": tuple(draw(st.lists(st.integers(2, 8), max_size=2, unique=True))),
    }


@settings(max_examples=15, deadline=None)
@given(spec=fuzz_specs())
def test_fuzzed_topologies_conform(spec):
    scenario = Scenario(
        "fuzz", ticks=10, drain_ticks=6, migrate_at=spec["migrate_at"]
    )
    results = run_configs(
        lambda: make_fuzz_topology(spec),
        fuzz_feeders(spec),
        scenario,
        configs=FUZZ_CONFIGS,
    )
    assert_equivalent(results)
    assert results["soa+seg+schema"]["metrics"]["processed_tuples"] > 0
    # Declared edges really carried typed batches (when any were declared).
    declared = spec["source_schema"] or any(o["schema"] for o in spec["ops"])
    if declared:
        assert results["soa+seg+schema"]["typed_batches"] > 0
    assert results["deque+fn"]["typed_batches"] == 0


@settings(max_examples=10, deadline=None)
@given(spec=fuzz_specs())
def test_fuzzed_topologies_conform_under_backpressure(spec):
    scenario = Scenario(
        "fuzz-pressure",
        ticks=12,
        drain_ticks=8,
        service_rate=220.0,
        migrate_at=spec["migrate_at"],
    )
    results = run_configs(
        lambda: make_fuzz_topology(spec),
        fuzz_feeders(spec),
        scenario,
        configs=FUZZ_CONFIGS,
    )
    assert_equivalent(results)

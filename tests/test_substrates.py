"""Checkpointing, optimizer, data pipeline, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data.pipeline import PipelineConfig, Prefetcher, TokenPipeline
from repro.optim import AdamW, compress_int8, cosine_schedule, decompress_int8


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_with_bf16(tmp_path):
    tree = {
        "w": jnp.ones((4, 4), jnp.bfloat16) * 1.5,
        "m": {"v": np.arange(6, dtype=np.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }
    p = str(tmp_path / "ckpt")
    save_pytree(p, tree, metadata={"note": "x"})
    loaded, meta = load_pytree(p)
    assert meta["note"] == "x"
    np.testing.assert_array_equal(
        np.asarray(loaded["w"], np.float32), np.asarray(tree["w"], np.float32)
    )
    assert str(loaded["w"].dtype) == "bfloat16"
    np.testing.assert_array_equal(loaded["m"]["v"], tree["m"]["v"])


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (10, 20, 30):
        mgr.save(step, {"x": np.full(3, step)})
    assert mgr.steps() == [20, 30]
    tree, meta = mgr.restore()
    assert meta["step"] == 30
    np.testing.assert_array_equal(tree["x"], np.full(3, 30))


def test_manager_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(1, {"x": np.ones(4)})
    mgr.wait()
    assert mgr.latest_step() == 1


def test_atomicity_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, {"x": np.ones(2)})
    for name in os.listdir(tmp_path):
        assert not name.endswith(".tmp")


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        updates, state = opt.update(grads, state, params)
        return jax.tree.map(lambda p, u: p + u, params, updates), state

    for _ in range(150):
        params, state = step(params, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_bounds_update():
    opt = AdamW(learning_rate=1.0, grad_clip=1e-3)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    updates, _ = opt.update({"w": jnp.full(3, 1e6)}, state, params)
    assert np.isfinite(np.asarray(updates["w"])).all()


def test_cosine_schedule_shape():
    fn = cosine_schedule(1.0, 10, 100)
    assert float(fn(jnp.asarray(0))) < 0.2
    assert abs(float(fn(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(fn(jnp.asarray(100))) < 0.2


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_pipeline_deterministic_restart():
    cfg = PipelineConfig(vocab_size=1000, seq_len=16, global_batch=8, num_shards=4)
    a = TokenPipeline(cfg)
    b1 = a.next_batch()
    b2 = a.next_batch()
    cursor = a.cursor()
    b3 = a.next_batch()
    b = TokenPipeline(cfg)
    b.restore(cursor)
    b3r = b.next_batch()
    np.testing.assert_array_equal(b3["tokens"], b3r["tokens"])
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_pipeline_labels_shift():
    cfg = PipelineConfig(vocab_size=100, seq_len=8, global_batch=4, num_shards=2)
    batch = TokenPipeline(cfg).next_batch()
    assert batch["tokens"].shape == (4, 8)
    assert batch["labels"].shape == (4, 8)
    assert (batch["tokens"] < 100).all()


def test_prefetcher_passthrough():
    cfg = PipelineConfig(vocab_size=100, seq_len=8, global_batch=4, num_shards=2)
    pipe = TokenPipeline(cfg)
    ref = TokenPipeline(cfg)
    pf = Prefetcher(iter(pipe), depth=2)
    for _ in range(3):
        got = next(pf)
        np.testing.assert_array_equal(got["tokens"], ref.next_batch()["tokens"])
    pf.close()


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(1e-3, 1e3))
def test_property_int8_compression_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, 64).astype(np.float32))
    q, s = compress_int8(x)
    back = decompress_int8(q, s)
    # Error bounded by one quantization step.
    assert float(jnp.abs(back - x).max()) <= float(s) + 1e-9
    assert q.dtype == jnp.int8

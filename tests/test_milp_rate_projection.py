"""The kg_tuple_rate growth projection feeds the MILP balance objective.

PR 4 fed the leading-load signal into ALBIC's step-3 *target scoring*; this
pins the next step (ROADMAP): ``solve_allocation(prev_rate=...)`` scales the
gLoad vector itself by the clipped rate-growth ratios, so a surging key
group changes the optimal allocation one period *before* its measured load
does.
"""

import numpy as np

from repro.core.milp import solve_allocation
from repro.core.scaling import rate_growth
from repro.core.stats import ClusterState


def _state(kg_load, rates):
    # Two nodes, four singleton key groups, two on each node.
    return ClusterState.create(
        2,
        np.zeros(4, dtype=np.int64),
        np.asarray(kg_load, dtype=np.float64),
        np.array([0, 0, 1, 1]),
        kg_state_bytes=np.full(4, 8.0),
        kg_tuple_rate=np.asarray(rates, dtype=np.float64),
    )


# This period: loads are perfectly balanced (20 per node), but key group 0's
# arrival rate tripled (5 → 15 tuples/tick).  Next period the surge
# materializes as load (gLoad tracks arrivals on uniform-cost operators).
BALANCED = _state([10.0, 10.0, 10.0, 10.0], [15.0, 5.0, 5.0, 5.0])
PREV_RATE = np.array([5.0, 5.0, 5.0, 5.0])
NEXT_PERIOD = _state([30.0, 10.0, 10.0, 10.0], [15.0, 5.0, 5.0, 5.0])


def test_growth_ratios_clip_and_gate():
    g = rate_growth(BALANCED, PREV_RATE)
    assert g is not None
    assert g.tolist() == [3.0, 1.0, 1.0, 1.0]
    assert rate_growth(BALANCED, None) is None
    # Quiet key groups (below min_rate) never project: their ratios are noise.
    quiet = rate_growth(BALANCED, np.array([0.0, 5.0, 5.0, 5.0]))
    assert quiet.tolist() == [1.0, 1.0, 1.0, 1.0]


def test_surge_changes_allocation_one_period_early():
    # Without the projection the measured loads are already balanced: the
    # solver keeps every key group where it is.
    plain = solve_allocation(BALANCED, time_limit=5.0)
    assert plain.alloc.tolist() == [0, 0, 1, 1]
    assert plain.num_migrations == 0

    # With the projection, key group 0 weighs 30: node 0 is about to carry
    # 40 vs node 1's 20, so the optimizer de-loads node 0 now.
    early = solve_allocation(BALANCED, prev_rate=PREV_RATE, time_limit=5.0)
    assert early.num_migrations > 0
    moved_off_0 = {k for k, src, _ in early.migrations if src == 0}
    assert moved_off_0, "projection should move load off the surging node"
    # The surging key group's node ends up with strictly less company.
    proj_load = np.array([30.0, 10.0, 10.0, 10.0])
    node0 = float(proj_load[early.alloc == 0].sum())
    node1 = float(proj_load[early.alloc == 1].sum())
    assert abs(node0 - node1) < 40.0 - 20.0  # strictly better than no move

    # "One period early": the plain solver reaches the same rebalancing only
    # on the next snapshot, where the surge shows up in the measured loads.
    late = solve_allocation(NEXT_PERIOD, time_limit=5.0)
    assert late.num_migrations > 0
    late_node0 = float(
        np.asarray(NEXT_PERIOD.kg_load)[late.alloc == 0].sum()
    )
    late_node1 = float(
        np.asarray(NEXT_PERIOD.kg_load)[late.alloc == 1].sum()
    )
    assert abs(late_node0 - late_node1) < 40.0 - 20.0


def test_projection_none_is_identical():
    """prev_rate=None (or missing kg_tuple_rate) is bit-identical to the
    unprojected program — the signal is strictly opt-in."""
    a = solve_allocation(BALANCED, time_limit=5.0)
    state_no_rate = ClusterState.create(
        2,
        np.zeros(4, dtype=np.int64),
        BALANCED.kg_load,
        BALANCED.alloc,
        kg_state_bytes=BALANCED.kg_state_bytes,
    )
    b = solve_allocation(state_no_rate, prev_rate=PREV_RATE, time_limit=5.0)
    assert a.alloc.tolist() == b.alloc.tolist()
    assert a.d == b.d

"""Worker fault injection: kill a live worker process, recover, converge.

Three layers of assurance:

* **Exactness** — a worker killed between ticks, with its key groups
  reinstalled from checkpoint envelopes, converges to the single-process
  oracle driven through the *same* crash (``fail_node`` over the worker's
  node block, same envelopes): identical sink outputs and states, because
  the replicas are bit-exact and both sides lose exactly the dead queues.
* **Liveness** — a worker killed *mid-tick* (the coordinator finds out
  while waiting on its report) must not wedge the pool: the in-flight tick
  completes via the coordinator's death-injection path and the survivors
  keep serving.
* **Interleavings** — hypothesis drives random migrate/kill/push/tick
  schedules through cluster and oracle together (skipped cleanly when
  hypothesis isn't installed).
"""

import numpy as np

from conformance import make_pipeline_topo
from repro.engine import Engine, ExecutionConfig, make_engine

KGS = 8


def _pair(num_nodes=4, service_rate=1e9, seed=0):
    """A 2-worker cluster and the single-process oracle, identically built."""
    cluster = make_engine(
        make_pipeline_topo(KGS),
        num_nodes,
        config=ExecutionConfig.workers(2),
        service_rate=service_rate,
        seed=seed,
    )
    oracle = Engine(
        make_pipeline_topo(KGS),
        num_nodes,
        config=ExecutionConfig.typed(),
        service_rate=service_rate,
        seed=seed,
    )
    return cluster, oracle


def _push_both(engines, n, seed, key_space=5_000):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_space, size=n).astype(np.int64)
    values, ts = rng.random(n), np.zeros(n)
    return [e.push_source("src", keys, values, ts) for e in engines]


def _drain_both(cluster, oracle, max_ticks=200):
    for _ in range(max_ticks):
        busy = cluster.worst_queue_cost() > 0.0
        busy |= any(q.cost for q in oracle._queues)
        if not busy:
            return
        cluster.tick()
        oracle.tick()
    raise AssertionError("failed to quiesce")


def test_kill_between_ticks_recovers_to_oracle():
    cluster, oracle = _pair(service_rate=400.0)
    try:
        for t in range(6):
            _push_both((cluster, oracle), 300, seed=10 + t)
            cluster.tick()
            oracle.tick()

        # Checkpoint every key group living on worker 1 — from *both*
        # engines, proving the envelopes are byte-identical, then keep
        # serving traffic so the checkpoints go stale before the crash.
        doomed_nodes = np.flatnonzero(cluster.node_worker == 1)
        doomed_kgs = np.flatnonzero(
            np.isin(cluster.router.table, doomed_nodes)
        )
        checkpoints = {}
        for kg in doomed_kgs.tolist():
            env_c = cluster.export_keygroup(kg)
            env_o = oracle.export_keygroup(kg)
            assert env_c.blob == env_o.blob and env_c.version == 1
            checkpoints[kg] = env_c
        for t in range(2):
            _push_both((cluster, oracle), 300, seed=20 + t)
            cluster.tick()
            oracle.tick()

        # Crash: the cluster loses a real OS process; the oracle loses the
        # same node block.  Both drop the same queued runs (bit-exact
        # replicas), so they stay comparable.
        orphans = cluster.fail_worker(1)
        assert np.array_equal(orphans, doomed_kgs)
        for node in doomed_nodes.tolist():
            oracle.fail_node(node)
        assert np.array_equal(cluster.alive, oracle.alive)

        # Recover from the (stale) checkpoints onto worker 0's first node.
        dst = int(np.flatnonzero(cluster.node_worker == 0)[0])
        for kg, env in checkpoints.items():
            cluster.import_keygroup(env, dst)
            oracle.router.table[kg] = dst
            oracle.router.version += 1
            oracle.import_keygroup(env, dst)
        assert np.array_equal(cluster.router.table, oracle.router.table)

        for t in range(3):
            _push_both((cluster, oracle), 300, seed=30 + t)
            cluster.tick()
            oracle.tick()
        _drain_both(cluster, oracle)
        cluster.finalize()
    finally:
        cluster.close()

    assert cluster.metrics.sink_outputs == oracle.metrics.sink_outputs
    c_states = {kg: s for kg, s in cluster.store.items() if s}
    o_states = {kg: s for kg, s in oracle.store.items() if s}
    assert c_states == o_states


def test_kill_mid_tick_does_not_wedge_the_pool():
    cluster = make_engine(
        make_pipeline_topo(KGS),
        4,
        config=ExecutionConfig.workers(2),
        service_rate=1e9,
        seed=0,
    )
    try:
        for t in range(3):
            rng = np.random.default_rng(50 + t)
            keys = rng.integers(0, 5_000, size=400).astype(np.int64)
            cluster.push_source("src", keys, rng.random(400), np.zeros(400))
            cluster.tick()
        sinks_before = len(cluster.metrics.sink_outputs)

        # Kill the raw process with no coordinator bookkeeping: the tick
        # below must detect the death, inject the missing exchange, and
        # complete on the survivor alone.
        cluster.pool.kill(1)
        rng = np.random.default_rng(99)
        keys = rng.integers(0, 5_000, size=400).astype(np.int64)
        cluster.push_source("src", keys, rng.random(400), np.zeros(400))
        cluster.tick()
        assert 1 in cluster._dead_workers
        assert not cluster.alive[cluster.node_worker == 1].any()

        # Survivors keep serving: traffic to surviving key groups flows end
        # to end and the pool still quiesces.
        for _ in range(20):
            if cluster.worst_queue_cost() == 0.0:
                break
            cluster.tick()
        assert cluster.worst_queue_cost() == 0.0
        assert len(cluster.metrics.sink_outputs) > sinks_before
        cluster.finalize()
    finally:
        cluster.close()


def test_fail_worker_reports_orphans_and_rejects_dead_installs():
    cluster, _ = _pair()
    try:
        _push_both((cluster,), 200, seed=1)
        cluster.tick()
        base = cluster.topology.kg_base(1)
        # A checkpoint taken before the crash, for a key group on worker 0.
        kg0 = next(
            k for k in range(base, base + KGS)
            if cluster.worker_of_node(cluster.router.node_of(k)) == 0
        )
        env = cluster.export_keygroup(kg0)

        orphans = cluster.fail_worker(1)
        dead_nodes = np.flatnonzero(cluster.node_worker == 1)
        assert set(orphans.tolist()) == set(
            np.flatnonzero(np.isin(cluster.router.table, dead_nodes)).tolist()
        )

        # Installing onto a dead worker's node is an error, not a silent drop.
        dead_dst = int(dead_nodes[0])
        try:
            cluster.import_keygroup(env, dead_dst)
        except RuntimeError as e:
            assert "dead" in str(e)
        else:  # pragma: no cover
            raise AssertionError("install to a dead worker must raise")
    finally:
        cluster.close()


def test_random_migrate_kill_interleavings_match_oracle():
    import pytest

    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def schedules(draw):
        steps = draw(st.integers(4, 8))
        ops = []
        for _ in range(steps):
            ops.append(
                draw(
                    st.one_of(
                        st.tuples(st.just("push"), st.integers(0, 10_000)),
                        st.just(("tick",)),
                        st.tuples(
                            st.just("migrate"),
                            st.integers(0, KGS - 1),
                            st.integers(0, 3),
                        ),
                    )
                )
            )
        kill_at = draw(st.one_of(st.none(), st.integers(0, steps - 1)))
        return ops, kill_at

    @settings(max_examples=5, deadline=None)
    @given(sched=schedules())
    def run(sched):
        ops, kill_at = sched
        cluster, oracle = _pair()
        try:
            killed = False
            for i, op in enumerate(ops):
                if kill_at == i and not killed:
                    # Crash worker 1 and immediately re-home its key groups
                    # from checkpoints, mirrored on the oracle (cross-tick
                    # in-flight migrations over a crash are covered by the
                    # between-ticks test; here migrations are immediate so
                    # none are in flight at kill time).
                    killed = True
                    doomed = np.flatnonzero(cluster.node_worker == 1)
                    kgs = np.flatnonzero(
                        np.isin(cluster.router.table, doomed)
                    )
                    envs = {
                        kg: cluster.export_keygroup(kg)
                        for kg in kgs.tolist()
                    }
                    cluster.fail_worker(1)
                    for node in doomed.tolist():
                        oracle.fail_node(node)
                    dst = int(np.flatnonzero(cluster.node_worker == 0)[0])
                    for kg, env in envs.items():
                        cluster.import_keygroup(env, dst)
                        oracle.router.table[kg] = dst
                        oracle.router.version += 1
                        oracle.import_keygroup(env, dst)
                if op[0] == "push":
                    _push_both((cluster, oracle), 120, seed=op[1])
                elif op[0] == "tick":
                    cluster.tick()
                    oracle.tick()
                elif op[0] == "migrate":
                    base = cluster.topology.kg_base(1)
                    kg, dst = base + op[1], op[2]
                    if (
                        not cluster.router.is_in_flight(kg)
                        and cluster.alive[cluster.router.node_of(kg)]
                        and cluster.alive[dst]
                    ):
                        cluster.redirect(kg, dst)
                        oracle.redirect(kg, dst)
                        blob_c = cluster.serialize(kg)
                        blob_o = oracle.serialize(kg)
                        assert blob_c == blob_o
                        cluster.install(kg, dst, blob_c)
                        oracle.install(kg, dst, blob_o)
            _drain_both(cluster, oracle)
            cluster.finalize()
        finally:
            cluster.close()
        assert cluster.metrics.sink_outputs == oracle.metrics.sink_outputs
        c_states = {kg: s for kg, s in cluster.store.items() if s}
        o_states = {kg: s for kg, s in oracle.store.items() if s}
        assert c_states == o_states

    run()


def test_sigkill_leaves_no_shm_segments(tmp_path):
    """The coordinator owns every exchange-lane segment: kill a worker with
    SIGKILL mid-service and close the pool — /dev/shm must hold no
    ``repro_xchg`` entry afterwards (nothing for the dead worker to leak)."""
    import os

    import pytest

    from repro.engine.shmx import SEGMENT_PREFIX

    if not os.path.isdir("/dev/shm"):  # pragma: no cover - non-POSIX-shm host
        pytest.skip("no /dev/shm to scan")

    def segments():
        return [f for f in os.listdir("/dev/shm") if f.startswith(SEGMENT_PREFIX)]

    cluster = make_engine(
        make_pipeline_topo(KGS),
        4,
        config=ExecutionConfig.workers(2),
        service_rate=1e9,
        seed=0,
    )
    try:
        _push_both((cluster,), 300, seed=5)
        cluster.tick()
        assert len(segments()) >= 2  # both directions allocated and live

        cluster.pool.kill(1)  # raw SIGKILL, no coordinator bookkeeping
        _push_both((cluster,), 300, seed=6)
        cluster.tick()  # death detected; coordinator unlinks the dead lanes
        cluster.finalize()
    finally:
        cluster.close()
    assert segments() == []


def test_random_mixed_transport_interleavings_match_oracle():
    """Hypothesis over ring capacities and push/tick/migrate schedules: with
    rings sized to overflow intermittently, one sender's ticks alternate
    between the shm lane and the queue fallback — every schedule must stay
    bit-exact against the single-process oracle."""
    import pytest

    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def schedules(draw):
        shm = draw(st.sampled_from([0, 128, 2048, 1 << 16]))
        steps = draw(st.integers(4, 8))
        ops = [
            draw(
                st.one_of(
                    st.tuples(st.just("push"), st.integers(0, 10_000)),
                    st.just(("tick",)),
                    st.tuples(
                        st.just("migrate"),
                        st.integers(0, KGS - 1),
                        st.integers(0, 3),
                    ),
                )
            )
            for _ in range(steps)
        ]
        return shm, ops

    @settings(max_examples=6, deadline=None)
    @given(sched=schedules())
    def run(sched):
        shm, ops = sched
        cluster = make_engine(
            make_pipeline_topo(KGS),
            4,
            config=ExecutionConfig.workers(2, shm=shm),
            service_rate=1e9,
            seed=0,
        )
        oracle = Engine(
            make_pipeline_topo(KGS),
            4,
            config=ExecutionConfig.typed(),
            service_rate=1e9,
            seed=0,
        )
        try:
            for op in ops:
                if op[0] == "push":
                    _push_both((cluster, oracle), 150, seed=op[1])
                elif op[0] == "tick":
                    cluster.tick()
                    oracle.tick()
                else:
                    base = cluster.topology.kg_base(1)
                    kg, dst = base + op[1], op[2]
                    if not cluster.router.is_in_flight(kg):
                        cluster.redirect(kg, dst)
                        oracle.redirect(kg, dst)
                        blob = cluster.serialize(kg)
                        assert blob == oracle.serialize(kg)
                        cluster.install(kg, dst, blob)
                        oracle.install(kg, dst, blob)
            _drain_both(cluster, oracle)
            cluster.finalize()
        finally:
            cluster.close()
        assert cluster.metrics.sink_outputs == oracle.metrics.sink_outputs
        c_states = {kg: s for kg, s in cluster.store.items() if s}
        o_states = {kg: s for kg, s in oracle.store.items() if s}
        assert c_states == o_states

    run()

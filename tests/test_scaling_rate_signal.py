"""kg_tuple_rate as a leading-load signal for the scaling policies.

The scalers remember the previous period's per-key-group arrival rates and
project each key group's load forward by its rate growth.  A hotspot key
group whose arrivals surge therefore triggers scale-out one period earlier
than the utilization-only watermark, which only reacts once the CPU load has
materialized.
"""

import numpy as np

from repro.core.milp import AllocationPlan
from repro.core.scaling import (
    LatencyProxyScaler,
    UtilizationScaler,
    projected_loads,
)
from repro.core.stats import ClusterState


def _state(kg_load, rate, *, num_nodes=2, alloc=None):
    g = len(kg_load)
    alloc = np.asarray(alloc if alloc is not None else np.arange(g) % num_nodes)
    return ClusterState.create(
        num_nodes,
        np.zeros(g, dtype=np.int64),
        np.asarray(kg_load, dtype=np.float64),
        alloc,
        kg_tuple_rate=None if rate is None else np.asarray(rate, dtype=np.float64),
    )


def _plan(state):
    return AllocationPlan(
        alloc=state.alloc.copy(),
        d=0.0,
        d_u=0.0,
        d_l=0.0,
        objective=0.0,
        status="ok",
        solve_seconds=0.0,
        load_distance=0.0,
        migrations=[],
        migration_cost=0.0,
    )


# Three periods of one hotspot story: load on key group 0 is about to triple.
# Period 1: calm.  Period 2: arrivals surge into kg 0, CPU load unchanged
# (it lags one period).  Period 3: the surged load has materialized.
P1 = ([35.0, 35.0, 35.0, 35.0], [10.0, 10.0, 10.0, 10.0])
P2 = ([35.0, 35.0, 35.0, 35.0], [30.0, 10.0, 10.0, 10.0])
P3 = ([105.0, 35.0, 35.0, 35.0], [30.0, 10.0, 10.0, 10.0])
ALLOC = [0, 0, 1, 1]


def _drive(scaler):
    """Feed the three periods; return the period index of first scale-out."""
    for i, (load, rate) in enumerate((P1, P2, P3), start=1):
        st = _state(load, rate, alloc=ALLOC)
        decision = scaler.decide(st, _plan(st))
        if decision.add_nodes > 0:
            return i
    return None


def test_hotspot_triggers_utilization_scaleout_one_period_early():
    # Node loads are [70, 70]: below high_wm=80 until the surge materializes
    # at period 3.  The rate signal projects kg 0's load ×3 at period 2.
    assert _drive(UtilizationScaler(high_wm=80.0, target=60.0)) == 2
    plain = UtilizationScaler(high_wm=80.0, target=60.0, use_rate_signal=False)
    assert _drive(plain) == 3


def test_hotspot_triggers_latency_scaleout_one_period_early():
    # rho_cap = 100·4/5 = 80: peak load 70 holds until period 3; the
    # projected peak (140 on node 0) breaches at period 2.
    assert _drive(LatencyProxyScaler(latency_budget=4.0)) == 2
    assert _drive(LatencyProxyScaler(latency_budget=4.0, use_rate_signal=False)) == 3


def test_rate_surge_vetoes_scale_in():
    scaler = UtilizationScaler(high_wm=80.0, low_wm=40.0, target=60.0)
    # Period 1 sits between the watermarks: no action, rates get remembered.
    calm = _state([25.0, 25.0, 25.0, 25.0], [10.0] * 4, alloc=ALLOC)
    assert not scaler.decide(calm, _plan(calm)).scaled
    surge = _state([15.0, 15.0, 15.0, 15.0], [18.0, 18.0, 18.0, 18.0], alloc=ALLOC)
    # Loads dropped far below low_wm, but arrivals are growing 1.8×: the
    # projected average (54) clears low_wm, so the removal is vetoed while
    # staying under high_wm (no spurious scale-out either).
    assert not scaler.decide(surge, _plan(surge)).scaled
    # Without the veto the same snapshot scales in.
    plain = UtilizationScaler(
        high_wm=80.0,
        low_wm=40.0,
        target=60.0,
        use_rate_signal=False,
    )
    plain.decide(calm, _plan(calm))
    assert plain.decide(surge, _plan(surge)).mark_for_removal


def test_projection_requires_rates_on_both_periods():
    st = _state([50.0, 50.0], None)
    assert projected_loads(st, st.alloc, np.array([1.0, 1.0])) is None
    st2 = _state([50.0, 50.0], [5.0, 5.0])
    assert projected_loads(st2, st2.alloc, None) is None
    # Mismatched key-group spaces (e.g. across a topology change) disable it.
    assert projected_loads(st2, st2.alloc, np.array([1.0])) is None


def test_projection_clips_growth_and_ignores_noise():
    prev = np.array([10.0, 0.1, 10.0, 10.0])
    st = _state(
        [10.0, 10.0, 10.0, 10.0],
        [100.0, 10.0, 5.0, 10.0],
        num_nodes=4,
        alloc=[0, 1, 2, 3],
    )
    proj = projected_loads(st, st.alloc, prev)
    assert proj is not None
    # kg0: 10× growth clipped to 4×; kg1: prev rate below the noise floor,
    # unscaled; kg2: shrinking rate never *lowers* the projection; kg3: flat.
    assert proj.tolist() == [40.0, 10.0, 10.0, 10.0]


def test_first_period_without_history_matches_plain_policy():
    """No stored rates yet → the rate-aware scaler is exactly the plain one."""
    st = _state([95.0, 95.0, 95.0, 95.0], [10.0] * 4, alloc=ALLOC)
    aware = UtilizationScaler(high_wm=80.0, target=60.0)
    plain = UtilizationScaler(high_wm=80.0, target=60.0, use_rate_signal=False)
    assert aware.decide(st, _plan(st)) == plain.decide(st, _plan(st))

"""Scenario generator contract: byte-identical streams per seed (the
determinism pin the benchmark grid and the docs promise), component shapes
(flash crowd, diurnal drift, churn), and engine pluggability."""

import dataclasses

import numpy as np
import pytest

from repro.engine import Engine
from repro.engine.topology import OperatorSpec, Topology
from repro.workloads import (
    GRID_SCENARIOS,
    Churn,
    Diurnal,
    FlashCrowd,
    ScenarioSpec,
    make_scenario,
    scenario_batches,
    scenario_schema,
    scenario_stream,
)
from repro.workloads.scenarios import SCENARIO_DTYPE


def _concat(spec, ticks=24):
    """The stream's first ``ticks`` batches, flattened to comparable arrays."""
    ks, vs, ts = [], [], []
    for k, v, t in scenario_batches(spec, ticks):
        ks.append(k)
        vs.append(v)
        ts.append(t)
    return np.concatenate(ks), np.concatenate(vs), np.concatenate(ts)


# ------------------------------------------------------------- determinism
def test_equal_specs_yield_byte_identical_streams():
    spec = make_scenario("flash_crowd", rate=64.0, key_space=128, seed=9)
    a = _concat(spec)
    b = _concat(make_scenario("flash_crowd", rate=64.0, key_space=128, seed=9))
    assert a[0].tobytes() == b[0].tobytes()
    assert a[1].tobytes() == b[1].tobytes()
    assert a[2].tobytes() == b[2].tobytes()


def test_different_seeds_differ():
    base = dict(rate=64.0, key_space=128)
    a = _concat(make_scenario("zipf", seed=1, **base))
    b = _concat(make_scenario("zipf", seed=2, **base))
    assert a[0].tobytes() != b[0].tobytes()


def test_stream_is_restartable_not_stateful():
    """Two independent iterators over the same spec agree tick by tick —
    generation must not lean on hidden global state."""
    spec = ScenarioSpec(rate=32.0, key_space=64, seed=3, churn=Churn(8))
    s1, s2 = scenario_stream(spec), scenario_stream(spec)
    for _ in range(12):
        (k1, v1, t1), (k2, v2, t2) = next(s1), next(s2)
        np.testing.assert_array_equal(k1, k2)
        np.testing.assert_array_equal(v1, v2)
        np.testing.assert_array_equal(t1, t2)


def test_hypothesis_property_seed_determinism():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=20, deadline=None)
    @hypothesis.given(
        seed=st.integers(0, 2**31 - 1),
        rate=st.floats(0.0, 64.0, allow_nan=False),
        key_space=st.integers(1, 64),
        zipf_a=st.floats(0.0, 2.5, allow_nan=False),
        scenario=st.sampled_from(GRID_SCENARIOS),
    )
    def prop(seed, rate, key_space, zipf_a, scenario):
        spec = dataclasses.replace(
            make_scenario(scenario, rate=rate, key_space=key_space, seed=seed),
            zipf_a=zipf_a,
        )
        a = _concat(spec, ticks=6)
        b = _concat(spec, ticks=6)
        assert a[0].tobytes() == b[0].tobytes()
        assert a[1].tobytes() == b[1].tobytes()
        assert a[2].tobytes() == b[2].tobytes()

    prop()


# ------------------------------------------------------------ batch contract
def test_batch_shapes_and_dtypes():
    keys, values, ts = _concat(ScenarioSpec(rate=64.0, key_space=32, seed=0))
    assert keys.dtype == np.int64
    assert values.dtype == SCENARIO_DTYPE
    assert ts.dtype == np.float64
    assert np.array_equal(values["entity"], keys)
    assert (keys >= 0).all() and (keys < 32).all()
    schema = scenario_schema()
    assert schema.value == SCENARIO_DTYPE


def test_spec_validation():
    with pytest.raises(ValueError, match="key_space"):
        ScenarioSpec(key_space=0)
    with pytest.raises(ValueError, match="rate"):
        ScenarioSpec(rate=-1.0)
    with pytest.raises(ValueError, match="zipf_a"):
        ScenarioSpec(zipf_a=-0.1)
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("tsunami")


# --------------------------------------------------------------- components
def test_flash_crowd_factor_step_ramp_duration():
    step = FlashCrowd(at_tick=10, ramp_ticks=0, duration=5)
    assert step.factor(9) == 0.0
    assert step.factor(10) == 1.0
    assert step.factor(14) == 1.0
    assert step.factor(15) == 0.0
    ramp = FlashCrowd(at_tick=0, ramp_ticks=4)
    assert [ramp.factor(t) for t in (0, 1, 2, 3, 4)] == [0.0, 0.25, 0.5, 0.75, 1.0]
    assert ramp.factor(1000) == 1.0  # duration=None → holds forever


def test_flash_crowd_raises_traffic_and_concentrates_it():
    base = dict(rate=256.0, key_space=64, zipf_a=0.5, seed=4)
    calm = ScenarioSpec(**base)
    surge = ScenarioSpec(flash=FlashCrowd(at_tick=0, hot_keys=1, boost=32.0), **base)
    n_calm = sum(len(k) for k, _, _ in scenario_batches(calm, 16))
    surge_keys = np.concatenate([k for k, _, _ in scenario_batches(surge, 16)])
    assert len(surge_keys) > 1.5 * n_calm  # a crowd adds traffic
    top_share = np.bincount(surge_keys).max() / len(surge_keys)
    assert top_share > 0.4  # and concentrates it on the boosted key


def test_diurnal_multipliers_rotate_across_cohorts():
    d = Diurnal(period_ticks=40.0, amplitude=0.6, cohorts=4)
    m0 = d.multipliers(0)
    assert m0.shape == (4,)
    assert (m0 >= 0.0).all()
    # half a period later the wave inverts: a different cohort leads
    m_half = d.multipliers(20)
    assert int(np.argmax(m0)) != int(np.argmax(m_half))
    np.testing.assert_allclose(d.multipliers(40), m0, atol=1e-12)


def test_churn_turns_over_the_alive_set():
    spec = ScenarioSpec(
        rate=128.0, key_space=64, zipf_a=0.0, churn=Churn(lifetime_ticks=4), seed=5
    )
    batches = scenario_batches(spec, 8)
    early = set(np.concatenate([k for k, _, _ in batches[:4]]).tolist())
    late = set(np.concatenate([k for k, _, _ in batches[4:]]).tolist())
    # phases are randomized, so the sets overlap — but neither contains the
    # other: some keys died and others were born across the half-lifetime
    assert early - late and late - early


# ------------------------------------------------------------- engine plug
def test_drive_scenario_feeds_an_engine():
    from repro.workloads import drive_scenario

    def count(state, keys, values, ts):
        state["n"] = state.get("n", 0) + len(keys)
        return state, None

    t = Topology()
    t.add_operator(
        OperatorSpec(
            "src", None, num_keygroups=4, is_source=True, schema=scenario_schema()
        )
    )
    t.add_operator(OperatorSpec("count", count, num_keygroups=4, is_sink=True))
    t.connect("src", "count")
    eng = Engine(t, 2, service_rate=1e9, seed=0)
    spec = ScenarioSpec(rate=64.0, key_space=32, seed=6)
    accepted = drive_scenario(eng, "src", spec, 10)
    for _ in range(4):
        eng.tick()
    counted = sum(
        eng.store.get(kg).get("n", 0) for kg in range(t.kg_base(1), t.kg_base(1) + 4)
    )
    assert accepted > 0
    assert counted == accepted

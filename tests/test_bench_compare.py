"""The CI perf-regression gate must fail on real slowdowns and stay quiet
otherwise — including on an injected 25% slowdown (the acceptance scenario
for the benchmark-gated pipeline)."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from benchmarks.compare import DEFAULT_THRESHOLD, compare, load_rows, main
from benchmarks.run import parse_row

BASE = {
    "engine_throughput/pipeline_d4_64kg_b512": 2000.0,
    "engine_throughput/milp_assembly_60x1200": 6000.0,
    "solver_perf/fig2_20n_400kg/v20/m20/t2s": 2_000_000.0,
    "albic_vs_cola/fig10": 900.0,  # not a gated module
    "engine_throughput/tiny_row": 10.0,  # below the --min-us noise floor
}


def _doc(rows: dict) -> dict:
    return {
        "schema": 1,
        "rows": [{"name": k, "us_per_call": v, "derived": ""} for k, v in rows.items()],
    }


def test_gate_passes_within_threshold():
    new = {k: v * 1.10 for k, v in BASE.items()}  # 10% < 20% threshold
    gated, regressions = compare(BASE, new)
    assert len(gated) == 4  # albic row not gated
    assert regressions == []


def test_gate_fails_on_injected_25pct_slowdown():
    new = {k: v * 1.25 for k, v in BASE.items()}
    gated, regressions = compare(BASE, new)
    names = {c.name for c in regressions}
    assert "engine_throughput/pipeline_d4_64kg_b512" in names
    assert "engine_throughput/milp_assembly_60x1200" in names
    assert "solver_perf/fig2_20n_400kg/v20/m20/t2s" in names
    # Non-gated module and sub-noise-floor rows never fail the gate.
    assert "albic_vs_cola/fig10" not in names
    assert "engine_throughput/tiny_row" not in names
    assert all(c.ratio > DEFAULT_THRESHOLD for c in regressions)


def test_gate_ignores_renamed_rows_and_improvements():
    new = {
        "engine_throughput/pipeline_d4_64kg_b512": 900.0,  # 2.2x faster
        "engine_throughput/renamed_row": 1.0,
    }
    gated, regressions = compare(BASE, new)
    assert [c.name for c in gated] == ["engine_throughput/pipeline_d4_64kg_b512"]
    assert regressions == []


def test_cli_exit_codes(tmp_path: Path):
    base_p = tmp_path / "baseline.json"
    ok_p = tmp_path / "ok.json"
    slow_p = tmp_path / "slow.json"
    base_p.write_text(json.dumps(_doc(BASE)))
    ok_p.write_text(json.dumps(_doc({k: v * 0.95 for k, v in BASE.items()})))
    slow_p.write_text(json.dumps(_doc({k: v * 1.25 for k, v in BASE.items()})))
    assert main([str(base_p), str(ok_p)]) == 0
    assert main([str(base_p), str(slow_p)]) == 1
    # No comparable rows → distinct exit code so CI misconfig is loud.
    empty_p = tmp_path / "empty.json"
    empty_p.write_text(json.dumps(_doc({})))
    assert main([str(base_p), str(empty_p), "--modules", "does_not_exist"]) == 2


def test_load_rows_roundtrip(tmp_path: Path):
    p = tmp_path / "bench.json"
    p.write_text(json.dumps(_doc(BASE)))
    assert load_rows(str(p)) == BASE


def test_derived_us_per_tick_entries_gate_as_sub_rows(tmp_path: Path):
    """``*_us_per_tick`` derived entries load as ``<row>:<key>`` sub-rows and
    regress the gate independently of the row's wall-clock us_per_call."""
    def doc(xchg_us: float) -> dict:
        return {
            "schema": 1,
            "rows": [
                {
                    "name": "engine_throughput/multiworker",
                    "us_per_call": 9000.0,
                    "derived": (
                        f"w2_vs_single=0.25;xchg_us_per_tick={xchg_us};"
                        "xchg_speedup=3.2;xchg_kb_per_tick=104.5"
                    ),
                }
            ],
        }

    base_p = tmp_path / "base.json"
    new_p = tmp_path / "new.json"
    base_p.write_text(json.dumps(doc(200.0)))
    new_p.write_text(json.dumps(doc(300.0)))  # exchange 1.5x slower, row flat

    rows = load_rows(str(base_p))
    assert rows["engine_throughput/multiworker:xchg_us_per_tick"] == 200.0
    assert "engine_throughput/multiworker:xchg_speedup" not in rows  # ratio, not a time

    gated, regressions = compare(rows, load_rows(str(new_p)))
    assert [c.name for c in regressions] == [
        "engine_throughput/multiworker:xchg_us_per_tick"
    ]
    assert main([str(base_p), str(new_p)]) == 1


def test_parse_row_matches_csv_format():
    row = parse_row("engine_throughput/pipeline,4306.5,tuples_per_sec=2377796")
    assert row == {
        "name": "engine_throughput/pipeline",
        "us_per_call": 4306.5,
        "derived": "tuples_per_sec=2377796",
    }
    # derived may itself contain commas (solver rows do)
    row = parse_row("solver_perf/fig2,12.0,a=1;b=2,c=3")
    assert row["derived"] == "a=1;b=2,c=3"


def test_committed_baseline_is_loadable_and_gated():
    """The repo baseline must cover every gated module (CI depends on it) —
    compare.py silently skips rows missing from the baseline, so a refresh
    run with a stale --only list would disarm part of the gate unnoticed."""
    baseline = load_rows(
        str(Path(__file__).parent.parent / "benchmarks" / "baseline.json"),
    )
    modules = {name.split("/", 1)[0] for name in baseline}
    from benchmarks.compare import DEFAULT_MODULES

    for module in DEFAULT_MODULES:
        assert module in modules, f"baseline.json lacks gated module {module!r}"
    # The per-job throughput rows are the gated real_jobs signal.
    for job in ("job1", "job2", "job3", "job4"):
        assert f"real_jobs/{job}_seg_throughput" in baseline


@pytest.mark.slow
def test_quick_run_writes_json(tmp_path: Path):
    """End to end: --json emits a document compare.py can consume."""
    out = tmp_path / "bench.json"
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "benchmarks.run",
            "--quick",
            "--only",
            "engine_throughput",
            "--json",
            str(out),
        ],
        capture_output=True,
        text=True,
        cwd=str(Path(__file__).parent.parent),
        # Minimal env, but keep the jax backend selection: dropping
        # JAX_PLATFORMS on a TPU-credentialed host sends the subprocess
        # into a multi-minute TPU-init stall before falling back.
        env={
            "PYTHONPATH": "src",
            "PATH": "/usr/bin:/bin:/usr/local/bin",
            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
        },
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    rows = load_rows(str(out))
    assert any(name.startswith("engine_throughput/") for name in rows)

"""Hot-key splitting conformance: the split path is pinned bit-exact against
the unsplit oracle on commutative/associative (delta-emitting) operators,
non-mergeable operators refuse to split with a clear error, and the
splitter/controller wiring splits exactly when migration alone cannot
balance."""

import numpy as np
import pytest

from repro.core.framework import AdaptationFramework
from repro.core.splitting import HotKeySplitter, SplitDecision
from repro.engine import (
    Controller,
    ControllerConfig,
    Engine,
    ExecutionConfig,
    make_engine,
)
from repro.engine.executor import hot_key_summary
from repro.engine.topology import OperatorSpec, Topology
from repro.workloads import make_scenario, scenario_batches

KGS = 8
NODES = 4


def _merge_counts(a, b):
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0) + v
    return out


def _count_op(state, keys, values, ts):
    for k in keys.tolist():
        state[k] = state.get(k, 0) + 1
    return state, list(zip(keys.tolist(), [1] * len(keys), ts.tolist()))


def _sum_sink(state, keys, values, ts):
    for k, v in zip(keys.tolist(), values.tolist()):
        state[k] = state.get(k, 0) + v
    return state, None


def _nonmergeable_op(state, keys, values, ts):
    # Order-sensitive: appends the arrival sequence — NOT a commutative
    # monoid, so splitting it would change semantics.
    state.setdefault("seq", []).extend(keys.tolist())
    return state, None


def make_topo(kgs=KGS, mergeable=True):
    t = Topology()
    t.add_operator(OperatorSpec("src", None, num_keygroups=kgs, is_source=True))
    t.add_operator(
        OperatorSpec(
            "count",
            _count_op,
            num_keygroups=kgs,
            merge_state=_merge_counts if mergeable else None,
        )
    )
    t.add_operator(OperatorSpec("sink", _sum_sink, num_keygroups=kgs, is_sink=True))
    t.connect("src", "count")
    t.connect("count", "sink")
    return t


def _drive(eng, ticks=16, batch=300, hot_key=3, hot_frac=0.5, seed=7):
    """Skewed feed: ``hot_frac`` of traffic on one key, rest uniform."""
    rng = np.random.default_rng(seed)
    for t in range(ticks):
        hot = rng.random(batch) < hot_frac
        keys = np.where(hot, hot_key, rng.integers(0, 1000, size=batch))
        keys = keys.astype(np.int64)
        eng.push_source("src", keys, rng.random(batch), np.full(batch, float(t)))
        eng.tick()
    for _ in range(6):  # drain stragglers
        eng.tick()


def _layer_totals(eng, op_idx):
    """Operator state folded across its key groups (replicas included)."""
    base = eng.topology.kg_base(op_idx)
    nkg = eng.topology.operators[op_idx].num_keygroups
    kgs = list(range(base, base + nkg))
    if hasattr(eng, "split_families"):
        for parent, slots in eng.split_families().items():
            if parent in kgs:
                kgs.extend(slots)
    out = {}
    for kg in kgs:
        for k, v in eng.store.get(kg).items():
            out[k] = out.get(k, 0) + v
    return out


def _hot_kg(eng, op_idx=1, key=3):
    return int(
        eng.topology.keygroups_of(op_idx, np.array([key], dtype=np.int64), None)[0]
    )


# ---------------------------------------------------------------- bit-exact
def test_split_pinned_bit_exact_against_unsplit_oracle():
    """Split + downstream merge must reproduce the oracle's integer totals
    exactly — emission interleaving may differ (that is the license the
    merge_state contract grants), the folded results may not."""
    oracle = Engine(make_topo(), NODES, service_rate=1e9, seed=0)
    _drive(oracle)

    split_eng = Engine(
        make_topo(), NODES, service_rate=1e9, seed=0, config=ExecutionConfig.split(4)
    )
    split_eng.split_keygroup(_hot_kg(split_eng))
    _drive(split_eng)

    assert _layer_totals(split_eng, 2) == _layer_totals(oracle, 2)  # sink
    assert _layer_totals(split_eng, 1) == _layer_totals(oracle, 1)  # count σ


def test_unsplit_merges_family_state_back_bit_exact():
    oracle = Engine(make_topo(), NODES, service_rate=1e9, seed=0)
    _drive(oracle)

    split_eng = Engine(
        make_topo(), NODES, service_rate=1e9, seed=0, config=ExecutionConfig.split(3)
    )
    kg = _hot_kg(split_eng)
    slots = split_eng.split_keygroup(kg)
    _drive(split_eng)
    # every replica actually took a share before the fold
    assert all(sum(split_eng.store.get(s).values()) > 0 for s in [kg] + slots)

    split_eng.unsplit_keygroup(kg)
    assert split_eng.split_families() == {}
    assert split_eng.store.get(kg) == oracle.store.get(kg)
    for s in slots:
        assert split_eng.store.get(s) == {}
    # slots returned to the reserve and reusable
    assert split_eng.split_slots_free == split_eng.config.split_reserve
    assert split_eng.split_keygroup(kg) == slots


def test_round_robin_spreads_a_single_hot_key():
    """The PKG property: even ONE hot key spreads evenly across replicas
    (a key sub-hash would pin it to a single replica)."""
    eng = Engine(
        make_topo(), NODES, service_rate=1e9, seed=0, config=ExecutionConfig.split(4)
    )
    kg = _hot_kg(eng)
    slots = eng.split_keygroup(kg)
    _drive(eng, hot_frac=1.0)  # the whole stream is one key
    counts = [sum(eng.store.get(s).values()) for s in [kg] + slots]
    assert max(counts) - min(counts) <= 1


def test_split_survives_replica_migration():
    """Replicas are ordinary key groups to the migration machinery."""
    eng = Engine(
        make_topo(), NODES, service_rate=1e9, seed=0, config=ExecutionConfig.split(3)
    )
    kg = _hot_kg(eng)
    slots = eng.split_keygroup(kg)
    _drive(eng, ticks=8)
    replica = slots[0]
    dst = (eng.router.node_of(replica) + 1) % NODES
    eng.redirect(replica, dst)
    eng.install(replica, dst, eng.serialize(replica))
    assert eng.router.node_of(replica) == dst
    _drive(eng, ticks=8, seed=11)

    oracle = Engine(make_topo(), NODES, service_rate=1e9, seed=0)
    _drive(oracle, ticks=8)
    _drive(oracle, ticks=8, seed=11)
    assert _layer_totals(eng, 1) == _layer_totals(oracle, 1)
    assert _layer_totals(eng, 2) == _layer_totals(oracle, 2)


# ------------------------------------------------------------------- errors
def test_non_mergeable_operator_refuses_to_split():
    t = Topology()
    t.add_operator(OperatorSpec("src", None, num_keygroups=4, is_source=True))
    t.add_operator(OperatorSpec("seq", _nonmergeable_op, num_keygroups=4))
    t.connect("src", "seq")
    eng = Engine(t, 2, service_rate=1e9, seed=0, config=ExecutionConfig.split(2))
    with pytest.raises(ValueError, match="not split-mergeable"):
        eng.split_keygroup(t.kg_base(1))


def test_split_requires_config_and_valid_target():
    eng = Engine(make_topo(), NODES, service_rate=1e9, seed=0)
    with pytest.raises(ValueError, match="disabled"):
        eng.split_keygroup(KGS)
    cfg = ExecutionConfig.split(3)
    eng = Engine(make_topo(), NODES, service_rate=1e9, seed=0, config=cfg)
    with pytest.raises(ValueError, match="source"):
        eng.split_keygroup(0)  # kg 0 belongs to the source operator
    kg = _hot_kg(eng)
    eng.split_keygroup(kg)
    with pytest.raises(ValueError, match="already split"):
        eng.split_keygroup(kg)
    with pytest.raises(ValueError, match="replica"):
        eng.split_keygroup(eng.split_families()[kg][0])
    with pytest.raises(ValueError, match="not split"):
        eng.unsplit_keygroup(kg + 1 if kg + 1 < 2 * KGS else kg - 1)


def test_config_validation():
    with pytest.raises(ValueError, match="split_degree"):
        ExecutionConfig(split_degree=1)
    with pytest.raises(ValueError, match="split_reserve"):
        ExecutionConfig(split_degree=8, split_reserve=3)
    with pytest.raises(ValueError, match="single-process"):
        ExecutionConfig(split_degree=2, num_workers=2)
    with pytest.raises(ValueError, match="single-process"):
        ExecutionConfig(split_degree=2, use_fn_jit=True)
    assert "split4" in ExecutionConfig.split(4).name
    # merge_state on a source is rejected at topology validation
    t = Topology()
    t.add_operator(
        OperatorSpec(
            "src", None, num_keygroups=2, is_source=True, merge_state=_merge_counts
        )
    )
    with pytest.raises(ValueError, match="source"):
        t.validate()


# -------------------------------------------------------- policy + controller
def test_splitter_policy_hysteresis_and_eligibility():
    from repro.core.stats import ClusterState

    kg_op = np.array([0, 0, 1, 1])
    load = np.array([1.0, 1.0, 1.0, 1.0])
    alloc = np.array([0, 1, 0, 1])
    state = ClusterState.create(
        2, kg_op, load, alloc,
        kg_state_bytes=np.ones(4),
        out_rates=np.zeros((4, 4)),
        downstream={0: [1], 1: []},
        kg_tuple_rate=np.array([100.0, 1.0, 1.0, 1.0]),
    )
    pol = HotKeySplitter(hot_frac=0.5, cool_frac=0.25)
    d = pol.decide(state, {})
    assert d.split == (0,)
    # eligibility mask vetoes the pick
    d = pol.decide(state, {}, eligible=np.array([False, True, True, True]))
    assert d.split == ()
    # an active family is not re-split, and folds back only when cooled
    d = pol.decide(state, {0: [3]})
    assert d == SplitDecision()
    cold = state.copy()
    cold.kg_tuple_rate = np.array([0.1, 50.0, 50.0, 0.1])
    assert pol.decide(cold, {0: [3]}).unsplit == (0,)


def test_controller_splits_on_flash_crowd_and_improves_balance():
    """End to end: scenario stream → SPL statistics → splitter decision →
    engine split, all through the controller's period loop."""
    spec = make_scenario("flash_crowd", rate=128.0, key_space=256, seed=1)
    batches = iter(scenario_batches(spec, 120))

    def feeder(engine, tick):
        try:
            keys, values, ts = next(batches)
        except StopIteration:
            return
        if len(keys):
            engine.push_source("src", keys, values["entity"], ts)

    eng = Engine(
        make_topo(16),
        NODES,
        service_rate=1e9,
        seed=0,
        config=ExecutionConfig.split(4),
    )
    fw = AdaptationFramework(
        mode="albic", max_migrations=8, splitter=HotKeySplitter()
    )
    ctl = Controller(eng, fw, ControllerConfig(ticks_per_period=10), feeder=feeder)
    history = [ctl.period() for _ in range(8)]
    assert sum(m.num_splits for m in history) >= 1
    assert eng.split_families()  # at least one family still active
    # the period metrics surface the splitting activity
    assert any(m.num_splits > 0 for m in history)


# ------------------------------------------------------- hot-key observability
def test_hot_key_summary_deterministic_and_normalized():
    top, share = hot_key_summary(np.array([0.0, 5.0, 5.0, 10.0]), topk=2)
    assert top == [(3, 10.0), (1, 5.0)]  # stable tie-break: lowest kg wins
    assert share == 0.5
    assert hot_key_summary(np.zeros(4)) == ([], 0.0)


def test_engine_metrics_expose_hot_keygroups():
    eng = Engine(make_topo(), NODES, service_rate=1e9, seed=0)
    _drive(eng, ticks=6)
    eng.end_period()
    assert eng.metrics.hot_keygroups
    assert 0.0 < eng.metrics.max_kg_share <= 1.0
    # the hot key's group leads its operator's layer
    hot = _hot_kg(eng)
    assert hot in [kg for kg, _ in eng.metrics.hot_keygroups]


def test_cluster_fold_matches_single_process_gauge():
    """The coordinator's folded gauge equals the single-process engine's for
    identical traffic (partial sums fold before the top-k)."""
    from conformance import make_pipeline_topo

    def run(config):
        eng = make_engine(
            make_pipeline_topo(8), 4, config=config, service_rate=1e9, seed=0
        )
        rng = np.random.default_rng(5)
        for t in range(6):
            keys = np.where(
                rng.random(200) < 0.4, 7, rng.integers(0, 4000, size=200)
            ).astype(np.int64)
            eng.push_source("src", keys, rng.random(200), np.zeros(200))
            eng.tick()
        eng.end_period()
        hot, share = eng.metrics.hot_keygroups, eng.metrics.max_kg_share
        eng.finalize()
        return hot, share

    single = run(ExecutionConfig.typed())
    multi = run(ExecutionConfig.workers(2))
    assert single == multi

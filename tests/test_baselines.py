"""Flux [36], PoTC [29], COLA [21] baseline behaviour."""

import numpy as np

from repro.core import solve_allocation
from repro.core.baselines import PotcSimulator, cola_allocate, flux_rebalance

from conftest import make_cluster


def test_flux_respects_migration_cap():
    state = make_cluster(seed=0)
    plan = flux_rebalance(state, max_migrations=7)
    assert plan.num_migrations <= 7


def test_flux_reduces_imbalance():
    state = make_cluster(seed=1)
    plan = flux_rebalance(state, max_migrations=13)
    assert plan.load_distance <= state.load_distance() + 1e-9


def test_milp_beats_flux_given_same_budget():
    """The paper's §5.2.1 headline: MILP > Flux at equal maxMigrations."""
    wins = 0
    for seed in range(5):
        state = make_cluster(seed=seed)
        flux = flux_rebalance(state, max_migrations=13)
        milp = solve_allocation(state, max_migrations=13, time_limit=3.0)
        if milp.load_distance <= flux.load_distance + 1e-9:
            wins += 1
    assert wins >= 4, f"MILP only won {wins}/5"


def test_potc_runs_and_has_overhead():
    state = make_cluster(seed=2)
    sim = PotcSimulator(state)
    _, ld0 = sim.step(state.kg_load)
    for _ in range(5):
        loads, ld = sim.step(state.kg_load)
    assert np.isfinite(ld)
    # The merge step is a continuous overhead even in steady state (paper).
    assert sim.continuous_overhead > 0.0


def test_cola_collocation_quality():
    state = make_cluster(seed=3, one_to_one_frac=0.9)
    plan = cola_allocate(state)
    # From-scratch partitioning should collocate most 1-1 traffic...
    assert state.collocation_factor(plan.alloc) > state.collocation_factor() + 10
    # ...at the price of many migrations (paper Fig. 12 behaviour).
    assert plan.num_migrations > state.num_keygroups / 4


def test_cola_balanced():
    state = make_cluster(seed=4)
    plan = cola_allocate(state, balance_tol=0.15)
    loads = state.node_loads(plan.alloc)
    live = state.nodes_a
    assert loads[live].max() <= loads[live].mean() * 1.6 + 1.0
